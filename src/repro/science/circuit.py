"""Circuit simulation [Bauer et al. 2012] (paper app 7) — distributed.

The Legion circuit benchmark: a graph of nodes (voltage, charge,
capacitance) and wires (resistance, current) partitioned into pieces.
Each timestep:

  1. calc_new_currents:  I_w = (V_src - V_dst) / R_w
  2. distribute_charge:  Q_n += dt * (sum of incident currents)
  3. update_voltages:    V_n += Q_n / C_n; Q_n = 0

Pieces own a contiguous slab of nodes and the wires sourced in the slab;
wires crossing piece boundaries make this communication-bound. The JAX
translation expresses the cross-piece reduction as all_gather(V) +
local scatter-add + psum_scatter(Q) — the all-reduce decomposition whose
placement Mapple's Region/decompose directives control.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.mapper import block_mapper
from repro.core.pspace import ProcSpace
from repro.matmul.common import MatmulGrid, build_grid
from repro.core.jaxcompat import shard_map

AXES = ("x",)


@dataclasses.dataclass(frozen=True)
class CircuitConfig:
    nodes_per_piece: int = 64
    wires_per_piece: int = 96
    pieces: int = 4
    pct_internal: float = 0.9      # fraction of wires that stay in-piece
    dt: float = 1e-2
    steps: int = 4

    @property
    def n_nodes(self) -> int:
        return self.nodes_per_piece * self.pieces

    @property
    def n_wires(self) -> int:
        return self.wires_per_piece * self.pieces


@dataclasses.dataclass
class CircuitState:
    voltage: jax.Array      # (n_nodes,)
    charge: jax.Array       # (n_nodes,)
    capacitance: jax.Array  # (n_nodes,)
    src: jax.Array          # (n_wires,) int32
    dst: jax.Array          # (n_wires,) int32
    resistance: jax.Array   # (n_wires,)


def generate(cfg: CircuitConfig, seed: int = 0) -> CircuitState:
    rng = np.random.default_rng(seed)
    n, w = cfg.n_nodes, cfg.n_wires
    src = np.empty(w, np.int32)
    dst = np.empty(w, np.int32)
    for p in range(cfg.pieces):
        lo = p * cfg.nodes_per_piece
        for i in range(cfg.wires_per_piece):
            wi = p * cfg.wires_per_piece + i
            src[wi] = lo + rng.integers(cfg.nodes_per_piece)
            if rng.random() < cfg.pct_internal:
                dst[wi] = lo + rng.integers(cfg.nodes_per_piece)
            else:
                dst[wi] = rng.integers(n)
    return CircuitState(
        voltage=jnp.asarray(rng.normal(size=n).astype(np.float32)),
        charge=jnp.zeros(n, jnp.float32),
        capacitance=jnp.asarray(rng.uniform(1.0, 2.0, size=n).astype(np.float32)),
        src=jnp.asarray(src),
        dst=jnp.asarray(dst),
        resistance=jnp.asarray(rng.uniform(1.0, 4.0, size=w).astype(np.float32)),
    )


def grid_for(machine: ProcSpace, cfg: CircuitConfig, devices=None) -> MatmulGrid:
    m1 = machine.merge(0, 1) if machine.ndim == 2 else machine
    mapper = block_mapper(m1, "circuit_block")
    return build_grid(mapper, (cfg.pieces,), AXES, devices)


def circuit_body(cfg: CircuitConfig, n_pieces: int):
    n_nodes = cfg.n_nodes

    def body(volt, charge, cap, src, dst, res):
        def step(_, carry):
            volt_loc, charge_loc = carry
            volt_full = jax.lax.all_gather(volt_loc, "x", tiled=True)
            cur = (volt_full[src] - volt_full[dst]) / res
            acc = jnp.zeros((n_nodes,), jnp.float32)
            acc = acc.at[src].add(-cfg.dt * cur)
            acc = acc.at[dst].add(cfg.dt * cur)
            acc_loc = jax.lax.psum_scatter(
                acc, "x", scatter_dimension=0, tiled=True
            )
            charge_loc = charge_loc + acc_loc
            volt_loc = volt_loc + charge_loc / cap
            charge_loc = jnp.zeros_like(charge_loc)
            return (volt_loc, charge_loc)

        volt, charge = jax.lax.fori_loop(0, cfg.steps, step, (volt, charge))
        return volt

    return body


def run(state: CircuitState, grid: MatmulGrid, cfg: CircuitConfig) -> jax.Array:
    fn = shard_map(
        circuit_body(cfg, grid.shape[0]),
        mesh=grid.mesh,
        in_specs=(P("x"), P("x"), P("x"), P("x"), P("x"), P("x")),
        out_specs=P("x"),
        check_vma=False,
    )
    return jax.jit(fn)(
        state.voltage, state.charge, state.capacitance,
        state.src, state.dst, state.resistance,
    )


def reference(state: CircuitState, cfg: CircuitConfig) -> jax.Array:
    """Pure-jnp oracle on one device."""
    volt, charge = state.voltage, state.charge
    for _ in range(cfg.steps):
        cur = (volt[state.src] - volt[state.dst]) / state.resistance
        charge = charge.at[state.src].add(-cfg.dt * cur)
        charge = charge.at[state.dst].add(cfg.dt * cur)
        volt = volt + charge / state.capacitance
        charge = jnp.zeros_like(charge)
    return volt
