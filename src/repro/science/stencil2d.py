"""2D stencil benchmark [Van der Wijngaart & Mattson 2014] (paper app 8).

A 5-point Jacobi stencil over an (X, Y) grid, distributed over a 2D
processor grid chosen by Mapple's ``decompose`` (the paper's Sec. 6.3
workload). Halo exchange is a pair of ppermutes per dimension; the
communication volume is exactly the quantity decompose minimizes, so this
app is the end-to-end validation of the primitive.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.decompose import cached_optimal, greedy_factorization
from repro.core.mapper import block_mapper
from repro.core.pspace import ProcSpace
from repro.matmul.common import build_grid, MatmulGrid
from repro.core.jaxcompat import shard_map

AXES = ("x", "y")


@dataclasses.dataclass(frozen=True)
class StencilConfig:
    nx: int
    ny: int
    halo: int = 1
    steps: int = 4


def choose_grid(nprocs: int, cfg: StencilConfig, *, use_greedy: bool = False
                ) -> tuple[int, int]:
    """The experiment knob of Sec. 6.3: decompose vs Algorithm 1."""
    if use_greedy:
        g = greedy_factorization(nprocs, 2)
    else:
        # Memoized + integrality-constrained: shard_map needs every factor
        # to divide its extent (the paper's l_m/w_m in N constraint).
        g = cached_optimal(nprocs, (cfg.nx, cfg.ny), require_divisible=True)
    return (int(g[0]), int(g[1]))


def grid_for(machine: ProcSpace, cfg: StencilConfig, devices=None,
             use_greedy: bool = False) -> MatmulGrid:
    shape = choose_grid(machine.nprocs, cfg, use_greedy=use_greedy)
    m2 = machine.merge(0, 1).decompose_with(0, shape) if machine.ndim == 2 \
        else machine.decompose_with(0, shape)
    mapper = block_mapper(m2, "stencil_block")
    return build_grid(mapper, shape, AXES, devices)


def _exchange(field: jax.Array, axis_name: str, axis_size: int, dim: int,
              halo: int) -> tuple[jax.Array, jax.Array]:
    """Receive the neighbouring halo slabs along one dimension."""
    idx = jax.lax.axis_index(axis_name)

    def take(x, lo, hi):
        sl = [slice(None)] * x.ndim
        sl[dim] = slice(lo, hi)
        return x[tuple(sl)]

    # Send my low face to the left neighbour; receive from the right, etc.
    lo_face = take(field, 0, halo)
    hi_face = take(field, field.shape[dim] - halo, field.shape[dim])
    right = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    left = [(i, (i - 1) % axis_size) for i in range(axis_size)]
    from_left = jax.lax.ppermute(hi_face, axis_name, right)
    from_right = jax.lax.ppermute(lo_face, axis_name, left)
    # Zero-flux boundary at the global edges.
    from_left = jnp.where(idx == 0, lo_face, from_left)
    from_right = jnp.where(idx == axis_size - 1, hi_face, from_right)
    return from_left, from_right


def stencil_body(grid_shape: tuple[int, int], cfg: StencilConfig):
    gx, gy = grid_shape

    def body(field: jax.Array) -> jax.Array:
        def step(_, f):
            up, down = _exchange(f, "x", gx, 0, cfg.halo)
            left, right = _exchange(f, "y", gy, 1, cfg.halo)
            fx = jnp.concatenate([up, f, down], axis=0)
            f_pad = jnp.concatenate(
                [
                    jnp.pad(left, ((cfg.halo, cfg.halo), (0, 0)), mode="edge"),
                    fx,
                    jnp.pad(right, ((cfg.halo, cfg.halo), (0, 0)), mode="edge"),
                ],
                axis=1,
            )
            c = f_pad[1:-1, 1:-1]
            n = f_pad[:-2, 1:-1]
            s = f_pad[2:, 1:-1]
            w = f_pad[1:-1, :-2]
            e = f_pad[1:-1, 2:]
            return 0.2 * (c + n + s + w + e)

        return jax.lax.fori_loop(0, cfg.steps, step, field)

    return body


def run(field: jax.Array, grid: MatmulGrid, cfg: StencilConfig) -> jax.Array:
    body = stencil_body(grid.shape, cfg)  # type: ignore[arg-type]
    fn = shard_map(
        body, mesh=grid.mesh, in_specs=(P("x", "y"),), out_specs=P("x", "y"),
        check_vma=False,
    )
    return jax.jit(fn)(field)


def reference(field, cfg: StencilConfig):
    """Pure-jnp oracle with zero-flux (edge-replicate) boundaries."""
    f = jnp.asarray(field)
    for _ in range(cfg.steps):
        fp = jnp.pad(f, cfg.halo, mode="edge")
        f = 0.2 * (
            fp[1:-1, 1:-1] + fp[:-2, 1:-1] + fp[2:, 1:-1]
            + fp[1:-1, :-2] + fp[1:-1, 2:]
        )
    return f
