"""Scientific workloads (paper apps 7-9): circuit, stencil, pennant proxy."""
from repro.science import circuit, pennant, stencil2d  # noqa: F401
