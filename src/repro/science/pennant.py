"""PENNANT proxy [Ferenbaugh 2015] (paper app 9) — staggered-grid hydro.

The real PENNANT is unstructured-mesh Lagrangian hydrodynamics; this proxy
keeps its computational character — staggered zone/node variables,
predictor-corrector update, gather (zone->node forces) and scatter
(node->zone volumes) phases — on a structured 2D mesh so the distributed
data movement (halo exchange of zone pressures and corner forces) is the
same pattern Mapple's decompose optimizes.

State (zones are cells, nodes are cell corners):
  zone: density rho, specific internal energy e, pressure p (ideal gas)
  node: velocity (u, v) at cell corners (staggered)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.decompose import cached_optimal
from repro.core.mapper import block_mapper
from repro.core.pspace import ProcSpace
from repro.matmul.common import MatmulGrid, build_grid
from repro.core.jaxcompat import shard_map

AXES = ("x", "y")
GAMMA = 1.4


@dataclasses.dataclass(frozen=True)
class PennantConfig:
    nzx: int = 32          # zones in x
    nzy: int = 32          # zones in y
    dt: float = 1e-3
    dx: float = 1.0
    steps: int = 4


def grid_for(machine: ProcSpace, cfg: PennantConfig, devices=None) -> MatmulGrid:
    # Memoized + integrality-constrained (shards must tile the zone arrays).
    g = cached_optimal(machine.nprocs, (cfg.nzx, cfg.nzy), require_divisible=True)
    m1 = machine.merge(0, 1) if machine.ndim == 2 else machine
    m2 = m1.decompose_with(0, g)
    mapper = block_mapper(m2, "pennant_block")
    return build_grid(mapper, tuple(int(x) for x in g), AXES, devices)


def init_state(cfg: PennantConfig, seed: int = 0):
    key = jax.random.key(seed)
    k1, k2 = jax.random.split(key)
    rho = 1.0 + 0.1 * jax.random.uniform(k1, (cfg.nzx, cfg.nzy))
    e = 1.0 + 0.1 * jax.random.uniform(k2, (cfg.nzx, cfg.nzy))
    u = jnp.zeros((cfg.nzx, cfg.nzy))
    v = jnp.zeros((cfg.nzx, cfg.nzy))
    return rho.astype(jnp.float32), e.astype(jnp.float32), u.astype(jnp.float32), v.astype(jnp.float32)


def _halo1(f: jax.Array, axis_name: str, axis_size: int, dim: int):
    """1-deep edge-replicated halo along one sharded dimension."""
    idx = jax.lax.axis_index(axis_name)

    def take(x, lo, hi):
        sl = [slice(None)] * x.ndim
        sl[dim] = slice(lo, hi)
        return x[tuple(sl)]

    lo_face = take(f, 0, 1)
    hi_face = take(f, f.shape[dim] - 1, f.shape[dim])
    fwd = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    bwd = [(i, (i - 1) % axis_size) for i in range(axis_size)]
    from_prev = jax.lax.ppermute(hi_face, axis_name, fwd)
    from_next = jax.lax.ppermute(lo_face, axis_name, bwd)
    from_prev = jnp.where(idx == 0, lo_face, from_prev)
    from_next = jnp.where(idx == axis_size - 1, hi_face, from_next)
    return jnp.concatenate([from_prev, f, from_next], axis=dim)


def _padded(f, gx, gy):
    """Edge-replicated 1-halo in both dims (corners via sequential pad)."""
    f = _halo1(f, "x", gx, 0)
    f = _halo1(f, "y", gy, 1)
    return f


def hydro_step(rho, e, u, v, cfg: PennantConfig, gx: int, gy: int):
    # --- zone pressure (ideal gas EOS)
    p = (GAMMA - 1.0) * rho * e
    # --- gather phase: pressure gradient forces at nodes need neighbours
    p_pad = _padded(p, gx, gy)
    fx = -(p_pad[2:, 1:-1] - p_pad[:-2, 1:-1]) / (2.0 * cfg.dx)
    fy = -(p_pad[1:-1, 2:] - p_pad[1:-1, :-2]) / (2.0 * cfg.dx)
    # --- node (corner) velocity update
    u = u + cfg.dt * fx / rho
    v = v + cfg.dt * fy / rho
    # --- scatter phase: velocity divergence back onto zones
    u_pad = _padded(u, gx, gy)
    v_pad = _padded(v, gx, gy)
    div = (
        (u_pad[2:, 1:-1] - u_pad[:-2, 1:-1])
        + (v_pad[1:-1, 2:] - v_pad[1:-1, :-2])
    ) / (2.0 * cfg.dx)
    # --- Lagrangian density/energy update (compressible flow)
    rho = rho * (1.0 - cfg.dt * div)
    e = e - cfg.dt * p * div / jnp.maximum(rho, 1e-6)
    return rho, e, u, v


def pennant_body(cfg: PennantConfig, grid_shape):
    gx, gy = grid_shape

    def body(rho, e, u, v):
        def step(_, carry):
            return hydro_step(*carry, cfg, gx, gy)

        return jax.lax.fori_loop(0, cfg.steps, step, (rho, e, u, v))

    return body


def run(state, grid: MatmulGrid, cfg: PennantConfig):
    fn = shard_map(
        pennant_body(cfg, grid.shape),
        mesh=grid.mesh,
        in_specs=(P("x", "y"),) * 4,
        out_specs=(P("x", "y"),) * 4,
        check_vma=False,
    )
    return jax.jit(fn)(*state)


def reference(state, cfg: PennantConfig):
    """Single-device oracle (identical math, jnp.pad halos)."""
    rho, e, u, v = state

    def pad(f):
        return jnp.pad(f, 1, mode="edge")

    for _ in range(cfg.steps):
        p = (GAMMA - 1.0) * rho * e
        pp = pad(p)
        fx = -(pp[2:, 1:-1] - pp[:-2, 1:-1]) / (2.0 * cfg.dx)
        fy = -(pp[1:-1, 2:] - pp[1:-1, :-2]) / (2.0 * cfg.dx)
        u = u + cfg.dt * fx / rho
        v = v + cfg.dt * fy / rho
        up, vp = pad(u), pad(v)
        div = (
            (up[2:, 1:-1] - up[:-2, 1:-1]) + (vp[1:-1, 2:] - vp[1:-1, :-2])
        ) / (2.0 * cfg.dx)
        rho = rho * (1.0 - cfg.dt * div)
        e = e - cfg.dt * p * div / jnp.maximum(rho, 1e-6)
    return rho, e, u, v
