"""Six distributed matmul algorithms, mapped by Mapple mappers (paper Sec. 6)."""
from repro.core.mapper import Mapper
from repro.core.pspace import ProcSpace
from repro.core.tuples import Tup

from repro.matmul import cannon, cosma, johnson, pumma, solomonik, summa  # noqa: F401
from repro.matmul.common import MatmulGrid, build_grid, make_inputs  # noqa: F401

ALGORITHMS = {
    "cannon": cannon,
    "summa": summa,
    "pumma": pumma,
    "johnson": johnson,
    "solomonik": solomonik,
    "cosma": cosma,
}


def runtime_heuristic_mapper(machine: ProcSpace) -> Mapper:
    """The Fig. 13 strawman: the runtime round-robins iteration points over
    the GPUs of a node instead of honoring the algorithm's distribution
    (modeling 'assign to the least-loaded GPU')."""
    nodes, gpus = machine.shape[0], machine.shape[-1]

    def fn(ipoint: Tup, ispace: Tup):
        linear = ipoint.linearize(ispace)
        return machine[(linear // gpus % nodes, linear % gpus)]

    return Mapper("runtime_heuristic", fn)
