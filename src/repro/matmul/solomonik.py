"""Solomonik's 2.5D algorithm [Solomonik & Demmel 2011] on a (q, q, c) grid.

c replicas of the Cannon schedule each execute q/c shift steps starting from
layer-offset alignments; a final psum over the replication axis combines the
partial C blocks. Mappers: the paper's ``hierarchical_block3D`` +
``linearize_cyclic`` pair (Fig. 12, Solomonik functions 1 and 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.mapper import (
    Mapper,
    hierarchical_block_mapper,
    linearize_cyclic_mapper,
)
from repro.core.pspace import ProcSpace
from repro.matmul.common import (
    MatmulGrid,
    build_grid,
    local_matmul,
    sharded_matmul_wrapper,
    shift,
)

AXES = ("x", "y", "z")


def grid_shape_for(nprocs: int, c: int) -> tuple[int, int, int]:
    base = nprocs // c
    q = int(round(base ** 0.5))
    if q * q * c != nprocs:
        raise ValueError(f"cannot form (q, q, {c}) grid from {nprocs} devices")
    return (q, q, c)


def paper_mapper(machine: ProcSpace, grid_shape: tuple[int, int, int]) -> Mapper:
    """Fig. 12 function 1: hierarchical block over the 3D iteration grid."""
    return hierarchical_block_mapper(machine, grid_shape, name="solomonik_hb3d")


def fallback_mapper(machine: ProcSpace) -> Mapper:
    """Fig. 12 function 2: linearize + cyclic (used for tuning comparisons)."""
    return linearize_cyclic_mapper(machine)


def grid_for(machine: ProcSpace, c: int, devices=None,
             use_fallback_mapper: bool = False) -> MatmulGrid:
    g = grid_shape_for(machine.nprocs, c)
    mapper = (
        fallback_mapper(machine)
        if use_fallback_mapper
        else paper_mapper(machine, g)
    )
    return build_grid(mapper, g, AXES, devices)


def masked_shift(x: jax.Array, axis: str, steps: jax.Array, size: int) -> jax.Array:
    """Shift ``x`` by a device-dependent number of single steps (<= size-1)."""

    def body(s, val):
        moved = shift(val, axis, -1, size)
        return jnp.where(s < steps, moved, val)

    return jax.lax.fori_loop(0, size - 1, body, x)


def solomonik_body(q: int, c: int, use_kernel: bool = False):
    steps_per_layer = q // c

    def body(a_blk: jax.Array, b_blk: jax.Array) -> jax.Array:
        i = jax.lax.axis_index("x")
        j = jax.lax.axis_index("y")
        layer = jax.lax.axis_index("z")
        # Cannon-style alignment plus the layer offset l * (q/c).
        a_blk = masked_shift(a_blk, "y", (i + layer * steps_per_layer) % q, q)
        b_blk = masked_shift(b_blk, "x", (j + layer * steps_per_layer) % q, q)
        c0 = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)

        def step(_, carry):
            acc, a, b = carry
            acc = acc + local_matmul(a, b, use_kernel)
            a = shift(a, "y", -1, q)
            b = shift(b, "x", -1, q)
            return (acc, a, b)

        acc, _, _ = jax.lax.fori_loop(0, steps_per_layer, step, (c0, a_blk, b_blk))
        # Combine the c partial C replicas.
        acc = jax.lax.psum(acc, "z")
        return acc.astype(a_blk.dtype)

    return body


def matmul(a: jax.Array, b: jax.Array, grid: MatmulGrid,
           use_kernel: bool = False) -> jax.Array:
    q, _, c = grid.shape
    if q % c != 0:
        raise ValueError(f"2.5D requires c | q, got q={q}, c={c}")
    fn = sharded_matmul_wrapper(
        grid,
        solomonik_body(q, c, use_kernel),
        # A, B block-distributed over (x, y), replicated over z.
        in_specs=(P("x", "y"), P("x", "y")),
        out_spec=P("x", "y"),
    )
    return fn(a, b)
