"""Johnson's 3D algorithm [Agarwal et al. 1995] on a (q1, q2, q3) grid.

A is sharded (m over x, k over z) and replicated over y; B (k over z,
n over y) replicated over x. One local product + one reduction (psum over
z) produces C (m over x, n over y). Mapper: the paper's
``conditional_linearize3D`` (Fig. 12).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.mapper import Mapper, conditional_linearize3d_mapper
from repro.core.pspace import ProcSpace
from repro.matmul.common import (
    MatmulGrid,
    build_grid,
    local_matmul,
    sharded_matmul_wrapper,
)

AXES = ("x", "y", "z")


def cube_grid(nprocs: int) -> tuple[int, int, int]:
    q = round(nprocs ** (1.0 / 3.0))
    if q ** 3 != nprocs:
        raise ValueError(f"Johnson's algorithm needs a cubic device count, got {nprocs}")
    return (q, q, q)


def paper_mapper(machine: ProcSpace) -> Mapper:
    return conditional_linearize3d_mapper(machine)


def grid_for(machine: ProcSpace, devices=None) -> MatmulGrid:
    g = cube_grid(machine.nprocs)
    mapper = paper_mapper(machine)
    return build_grid(mapper, g, AXES, devices)


def johnson_body(use_kernel: bool = False):
    def body(a_blk: jax.Array, b_blk: jax.Array) -> jax.Array:
        c_partial = local_matmul(a_blk, b_blk, use_kernel)
        c = jax.lax.psum(c_partial, "z")
        return c.astype(a_blk.dtype)

    return body


def matmul(a: jax.Array, b: jax.Array, grid: MatmulGrid,
           use_kernel: bool = False) -> jax.Array:
    fn = sharded_matmul_wrapper(
        grid,
        johnson_body(use_kernel),
        # A: m over x, k over z (replicated over y); B: k over z, n over y.
        in_specs=(P("x", "z"), P("z", "y")),
        out_spec=P("x", "y"),
    )
    return fn(a, b)
