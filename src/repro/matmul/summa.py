"""SUMMA [Van De Geijn & Watts 1997] on a (q, q) grid via shard_map.

Each of the q panel steps broadcasts the owning column's A panel along rows
and the owning row's B panel along columns (realized as masked psum — the
SPMD broadcast idiom), then accumulates the local product.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.mapper import Mapper, hierarchical_block_mapper
from repro.core.pspace import ProcSpace
from repro.matmul.common import (
    MatmulGrid,
    build_grid,
    local_matmul,
    sharded_matmul_wrapper,
)

AXES = ("x", "y")


def paper_mapper(machine: ProcSpace, grid_shape: tuple[int, int]) -> Mapper:
    return hierarchical_block_mapper(machine, grid_shape, name="summa_hb2d")


def grid_for(machine: ProcSpace, devices=None) -> MatmulGrid:
    n = machine.nprocs
    q = int(round(n ** 0.5))
    if q * q != n:
        raise ValueError(f"SUMMA (square variant) needs square device count, got {n}")
    mapper = paper_mapper(machine, (q, q))
    return build_grid(mapper, (q, q), AXES, devices)


def summa_body(q: int, use_kernel: bool = False):
    def body(a_blk: jax.Array, b_blk: jax.Array) -> jax.Array:
        row = jax.lax.axis_index("x")
        col = jax.lax.axis_index("y")
        c0 = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)

        def step(t, c):
            # Broadcast A panel from column t along each row.
            a_panel = jax.lax.psum(
                jnp.where(col == t, a_blk, jnp.zeros_like(a_blk)), "y"
            )
            # Broadcast B panel from row t along each column.
            b_panel = jax.lax.psum(
                jnp.where(row == t, b_blk, jnp.zeros_like(b_blk)), "x"
            )
            return c + local_matmul(a_panel, b_panel, use_kernel)

        c = jax.lax.fori_loop(0, q, step, c0)
        return c.astype(a_blk.dtype)

    return body


def matmul(a: jax.Array, b: jax.Array, grid: MatmulGrid,
           use_kernel: bool = False) -> jax.Array:
    q = grid.shape[0]
    fn = sharded_matmul_wrapper(
        grid,
        summa_body(q, use_kernel),
        in_specs=(P("x", "y"), P("x", "y")),
        out_spec=P("x", "y"),
    )
    return fn(a, b)
