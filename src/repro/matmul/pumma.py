"""PUMMA [Choi, Walker & Dongarra 1994] — block-cyclic panel matmul.

PUMMA's defining feature versus SUMMA is its block-cyclic data-to-processor
distribution. In Mapple terms it is the *same* collective schedule with a
different mapper: the block-cyclic mapping function (Fig. 7) permutes the
device order of the mesh; the panel loop is unchanged. This mirrors the
paper's observation that the six algorithms differ chiefly in their mapping
decisions.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.core.mapper import Mapper
from repro.core.pspace import ProcSpace
from repro.core.tuples import Tup
from repro.matmul.common import MatmulGrid, build_grid, sharded_matmul_wrapper
from repro.matmul.summa import summa_body

AXES = ("x", "y")


def paper_mapper(machine: ProcSpace, grid_shape: tuple[int, int]) -> Mapper:
    """Block-cyclic tile->device map over the (node, gpu) hierarchy.

    Tiles cycle over nodes first (coarse), then over gpus within the node —
    the distribution PUMMA's panel rotation assumes.
    """
    nodes, gpus = machine.shape[0], machine.shape[1]

    def fn(ipoint: Tup, ispace: Tup):
        linear = ipoint.linearize(ispace)
        return machine[(linear % nodes, (linear // nodes) % gpus)]

    return Mapper("pumma_blockcyclic", fn)


def grid_for(machine: ProcSpace, devices=None) -> MatmulGrid:
    n = machine.nprocs
    q = int(round(n ** 0.5))
    if q * q != n:
        raise ValueError(f"PUMMA (square variant) needs square device count, got {n}")
    mapper = paper_mapper(machine, (q, q))
    return build_grid(mapper, (q, q), AXES, devices)


def matmul(a: jax.Array, b: jax.Array, grid: MatmulGrid,
           use_kernel: bool = False) -> jax.Array:
    q = grid.shape[0]
    fn = sharded_matmul_wrapper(
        grid,
        summa_body(q, use_kernel),
        in_specs=(P("x", "y"), P("x", "y")),
        out_spec=P("x", "y"),
    )
    return fn(a, b)
