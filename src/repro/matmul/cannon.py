"""Cannon's algorithm [Cannon 1969] on a (q, q) torus via shard_map.

Mapper: the paper's ``hierarchical_block2D`` (Fig. 12) — node-block over the
outer factors, cyclic over the intra-node factors. Swapping in the "runtime
heuristics" mapper (Fig. 13 strawman) changes only the Mesh device order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.mapper import Mapper, hierarchical_block_mapper
from repro.core.pspace import ProcSpace
from repro.matmul.common import (
    MatmulGrid,
    build_grid,
    local_matmul,
    sharded_matmul_wrapper,
    shift,
    skew,
)

AXES = ("x", "y")


def paper_mapper(machine: ProcSpace, grid_shape: tuple[int, int]) -> Mapper:
    """Fig. 12: hierarchical_block2D over the (node, gpu) machine."""
    return hierarchical_block_mapper(machine, grid_shape, name="cannon_hb2d")


def grid_for(machine: ProcSpace, devices=None) -> MatmulGrid:
    n = machine.nprocs
    q = int(round(n ** 0.5))
    if q * q != n:
        raise ValueError(f"Cannon's algorithm needs a square device count, got {n}")
    mapper = paper_mapper(machine, (q, q))
    return build_grid(mapper, (q, q), AXES, devices)


def cannon_body(q: int, use_kernel: bool = False):
    def body(a_blk: jax.Array, b_blk: jax.Array) -> jax.Array:
        # Initial alignment: A row i shifts left i, B col j shifts up j.
        a_blk = skew(a_blk, by_axis="x", along_axis="y", sizes=(q, q), sign=-1)
        b_blk = skew(b_blk, by_axis="y", along_axis="x", sizes=(q, q), sign=-1)
        c0 = jnp.zeros((a_blk.shape[0], b_blk.shape[1]), jnp.float32)

        def step(_, carry):
            c, a, b = carry
            c = c + local_matmul(a, b, use_kernel)
            a = shift(a, "y", -1, q)
            b = shift(b, "x", -1, q)
            return (c, a, b)

        c, _, _ = jax.lax.fori_loop(0, q, step, (c0, a_blk, b_blk))
        return c.astype(a_blk.dtype)

    return body


def matmul(a: jax.Array, b: jax.Array, grid: MatmulGrid,
           use_kernel: bool = False) -> jax.Array:
    q = grid.shape[0]
    fn = sharded_matmul_wrapper(
        grid,
        cannon_body(q, use_kernel),
        in_specs=(P("x", "y"), P("x", "y")),
        out_spec=P("x", "y"),
    )
    return fn(a, b)
