"""Shared machinery for the six distributed matmul algorithms (paper Sec. 6).

Every algorithm is a `shard_map` program over a Mesh whose *device order is
produced by a Mapple mapper* (see repro.core.translate). The algorithms
differ in (a) the processor grid the mapper produces and (b) the collective
schedule of the body — exactly the paper's framing: the mapper is the
performance-critical, swappable part.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.mapper import Mapper
from repro.core.translate import mesh_from_mapper
from repro.core.jaxcompat import shard_map


@dataclasses.dataclass(frozen=True)
class MatmulGrid:
    """A processor grid + the mesh realizing a Mapple mapper on it."""

    mesh: Mesh
    axis_names: tuple[str, ...]

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.mesh.devices.shape)


def build_grid(
    mapper: Mapper,
    grid_shape: Sequence[int],
    axis_names: Sequence[str],
    devices: Sequence[Any] | None = None,
) -> MatmulGrid:
    mesh = mesh_from_mapper(mapper, grid_shape, axis_names, devices)
    return MatmulGrid(mesh=mesh, axis_names=tuple(axis_names))


def shift(x: jax.Array, axis_name: str, offset: int, axis_size: int) -> jax.Array:
    """Cyclic shift of blocks along a mesh axis (Cannon's systolic move)."""
    perm = [(i, (i + offset) % axis_size) for i in range(axis_size)]
    return jax.lax.ppermute(x, axis_name, perm)


def skew(x: jax.Array, by_axis: str, along_axis: str, sizes: tuple[int, int],
         sign: int) -> jax.Array:
    """Cannon's initial alignment: block (i, j) -> (i, j - sign*i) etc.

    ``by_axis`` provides the row index i; blocks move ``sign * i`` steps
    along ``along_axis``.
    """
    i = jax.lax.axis_index(by_axis)
    n = sizes[1]

    # Data-dependent shift distance: implement as (n-1) single-step shifts
    # with a predicated copy (SPMD-safe; every device runs the same program).
    def body(step, val):
        moved = shift(val, along_axis, sign, n)
        keep = step >= i
        return jnp.where(keep, val, moved)

    return jax.lax.fori_loop(0, n - 1, body, x)


def block_spec(*axes: str | None) -> P:
    return P(*axes)


def reference_matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.asarray(a) @ np.asarray(b)


def make_inputs(m: int, k: int, n: int, seed: int = 0, dtype=jnp.float32
                ) -> tuple[jax.Array, jax.Array]:
    kA, kB = jax.random.split(jax.random.key(seed))
    a = jax.random.normal(kA, (m, k), dtype=dtype)
    b = jax.random.normal(kB, (k, n), dtype=dtype)
    return a, b


def local_matmul(a: jax.Array, b: jax.Array,
                 use_kernel: bool = False) -> jax.Array:
    """Local block product — the per-device compute hot spot.

    With ``use_kernel=True`` routes through the Pallas MXU kernel
    (repro.kernels.ops.matmul); default jnp.dot for portability.
    """
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.matmul(a, b)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def sharded_matmul_wrapper(
    grid: MatmulGrid,
    body: Callable[..., jax.Array],
    in_specs: tuple[P, ...],
    out_spec: P,
    check_vma: bool = False,
):
    """Wrap an algorithm body in shard_map + jit over the grid's mesh."""
    fn = shard_map(
        body, mesh=grid.mesh, in_specs=in_specs, out_specs=out_spec,
        check_vma=check_vma,
    )
    return jax.jit(fn)
