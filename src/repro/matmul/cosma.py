"""COSMA [Kwasniewski et al. 2019] — communication-optimal grid matmul.

COSMA derives a near-I/O-optimal processor grid from the red-blue pebbling
bound and executes a 3D (Johnson-style) schedule on it. Here the grid comes
from :func:`repro.core.commvolume.cosma_grid` (greedy largest-extent split,
the COSMA heuristic) and the device order from the paper's
``special_linearize3D`` mapper (Fig. 12).
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.core.commvolume import MatmulProblem, cosma_grid
from repro.core.mapper import Mapper, special_linearize3d_mapper
from repro.core.pspace import ProcSpace
from repro.matmul.common import MatmulGrid, build_grid, sharded_matmul_wrapper
from repro.matmul.johnson import johnson_body

AXES = ("x", "y", "z")


def paper_mapper(machine: ProcSpace, grid: tuple[int, int, int] | None = None
                 ) -> Mapper:
    """Fig. 12 ``special_linearize3D``: linearize with the COSMA grid's
    strides, cyclic over the node dimension.

    The paper derives the strides from ``m_2d.decompose(0, (1,1,1))`` because
    COSMA picks the machine decomposition equal to its own grid; we pass the
    actual grid so the map stays a bijection for non-balanced grids too.
    """
    if grid is None:
        return special_linearize3d_mapper(machine)
    gx, gy, _ = grid
    from repro.core.tuples import Tup

    nodes = machine.shape[0]

    def fn(ipoint: Tup, ispace: Tup):
        linearized = ipoint[0] + ipoint[1] * gx + ipoint[2] * gx * gy
        return machine[(linearized % nodes, (linearized // nodes) % machine.shape[1])]

    return Mapper("cosma_special_linearize3D", fn)


def grid_for(machine: ProcSpace, problem: MatmulProblem, devices=None
             ) -> MatmulGrid:
    g = cosma_grid(problem, machine.nprocs)
    mapper = paper_mapper(machine, g)
    return build_grid(mapper, g, AXES, devices)


def matmul(a: jax.Array, b: jax.Array, grid: MatmulGrid,
           use_kernel: bool = False) -> jax.Array:
    fn = sharded_matmul_wrapper(
        grid,
        johnson_body(use_kernel),
        in_specs=(P("x", "z"), P("z", "y")),
        out_spec=P("x", "y"),
    )
    return fn(a, b)
