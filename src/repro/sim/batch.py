"""Batched analytic-envelope engine: price whole candidate beams at once.

The event engine (``repro.sim.engine``) replays one placement's step
loop task by task — exact, but a Python heap walk per candidate. For
*search* the repeated structure is enormous: every candidate of a tuner
beam shares the same tile-space schedule (``PackedSchedule``), the same
compute leg, and the same steady-state step recurrence; only the
tile->processor assignment (and hence the congestion prices) changes.

:class:`BatchSimulator` exploits that. It prices a stack of candidate
assignments in one vectorized ``candidates x phases x ports`` pass
(``Topology.bucket_times``) and collapses the step recurrence to its
closed form. For a constant per-step schedule the event queue's
steady-state marginal step time is exactly

  * ``compute + comm``        when ``backpressure == 1`` (or a single
    step): compute, its phases, then the gate — fully serial;
  * ``max(compute, comm)``    when ``backpressure >= 2``: the serial
    network stream pipelines one step behind the compute stream, so the
    slower resource sets the cadence

with ``comm`` the chained sum of that step's phase durations. Both legs
reproduce ``Timeline.per_step_time()`` to float rounding —
``benchmarks/sim_eval.py`` and ``tests/test_sim.py`` hold the two
engines to 1e-9 agreement across the registry — while the event engine
stays the exact reference for ``--simulate`` timelines, warmup
transients, and ``Backpressure`` in-flight depth accounting.

:func:`canonical_assignment` is the symmetry companion: congestion
pricing is invariant under relabeling subtrees within a machine level
(every port of a level shares one bandwidth), so placements that agree
up to node / within-node processor renaming are *isomorphic* — the
tuner dedups them before pricing.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.machine import MachineSpec
from repro.sim.collectives import (
    CollectivePattern,
    PackedSchedule,
    packed_schedule,
)
from repro.sim.topology import Topology

#: Cap on ``candidates_per_chunk * transfers`` for one gather/pricing
#: pass, bounding peak memory of the (chunk, T) endpoint arrays.
_MAX_GATHER_ELEMS = 1 << 24


@dataclasses.dataclass(frozen=True)
class BatchSimulator:
    """Analytic-envelope pricing of many placements of one schedule.

    ``assignments`` arguments accept shape ``(N, *grid)`` or
    ``(N, prod(grid))`` stacks of **bijective** tile->processor
    placements (the tuner filters bijectivity before pricing; local
    transfers were already dropped in tile space).
    """

    topology: Topology
    schedule: PackedSchedule
    compute_s: float
    backpressure: int = 2
    steps: int = 3

    def __post_init__(self) -> None:
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.backpressure < 1:
            raise ValueError(
                f"backpressure must be >= 1, got {self.backpressure}"
            )

    # ---------------------------------------------------------------- pricing
    def _flat_assignments(self, assignments: np.ndarray) -> np.ndarray:
        a = np.asarray(assignments, dtype=np.int64)
        ntiles = int(np.prod(self.schedule.grid))
        if a.ndim == len(self.schedule.grid) + 1 \
                and a.shape[1:] == self.schedule.grid:
            a = a.reshape(a.shape[0], ntiles)
        if a.ndim != 2 or a.shape[1] != ntiles:
            raise ValueError(
                f"assignments shape {np.asarray(assignments).shape} does not "
                f"stack placements of tile grid {self.schedule.grid}"
            )
        return a

    def phase_durations(self, assignments: np.ndarray) -> np.ndarray:
        """(N, n_phases) congestion-priced phase times, all candidates in
        one bucketed pass. Only the schedule's *unique* transfer slabs are
        priced (repeated rounds broadcast back over ``phase_map``), and
        candidates are chunked to bound the gather footprint."""
        a = self._flat_assignments(assignments)
        n, sched = a.shape[0], self.schedule
        u, t = sched.n_unique, sched.n_transfers
        if t == 0 or n == 0 or sched.n_phases == 0:
            return np.zeros((n, sched.n_phases), dtype=np.float64)
        slab_times = np.zeros((n, u), dtype=np.float64)
        chunk = max(1, _MAX_GATHER_ELEMS // t)
        for lo in range(0, n, chunk):
            sub = a[lo:lo + chunk]
            m = sub.shape[0]
            src = sub[:, sched.src]
            dst = sub[:, sched.dst]
            nbytes = np.broadcast_to(sched.nbytes, (m, t))
            bucket = (np.arange(m, dtype=np.int64)[:, None] * u
                      + sched.phase_id[None, :])
            slab_times[lo:lo + m] = self.topology.bucket_times(
                src, dst, nbytes, bucket, m * u,
            ).reshape(m, u)
        return slab_times[:, sched.phase_map]

    def _close_steps(self, durations: np.ndarray) -> np.ndarray:
        """(N, n_phases) phase durations -> (N,) steady-state step times:
        the closed form of ``simulate_steps(...).per_step_time()`` for a
        constant schedule (cumsum matches the event engine's sequential
        accumulation on the serial network stream)."""
        if durations.shape[1] == 0:
            comm = np.zeros(durations.shape[0], dtype=np.float64)
        else:
            comm = np.cumsum(durations, axis=1)[:, -1]
        if self.steps == 1 or self.backpressure == 1:
            return self.compute_s + comm
        return np.maximum(self.compute_s, comm)

    def step_times(self, assignments: np.ndarray) -> np.ndarray:
        """(N,) steady-state seconds per step — the closed form of
        ``simulate_steps(...).per_step_time()`` for a constant schedule."""
        return self._close_steps(self.phase_durations(assignments))

    def step_time(self, assignment: np.ndarray) -> float:
        """Seconds per step of a single placement."""
        return float(self.step_times(
            np.asarray(assignment, dtype=np.int64).reshape(1, -1))[0])


def price_stacks(stacks: Sequence[tuple["BatchSimulator", np.ndarray]]
                 ) -> list[np.ndarray]:
    """Step times for several (engine, assignment-stack) groups in as few
    congestion passes as possible.

    The bucket axis of :meth:`Topology.bucket_times` does not care that
    different buckets came from different schedules, so a whole tuner
    beam — every shortlisted grid's surviving variants, across option
    points — prices in one ``candidates x phases x ports`` sweep as long
    as the groups share a topology. Groups are greedily packed into
    passes bounded by the gather ceiling; an oversized single group falls
    back to its own (internally chunked) :meth:`BatchSimulator.step_times`.
    """
    out: list[np.ndarray | None] = [None] * len(stacks)
    runs: list[list[int]] = []
    run: list[int] = []
    run_elems = 0
    for i, (engine, assigns) in enumerate(stacks):
        a = engine._flat_assignments(assigns)
        elems = a.shape[0] * max(engine.schedule.n_transfers, 1)
        same_topo = (not run
                     or stacks[run[0]][0].topology == engine.topology)
        if run and (run_elems + elems > _MAX_GATHER_ELEMS or not same_topo):
            runs.append(run)
            run, run_elems = [], 0
        if elems > _MAX_GATHER_ELEMS:
            out[i] = engine.step_times(assigns)
            continue
        run.append(i)
        run_elems += elems
    if run:
        runs.append(run)
    for run in runs:
        if len(run) == 1:
            i = run[0]
            out[i] = stacks[i][0].step_times(stacks[i][1])
            continue
        topo = stacks[run[0]][0].topology
        srcs, dsts, nbytes, buckets = [], [], [], []
        offsets = []
        total_buckets = 0
        for i in run:
            engine, assigns = stacks[i]
            a = engine._flat_assignments(assigns)
            m, sched = a.shape[0], engine.schedule
            u, t = sched.n_unique, sched.n_transfers
            offsets.append((i, total_buckets, m, u))
            if t:
                srcs.append(a[:, sched.src].reshape(-1))
                dsts.append(a[:, sched.dst].reshape(-1))
                nbytes.append(np.broadcast_to(
                    sched.nbytes, (m, t)).reshape(-1))
                buckets.append(
                    (total_buckets
                     + np.arange(m, dtype=np.int64)[:, None] * u
                     + sched.phase_id[None, :]).reshape(-1))
            total_buckets += m * u
        times = topo.bucket_times(
            np.concatenate(srcs) if srcs else np.empty(0, np.int64),
            np.concatenate(dsts) if dsts else np.empty(0, np.int64),
            np.concatenate(nbytes) if nbytes else np.empty(0, np.float64),
            np.concatenate(buckets) if buckets else np.empty(0, np.int64),
            total_buckets,
        )
        for i, off, m, u in offsets:
            engine = stacks[i][0]
            durations = times[off:off + m * u].reshape(m, u)[
                :, engine.schedule.phase_map]
            out[i] = engine._close_steps(durations)
    return [np.asarray(o) for o in out]


def batch_simulator(pattern: CollectivePattern, spec: MachineSpec,
                    grid: Sequence[int], *, step_flops: float,
                    elem_bytes: int = 4, backpressure: int = 2,
                    steps: int = 3,
                    alphas: tuple[float, ...] | None = None
                    ) -> BatchSimulator:
    """Build the batch engine for one (pattern, machine, grid) point:
    memoized packed schedule + topology + the app's compute leg."""
    grid = tuple(int(g) for g in grid)
    return BatchSimulator(
        topology=Topology.from_spec(spec, alphas=alphas),
        schedule=packed_schedule(pattern, grid, elem_bytes=elem_bytes),
        compute_s=float(step_flops) / (spec.nprocs * spec.peak_flops),
        backpressure=backpressure,
        steps=steps,
    )


# ------------------------------------------------------------------ symmetry
def canonical_assignment(assignment: np.ndarray,
                         machine_shape: Sequence[int]) -> np.ndarray:
    """The representative of a placement's isomorphism class under
    per-level processor relabeling.

    Nodes are renumbered in order of first appearance (row-major over the
    tile grid), then processors within each node likewise. Two placements
    with equal canonical forms put identical byte loads on every port of
    the level tree — crossing levels depend only on the *equality
    pattern* of coordinates and each level's ports share one bandwidth —
    so their simulated times and cross-node fractions coincide and the
    tuner prices one representative.
    """
    nodes, gpus = (int(s) for s in machine_shape)
    flat = np.asarray(assignment, dtype=np.int64).reshape(-1)
    node, gpu = flat // gpus, flat % gpus
    new_node = _appearance_rank(node)
    # Within-node relabeling: rank each (node, gpu) pair by its first
    # appearance among the pairs of the same (relabeled) node.
    pair = new_node * gpus + gpu
    uniq, first = np.unique(pair, return_index=True)
    seg_node = uniq // gpus
    order = np.lexsort((first, seg_node))
    seg_start = np.r_[0, np.flatnonzero(np.diff(seg_node[order])) + 1]
    sizes = np.diff(np.r_[seg_start, uniq.size])
    pos = np.arange(uniq.size) - np.repeat(seg_start, sizes)
    new_gpu_of_uniq = np.empty(uniq.size, dtype=np.int64)
    new_gpu_of_uniq[order] = pos
    new_gpu = new_gpu_of_uniq[np.searchsorted(uniq, pair)]
    return (new_node * gpus + new_gpu).reshape(np.asarray(assignment).shape)


def _appearance_rank(values: np.ndarray) -> np.ndarray:
    """Relabel integer values by order of first appearance."""
    uniq, first = np.unique(values, return_index=True)
    ranks = np.empty(uniq.size, dtype=np.int64)
    ranks[np.argsort(first)] = np.arange(uniq.size)
    return ranks[np.searchsorted(uniq, values)]


__all__ = [
    "BatchSimulator",
    "batch_simulator",
    "canonical_assignment",
]
