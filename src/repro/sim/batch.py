"""Batched analytic-envelope engine: price whole candidate beams at once.

The event engine (``repro.sim.engine``) replays one placement's step
loop task by task — exact, but a Python heap walk per candidate. For
*search* the repeated structure is enormous: every candidate of a tuner
beam shares the same tile-space schedule (``PackedSchedule``), the same
compute leg, and the same steady-state step recurrence; only the
tile->processor assignment (and hence the congestion prices) changes.

:class:`BatchSimulator` exploits that. It prices a stack of candidate
assignments in one vectorized ``candidates x phases x ports`` pass
(``Topology.bucket_times``) and collapses the step recurrence to its
closed form. For a constant per-step schedule the event queue's
steady-state marginal step time is exactly

  * ``compute + comm``        when ``backpressure == 1`` (or a single
    step): compute, its phases, then the gate — fully serial;
  * ``max(compute, comm)``    when ``backpressure >= 2``: the serial
    network stream pipelines one step behind the compute stream, so the
    slower resource sets the cadence

with ``comm`` the chained sum of that step's phase durations. Both legs
reproduce ``Timeline.per_step_time()`` to float rounding —
``benchmarks/sim_eval.py`` and ``tests/test_sim.py`` hold the two
engines to 1e-9 agreement across the registry — while the event engine
stays the exact reference for ``--simulate`` timelines, warmup
transients, and ``Backpressure`` in-flight depth accounting.

:func:`canonical_assignment` is the symmetry companion: congestion
pricing is invariant under relabeling subtrees within a machine level
(every port of a level shares one bandwidth), so placements that agree
up to node / within-node processor renaming are *isomorphic* — the
tuner dedups them before pricing.

The same invariance powers the scaled pricing paths. When a schedule
slab is a tile-grid *translation* of another (``PackedSchedule.fold_rep``
— e.g. SUMMA's round-``r`` panel broadcast is round 0 shifted ``r``
columns) and the candidate assignment is itself periodic under that
shift (checked per candidate: the induced processor permutation must
keep every machine level's subtrees intact), the translated slab's
congestion price *is* the representative's, bit for bit — so hundreds of
broadcast rounds price as a handful of representatives. Likewise a beam
neighbor that moved only a few tiles re-prices only the slabs touching
them, copying the rest from the stack's base candidate
(``incremental``). Both shortcuts are exact, never approximations:
``FOLD_STATS`` counts what was folded, reused, priced, or fell back.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Iterator, Sequence

import numpy as np

from repro.core.machine import DegradedMachine, MachineSpec
from repro.sim.collectives import (
    CollectivePattern,
    PackedSchedule,
    packed_schedule,
)
from repro.sim.topology import Topology

#: Cap on ``candidates_per_chunk * transfers`` for one gather/pricing
#: pass, bounding peak memory of the (chunk, T) endpoint arrays.
_MAX_GATHER_ELEMS = 1 << 24

#: Counter keys for the scaled pricing paths. A "pair" is one
#: (candidate, unique-slab) congestion price.
FOLD_STAT_KEYS = (
    "pairs_priced",     # priced directly via Topology.bucket_times
    "pairs_folded",     # copied from a translation representative
    "pairs_reused",     # copied from the stack's base candidate
    "fold_fallbacks",   # candidates whose assignment broke a fold
)

#: Process-lifetime instrumentation totals. Kept as a module global for
#: backward compatibility (reset with :func:`fold_stats_reset`), but
#: concurrent or nested runs should scope their counts with the
#: :func:`fold_stats` context manager instead of resetting this dict —
#: a reset in one run silently corrupts another run's readings.
FOLD_STATS = {key: 0 for key in FOLD_STAT_KEYS}

#: Guards the global totals: the streaming tuner pipeline prices on a
#: consumer thread while the producer expands candidates, so the legacy
#: dict would race its read-modify-write increments without it. The
#: thread-local scope stacks need no lock (each thread sees only its
#: own), and per-key increments merge atomically under the lock.
_FOLD_LOCK = threading.Lock()

_FOLD_SCOPES = threading.local()


def _fold_scopes() -> list[dict]:
    scopes = getattr(_FOLD_SCOPES, "stack", None)
    if scopes is None:
        scopes = _FOLD_SCOPES.stack = []
    return scopes


def _count(key: str, n: int) -> None:
    """Bump one fold counter: the global totals (lock-protected — the
    pipeline's producer and consumer threads price concurrently) plus
    every counter opened by this thread's active :func:`fold_stats`
    scopes (so nested scopes each see the events of the work they
    wrap)."""
    with _FOLD_LOCK:
        FOLD_STATS[key] += n
    for counter in _fold_scopes():
        counter[key] += n


@contextlib.contextmanager
def fold_stats() -> Iterator[dict]:
    """Scope a pricing run's fold instrumentation.

    Yields a fresh per-run counter dict (the :data:`FOLD_STAT_KEYS`)
    that accumulates only the events of pricing performed inside the
    ``with`` block on the current thread. Unlike resetting the module
    global, scopes are safe to nest and cannot corrupt a concurrent
    run's counts; the global :data:`FOLD_STATS` totals keep
    accumulating regardless.
    """
    counter = {key: 0 for key in FOLD_STAT_KEYS}
    scopes = _fold_scopes()
    scopes.append(counter)
    try:
        yield counter
    finally:
        scopes.remove(counter)


def fold_stats_snapshot() -> dict:
    """A point-in-time copy of the global fold counters."""
    return dict(FOLD_STATS)


def fold_stats_reset() -> None:
    """Zero the global :data:`FOLD_STATS` totals (legacy API; prefer the
    :func:`fold_stats` scope, which needs no reset)."""
    with _FOLD_LOCK:
        for key in FOLD_STATS:
            FOLD_STATS[key] = 0


class ReadyPrices:
    """An already-materialized pricing result behind the async handle
    protocol (``result()``): the host NumPy engine computes eagerly on
    the calling thread, so its "handle" is just the finished array. The
    JAX engine overrides :meth:`BatchSimulator.step_times_async` with a
    genuinely deferred handle (device dispatch returns before the XLA
    program finishes)."""

    __slots__ = ("_value",)

    def __init__(self, value: np.ndarray) -> None:
        self._value = value

    def result(self) -> np.ndarray:
        return self._value


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def _is_permutation(flat: np.ndarray, nprocs: int) -> bool:
    if flat.size != nprocs or flat.size == 0:
        return False
    if int(flat.min()) < 0 or int(flat.max()) >= nprocs:
        return False
    seen = np.zeros(nprocs, dtype=bool)
    seen[flat] = True
    return bool(seen.all())


def _chunk_pairs(sizes: np.ndarray, cap: int) -> list[tuple[int, int]]:
    """Split a pair list into contiguous chunks whose transfer totals stay
    under ``cap`` (a single oversize pair still gets its own chunk)."""
    if sizes.size == 0:
        return []
    csum = np.cumsum(sizes)
    bounds = []
    lo, base = 0, 0
    while lo < sizes.size:
        hi = int(np.searchsorted(csum, base + cap, side="right"))
        hi = max(hi, lo + 1)
        bounds.append((lo, hi))
        base = int(csum[hi - 1])
        lo = hi
    return bounds


@dataclasses.dataclass(frozen=True)
class BatchSimulator:
    """Analytic-envelope pricing of many placements of one schedule.

    ``assignments`` arguments accept shape ``(N, *grid)`` or
    ``(N, prod(grid))`` stacks of **bijective** tile->processor
    placements (the tuner filters bijectivity before pricing; local
    transfers were already dropped in tile space).
    """

    topology: Topology
    schedule: PackedSchedule
    compute_s: float
    backpressure: int = 2
    steps: int = 3

    def __post_init__(self) -> None:
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.backpressure < 1:
            raise ValueError(
                f"backpressure must be >= 1, got {self.backpressure}"
            )

    # ---------------------------------------------------------------- pricing
    def _flat_assignments(self, assignments: np.ndarray) -> np.ndarray:
        a = np.asarray(assignments, dtype=np.int64)
        ntiles = int(np.prod(self.schedule.grid))
        if a.ndim == len(self.schedule.grid) + 1 \
                and a.shape[1:] == self.schedule.grid:
            a = a.reshape(a.shape[0], ntiles)
        if a.ndim != 2 or a.shape[1] != ntiles:
            raise ValueError(
                f"assignments shape {np.asarray(assignments).shape} does not "
                f"stack placements of tile grid {self.schedule.grid}"
            )
        return a

    # -------------------------------------------------- symmetry folding
    def _shift_symmetric(self, agrid: np.ndarray, axis: int,
                         step: int) -> bool:
        """True when translating the tile grid ``step`` tiles along
        ``axis`` (wraparound) maps this assignment onto a machine
        symmetry: the induced processor permutation keeps every level's
        subtrees intact, so every port's transfer list — and therefore
        every congestion price — is unchanged bit for bit."""
        a = agrid.reshape(-1)
        b = np.roll(agrid, -step, axis=axis).reshape(-1)
        inv = np.empty(a.size, dtype=np.int64)
        inv[a] = np.arange(a.size, dtype=np.int64)
        perm = b[inv]                    # processor permutation: b = perm∘a
        degraded = self.topology.degraded
        for lvl, stride in enumerate(self.topology.port_strides):
            if stride == 1:
                # Every proc is its own port: any permutation permutes the
                # ports, and uniform bandwidth makes that free — unless
                # per-port contention breaks the port symmetry.
                if degraded is not None and degraded.contention is not None:
                    cont = np.asarray(degraded.port_contention(lvl))
                    if not (cont[perm] == cont).all():
                        return False
                continue
            blocks = (perm // stride).reshape(-1, stride)
            if not (blocks == blocks[:, :1]).all():
                return False
            if degraded is not None and degraded.contention is not None:
                # The shift permutes this level's ports (port q -> image
                # of its block); the fold is only exact if the induced
                # port map preserves each port's contention factor.
                cont = np.asarray(degraded.port_contention(lvl))
                img = blocks[:, 0]
                if not (cont[img] == cont).all():
                    return False
        return True

    def _axis_period(self, agrid: np.ndarray, axis: int) -> int:
        """Smallest tile translation along ``axis`` that is a machine
        symmetry of this assignment (compatible shifts compose, so every
        multiple of the period folds too; the axis extent itself — only
        the zero shift — when the assignment has no periodicity)."""
        extent = agrid.shape[axis]
        for q in _divisors(extent):
            if q == extent or self._shift_symmetric(agrid, axis, q):
                return q
        return extent

    def _plan(self, a: np.ndarray, fold: bool, incremental: bool
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-(candidate, slab) pricing plan for a stack.

        Returns ``(rep, unch, need)``, all shaped ``(N, n_unique)``:
        ``rep[c, s]`` is the slab whose priced time slab ``s`` of
        candidate ``c`` copies — its translation-class representative
        under the candidate's own periodicities, ``s`` itself when it
        must be priced; ``unch[c, s]`` marks slabs whose physical
        transfers are identical to candidate 0's, so the base row's time
        is reused (exact: same endpoint arrays, independent buckets);
        ``need`` is the mask of pairs that go to ``bucket_times``. Both
        shortcuts reproduce the dense result bit for bit, enforced by
        tests/test_scale.py and the ``sim_eval --scale`` fold-parity
        lane.
        """
        sched = self.schedule
        n, u = a.shape[0], sched.n_unique
        slab_ids = np.arange(u, dtype=np.int64)
        rep = np.tile(slab_ids, (n, 1))
        unch = np.zeros((n, u), dtype=bool)
        frep, fshift = sched.fold_rep, sched.fold_shift
        nprocs = self.topology.nprocs
        foldable = (fold and (frep != slab_ids).any()
                    and int(np.prod(sched.grid)) == nprocs)
        if foldable:
            axes = np.flatnonzero((fshift != 0).any(axis=0))
            for c in range(n):
                if not _is_permutation(a[c], nprocs):
                    _count("fold_fallbacks", 1)
                    continue
                agrid = a[c].reshape(sched.grid)
                periods = {ax: self._axis_period(agrid, ax) for ax in axes}
                # Slabs fold together when they share a class and their
                # shifts agree modulo the candidate's per-axis periods.
                cols = [frep] + [fshift[:, ax] % periods[ax] for ax in axes]
                _, inverse = np.unique(np.stack(cols, axis=1), axis=0,
                                       return_inverse=True)
                inverse = inverse.reshape(-1)
                first = np.full(int(inverse.max()) + 1, u, dtype=np.int64)
                np.minimum.at(first, inverse, slab_ids)
                rep[c] = first[inverse]
                if (rep[c] != frep).any():
                    _count("fold_fallbacks", 1)
        if incremental and n > 1:
            changed = a[1:] != a[:1]
            for c in range(1, n):
                mask = changed[c - 1]
                if mask.all():
                    continue
                if not mask.any():
                    unch[c] = True
                    continue
                moved = mask[sched.src] | mask[sched.dst]
                unch[c] = np.bincount(sched.phase_id[moved],
                                      minlength=u) == 0
        sizes = np.diff(sched.starts)
        need = (rep == slab_ids[None, :]) & ~unch & (sizes > 0)[None, :]
        _count("pairs_priced", int(need.sum()))
        _count("pairs_folded", int((rep != slab_ids[None, :]).sum()))
        _count("pairs_reused",
               int((unch & (rep == slab_ids[None, :])).sum()))
        return rep, unch, need

    def _gather_pairs(self, a: np.ndarray, cc: np.ndarray, ss: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray, int]:
        """Endpoint/bucket arrays pricing the ``(cc, ss)`` candidate/slab
        pairs: one bucket per pair, transfers in slab order (the same
        accumulation order as the dense all-pairs pass, so the priced
        values are bit-identical)."""
        sched = self.schedule
        sizes = np.diff(sched.starts)[ss]
        total = int(sizes.sum())
        cand = np.repeat(cc, sizes)
        t_idx = (np.repeat(sched.starts[:-1][ss], sizes)
                 + np.arange(total, dtype=np.int64)
                 - np.repeat(np.cumsum(sizes) - sizes, sizes))
        src = a[cand, sched.src[t_idx]]
        dst = a[cand, sched.dst[t_idx]]
        bucket = np.repeat(np.arange(cc.size, dtype=np.int64), sizes)
        return src, dst, sched.nbytes[t_idx], bucket, int(cc.size)

    def _fill_slabs(self, rep: np.ndarray, unch: np.ndarray,
                    need: np.ndarray, values: np.ndarray) -> np.ndarray:
        """Re-expand priced pair values to the full (N, n_unique) slab
        times: scatter, resolve the base row's folds, copy base-identical
        slabs, then broadcast every row's translation folds."""
        n, u = need.shape
        times = np.zeros((n, u), dtype=np.float64)
        times[need] = values
        times[0] = times[0][rep[0]]
        if n > 1:
            times = np.where(unch, times[0][None, :], times)
            times = np.take_along_axis(times, rep, axis=1)
        return times

    def phase_durations(self, assignments: np.ndarray, *,
                        fold: bool = True,
                        incremental: bool = True) -> np.ndarray:
        """(N, n_phases) congestion-priced phase times, all candidates in
        one bucketed pass. Only the schedule's *unique* transfer slabs
        are priced (repeated rounds broadcast back over ``phase_map``),
        and of those only one translation representative per candidate
        symmetry class (``fold``) and only the slabs whose placements
        differ from candidate 0's (``incremental``) — both copies are
        bit-exact, so disabling the flags changes nothing but speed.
        Gathers are chunked to bound peak memory."""
        a = self._flat_assignments(assignments)
        n, sched = a.shape[0], self.schedule
        u, t = sched.n_unique, sched.n_transfers
        if t == 0 or n == 0 or sched.n_phases == 0:
            return np.zeros((n, sched.n_phases), dtype=np.float64)
        rep, unch, need = self._plan(a, fold, incremental)
        cc, ss = np.nonzero(need)
        values = np.empty(cc.size, dtype=np.float64)
        sizes = np.diff(sched.starts)[ss]
        for lo, hi in _chunk_pairs(sizes, _MAX_GATHER_ELEMS):
            src, dst, nbytes, bucket, nb = self._gather_pairs(
                a, cc[lo:hi], ss[lo:hi])
            values[lo:hi] = self.topology.bucket_times(
                src, dst, nbytes, bucket, nb)
        slab_times = self._fill_slabs(rep, unch, need, values)
        return slab_times[:, sched.phase_map]

    def _close_steps(self, durations: np.ndarray) -> np.ndarray:
        """(N, n_phases) phase durations -> (N,) steady-state step times:
        the closed form of ``simulate_steps(...).per_step_time()`` for a
        constant schedule (cumsum matches the event engine's sequential
        accumulation on the serial network stream)."""
        if durations.shape[1] == 0:
            comm = np.zeros(durations.shape[0], dtype=np.float64)
        else:
            comm = np.cumsum(durations, axis=1)[:, -1]
        if self.steps == 1 or self.backpressure == 1:
            return self.compute_s + comm
        return np.maximum(self.compute_s, comm)

    def step_times(self, assignments: np.ndarray, *, fold: bool = True,
                   incremental: bool = True) -> np.ndarray:
        """(N,) steady-state seconds per step — the closed form of
        ``simulate_steps(...).per_step_time()`` for a constant schedule."""
        return self._close_steps(self.phase_durations(
            assignments, fold=fold, incremental=incremental))

    def step_time(self, assignment: np.ndarray) -> float:
        """Seconds per step of a single placement."""
        return float(self.step_times(
            np.asarray(assignment, dtype=np.int64).reshape(1, -1))[0])

    def step_times_async(self, assignments: np.ndarray, *,
                         fold: bool = True,
                         incremental: bool = True) -> ReadyPrices:
        """Asynchronous-dispatch twin of :meth:`step_times`: returns a
        handle whose ``result()`` yields the (N,) step times. The host
        engine computes eagerly (NumPy has no deferred execution — but
        its pricing releases the GIL, so a pipeline's producer thread
        still overlaps it); the JAX engine overrides this to dispatch
        the compiled program and return before the device finishes."""
        return ReadyPrices(self.step_times(
            assignments, fold=fold, incremental=incremental))


def price_stacks(stacks: Sequence[tuple["BatchSimulator", np.ndarray]],
                 *, fold: bool = True,
                 incremental: bool = True) -> list[np.ndarray]:
    """Step times for several (engine, assignment-stack) groups in as few
    congestion passes as possible.

    The bucket axis of :meth:`Topology.bucket_times` does not care that
    different buckets came from different schedules, so a whole tuner
    beam — every shortlisted grid's surviving variants, across option
    points — prices in one ``candidates x phases x ports`` sweep as long
    as the groups share a topology. Each group is first *planned*
    (:meth:`BatchSimulator._plan`): symmetry-folded and base-identical
    slabs are dropped from the gather and reconstructed bit-exactly
    afterwards, so only the irreducible pairs hit the congestion pass.
    Groups are greedily packed into passes bounded by the gather ceiling;
    an oversized single group falls back to its own (internally chunked)
    :meth:`BatchSimulator.step_times`.
    """
    out: list[np.ndarray | None] = [None] * len(stacks)
    prepared: list[tuple] = []
    for i, (engine, assigns) in enumerate(stacks):
        if getattr(engine, "prices_independently", False):
            # Accelerator-resident engines (repro.sim.jax_backend) price
            # each stack as one compiled program; concatenating their
            # transfers into the shared NumPy congestion pass would force
            # the data back to the host.
            out[i] = engine.step_times(assigns, fold=fold,
                                       incremental=incremental)
            continue
        a = engine._flat_assignments(assigns)
        sched = engine.schedule
        if (a.shape[0] == 0 or sched.n_phases == 0
                or sched.n_transfers == 0
                or a.shape[0] * sched.n_transfers > _MAX_GATHER_ELEMS):
            out[i] = engine.step_times(a, fold=fold, incremental=incremental)
            continue
        rep, unch, need = engine._plan(a, fold, incremental)
        cc, ss = np.nonzero(need)
        elems = int(np.diff(sched.starts)[ss].sum())
        prepared.append((i, a, rep, unch, need, cc, ss, elems))
    runs: list[list[int]] = []
    run: list[int] = []
    run_elems = 0
    for j, item in enumerate(prepared):
        engine = stacks[item[0]][0]
        same_topo = (not run or stacks[prepared[run[0]][0]][0].topology
                     == engine.topology)
        if run and (run_elems + item[-1] > _MAX_GATHER_ELEMS
                    or not same_topo):
            runs.append(run)
            run, run_elems = [], 0
        run.append(j)
        run_elems += item[-1]
    if run:
        runs.append(run)
    for run in runs:
        topo = stacks[prepared[run[0]][0]][0].topology
        srcs, dsts, nbs, buckets = [], [], [], []
        offs = []
        total = 0
        for j in run:
            i, a, rep, unch, need, cc, ss, _ = prepared[j]
            engine = stacks[i][0]
            src, dst, nb, bucket, npairs = engine._gather_pairs(a, cc, ss)
            srcs.append(src)
            dsts.append(dst)
            nbs.append(nb)
            buckets.append(bucket + total)
            offs.append((j, total, npairs))
            total += npairs
        times = topo.bucket_times(
            np.concatenate(srcs) if srcs else np.empty(0, np.int64),
            np.concatenate(dsts) if dsts else np.empty(0, np.int64),
            np.concatenate(nbs) if nbs else np.empty(0, np.float64),
            np.concatenate(buckets) if buckets else np.empty(0, np.int64),
            total,
        )
        for j, off, npairs in offs:
            i, a, rep, unch, need, cc, ss, _ = prepared[j]
            engine = stacks[i][0]
            slab_times = engine._fill_slabs(rep, unch, need,
                                            times[off:off + npairs])
            out[i] = engine._close_steps(
                slab_times[:, engine.schedule.phase_map])
    return [np.asarray(o) for o in out]


def iter_price_stacks(stacks: Sequence[tuple["BatchSimulator", np.ndarray]],
                      *, fold: bool = True,
                      incremental: bool = True
                      ) -> Iterator[tuple[int, np.ndarray]]:
    """Streaming entry point: yield ``(index, step_times)`` per group as
    each finishes, dispatching every group asynchronously up front.

    Where :func:`price_stacks` is a strict barrier (nothing returns until
    the whole beam is priced), this generator lets a consumer merge
    results group by group while later groups are still pricing — on the
    JAX engine the dispatches queue on the device and the host only
    blocks per-group at ``result()``. Values are identical to
    :func:`price_stacks` (each group prices from its own endpoint
    arrays into independent buckets; packing groups together never
    changed the arithmetic). The tuner's pipelined Phase 3
    (``repro.search.pipeline``) is the primary consumer.
    """
    handles = [
        (i, engine.step_times_async(assigns, fold=fold,
                                    incremental=incremental))
        for i, (engine, assigns) in enumerate(stacks)
    ]
    for i, handle in handles:
        yield i, np.asarray(handle.result())


def batch_simulator(pattern: CollectivePattern, spec: MachineSpec,
                    grid: Sequence[int], *, step_flops: float,
                    elem_bytes: int = 4, backpressure: int = 2,
                    steps: int = 3,
                    alphas: tuple[float, ...] | None = None,
                    degraded: "DegradedMachine | None" = None
                    ) -> BatchSimulator:
    """Build the batch engine for one (pattern, machine, grid) point:
    memoized packed schedule + topology + the app's compute leg."""
    grid = tuple(int(g) for g in grid)
    return BatchSimulator(
        topology=Topology.from_spec(spec, alphas=alphas, degraded=degraded),
        schedule=packed_schedule(pattern, grid, elem_bytes=elem_bytes),
        compute_s=float(step_flops) / (spec.nprocs * spec.peak_flops),
        backpressure=backpressure,
        steps=steps,
    )


# ------------------------------------------------------------------ symmetry
def canonical_assignment(assignment: np.ndarray,
                         machine_shape: Sequence[int]) -> np.ndarray:
    """The representative of a placement's isomorphism class under
    per-level processor relabeling.

    Nodes are renumbered in order of first appearance (row-major over the
    tile grid), then processors within each node likewise. Two placements
    with equal canonical forms put identical byte loads on every port of
    the level tree — crossing levels depend only on the *equality
    pattern* of coordinates and each level's ports share one bandwidth —
    so their simulated times and cross-node fractions coincide and the
    tuner prices one representative.
    """
    nodes, gpus = (int(s) for s in machine_shape)
    flat = np.asarray(assignment, dtype=np.int64).reshape(-1)
    node, gpu = flat // gpus, flat % gpus
    new_node = _appearance_rank(node)
    # Within-node relabeling: rank each (node, gpu) pair by its first
    # appearance among the pairs of the same (relabeled) node.
    pair = new_node * gpus + gpu
    uniq, first = np.unique(pair, return_index=True)
    seg_node = uniq // gpus
    order = np.lexsort((first, seg_node))
    seg_start = np.r_[0, np.flatnonzero(np.diff(seg_node[order])) + 1]
    sizes = np.diff(np.r_[seg_start, uniq.size])
    pos = np.arange(uniq.size) - np.repeat(seg_start, sizes)
    new_gpu_of_uniq = np.empty(uniq.size, dtype=np.int64)
    new_gpu_of_uniq[order] = pos
    new_gpu = new_gpu_of_uniq[np.searchsorted(uniq, pair)]
    return (new_node * gpus + new_gpu).reshape(np.asarray(assignment).shape)


def _appearance_rank(values: np.ndarray) -> np.ndarray:
    """Relabel integer values by order of first appearance."""
    uniq, first = np.unique(values, return_index=True)
    ranks = np.empty(uniq.size, dtype=np.int64)
    ranks[np.argsort(first)] = np.arange(uniq.size)
    return ranks[np.searchsorted(uniq, values)]


__all__ = [
    "BatchSimulator",
    "FOLD_STATS",
    "FOLD_STAT_KEYS",
    "ReadyPrices",
    "batch_simulator",
    "canonical_assignment",
    "fold_stats",
    "fold_stats_reset",
    "fold_stats_snapshot",
    "iter_price_stacks",
    "price_stacks",
]
