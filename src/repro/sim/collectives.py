"""Collective communication schedules for the patterns the nine apps emit.

Every builder turns one *logical* collective into a list of
:class:`Phase` objects — sets of point-to-point transfers that run
concurrently, with phases executing in order. Crucially the endpoints are
**physical processor ids taken from the mapper's assignment grid**, so
tile->processor placement (and therefore node-crossing) is exact, not
averaged: two mappers with identical communication *volume* produce
different schedules when one keeps neighbours on a node and the other
scatters them round-robin.

Patterns (paper Sec. 6 workloads + the transpose/MoE all-to-all):

  ``halo``              face exchange with each grid neighbour (stencil,
                        PENNANT; per-axis wraparound matches the tuner's
                        locality metric)
  ``shift``             systolic ring shifts of A/B tiles (Cannon)
  ``panel_broadcast``   per-round row/column panel broadcasts
                        (SUMMA, PUMMA)
  ``bcast_reduce_3d``   operand broadcasts + C reduction along the grid
                        axes (Johnson, COSMA)
  ``replicated_shift``  2.5D: replicate over c, shifted rounds, reduce
                        over c (Solomonik)
  ``gather_scatter``    ring all-gather(V) + ring reduce-scatter(Q)
                        (circuit)
  ``alltoall``          pairwise exchange (transpose / MoE dispatch)

Primitive schedules (ring all-gather / reduce-scatter, ring or binomial
tree all-reduce, binomial broadcast/reduce) are exposed for new patterns;
``build_phases`` dispatches a declared :class:`CollectivePattern` for an
application grid + assignment. See docs/simulator.md for how to add one.

Everything on the hot path is array-programmed and memoized. A
collective's endpoints are a pure function of *tile grid positions* —
the assignment only substitutes physical ids at the end — so one step's
schedule is expanded once per ``(pattern, grid)`` into a
:class:`PackedSchedule` of tile-index tensors (``src``/``dst``/``nbytes``
arrays over all phases), and ``build_phases`` derives any assignment's
physical schedule from it with a single gather, memoized per
``(pattern, grid, assignment digest)``. The batched engine
(``repro.sim.batch``) consumes the packed form directly to price whole
candidate beams without ever materializing per-candidate Phase lists.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

#: Bounds for the module-level schedule caches (FIFO eviction). Packed
#: schedules are assignment-independent (one per pattern x grid); the
#: phase cache additionally keys on the assignment digest, so tuner
#: sweeps that revisit placements (phase 1 default vs phase 3 variants,
#: the double runs of benchmarks/sim_eval.py) expand each schedule once.
_PACKED_CACHE_MAX = 128
_PHASES_CACHE_MAX = 256

_PACKED_CACHE: dict = {}
_PHASES_CACHE: dict = {}

#: Hit/miss/eviction counters for the two schedule memos, read through
#: ``cache_stats()`` and zeroed by ``clear_caches()``. Diagnostics only
#: — correctness never depends on a hit.
_CACHE_STATS = {
    "packed_hits": 0, "packed_misses": 0, "packed_evictions": 0,
    "phases_hits": 0, "phases_misses": 0, "phases_evictions": 0,
}

#: Auxiliary caches that want to ride the sim-wide ``clear_caches()`` /
#: ``cache_stats()`` surface: name -> (clear_fn, stats_fn). The JAX
#: backend registers its per-schedule ``_ScheduleExport`` cache and the
#: price cache registers its open on-disk tables here, so one call
#: reclaims every sim-side memo between tuning runs and one snapshot
#: shows every hit rate.
_EXTRA_CACHES: dict = {}


def register_cache(name: str, clear_fn, stats_fn) -> None:
    """Attach an auxiliary cache to :func:`clear_caches` (``clear_fn``,
    zero-arg) and :func:`cache_stats` (``stats_fn`` returning a dict,
    reported under ``name``). Re-registering a name replaces it."""
    _EXTRA_CACHES[name] = (clear_fn, stats_fn)


@dataclasses.dataclass(frozen=True)
class Phase:
    """One set of concurrent point-to-point transfers."""

    label: str
    src: np.ndarray           # flat physical processor ids
    dst: np.ndarray
    nbytes: np.ndarray        # per-transfer payload bytes

    @property
    def total_bytes(self) -> float:
        return float(np.sum(self.nbytes))


@dataclasses.dataclass(frozen=True)
class PackedSchedule:
    """One step's whole schedule as packed tensors in *tile-index* space.

    ``src``/``dst`` are flat indices into the tile grid (row-major), not
    processor ids: endpoints of every builder are functions of grid
    positions alone, so the packed form is assignment-independent and a
    bijective placement's physical schedule is ``assignment[src]`` /
    ``assignment[dst]`` — one gather. ``starts`` delimits the phases
    (``starts[p]:starts[p+1]`` is phase ``p``'s transfer slab).
    """

    grid: tuple[int, ...]
    labels: tuple[str, ...]
    phase_map: np.ndarray     # (n_phases,) -> owning unique transfer slab
    starts: np.ndarray        # (n_unique + 1,) slab offsets
    phase_id: np.ndarray      # (T,) owning unique slab per transfer
    src: np.ndarray           # (T,) flat tile indices
    dst: np.ndarray
    nbytes: np.ndarray        # (T,) payload bytes
    #: Translation-symmetry metadata: slab ``u`` is, elementwise, slab
    #: ``fold_rep[u]`` with every endpoint translated by ``fold_shift[u]``
    #: tiles along each grid axis (wraparound). The batched engine prices
    #: one representative per translation class and copies the time to
    #: the translated members whenever the candidate assignment is itself
    #: periodic under those shifts (``repro.sim.batch``). Representatives
    #: point at themselves with a zero shift.
    fold_rep: np.ndarray      # (n_unique,) representative slab index
    fold_shift: np.ndarray    # (n_unique, len(grid)) tile shift from rep

    @property
    def n_phases(self) -> int:
        return len(self.labels)

    @property
    def n_unique(self) -> int:
        """Distinct transfer sets. Repeated rounds (a ring's p-1 identical
        shifts, Cannon's systolic repeats) collapse to one slab — pricing
        is per unique slab, then broadcast back over ``phase_map``."""
        return int(self.starts.size) - 1

    @property
    def n_transfers(self) -> int:
        return int(self.src.size)

    @property
    def total_bytes(self) -> float:
        """Scheduled wire bytes of the full step (all phases, with
        repeated slabs counted every round they run)."""
        slab = np.zeros(self.n_unique, dtype=np.float64)
        np.add.at(slab, self.phase_id, self.nbytes)
        return float(slab[self.phase_map].sum()) if self.n_phases else 0.0


@dataclasses.dataclass(frozen=True)
class CollectivePattern:
    """An application's declared communication pattern + static parameters.

    ``params`` holds problem constants (matrix dims, iteration lengths,
    halo field counts, ...); everything grid-dependent is derived inside
    the builder so one declaration scales with the processor count.
    """

    kind: str
    params: dict = dataclasses.field(default_factory=dict)


def _freeze(*arrays: np.ndarray) -> None:
    for a in arrays:
        a.setflags(write=False)


def _phase(label: str, src, dst, nbytes) -> Phase:
    """Build a Phase from endpoint arrays, dropping same-processor
    (local) transfers."""
    src = np.asarray(src, dtype=np.int64).reshape(-1)
    dst = np.asarray(dst, dtype=np.int64).reshape(-1)
    nbytes = np.broadcast_to(np.asarray(nbytes, dtype=np.float64), src.shape)
    keep = src != dst
    if not keep.all():
        src, dst, nbytes = src[keep], dst[keep], nbytes[keep]
    else:
        src = np.ascontiguousarray(src)
        dst = np.ascontiguousarray(dst)
        nbytes = np.ascontiguousarray(nbytes)
    _freeze(src, dst, nbytes)
    return Phase(label, src, dst, nbytes)


# ----------------------------------------------------------- primitive rings
def ring_allgather(group: Sequence[int], total_bytes: float,
                   label: str = "all_gather") -> list[Phase]:
    """Ring all-gather of ``total_bytes`` split over the group: p-1 rounds,
    each member forwarding one shard (bytes/p) to its ring successor.
    Memoized by group tuple — every round shares one endpoint array."""
    return list(_ring_phases(tuple(int(g) for g in group),
                             float(total_bytes), str(label)))


@functools.lru_cache(maxsize=512)
def _ring_phases(group: tuple[int, ...], total_bytes: float,
                 label: str) -> tuple[Phase, ...]:
    p = len(group)
    if p <= 1:
        return ()
    g = np.asarray(group, dtype=np.int64)
    first = _phase(f"{label}[0]", g, np.roll(g, -1), total_bytes / p)
    return (first,) + tuple(
        Phase(f"{label}[{r}]", first.src, first.dst, first.nbytes)
        for r in range(1, p - 1)
    )


def ring_reduce_scatter(group: Sequence[int], total_bytes: float,
                        label: str = "reduce_scatter") -> list[Phase]:
    """Same wire schedule as the all-gather ring, reducing as it goes."""
    return ring_allgather(group, total_bytes, label=label)


def ring_allreduce(group: Sequence[int], total_bytes: float,
                   label: str = "all_reduce") -> list[Phase]:
    """Reduce-scatter + all-gather: 2(p-1) rounds of bytes/p shards."""
    return (ring_reduce_scatter(group, total_bytes, label=f"{label}/rs")
            + ring_allgather(group, total_bytes, label=f"{label}/ag"))


# ------------------------------------------------------------ primitive trees
@functools.lru_cache(maxsize=256)
def _tree_rounds(p: int) -> tuple[tuple[tuple[int, int], ...], ...]:
    """Binomial doubling rounds as (src_index, dst_index) pairs in a group
    (memoized — the same round structure recurs for every group size)."""
    rounds: list[tuple[tuple[int, int], ...]] = []
    have = 1
    while have < p:
        rounds.append(tuple((i, i + have) for i in range(min(have, p - have))))
        have *= 2
    return tuple(rounds)


def concurrent_tree_broadcast(groups: Sequence[Sequence[int]], nbytes: float,
                              label: str = "bcast") -> list[Phase]:
    """Binomial broadcasts from each group's first member, with all groups
    progressing in lockstep — one congestion-priced phase per tree round,
    so disjoint groups (e.g. the rows of a SUMMA grid) genuinely overlap.
    Memoized by the group tuple."""
    key = tuple(
        tuple(int(g) for g in grp) for grp in groups if len(grp) > 1
    )
    return list(_tree_bcast_phases(key, float(nbytes), str(label)))


@functools.lru_cache(maxsize=512)
def _tree_bcast_phases(groups: tuple[tuple[int, ...], ...], nbytes: float,
                       label: str) -> tuple[Phase, ...]:
    if not groups:
        return ()
    longest = max(len(g) for g in groups)
    uniform = all(len(g) == longest for g in groups)
    grid = np.asarray(groups, dtype=np.int64) if uniform else None
    phases: list[Phase] = []
    for r, rnd in enumerate(_tree_rounds(longest)):
        if uniform:
            ii = np.fromiter((i for i, _ in rnd), dtype=np.int64)
            jj = np.fromiter((j for _, j in rnd), dtype=np.int64)
            src, dst = grid[:, ii].reshape(-1), grid[:, jj].reshape(-1)
        else:
            sends = [
                (grp[i], grp[j])
                for grp in groups for i, j in rnd if j < len(grp)
            ]
            src = np.fromiter((s for s, _ in sends), dtype=np.int64,
                              count=len(sends))
            dst = np.fromiter((d for _, d in sends), dtype=np.int64,
                              count=len(sends))
        phases.append(_phase(f"{label}[{r}]", src, dst, nbytes))
    return tuple(phases)


def concurrent_tree_reduce(groups: Sequence[Sequence[int]], nbytes: float,
                           label: str = "reduce") -> list[Phase]:
    """The broadcast wire schedule run backwards: reduce to each group's
    first member, all groups in lockstep."""
    return [
        Phase(ph.label, ph.dst, ph.src, ph.nbytes)
        for ph in reversed(concurrent_tree_broadcast(groups, nbytes, label))
    ]


def tree_broadcast(group: Sequence[int], nbytes: float,
                   label: str = "bcast") -> list[Phase]:
    """Binomial-tree broadcast from group[0]: ceil(log2 p) doubling rounds."""
    return concurrent_tree_broadcast([group], nbytes, label=label)


def tree_reduce(group: Sequence[int], nbytes: float,
                label: str = "reduce") -> list[Phase]:
    """Binomial-tree reduction to group[0] (the broadcast run backwards)."""
    return concurrent_tree_reduce([group], nbytes, label=label)


def tree_allreduce(group: Sequence[int], nbytes: float,
                   label: str = "all_reduce") -> list[Phase]:
    """Reduce-to-root + broadcast: 2*ceil(log2 p) rounds of full payloads.

    Cheaper than the ring for latency-bound (small) payloads; callers pick
    via :func:`allreduce`.
    """
    return (tree_reduce(group, nbytes, label=f"{label}/red")
            + tree_broadcast(group, nbytes, label=f"{label}/bc"))


def allreduce(group: Sequence[int], total_bytes: float, *,
              alpha: float = 1e-6, beta: float = 1e11,
              label: str = "all_reduce") -> list[Phase]:
    """Ring-or-tree all-reduce, picking the cheaper schedule by the
    uncontended alpha-beta estimate (rings win on bandwidth, trees on
    latency)."""
    p = len(group)
    if p <= 1:
        return []
    import math

    rounds_tree = 2 * math.ceil(math.log2(p))
    t_ring = 2 * (p - 1) * (alpha + (total_bytes / p) / beta)
    t_tree = rounds_tree * (alpha + total_bytes / beta)
    if t_tree < t_ring:
        return tree_allreduce(group, total_bytes, label=label)
    return ring_allreduce(group, total_bytes, label=label)


def alltoall(group: Sequence[int], bytes_per_pair: float,
             label: str = "all_to_all") -> list[Phase]:
    """Full pairwise exchange in one congestion-priced phase: every member
    sends ``bytes_per_pair`` to every other (transpose / MoE dispatch)."""
    g = np.asarray([int(x) for x in group], dtype=np.int64)
    p = int(g.size)
    if p <= 1:
        return []
    ph = _phase(label, np.repeat(g, p), np.tile(g, p), bytes_per_pair)
    return [ph] if ph.src.size else []


# ------------------------------------------------------------- grid utilities
def _assignment(grid: Sequence[int], assignment: np.ndarray) -> np.ndarray:
    a = np.asarray(assignment, dtype=np.int64)
    grid = tuple(int(g) for g in grid)
    if a.shape != grid:
        raise ValueError(
            f"assignment shape {a.shape} does not match tile grid {grid}"
        )
    return a


def _shift_phases(assign: np.ndarray, axis: int, step: int, nbytes: float,
                  label: str) -> Phase:
    """Every tile sends ``nbytes`` to the tile ``step`` away along ``axis``
    (wraparound): the systolic / halo neighbour structure."""
    dst = np.roll(assign, -step, axis=axis)
    return _phase(label, assign.reshape(-1), dst.reshape(-1), nbytes)


def _axis_groups(assign: np.ndarray, axis: int) -> list[list[int]]:
    """Processor groups along one grid axis (all other coordinates fixed)."""
    moved = np.moveaxis(assign, axis, -1)
    return [list(map(int, row)) for row in moved.reshape(-1, assign.shape[axis])]


# ------------------------------------------------------------ pattern builders
def _halo_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                 assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    lengths = pattern.params["lengths"]
    fields = int(pattern.params.get("fields", 1))
    if len(lengths) != len(grid):
        raise ValueError(
            f"halo grid rank {len(grid)} != iteration rank {len(lengths)}"
        )
    phases = []
    for axis in range(len(grid)):
        if grid[axis] == 1:
            continue
        face_elems = 1.0
        for m in range(len(grid)):
            if m != axis:
                face_elems *= lengths[m] / grid[m]
        face_bytes = fields * face_elems * elem_bytes
        for step, side in ((1, "+"), (-1, "-")):
            phases.append(_shift_phases(
                assign, axis, step, face_bytes, f"halo[ax{axis}{side}]"))
    return phases


def _shift_pattern_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                          assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    m, n, k = (pattern.params[key] for key in ("m", "n", "k"))
    if len(grid) != 2 or grid[0] != grid[1]:
        raise ValueError(f"systolic shift needs a square 2D grid, got {grid}")
    q = grid[0]
    tile_a = (m / q) * (k / q) * elem_bytes
    tile_b = (k / q) * (n / q) * elem_bytes
    phases = []
    for r in range(max(q - 1, 0)):
        phases.append(_shift_phases(assign, 1, 1, tile_a, f"shiftA[{r}]"))
        phases.append(_shift_phases(assign, 0, 1, tile_b, f"shiftB[{r}]"))
    return phases


def _panel_broadcast_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                            assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    m, n, k = (pattern.params[key] for key in ("m", "n", "k"))
    if len(grid) != 2:
        raise ValueError(f"panel broadcast needs a 2D grid, got {grid}")
    pr, pc = grid
    rounds = max(pr, pc)
    panel_a = (m / pr) * (k / rounds) * elem_bytes   # A panel along the row
    panel_b = (k / rounds) * (n / pc) * elem_bytes   # B panel down the column
    # Round r: column (r % pc) roots broadcast A along each row, row
    # (r % pr) roots broadcast B along each column; all rows (resp.
    # columns) progress concurrently. The group member j of row i's round-r
    # broadcast is assign[i, (r + j) % pc] (and transposed for columns), so
    # each tree round builds directly from index arithmetic on the
    # assignment grid — no per-round Python group materialization.
    row_rounds = [
        (np.fromiter((i for i, _ in rnd), dtype=np.int64, count=len(rnd)),
         np.fromiter((j for _, j in rnd), dtype=np.int64, count=len(rnd)))
        for rnd in _tree_rounds(pc)
    ]
    col_rounds = [
        (np.fromiter((i for i, _ in rnd), dtype=np.int64, count=len(rnd)),
         np.fromiter((j for _, j in rnd), dtype=np.int64, count=len(rnd)))
        for rnd in _tree_rounds(pr)
    ]
    phases: list[Phase] = []
    for r in range(rounds):
        for t, (ii, jj) in enumerate(row_rounds):
            phases.append(_phase(
                f"bcastA[{r}][{t}]",
                assign[:, (r + ii) % pc].reshape(-1),
                assign[:, (r + jj) % pc].reshape(-1),
                panel_a,
            ))
        for t, (ii, jj) in enumerate(col_rounds):
            phases.append(_phase(
                f"bcastB[{r}][{t}]",
                assign[(r + ii) % pr, :].T.reshape(-1),
                assign[(r + jj) % pr, :].T.reshape(-1),
                panel_b,
            ))
    return phases


def _bcast_reduce_3d_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                            assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    m, n, k = (pattern.params[key] for key in ("m", "n", "k"))
    if len(grid) != 3:
        raise ValueError(f"3D bcast+reduce needs a 3D grid, got {grid}")
    q1, q2, q3 = grid
    tile_a = (m / q1) * (k / q3) * elem_bytes
    tile_b = (k / q3) * (n / q2) * elem_bytes
    tile_c = (m / q1) * (n / q2) * elem_bytes
    # A(i, :, l) is broadcast along the j axis, B(:, j, l) along i, and the
    # C(i, j, :) partials reduce along the k axis — Johnson's 3D schedule,
    # every group along an axis progressing concurrently.
    return (
        concurrent_tree_broadcast(_axis_groups(assign, 1), tile_a, "bcastA")
        + concurrent_tree_broadcast(_axis_groups(assign, 0), tile_b, "bcastB")
        + concurrent_tree_reduce(_axis_groups(assign, 2), tile_c, "reduceC")
    )


def _replicated_shift_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                             assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    m, n, k = (pattern.params[key] for key in ("m", "n", "k"))
    if len(grid) != 3 or grid[0] != grid[1]:
        raise ValueError(f"2.5D shift needs a (q, q, c) grid, got {grid}")
    q, _, c = grid
    tile_a = (m / q) * (k / q) * elem_bytes
    tile_b = (k / q) * (n / q) * elem_bytes
    tile_c = (m / q) * (n / q) * elem_bytes
    phases: list[Phase] = []
    if c > 1:
        # Replicate the initial A/B layer over the c axis.
        phases.extend(concurrent_tree_broadcast(
            _axis_groups(assign, 2), tile_a + tile_b, "replAB"))
    for r in range(max(q // max(c, 1) - 1, 0)):
        # All c layers shift concurrently; the shift over the full 3D
        # assignment rolls only the (q, q) plane coordinates.
        phases.append(_shift_phases(assign, 1, 1, tile_a, f"shiftA[{r}]"))
        phases.append(_shift_phases(assign, 0, 1, tile_b, f"shiftB[{r}]"))
    if c > 1:
        phases.extend(concurrent_tree_reduce(
            _axis_groups(assign, 2), tile_c, "reduceC"))
    return phases


def _gather_scatter_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                           assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    if len(grid) != 1:
        raise ValueError(f"gather/scatter needs a 1D piece grid, got {grid}")
    npp = pattern.params["nodes_per_piece"]
    discount = float(pattern.params.get("discount", 1.0))
    procs = [int(p) for p in assign.reshape(-1)]
    total = discount * npp * len(procs) * elem_bytes
    return (ring_allgather(procs, total, label="gatherV")
            + ring_reduce_scatter(procs, total, label="scatterQ"))


def _alltoall_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                     assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    per_pair = pattern.params["elems_per_pair"] * elem_bytes
    procs = [int(p) for p in assign.reshape(-1)]
    return alltoall(procs, per_pair)


_BUILDERS = {
    "halo": _halo_phases,
    "shift": _shift_pattern_phases,
    "panel_broadcast": _panel_broadcast_phases,
    "bcast_reduce_3d": _bcast_reduce_3d_phases,
    "replicated_shift": _replicated_shift_phases,
    "gather_scatter": _gather_scatter_phases,
    "alltoall": _alltoall_phases,
}


def schedule_transfer_bound(pattern: CollectivePattern,
                            grid: Sequence[int]) -> int:
    """Upper bound on the total transfer count of ``pattern``'s packed
    schedule on ``grid``, in O(1) — without building it.

    The bound is the exact pre-dedup count each builder emits before
    :func:`_phase` drops same-processor transfers, so the real schedule
    is never larger. ``SimulatedTimeCostModel`` consults this to reject
    candidate grids whose schedule would be prohibitively large to even
    materialize (a skewed panel grid at 100k+ procs runs to hundreds of
    millions of transfers) before paying the build. Kept adjacent to
    ``_BUILDERS`` so formula and builder evolve together; a property
    test asserts bound >= the built schedule's ``n_transfers`` for every
    registry pattern.
    """
    grid = tuple(int(g) for g in grid)
    total = int(np.prod(grid)) if grid else 0
    kind = pattern.kind
    if kind == "halo":
        return 2 * sum(1 for g in grid if g > 1) * total
    if kind == "shift":
        return 2 * max(grid[0] - 1, 0) * total
    if kind == "panel_broadcast":
        pr, pc = grid
        return max(pr, pc) * (pr * (pc - 1) + pc * (pr - 1))
    if kind == "bcast_reduce_3d":
        q1, q2, q3 = grid
        return q1 * q3 * (q2 - 1) + q2 * q3 * (q1 - 1) + q1 * q2 * (q3 - 1)
    if kind == "replicated_shift":
        q, _, c = grid
        shifts = 2 * max(q // max(c, 1) - 1, 0) * total
        repl = 2 * q * q * max(c - 1, 0)     # replAB bcast + reduceC
        return shifts + repl
    if kind == "gather_scatter":
        # 2(p-1) ring rounds, but every round shares one endpoint array
        # (see _ring_phases), so the packed schedule holds two unique
        # slabs of p transfers each.
        return 2 * total
    if kind == "alltoall":
        return total * total
    raise ValueError(
        f"no transfer bound for pattern kind {pattern.kind!r}; "
        f"known: {sorted(_BUILDERS)}"
    )


# --------------------------------------------------------- packed expansion
def _hashable(v):
    if isinstance(v, (list, tuple)):
        return tuple(_hashable(x) for x in v)
    if isinstance(v, np.ndarray):
        return (v.shape, str(v.dtype), v.tobytes())
    if isinstance(v, dict):
        return tuple(sorted((k, _hashable(x)) for k, x in v.items()))
    return v


def _pattern_key(pattern: CollectivePattern) -> tuple:
    return (pattern.kind,
            tuple(sorted((k, _hashable(v)) for k, v in pattern.params.items())))


def _memo_put(cache: dict, key, value, maxsize: int, stat: str):
    cache[key] = value
    while len(cache) > maxsize:
        cache.pop(next(iter(cache)))
        _CACHE_STATS[stat + "_evictions"] += 1
    return value


def packed_schedule(pattern: CollectivePattern, grid: Sequence[int], *,
                    elem_bytes: int = 4) -> PackedSchedule:
    """One step's schedule for ``pattern`` on ``grid`` as packed tensors
    in tile-index space (assignment-independent; memoized by
    ``(pattern, grid, elem_bytes)``).

    Built by running the pattern builder against the identity placement,
    so the per-phase transfer order is exactly ``build_phases`` order —
    the float-accumulation contract behind the batched engine's 1e-9
    agreement with the event engine.
    """
    grid = tuple(int(g) for g in grid)
    key = (_pattern_key(pattern), grid, int(elem_bytes))
    hit = _PACKED_CACHE.get(key)
    if hit is not None:
        _CACHE_STATS["packed_hits"] += 1
        return hit
    _CACHE_STATS["packed_misses"] += 1
    try:
        builder = _BUILDERS[pattern.kind]
    except KeyError:
        raise ValueError(
            f"unknown collective pattern {pattern.kind!r}; "
            f"known: {sorted(_BUILDERS)}"
        ) from None
    identity = np.arange(int(np.prod(grid)), dtype=np.int64).reshape(grid)
    phases = builder(pattern, grid, identity, elem_bytes)
    # Collapse phases with identical transfer sets (a ring's p-1 repeated
    # rounds, systolic shift repeats) into one unique slab each; pricing
    # runs per slab and broadcasts back over phase_map. Digests are
    # memoized by array identity — repeated rounds share their endpoint
    # arrays, so a p-round ring hashes its transfers once, not p times.
    arr_digests: dict[int, bytes] = {}

    def _digest(arr: np.ndarray) -> bytes:
        d = arr_digests.get(id(arr))
        if d is None:
            d = arr_digests[id(arr)] = arr.tobytes()
        return d

    slab_of: dict[tuple, int] = {}
    phase_map = np.empty(len(phases), dtype=np.int64)
    unique: list[Phase] = []
    for p, ph in enumerate(phases):
        digest = (_digest(ph.src), _digest(ph.dst), _digest(ph.nbytes))
        slab = slab_of.get(digest)
        if slab is None:
            slab = slab_of[digest] = len(unique)
            unique.append(ph)
        phase_map[p] = slab
    sizes = [ph.src.size for ph in unique]
    starts = np.zeros(len(unique) + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    if unique:
        src = np.concatenate([ph.src for ph in unique])
        dst = np.concatenate([ph.dst for ph in unique])
        nbytes = np.concatenate([ph.nbytes for ph in unique])
    else:
        src = np.empty(0, np.int64)
        dst = np.empty(0, np.int64)
        nbytes = np.empty(0, np.float64)
    phase_id = np.repeat(np.arange(len(unique), dtype=np.int64), sizes)
    fold_rep, fold_shift = _fold_metadata(grid, starts, src, dst, nbytes)
    _freeze(phase_map, starts, phase_id, src, dst, nbytes,
            fold_rep, fold_shift)
    packed = PackedSchedule(
        grid=grid,
        labels=tuple(ph.label for ph in phases),
        phase_map=phase_map,
        starts=starts, phase_id=phase_id, src=src, dst=dst, nbytes=nbytes,
        fold_rep=fold_rep, fold_shift=fold_shift,
    )
    return _memo_put(_PACKED_CACHE, key, packed, _PACKED_CACHE_MAX, "packed")


def _fold_metadata(grid: tuple[int, ...], starts: np.ndarray,
                   src: np.ndarray, dst: np.ndarray, nbytes: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Group the unique slabs into tile-translation classes.

    Two slabs are in one class when their transfer lists are equal
    elementwise up to a single per-axis wraparound translation of every
    endpoint (identical payloads, identical src->dst coordinate deltas,
    and src coordinates offset by one constant vector). A SUMMA panel
    broadcast's round-``r`` slab is the round-0 slab translated ``r``
    columns over, so hundreds of rounds collapse to a handful of
    classes; pricing-time symmetry checks then decide per candidate
    whether the translation is also a machine symmetry.
    """
    n_unique = int(starts.size) - 1
    rank = len(grid)
    fold_rep = np.arange(n_unique, dtype=np.int64)
    fold_shift = np.zeros((n_unique, rank), dtype=np.int64)
    if n_unique == 0 or src.size == 0:
        return fold_rep, fold_shift
    gridarr = np.asarray(grid, dtype=np.int64)
    sc = np.unravel_index(src, grid)
    dc = np.unravel_index(dst, grid)
    delta = [(d - s) % g for s, d, g in zip(sc, dc, gridarr)]
    # class key: payload bytes + coordinate deltas, both elementwise.
    classes: dict[tuple, list[int]] = {}
    for u in range(n_unique):
        lo, hi = int(starts[u]), int(starts[u + 1])
        if lo == hi:
            continue
        digest = (nbytes[lo:hi].tobytes(),
                  b"".join(d[lo:hi].tobytes() for d in delta))
        candidates = classes.setdefault(digest, [])
        for rep in candidates:
            rlo = int(starts[rep])
            off = [(s[lo] - s[rlo]) % g for s, g in zip(sc, gridarr)]
            if all(((s[lo:hi] - s[rlo:rlo + hi - lo] - o) % g == 0).all()
                   for s, o, g in zip(sc, off, gridarr)):
                fold_rep[u] = rep
                fold_shift[u] = off
                break
        else:
            candidates.append(u)
    return fold_rep, fold_shift


def expand_packed(packed: PackedSchedule, assignment: np.ndarray
                  ) -> list[Phase]:
    """Materialize a packed schedule against a concrete tile->processor
    assignment (one gather; local transfers re-dropped for non-bijective
    placements)."""
    flat = _assignment(packed.grid, assignment).reshape(-1)
    src, dst = flat[packed.src], flat[packed.dst]
    starts = packed.starts
    slabs = [
        _phase("", src[starts[u]:starts[u + 1]], dst[starts[u]:starts[u + 1]],
               packed.nbytes[starts[u]:starts[u + 1]])
        for u in range(packed.n_unique)
    ]
    return [
        Phase(packed.labels[p], ph.src, ph.dst, ph.nbytes)
        for p, ph in ((p, slabs[packed.phase_map[p]])
                      for p in range(packed.n_phases))
    ]


def build_phases(pattern: CollectivePattern, grid: Sequence[int],
                 assignment: np.ndarray, *, elem_bytes: int = 4
                 ) -> list[Phase]:
    """One step's communication schedule for ``pattern`` under the exact
    tile->processor ``assignment`` (shape == ``grid``). Memoized by
    ``(pattern, grid, assignment digest)`` on top of the packed
    tile-space expansion."""
    grid = tuple(int(g) for g in grid)
    flat = _assignment(grid, assignment).reshape(-1)
    key = (_pattern_key(pattern), grid, int(elem_bytes), flat.tobytes())
    hit = _PHASES_CACHE.get(key)
    if hit is not None:
        _CACHE_STATS["phases_hits"] += 1
        return list(hit)
    _CACHE_STATS["phases_misses"] += 1
    packed = packed_schedule(pattern, grid, elem_bytes=elem_bytes)
    phases = expand_packed(packed, flat.reshape(grid))
    _memo_put(_PHASES_CACHE, key, tuple(phases), _PHASES_CACHE_MAX, "phases")
    return phases


def clear_caches() -> None:
    """Drop every memoized schedule — the two FIFO memos and the three
    phase-shape ``lru_cache``s — plus every registered auxiliary cache
    (the JAX backend's compiled ``_ScheduleExport``s, the price cache's
    in-memory tables), and zero ``cache_stats()`` counters.

    Rebuilds after a clear are bit-identical (the builders are pure
    functions of their keys, the price cache reloads from disk); test
    fixtures and benchmarks call this to isolate timings, exercise cold
    paths, and reclaim memory between tuning runs.
    """
    _PACKED_CACHE.clear()
    _PHASES_CACHE.clear()
    _ring_phases.cache_clear()
    _tree_bcast_phases.cache_clear()
    _tree_rounds.cache_clear()
    for k in _CACHE_STATS:
        _CACHE_STATS[k] = 0
    for clear_fn, _ in _EXTRA_CACHES.values():
        clear_fn()


def schedule_cache_clear() -> None:
    """Back-compat alias of :func:`clear_caches`."""
    clear_caches()


def cache_stats() -> dict:
    """Sizes, bounds, and hit/miss/eviction counters of every schedule
    cache (a snapshot; mutating the returned dict changes nothing)."""
    stats = dict(_CACHE_STATS)
    stats["packed_size"] = len(_PACKED_CACHE)
    stats["packed_max"] = _PACKED_CACHE_MAX
    stats["phases_size"] = len(_PHASES_CACHE)
    stats["phases_max"] = _PHASES_CACHE_MAX
    for name, fn in (("ring_phases", _ring_phases),
                     ("tree_bcast_phases", _tree_bcast_phases),
                     ("tree_rounds", _tree_rounds)):
        info = fn.cache_info()
        stats[name] = {"hits": info.hits, "misses": info.misses,
                       "size": info.currsize, "max": info.maxsize}
    for name, (_, stats_fn) in _EXTRA_CACHES.items():
        stats[name] = dict(stats_fn())
    return stats


__all__ = [
    "CollectivePattern",
    "PackedSchedule",
    "Phase",
    "allreduce",
    "alltoall",
    "build_phases",
    "cache_stats",
    "clear_caches",
    "expand_packed",
    "packed_schedule",
    "register_cache",
    "ring_allgather",
    "ring_allreduce",
    "ring_reduce_scatter",
    "schedule_cache_clear",
    "schedule_transfer_bound",
    "tree_allreduce",
    "tree_broadcast",
    "tree_reduce",
]
