"""Collective communication schedules for the patterns the nine apps emit.

Every builder turns one *logical* collective into a list of
:class:`Phase` objects — sets of point-to-point transfers that run
concurrently, with phases executing in order. Crucially the endpoints are
**physical processor ids taken from the mapper's assignment grid**, so
tile->processor placement (and therefore node-crossing) is exact, not
averaged: two mappers with identical communication *volume* produce
different schedules when one keeps neighbours on a node and the other
scatters them round-robin.

Patterns (paper Sec. 6 workloads + the transpose/MoE all-to-all):

  ``halo``              face exchange with each grid neighbour (stencil,
                        PENNANT; per-axis wraparound matches the tuner's
                        locality metric)
  ``shift``             systolic ring shifts of A/B tiles (Cannon)
  ``panel_broadcast``   per-round row/column panel broadcasts
                        (SUMMA, PUMMA)
  ``bcast_reduce_3d``   operand broadcasts + C reduction along the grid
                        axes (Johnson, COSMA)
  ``replicated_shift``  2.5D: replicate over c, shifted rounds, reduce
                        over c (Solomonik)
  ``gather_scatter``    ring all-gather(V) + ring reduce-scatter(Q)
                        (circuit)
  ``alltoall``          pairwise exchange (transpose / MoE dispatch)

Primitive schedules (ring all-gather / reduce-scatter, ring or binomial
tree all-reduce, binomial broadcast/reduce) are exposed for new patterns;
``build_phases`` dispatches a declared :class:`CollectivePattern` for an
application grid + assignment. See docs/simulator.md for how to add one.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Phase:
    """One set of concurrent point-to-point transfers."""

    label: str
    src: np.ndarray           # flat physical processor ids
    dst: np.ndarray
    nbytes: np.ndarray        # per-transfer payload bytes

    @property
    def total_bytes(self) -> float:
        return float(np.sum(self.nbytes))


@dataclasses.dataclass(frozen=True)
class CollectivePattern:
    """An application's declared communication pattern + static parameters.

    ``params`` holds problem constants (matrix dims, iteration lengths,
    halo field counts, ...); everything grid-dependent is derived inside
    the builder so one declaration scales with the processor count.
    """

    kind: str
    params: dict = dataclasses.field(default_factory=dict)


def _phase(label: str, transfers: Sequence[tuple[int, int, float]]) -> Phase:
    """Build a Phase, dropping same-processor (local) transfers."""
    keep = [(s, d, b) for s, d, b in transfers if s != d]
    if not keep:
        return Phase(label, np.empty(0, np.int64), np.empty(0, np.int64),
                     np.empty(0, np.float64))
    src, dst, nbytes = zip(*keep)
    return Phase(label, np.asarray(src, np.int64), np.asarray(dst, np.int64),
                 np.asarray(nbytes, np.float64))


# ----------------------------------------------------------- primitive rings
def ring_allgather(group: Sequence[int], total_bytes: float,
                   label: str = "all_gather") -> list[Phase]:
    """Ring all-gather of ``total_bytes`` split over the group: p-1 rounds,
    each member forwarding one shard (bytes/p) to its ring successor."""
    group = [int(g) for g in group]
    p = len(group)
    if p <= 1:
        return []
    shard = total_bytes / p
    return [
        _phase(f"{label}[{r}]",
               [(group[i], group[(i + 1) % p], shard) for i in range(p)])
        for r in range(p - 1)
    ]


def ring_reduce_scatter(group: Sequence[int], total_bytes: float,
                        label: str = "reduce_scatter") -> list[Phase]:
    """Same wire schedule as the all-gather ring, reducing as it goes."""
    return ring_allgather(group, total_bytes, label=label)


def ring_allreduce(group: Sequence[int], total_bytes: float,
                   label: str = "all_reduce") -> list[Phase]:
    """Reduce-scatter + all-gather: 2(p-1) rounds of bytes/p shards."""
    return (ring_reduce_scatter(group, total_bytes, label=f"{label}/rs")
            + ring_allgather(group, total_bytes, label=f"{label}/ag"))


# ------------------------------------------------------------ primitive trees
def _tree_rounds(p: int) -> list[list[tuple[int, int]]]:
    """Binomial doubling rounds as (src_index, dst_index) pairs in a group."""
    rounds: list[list[tuple[int, int]]] = []
    have = 1
    while have < p:
        rounds.append([(i, i + have) for i in range(min(have, p - have))])
        have *= 2
    return rounds


def concurrent_tree_broadcast(groups: Sequence[Sequence[int]], nbytes: float,
                              label: str = "bcast") -> list[Phase]:
    """Binomial broadcasts from each group's first member, with all groups
    progressing in lockstep — one congestion-priced phase per tree round,
    so disjoint groups (e.g. the rows of a SUMMA grid) genuinely overlap."""
    groups = [[int(g) for g in grp] for grp in groups if len(grp) > 1]
    if not groups:
        return []
    phases: list[Phase] = []
    for r, rnd in enumerate(_tree_rounds(max(len(g) for g in groups))):
        sends = [
            (grp[i], grp[j], nbytes)
            for grp in groups for i, j in rnd if j < len(grp)
        ]
        phases.append(_phase(f"{label}[{r}]", sends))
    return phases


def concurrent_tree_reduce(groups: Sequence[Sequence[int]], nbytes: float,
                           label: str = "reduce") -> list[Phase]:
    """The broadcast wire schedule run backwards: reduce to each group's
    first member, all groups in lockstep."""
    return [
        Phase(ph.label, ph.dst, ph.src, ph.nbytes)
        for ph in reversed(concurrent_tree_broadcast(groups, nbytes, label))
    ]


def tree_broadcast(group: Sequence[int], nbytes: float,
                   label: str = "bcast") -> list[Phase]:
    """Binomial-tree broadcast from group[0]: ceil(log2 p) doubling rounds."""
    return concurrent_tree_broadcast([group], nbytes, label=label)


def tree_reduce(group: Sequence[int], nbytes: float,
                label: str = "reduce") -> list[Phase]:
    """Binomial-tree reduction to group[0] (the broadcast run backwards)."""
    return concurrent_tree_reduce([group], nbytes, label=label)


def tree_allreduce(group: Sequence[int], nbytes: float,
                   label: str = "all_reduce") -> list[Phase]:
    """Reduce-to-root + broadcast: 2*ceil(log2 p) rounds of full payloads.

    Cheaper than the ring for latency-bound (small) payloads; callers pick
    via :func:`allreduce`.
    """
    return (tree_reduce(group, nbytes, label=f"{label}/red")
            + tree_broadcast(group, nbytes, label=f"{label}/bc"))


def allreduce(group: Sequence[int], total_bytes: float, *,
              alpha: float = 1e-6, beta: float = 1e11,
              label: str = "all_reduce") -> list[Phase]:
    """Ring-or-tree all-reduce, picking the cheaper schedule by the
    uncontended alpha-beta estimate (rings win on bandwidth, trees on
    latency)."""
    p = len(group)
    if p <= 1:
        return []
    import math

    rounds_tree = 2 * math.ceil(math.log2(p))
    t_ring = 2 * (p - 1) * (alpha + (total_bytes / p) / beta)
    t_tree = rounds_tree * (alpha + total_bytes / beta)
    if t_tree < t_ring:
        return tree_allreduce(group, total_bytes, label=label)
    return ring_allreduce(group, total_bytes, label=label)


def alltoall(group: Sequence[int], bytes_per_pair: float,
             label: str = "all_to_all") -> list[Phase]:
    """Full pairwise exchange in one congestion-priced phase: every member
    sends ``bytes_per_pair`` to every other (transpose / MoE dispatch)."""
    group = [int(g) for g in group]
    sends = [
        (s, d, bytes_per_pair)
        for s in group for d in group if s != d
    ]
    return [_phase(label, sends)] if sends else []


# ------------------------------------------------------------- grid utilities
def _assignment(grid: Sequence[int], assignment: np.ndarray) -> np.ndarray:
    a = np.asarray(assignment, dtype=np.int64)
    grid = tuple(int(g) for g in grid)
    if a.shape != grid:
        raise ValueError(
            f"assignment shape {a.shape} does not match tile grid {grid}"
        )
    return a


def _shift_phases(assign: np.ndarray, axis: int, step: int, nbytes: float,
                  label: str) -> Phase:
    """Every tile sends ``nbytes`` to the tile ``step`` away along ``axis``
    (wraparound): the systolic / halo neighbour structure."""
    dst = np.roll(assign, -step, axis=axis)
    return _phase(label, list(zip(assign.reshape(-1).tolist(),
                                  dst.reshape(-1).tolist(),
                                  [nbytes] * assign.size)))


def _axis_groups(assign: np.ndarray, axis: int) -> list[list[int]]:
    """Processor groups along one grid axis (all other coordinates fixed)."""
    moved = np.moveaxis(assign, axis, -1)
    return [list(map(int, row)) for row in moved.reshape(-1, assign.shape[axis])]


# ------------------------------------------------------------ pattern builders
def _halo_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                 assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    lengths = pattern.params["lengths"]
    fields = int(pattern.params.get("fields", 1))
    if len(lengths) != len(grid):
        raise ValueError(
            f"halo grid rank {len(grid)} != iteration rank {len(lengths)}"
        )
    phases = []
    for axis in range(len(grid)):
        if grid[axis] == 1:
            continue
        face_elems = 1.0
        for m in range(len(grid)):
            if m != axis:
                face_elems *= lengths[m] / grid[m]
        face_bytes = fields * face_elems * elem_bytes
        for step, side in ((1, "+"), (-1, "-")):
            phases.append(_shift_phases(
                assign, axis, step, face_bytes, f"halo[ax{axis}{side}]"))
    return phases


def _shift_pattern_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                          assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    m, n, k = (pattern.params[key] for key in ("m", "n", "k"))
    if len(grid) != 2 or grid[0] != grid[1]:
        raise ValueError(f"systolic shift needs a square 2D grid, got {grid}")
    q = grid[0]
    tile_a = (m / q) * (k / q) * elem_bytes
    tile_b = (k / q) * (n / q) * elem_bytes
    phases = []
    for r in range(max(q - 1, 0)):
        phases.append(_shift_phases(assign, 1, 1, tile_a, f"shiftA[{r}]"))
        phases.append(_shift_phases(assign, 0, 1, tile_b, f"shiftB[{r}]"))
    return phases


def _panel_broadcast_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                            assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    m, n, k = (pattern.params[key] for key in ("m", "n", "k"))
    if len(grid) != 2:
        raise ValueError(f"panel broadcast needs a 2D grid, got {grid}")
    pr, pc = grid
    rounds = max(pr, pc)
    panel_a = (m / pr) * (k / rounds) * elem_bytes   # A panel along the row
    panel_b = (k / rounds) * (n / pc) * elem_bytes   # B panel down the column
    phases: list[Phase] = []
    for r in range(rounds):
        # Round r: column (r % pc) roots broadcast A along each row, row
        # (r % pr) roots broadcast B along each column; all rows (resp.
        # columns) progress concurrently.
        row_groups = [
            [int(assign[row, (r + j) % pc]) for j in range(pc)]
            for row in range(pr)
        ]
        col_groups = [
            [int(assign[(r + i) % pr, col]) for i in range(pr)]
            for col in range(pc)
        ]
        phases.extend(concurrent_tree_broadcast(
            row_groups, panel_a, label=f"bcastA[{r}]"))
        phases.extend(concurrent_tree_broadcast(
            col_groups, panel_b, label=f"bcastB[{r}]"))
    return phases


def _bcast_reduce_3d_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                            assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    m, n, k = (pattern.params[key] for key in ("m", "n", "k"))
    if len(grid) != 3:
        raise ValueError(f"3D bcast+reduce needs a 3D grid, got {grid}")
    q1, q2, q3 = grid
    tile_a = (m / q1) * (k / q3) * elem_bytes
    tile_b = (k / q3) * (n / q2) * elem_bytes
    tile_c = (m / q1) * (n / q2) * elem_bytes
    # A(i, :, l) is broadcast along the j axis, B(:, j, l) along i, and the
    # C(i, j, :) partials reduce along the k axis — Johnson's 3D schedule,
    # every group along an axis progressing concurrently.
    return (
        concurrent_tree_broadcast(_axis_groups(assign, 1), tile_a, "bcastA")
        + concurrent_tree_broadcast(_axis_groups(assign, 0), tile_b, "bcastB")
        + concurrent_tree_reduce(_axis_groups(assign, 2), tile_c, "reduceC")
    )


def _replicated_shift_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                             assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    m, n, k = (pattern.params[key] for key in ("m", "n", "k"))
    if len(grid) != 3 or grid[0] != grid[1]:
        raise ValueError(f"2.5D shift needs a (q, q, c) grid, got {grid}")
    q, _, c = grid
    tile_a = (m / q) * (k / q) * elem_bytes
    tile_b = (k / q) * (n / q) * elem_bytes
    tile_c = (m / q) * (n / q) * elem_bytes
    phases: list[Phase] = []
    if c > 1:
        # Replicate the initial A/B layer over the c axis.
        phases.extend(concurrent_tree_broadcast(
            _axis_groups(assign, 2), tile_a + tile_b, "replAB"))
    for r in range(max(q // max(c, 1) - 1, 0)):
        # All c layers shift concurrently; the shift over the full 3D
        # assignment rolls only the (q, q) plane coordinates.
        phases.append(_shift_phases(assign, 1, 1, tile_a, f"shiftA[{r}]"))
        phases.append(_shift_phases(assign, 0, 1, tile_b, f"shiftB[{r}]"))
    if c > 1:
        phases.extend(concurrent_tree_reduce(
            _axis_groups(assign, 2), tile_c, "reduceC"))
    return phases


def _gather_scatter_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                           assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    if len(grid) != 1:
        raise ValueError(f"gather/scatter needs a 1D piece grid, got {grid}")
    npp = pattern.params["nodes_per_piece"]
    discount = float(pattern.params.get("discount", 1.0))
    procs = [int(p) for p in assign.reshape(-1)]
    total = discount * npp * len(procs) * elem_bytes
    return (ring_allgather(procs, total, label="gatherV")
            + ring_reduce_scatter(procs, total, label="scatterQ"))


def _alltoall_phases(pattern: CollectivePattern, grid: tuple[int, ...],
                     assign: np.ndarray, elem_bytes: int) -> list[Phase]:
    per_pair = pattern.params["elems_per_pair"] * elem_bytes
    procs = [int(p) for p in assign.reshape(-1)]
    return alltoall(procs, per_pair)


_BUILDERS = {
    "halo": _halo_phases,
    "shift": _shift_pattern_phases,
    "panel_broadcast": _panel_broadcast_phases,
    "bcast_reduce_3d": _bcast_reduce_3d_phases,
    "replicated_shift": _replicated_shift_phases,
    "gather_scatter": _gather_scatter_phases,
    "alltoall": _alltoall_phases,
}


def build_phases(pattern: CollectivePattern, grid: Sequence[int],
                 assignment: np.ndarray, *, elem_bytes: int = 4
                 ) -> list[Phase]:
    """One step's communication schedule for ``pattern`` under the exact
    tile->processor ``assignment`` (shape == ``grid``)."""
    try:
        builder = _BUILDERS[pattern.kind]
    except KeyError:
        raise ValueError(
            f"unknown collective pattern {pattern.kind!r}; "
            f"known: {sorted(_BUILDERS)}"
        ) from None
    grid = tuple(int(g) for g in grid)
    assign = _assignment(grid, assignment)
    return builder(pattern, grid, assign, elem_bytes)


__all__ = [
    "CollectivePattern",
    "Phase",
    "allreduce",
    "alltoall",
    "build_phases",
    "ring_allgather",
    "ring_allreduce",
    "ring_reduce_scatter",
    "tree_allreduce",
    "tree_broadcast",
    "tree_reduce",
]
