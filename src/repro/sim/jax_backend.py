"""Accelerator-resident batched pricing: the jit/vmap congestion engine.

:class:`JaxBatchSimulator` is the JAX port of ``repro.sim.batch``'s hot
path. Where the NumPy engine gathers ``candidates x phases x ports``
endpoint arrays on the host and prices them through
``Topology.bucket_times``, this engine compiles the whole pricing of a
candidate stack — endpoint gather, crossing-level stride arithmetic,
per-level congestion reduction, and the slab maxima — into one XLA
program with static shapes per (pattern, grid, machine), so a beam
prices with no host<->device round trips inside the loop. On CPU the
jit still wins on the workload the ASI search loops generate (arbitrary
proposer placements, where the NumPy engine's symmetry-folding and
incremental shortcuts cannot fire); on an accelerator the same program
runs device-resident.

Two compiled formulations, chosen per schedule on the host:

**Dense gather** (``mode="dense"``) — for schedules whose (slab,
endpoint) pairs are unique (each tile sends and receives at most once
per slab: trees, rings, halos, shifted panels — everything the registry
builders emit) and bijective candidate rows. The schedule exports
candidate-independent matrices ``M[slab, tile] -> transfer id``
(sentinel for absent), so a candidate's per-port loads are *pure
gathers*: permute columns by the inverse assignment, look up per-level
masked weights, and reduce — per-row segment sums over each level's
``stride`` processors, then the port max. No scatter appears anywhere,
which is what makes XLA:CPU fast here (its scatter lowers to a serial
loop; gathers and contiguous reductions vectorize). The per-level alpha
term folds into the byte weight exactly: ``msgs*alpha + load/beta ==
sum(nbytes + alpha*beta)/beta``.

**Segment scatter** (``mode="scatter"``) — the general fallback (repeat
endpoints per slab, non-bijective rows, or a dense table past the cell
ceiling): the ``bucket_times`` formulation as masked ``segment-sum``
scatter-adds into compact per-level (direction, slab, port) tables with
out-of-bounds drop masking.

The per-level reduction of the dense mode is also available as a Pallas
kernel (``repro.kernels.segment_reduce``, ``use_pallas=True``) — on CPU
it runs in interpret mode as a correctness path, on TPU it lowers to
Mosaic.

``dtype="float64"`` (the default, run under ``jax.experimental
.enable_x64``) reproduces the NumPy reference to ~1e-15 relative — the
registry-wide <=1e-6 parity gate in ``benchmarks/sim_eval.py`` runs in
float64. ``dtype="float32"`` halves bandwidth but accumulates port loads
in single precision: expect ~1e-5 relative drift on large slabs, fine
for search ranking, NOT enough for the parity gate (see
docs/simulator.md "Backends").

Folding flags are accepted for API parity and ignored: the fold and
incremental shortcuts *copy* dense prices bit-for-bit by construction,
so always pricing dense returns identical values — the flags only trade
speed, and on this engine the compiled dense pass is the fast path.
"""
from __future__ import annotations

import dataclasses
import weakref
from contextlib import nullcontext
from typing import Sequence

import numpy as np

from repro.core.machine import DegradedMachine, MachineSpec
from repro.sim.batch import BatchSimulator, ReadyPrices, _count
from repro.sim.collectives import (
    CollectivePattern,
    PackedSchedule,
    packed_schedule,
    register_cache,
)
from repro.sim.topology import Topology

try:  # pragma: no cover - exercised only where jax is absent
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64
except Exception:  # noqa: BLE001 - any import failure means "no jax"
    jax = None
    jnp = None
    enable_x64 = None

#: Cell ceiling for the dense-gather mode's (n_unique x ntiles) lookup
#: tables; schedules past it (or with repeated per-slab endpoints) use
#: the segment-scatter formulation.
_DENSE_CELLS_MAX = 1 << 25

#: Per-pricing-call device working-set budget (elements); candidate
#: stacks are chunked so ``chunk * cells_per_candidate`` stays under it.
_MAX_DEVICE_ELEMS = 1 << 24

_DTYPES = ("float64", "float32")


def have_jax() -> bool:
    """True when the JAX backend can be constructed in this process."""
    return jax is not None


def platform_info() -> dict:
    """What this process's JAX runtime resolved to: platform name, device
    count and kinds, and whether the Pallas kernel would run in interpret
    mode (it does on CPU — a correctness path, slower than the plain jit).
    ``repro.apps.run --backend jax`` prints this so a CPU fallback is
    never silent."""
    if jax is None:
        return {"available": False}
    devices = jax.devices()
    platform = jax.default_backend()
    return {
        "available": True,
        "platform": platform,
        "device_count": len(devices),
        "devices": [d.device_kind for d in devices],
        "pallas_interpret": platform == "cpu",
    }


def enable_compilation_cache(path: str) -> None:
    """Point JAX's persistent compilation cache at ``path`` so repeat
    tunes in fresh processes skip XLA compilation entirely. Thresholds
    are dropped to zero because this engine's programs are many and
    individually quick to compile — exactly the population the default
    min-compile-time filter would decline to cache."""
    if jax is None:  # pragma: no cover - guarded by have_jax() upstream
        return
    jax.config.update("jax_compilation_cache_dir", str(path))
    for knob, val in (("jax_persistent_cache_min_entry_size_bytes", -1),
                      ("jax_persistent_cache_min_compile_time_secs", 0.0)):
        try:
            jax.config.update(knob, val)
        except Exception:  # noqa: BLE001 - knob absent on this jax version
            pass


def _x64(dtype: str):
    return enable_x64() if dtype == "float64" else nullcontext()


def _pow2_floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


def _rows_bijective(a: np.ndarray, nprocs: int) -> bool:
    """True when every stack row is a tile->processor permutation (the
    precondition of the dense-gather mode's inverse-assignment trick)."""
    if a.shape[1] != nprocs or a.size == 0:
        return False
    if int(a.min()) < 0 or int(a.max()) >= nprocs:
        return False
    seen = np.zeros(a.shape, dtype=bool)
    seen[np.arange(a.shape[0])[:, None], a] = True
    return bool(seen.all())


class _ScheduleExport:
    """Device-ready constants of one (PackedSchedule, Topology) pair.

    Host-side numpy views in canonical dtypes (int32 endpoints/slab ids,
    float64 payloads) plus, in dense mode, the candidate-independent
    ``M[slab, tile] -> transfer id`` lookup matrices. Compiled pricing
    callables are built lazily per (mode, dtype, use_pallas) and cached
    here; the export itself is cached on the schedule object, so its
    lifetime (and its jit cache's) tracks the memoized schedule's.
    """

    def __init__(self, sched: PackedSchedule, topo: Topology) -> None:
        self.u = sched.n_unique
        self.T = sched.n_transfers
        self.ntiles = int(np.prod(sched.grid))
        self.strides = tuple(int(s) for s in topo.port_strides)
        self.nports = tuple(int(p) for p in topo.spec.level_ports)
        self.alphas = tuple(float(x) for x in topo.alphas)
        self.betas = tuple(float(x) for x in topo.betas)
        self.nprocs = topo.nprocs
        # Per-level port contention factors of the degraded machine, or
        # None when healthy (dead-proc checks stay host-side in
        # ``_dispatch_slabs`` — a masked proc is a refusal, not a price).
        degraded = topo.degraded
        if degraded is not None and degraded.contention is not None:
            self.cont = tuple(
                np.asarray(degraded.port_contention(lvl), dtype=np.float64)
                for lvl in range(len(topo.spec.shape))
            )
        else:
            self.cont = None
        self.src = sched.src.astype(np.int32)
        self.dst = sched.dst.astype(np.int32)
        self.slab = sched.phase_id.astype(np.int32)
        self.nbytes = np.asarray(sched.nbytes, dtype=np.float64)
        key = self.slab.astype(np.int64) * self.ntiles
        unique_endpoints = self.T == 0 or all(
            np.unique(key + e).size == self.T for e in (self.src, self.dst)
        )
        self.mode = (
            "dense"
            if unique_endpoints and self.ntiles == self.nprocs
            and self.u * self.ntiles <= _DENSE_CELLS_MAX
            else "scatter"
        )
        if self.mode == "dense":
            ids = np.arange(self.T, dtype=np.int32)
            self.Ms = np.full((self.u, self.ntiles), self.T, np.int32)
            self.Md = np.full((self.u, self.ntiles), self.T, np.int32)
            self.Ms[self.slab, self.src] = ids
            self.Md[self.slab, self.dst] = ids
        if max(2 * self.u * p for p in self.nports) >= 2 ** 31:
            raise ValueError(
                "schedule's congestion table exceeds int32 indexing; "
                "use the NumPy batch engine for this scale"
            )
        self._fns: dict = {}

    # ------------------------------------------------------------ chunking
    def chunk(self, mode: str) -> int:
        if mode == "dense":
            cells = 2 * self.u * self.ntiles
        else:
            cells = sum(2 * self.u * p for p in self.nports) + 4 * self.T
        return _pow2_floor(max(1, _MAX_DEVICE_ELEMS // max(cells, 1)))

    # ------------------------------------------------- compiled callables
    def fn(self, mode: str, dtype: str, use_pallas: bool,
           donate: bool = False):
        """The jitted pricing callable for one formulation. ``donate``
        hands the chunk's device input buffer to XLA for reuse — worth it
        only when a stack spans several chunks (each chunk's input is
        dead the moment its program launches) and only off-CPU (the CPU
        backend does not implement donation and warns)."""
        if use_pallas and self.cont is not None:
            # The Pallas tables fold alpha into one byte weight per
            # transfer; per-port contention needs the byte and alpha
            # terms reduced separately, so route contended machines
            # through the plain dense build (numerically identical).
            use_pallas = False
        key = (mode, dtype, use_pallas, donate)
        hit = self._fns.get(key)
        if hit is None:
            dt = jnp.float64 if dtype == "float64" else jnp.float32
            if mode == "dense":
                raw = (self._build_dense_pallas(dt) if use_pallas
                       else self._build_dense(dt))
            else:
                raw = self._build_scatter(dt)
            hit = jax.jit(raw, donate_argnums=(0,) if donate else ())
            self._fns[key] = hit
        return hit

    def _level_masks(self, src, dst):
        """Per-level exactly-crossing masks from stride arithmetic:
        ``src // stride[L] != dst // stride[L]`` first differs at the
        crossing level and stays different inward."""
        masks = []
        outer = jnp.zeros(src.shape, dtype=bool)
        for s in self.strides:
            diff = (src // s) != (dst // s)
            masks.append(diff & ~outer)
            outer = outer | diff
        return masks

    def _build_dense(self, dt):
        exp = self

        def row(a_row):
            src = a_row[jnp.asarray(exp.src)]
            dst = a_row[jnp.asarray(exp.dst)]
            inv = jnp.zeros((exp.ntiles,), jnp.int32).at[a_row].set(
                jnp.arange(exp.ntiles, dtype=jnp.int32))
            nb = jnp.asarray(exp.nbytes, dtype=dt)
            zero = jnp.zeros((1,), dtype=dt)
            out = jnp.zeros((exp.u,), dtype=dt)
            masks = exp._level_masks(src, dst)
            for L, (stride, ports, al, be) in enumerate(
                    zip(exp.strides, exp.nports, exp.alphas, exp.betas)):
                cl = (jnp.asarray(exp.cont[L], dtype=dt)
                      if exp.cont is not None else None)
                if stride == 1:
                    # One message per (slab, port, direction): the slab
                    # time at this level is a pure segment-max of the
                    # per-transfer times; under contention the slower of
                    # the transfer's two ports sets its drain.
                    if cl is None:
                        t = al + nb / be
                    else:
                        t = al + nb * jnp.maximum(cl[src], cl[dst]) / be
                    t1 = jnp.concatenate(
                        [jnp.where(masks[L], t, 0.0), zero])
                    out = jnp.maximum(out, t1[jnp.asarray(exp.Ms)]
                                      .max(axis=1))
                else:
                    # Port loads by gather: column-permute M by the
                    # inverse assignment, look up masked byte weights
                    # (alpha folded in), sum each subtree's `stride`
                    # processors, max over ports, both directions.
                    if cl is None:
                        w = jnp.concatenate(
                            [jnp.where(masks[L], nb + al * be, 0.0), zero])
                        eg = (w[jnp.asarray(exp.Ms)[:, inv]]
                              .reshape(exp.u, ports, stride).sum(axis=2))
                        ing = (w[jnp.asarray(exp.Md)[:, inv]]
                               .reshape(exp.u, ports, stride).sum(axis=2))
                    else:
                        # Contention scales a port's *byte* drain but not
                        # its per-message alpha, so the folded weight
                        # splits: bytes (scaled per port after the
                        # segment sum) + alpha*beta (unscaled).
                        wb = jnp.concatenate(
                            [jnp.where(masks[L], nb, 0.0), zero])
                        wa = jnp.concatenate(
                            [jnp.where(masks[L], jnp.full_like(nb, al * be),
                                       0.0), zero])
                        Msi = jnp.asarray(exp.Ms)[:, inv]
                        Mdi = jnp.asarray(exp.Md)[:, inv]
                        eg = (wb[Msi].reshape(exp.u, ports, stride)
                              .sum(axis=2) * cl[None, :]
                              + wa[Msi].reshape(exp.u, ports, stride)
                              .sum(axis=2))
                        ing = (wb[Mdi].reshape(exp.u, ports, stride)
                               .sum(axis=2) * cl[None, :]
                               + wa[Mdi].reshape(exp.u, ports, stride)
                               .sum(axis=2))
                    out = jnp.maximum(
                        out,
                        jnp.maximum(eg.max(axis=1), ing.max(axis=1)) / be,
                    )
            return out

        return jax.vmap(row)

    def _build_dense_pallas(self, dt):
        """Dense mode with the per-level reduction routed through the
        Pallas segment-reduce kernel (tables materialize per chunk, then
        ``segment_rowmax`` reduces them; numerically identical on CPU
        interpret mode, Mosaic-lowered on TPU)."""
        from repro.kernels import ops as kops

        exp = self

        def tables(a_row):
            src = a_row[jnp.asarray(exp.src)]
            dst = a_row[jnp.asarray(exp.dst)]
            inv = jnp.zeros((exp.ntiles,), jnp.int32).at[a_row].set(
                jnp.arange(exp.ntiles, dtype=jnp.int32))
            nb = jnp.asarray(exp.nbytes, dtype=dt)
            zero = jnp.zeros((1,), dtype=dt)
            masks = exp._level_masks(src, dst)
            tabs = []
            for L, (stride, al, be) in enumerate(
                    zip(exp.strides, exp.alphas, exp.betas)):
                if stride == 1:
                    t1 = jnp.concatenate(
                        [jnp.where(masks[L], al + nb / be, 0.0), zero])
                    tabs.append(t1[jnp.asarray(exp.Ms)])
                else:
                    w = jnp.concatenate(
                        [jnp.where(masks[L], nb + al * be, 0.0), zero])
                    tabs.append(w[jnp.asarray(exp.Ms)[:, inv]])
                    tabs.append(w[jnp.asarray(exp.Md)[:, inv]])
            return tuple(tabs)

        batched = jax.vmap(tables)

        def fn(a):
            tabs = batched(a)
            n = a.shape[0]
            out = jnp.zeros((n, exp.u), dtype=dt)
            i = 0
            for stride, be in zip(exp.strides, exp.betas):
                if stride == 1:
                    red = kops.segment_rowmax(
                        tabs[i].reshape(n * exp.u, exp.ntiles), 1)
                    out = jnp.maximum(out, red.reshape(n, exp.u))
                    i += 1
                else:
                    for _ in range(2):
                        red = kops.segment_rowmax(
                            tabs[i].reshape(n * exp.u, exp.ntiles), stride)
                        out = jnp.maximum(out,
                                          red.reshape(n, exp.u) / be)
                        i += 1
            return out

        return fn

    def _build_scatter(self, dt):
        """The general formulation: masked segment-sum scatter-adds into
        per-level (direction, slab, port) tables, out-of-bounds indices
        dropped. Handles repeated per-slab endpoints (alltoall) and
        non-bijective placements."""
        exp = self

        def row(a_row):
            src = a_row[jnp.asarray(exp.src)]
            dst = a_row[jnp.asarray(exp.dst)]
            slab = jnp.asarray(exp.slab)
            nb = jnp.asarray(exp.nbytes, dtype=dt)
            out = jnp.zeros((exp.u,), dtype=dt)
            masks = exp._level_masks(src, dst)
            for L, (stride, ports, al, be) in enumerate(
                    zip(exp.strides, exp.nports, exp.alphas, exp.betas)):
                oob = jnp.int32(2 * exp.u * ports)
                base = slab * ports
                cell = jnp.concatenate([
                    jnp.where(masks[L], base + src // stride, oob),
                    jnp.where(masks[L], oob // 2 + base + dst // stride,
                              oob),
                ])
                if exp.cont is None:
                    w = jnp.where(masks[L], nb + al * be, 0.0)
                    ws = jnp.concatenate([w, w])
                else:
                    # Scale each transfer's byte load by its port's
                    # contention factor per direction; alpha unscaled.
                    cl = jnp.asarray(exp.cont[L], dtype=dt)
                    ws = jnp.concatenate([
                        jnp.where(masks[L],
                                  nb * cl[src // stride] + al * be, 0.0),
                        jnp.where(masks[L],
                                  nb * cl[dst // stride] + al * be, 0.0),
                    ])
                tab = jnp.zeros((2 * exp.u * ports,), dtype=dt).at[cell].add(
                    ws, mode="drop")
                out = jnp.maximum(
                    out,
                    (tab / be).reshape(2, exp.u, ports).max(axis=(0, 2)),
                )
            return out

        return jax.vmap(row)


#: Live schedules carrying a ``_jax_exports`` cache, held weakly (by
#: ``id`` — PackedSchedule's ndarray fields make it unhashable, ruling
#: out a WeakSet; dead ids are pruned automatically and a recycled id
#: simply overwrites) plus hit/miss counters, so ``repro.sim
#: .collectives.cache_stats()`` can report the compiled-program
#: population and ``clear_caches()`` can reclaim it.
_EXPORT_HOSTS: "weakref.WeakValueDictionary[int, PackedSchedule]" = \
    weakref.WeakValueDictionary()
_EXPORT_STATS = {"hits": 0, "misses": 0}


def _exports_clear() -> None:
    for sched in list(_EXPORT_HOSTS.values()):
        cache = getattr(sched, "_jax_exports", None)
        if cache:
            cache.clear()
    for key in _EXPORT_STATS:
        _EXPORT_STATS[key] = 0


def _exports_stats() -> dict:
    size = sum(len(getattr(sched, "_jax_exports", ()) or ())
               for sched in _EXPORT_HOSTS.values())
    return {"size": size, **_EXPORT_STATS}


register_cache("jax_exports", _exports_clear, _exports_stats)


def _export_for(sched: PackedSchedule, topo: Topology) -> _ScheduleExport:
    """The (schedule, topology) export, cached on the schedule object so
    compiled programs are shared by every engine pricing that schedule
    and die with it."""
    cache = getattr(sched, "_jax_exports", None)
    if cache is None:
        cache = {}
        object.__setattr__(sched, "_jax_exports", cache)
        _EXPORT_HOSTS[id(sched)] = sched
    key = (topo.spec, topo.alphas, topo.betas, topo.degraded)
    hit = cache.get(key)
    if hit is None:
        _EXPORT_STATS["misses"] += 1
        hit = cache[key] = _ScheduleExport(sched, topo)
    else:
        _EXPORT_STATS["hits"] += 1
    return hit


_SHARDINGS: dict = {}


def _device_put_chunk(blk: np.ndarray):
    """Stage one candidate chunk on device. Multi-device hosts shard the
    leading (candidate) axis — rows are independent under ``vmap``, so
    jit partitions the whole program with no cross-device traffic; chunk
    shapes are powers of two, so any power-of-two device count divides
    them. Uneven or single-device cases fall back to one replica."""
    devices = jax.devices()
    nd = len(devices)
    if nd > 1 and blk.shape[0] % nd == 0:
        sharding = _SHARDINGS.get(nd)
        if sharding is None:
            mesh = jax.sharding.Mesh(np.asarray(devices), ("candidates",))
            sharding = _SHARDINGS[nd] = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec("candidates"))
        return jax.device_put(blk, sharding)
    return jnp.asarray(blk)


@dataclasses.dataclass(frozen=True)
class JaxBatchSimulator(BatchSimulator):
    """The batched engine with device-compiled congestion pricing.

    Same contract as :class:`BatchSimulator` (stacks of tile->processor
    placements in, steady-state seconds out; ``fold``/``incremental``
    accepted but moot — see the module docstring); ``price_stacks``
    detects ``prices_independently`` and lets each stack run as its own
    compiled program instead of joining the host gather pass.
    """

    dtype: str = "float64"
    use_pallas: bool = False

    #: Each stack prices as one compiled program; do not concatenate
    #: into the NumPy congestion pass (checked by ``price_stacks``).
    prices_independently = True

    def __post_init__(self) -> None:
        super().__post_init__()
        if jax is None:
            raise RuntimeError(
                "the 'batched-jax' engine needs jax installed; use the "
                "NumPy batch engine (engine='batched') instead"
            )
        if self.dtype not in _DTYPES:
            raise ValueError(
                f"dtype must be one of {_DTYPES}, got {self.dtype!r}"
            )

    def phase_durations(self, assignments: np.ndarray, *,
                        fold: bool = True,
                        incremental: bool = True) -> np.ndarray:
        """(N, n_phases) congestion-priced phase times, the whole stack
        as chunked invocations of one compiled program. ``fold`` and
        ``incremental`` are accepted for interface parity and ignored:
        both shortcuts copy dense prices bit-exactly, so dense pricing
        returns the same values either way."""
        del fold, incremental
        a = self._flat_assignments(assignments)
        n, sched = a.shape[0], self.schedule
        if sched.n_transfers == 0 or n == 0 or sched.n_phases == 0:
            return np.zeros((n, sched.n_phases), dtype=np.float64)
        slab_times = self._slab_times(a)
        _count("pairs_priced",
               n * int((np.diff(sched.starts) > 0).sum()))
        return slab_times[:, sched.phase_map]

    def _dispatch_slabs(self, a: np.ndarray) -> list[tuple]:
        """Launch the stack's chunked pricing programs and return the
        in-flight ``(device_output, take)`` pairs without waiting.

        JAX dispatch is asynchronous on every backend: each ``fn`` call
        returns as soon as the program is enqueued, so by the time the
        first chunk finishes the rest are already queued behind it —
        double-buffered by the runtime — and the host is free to expand
        the next candidate group. Oversize stacks that split into
        several chunks donate each chunk's input buffer back to XLA
        (off-CPU only; the CPU backend does not implement donation)."""
        exp = _export_for(self.schedule, self.topology)
        degraded = self.topology.degraded
        if degraded is not None and degraded.dead_procs:
            # Masked procs are unplaceable: refuse on the host before any
            # device dispatch (same contract as Topology.bucket_times).
            self.topology.check_placeable(a)
        mode = exp.mode
        if mode == "dense" and not _rows_bijective(a, exp.nprocs):
            mode = "scatter"      # dense needs invertible rows
        n = a.shape[0]
        chunk = min(exp.chunk(mode), _pow2_floor(2 * n - 1) if n else 1)
        donate = n > chunk and jax.default_backend() != "cpu"
        a32 = np.ascontiguousarray(a, dtype=np.int32)
        parts: list[tuple] = []
        with _x64(self.dtype):
            fn = exp.fn(mode, self.dtype, self.use_pallas, donate)
            for lo in range(0, n, chunk):
                blk = a32[lo:lo + chunk]
                take = blk.shape[0]
                if take < chunk:      # pad to the compiled chunk shape
                    blk = np.concatenate(
                        [blk, np.broadcast_to(blk[-1:],
                                              (chunk - take, blk.shape[1]))])
                parts.append((fn(_device_put_chunk(blk)), take))
        return parts

    @staticmethod
    def _collect_slabs(parts: list[tuple], n: int, u: int) -> np.ndarray:
        """Block on the in-flight chunk programs (oldest first — the
        device finishes them in dispatch order) and assemble the full
        (N, n_unique) slab-time matrix on the host."""
        out = np.empty((n, u), dtype=np.float64)
        lo = 0
        for dev, take in parts:
            out[lo:lo + take] = np.asarray(dev)[:take]
            lo += take
        return out

    def _slab_times(self, a: np.ndarray) -> np.ndarray:
        exp = _export_for(self.schedule, self.topology)
        return self._collect_slabs(self._dispatch_slabs(a), a.shape[0],
                                   exp.u)

    def step_times_async(self, assignments: np.ndarray, *,
                         fold: bool = True,
                         incremental: bool = True) -> "ReadyPrices":
        """Dispatch the whole stack's pricing and return immediately with
        a deferred handle; ``result()`` blocks on the device outputs and
        closes the step recurrence. Between dispatch and ``result()`` the
        host is free — this is the overlap the tuner's streaming pipeline
        lives on. Values are bit-identical to :meth:`step_times` (same
        programs, same chunking; only the wait moves)."""
        del fold, incremental     # moot — see phase_durations
        a = self._flat_assignments(assignments)
        n, sched = a.shape[0], self.schedule
        if sched.n_transfers == 0 or n == 0 or sched.n_phases == 0:
            return ReadyPrices(self._close_steps(
                np.zeros((n, sched.n_phases), dtype=np.float64)))
        parts = self._dispatch_slabs(a)
        _count("pairs_priced",
               n * int((np.diff(sched.starts) > 0).sum()))
        return _InFlightPrices(self, parts, n)


class _InFlightPrices:
    """Deferred step times of one dispatched stack: the chunk programs
    are already running on the device; ``result()`` blocks on their
    outputs (oldest chunk first), assembles slab times, and closes the
    step recurrence. Idempotent — the device buffers are dropped after
    the first materialization."""

    __slots__ = ("_sim", "_parts", "_n", "_value")

    def __init__(self, sim: "JaxBatchSimulator", parts: list[tuple],
                 n: int) -> None:
        self._sim = sim
        self._parts = parts
        self._n = n
        self._value: np.ndarray | None = None

    def result(self) -> np.ndarray:
        if self._value is None:
            sim = self._sim
            sched = sim.schedule
            exp = _export_for(sched, sim.topology)
            slab_times = sim._collect_slabs(self._parts, self._n, exp.u)
            self._parts = []
            self._value = sim._close_steps(slab_times[:, sched.phase_map])
        return self._value


def to_jax(engine: BatchSimulator, *, dtype: str = "float64",
           use_pallas: bool = False) -> JaxBatchSimulator:
    """The JAX twin of a NumPy batch engine (same schedule/topology/step
    closure, compiled pricing)."""
    return JaxBatchSimulator(
        topology=engine.topology, schedule=engine.schedule,
        compute_s=engine.compute_s, backpressure=engine.backpressure,
        steps=engine.steps, dtype=dtype, use_pallas=use_pallas,
    )


def jax_batch_simulator(pattern: CollectivePattern, spec: MachineSpec,
                        grid: Sequence[int], *, step_flops: float,
                        elem_bytes: int = 4, backpressure: int = 2,
                        steps: int = 3,
                        alphas: tuple[float, ...] | None = None,
                        dtype: str = "float64",
                        use_pallas: bool = False,
                        degraded: "DegradedMachine | None" = None
                        ) -> JaxBatchSimulator:
    """Build the JAX engine for one (pattern, machine, grid) point —
    the device-compiled counterpart of ``batch_simulator``."""
    grid = tuple(int(g) for g in grid)
    return JaxBatchSimulator(
        topology=Topology.from_spec(spec, alphas=alphas, degraded=degraded),
        schedule=packed_schedule(pattern, grid, elem_bytes=elem_bytes),
        compute_s=float(step_flops) / (spec.nprocs * spec.peak_flops),
        backpressure=backpressure,
        steps=steps,
        dtype=dtype,
        use_pallas=use_pallas,
    )


__all__ = [
    "JaxBatchSimulator",
    "enable_compilation_cache",
    "have_jax",
    "jax_batch_simulator",
    "platform_info",
    "to_jax",
]
