"""Discrete-event distributed-execution simulator (the repo's fabric truth).

Predicts per-step wall-clock time for a mapped application:

  ``topology``     hierarchical alpha-beta network from a MachineSpec
                   (per-level latency/bandwidth, port contention;
                   stride-arithmetic routing + bucketed vectorized
                   pricing, no processor-count ceiling)
  ``collectives``  wire schedules for the patterns the nine apps emit,
                   derived from the exact tile->processor assignment
                   (packed tile-space tensors, memoized expansion)
  ``engine``       event-queue execution of compute segments overlapped
                   with comm streams, Backpressure = in-flight depth
  ``batch``        analytic-envelope engine pricing whole candidate
                   beams in one candidates x phases x ports pass
  ``cost``         SimulatedTimeCostModel: the simulator behind the
                   CostModel protocol, so the tuner optimizes seconds

See docs/simulator.md. ``machine.modeled_step_time`` remains the
documented flat-topology fast path.
"""
from repro.sim.batch import BatchSimulator, batch_simulator, canonical_assignment
from repro.sim.collectives import (
    CollectivePattern,
    PackedSchedule,
    Phase,
    build_phases,
    packed_schedule,
)
from repro.sim.cost import (
    SimReport,
    SimulatedTimeCostModel,
    simulate_app,
    spec_for,
    time_search_space,
    time_tuned_app,
)
from repro.sim.engine import Timeline, simulate_steps, simulate_tasks
from repro.sim.topology import Topology

__all__ = [
    "BatchSimulator",
    "CollectivePattern",
    "PackedSchedule",
    "Phase",
    "SimReport",
    "SimulatedTimeCostModel",
    "Timeline",
    "Topology",
    "batch_simulator",
    "build_phases",
    "canonical_assignment",
    "packed_schedule",
    "simulate_app",
    "simulate_steps",
    "simulate_tasks",
    "spec_for",
    "time_search_space",
    "time_tuned_app",
]
