"""Discrete-event distributed-execution simulator (the repo's fabric truth).

Predicts per-step wall-clock time for a mapped application:

  ``topology``     hierarchical alpha-beta network from a MachineSpec
                   (per-level latency/bandwidth, port contention)
  ``collectives``  wire schedules for the patterns the nine apps emit,
                   derived from the exact tile->processor assignment
  ``engine``       event-queue execution of compute segments overlapped
                   with comm streams, Backpressure = in-flight depth
  ``cost``         SimulatedTimeCostModel: the simulator behind the
                   CostModel protocol, so the tuner optimizes seconds

See docs/simulator.md. ``machine.modeled_step_time`` remains the
documented flat-topology fast path.
"""
from repro.sim.collectives import CollectivePattern, Phase, build_phases
from repro.sim.cost import (
    SimReport,
    SimulatedTimeCostModel,
    simulate_app,
    spec_for,
    time_search_space,
    time_tuned_app,
)
from repro.sim.engine import Timeline, simulate_steps, simulate_tasks
from repro.sim.topology import Topology

__all__ = [
    "CollectivePattern",
    "Phase",
    "SimReport",
    "SimulatedTimeCostModel",
    "Timeline",
    "Topology",
    "build_phases",
    "simulate_app",
    "simulate_steps",
    "simulate_tasks",
    "spec_for",
    "time_search_space",
    "time_tuned_app",
]
