"""Hierarchical alpha-beta network model built from a :class:`MachineSpec`.

The machine is a level tree: ``spec.shape = (nodes, gpus)`` means every
node is a switch whose children are GPUs, and the nodes hang off one root
fabric. A point-to-point message between processors ``src`` and ``dst``
routes up the tree to their lowest common ancestor and back down; the
*crossing level* — the outermost coordinate where the two processors
differ — determines which fabric the message pays for:

  * latency ``alpha[level]`` per message, and
  * bandwidth ``beta[level]`` (= ``spec.link_bw(level)``) per *port*.

Ports model contention on shared links. A message crossing level ``L``
leaves through the port of the level-``(L+1)`` subtree containing ``src``
(for a two-level machine and ``L = 0`` that is the source *node's* NIC,
shared by every GPU in the node) and enters through the subtree port
containing ``dst``. Messages in flight at the same time through the same
port share its bandwidth, so the time of a set of concurrent transfers is
the max over ports of ``n_msgs * alpha + port_bytes / beta`` — the
standard congestion (max-load) alpha-beta cost used by static mapping
cost models.

Everything is vectorized over transfer arrays with NumPy so the simulator
can price thousands of transfers per event without Python loops.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.machine import MachineSpec

#: Default per-message latencies by level depth, outermost first. The
#: outermost fabric (DCI / inter-node Ethernet) is ~an order of magnitude
#: slower to enter than the intra-node links. Both are scaled to the
#: repo's scaled-down problem sizes (the registry's canonical workloads
#: move KB..MB faces, not the GB payloads of the paper's full runs) so
#: the per-message setup term does not drown the byte costs the volume
#: models price; pass explicit ``alphas`` to ``Topology.from_spec`` for
#: full-scale latency studies.
DEFAULT_ALPHA_OUTER = 2e-7      # seconds, inter-node message setup
DEFAULT_ALPHA_INNER = 5e-8      # seconds, intra-node / on-fabric setup


@dataclasses.dataclass(frozen=True)
class Topology:
    """The level tree with per-level (alpha, beta) parameters.

    ``alphas``/``betas`` are outermost-first, one entry per level of
    ``spec.shape``; ``betas`` defaults to ``spec.level_bws``.
    """

    spec: MachineSpec
    alphas: tuple[float, ...]
    betas: tuple[float, ...]

    @classmethod
    def from_spec(cls, spec: MachineSpec,
                  alphas: tuple[float, ...] | None = None) -> "Topology":
        k = len(spec.shape)
        if alphas is None:
            alphas = ((DEFAULT_ALPHA_OUTER,) + (DEFAULT_ALPHA_INNER,) * (k - 1)
                      if k > 1 else (DEFAULT_ALPHA_INNER,))
        if len(alphas) != k:
            raise ValueError(
                f"alphas needs one latency per level: got {len(alphas)} "
                f"for {k} levels"
            )
        return cls(spec=spec, alphas=tuple(alphas), betas=spec.level_bws)

    # -------------------------------------------------------------- routing
    @property
    def nprocs(self) -> int:
        return self.spec.nprocs

    def coords(self, procs: np.ndarray) -> np.ndarray:
        """(n, k) level coordinates of flat processor ids (row-major)."""
        procs = np.asarray(procs, dtype=np.int64)
        return np.stack(
            np.unravel_index(procs, self.spec.shape), axis=-1
        ).reshape(procs.shape + (len(self.spec.shape),))

    def crossing_levels(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Outermost level where src and dst coordinates differ (the fabric
        the message crosses); ``k`` (= number of levels) for src == dst,
        i.e. a local copy that never touches the network."""
        cs, cd = self.coords(np.asarray(src)), self.coords(np.asarray(dst))
        diff = cs != cd
        k = diff.shape[-1]
        # argmax finds the first True; all-False rows (same proc) map to k.
        first = np.argmax(diff, axis=-1)
        return np.where(diff.any(axis=-1), first, k)

    def transfer_time(self, nbytes: float, level: int) -> float:
        """Uncontended point-to-point time for one message at one level."""
        return self.alphas[level] + float(nbytes) / self.betas[level]

    # ----------------------------------------------------------- congestion
    def phase_time(self, src: np.ndarray, dst: np.ndarray,
                   nbytes: np.ndarray) -> float:
        """Time for a set of concurrent transfers under port contention.

        For each level ``L``, the transfers crossing at ``L`` load the
        egress port of the subtree ``src[:L+1]`` and the ingress port of
        ``dst[:L+1]``; the phase completes when the most-loaded port
        drains: ``max over ports (msgs * alpha[L] + bytes / beta[L])``.
        Same-processor transfers are free (no network crossing).
        """
        src = np.asarray(src, dtype=np.int64).reshape(-1)
        dst = np.asarray(dst, dtype=np.int64).reshape(-1)
        nbytes = np.broadcast_to(
            np.asarray(nbytes, dtype=np.float64), src.shape
        )
        if src.size == 0:
            return 0.0
        levels = self.crossing_levels(src, dst)
        k = len(self.spec.shape)
        worst = 0.0
        cs, cd = self.coords(src), self.coords(dst)
        for lvl in range(k):
            mask = levels == lvl
            if not mask.any():
                continue
            # Port id = flat index of the level-(lvl+1) subtree containing
            # the endpoint: unique per (coords[0..lvl]) prefix.
            dims = self.spec.shape[: lvl + 1]
            sub_s = np.ravel_multi_index(
                tuple(cs[mask, i] for i in range(lvl + 1)), dims
            )
            sub_d = np.ravel_multi_index(
                tuple(cd[mask, i] for i in range(lvl + 1)), dims
            )
            # Full-duplex ports: egress and ingress are separate directions
            # of the same link, each with the level's bandwidth.
            nports = int(np.prod(dims))
            load = np.zeros((2, nports), dtype=np.float64)
            msgs = np.zeros((2, nports), dtype=np.float64)
            np.add.at(load[0], sub_s, nbytes[mask])
            np.add.at(load[1], sub_d, nbytes[mask])
            np.add.at(msgs[0], sub_s, 1.0)
            np.add.at(msgs[1], sub_d, 1.0)
            port_t = msgs * self.alphas[lvl] + load / self.betas[lvl]
            worst = max(worst, float(port_t.max()))
        return worst


__all__ = [
    "DEFAULT_ALPHA_INNER",
    "DEFAULT_ALPHA_OUTER",
    "Topology",
]
