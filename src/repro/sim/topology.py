"""Hierarchical alpha-beta network model built from a :class:`MachineSpec`.

The machine is a level tree: ``spec.shape = (nodes, gpus)`` means every
node is a switch whose children are GPUs, and the nodes hang off one root
fabric. A point-to-point message between processors ``src`` and ``dst``
routes up the tree to their lowest common ancestor and back down; the
*crossing level* — the outermost coordinate where the two processors
differ — determines which fabric the message pays for:

  * latency ``alpha[level]`` per message, and
  * bandwidth ``beta[level]`` (= ``spec.link_bw(level)``) per *port*.

Ports model contention on shared links. A message crossing level ``L``
leaves through the port of the level-``(L+1)`` subtree containing ``src``
(for a two-level machine and ``L = 0`` that is the source *node's* NIC,
shared by every GPU in the node) and enters through the subtree port
containing ``dst``. Messages in flight at the same time through the same
port share its bandwidth, so the time of a set of concurrent transfers is
the max over ports of ``n_msgs * alpha + port_bytes / beta`` — the
standard congestion (max-load) alpha-beta cost used by static mapping
cost models.

The hot path is fully array-programmed: crossing levels are pure
stride arithmetic on flat processor ids (``src // stride[L] != dst //
stride[L]`` — no precomputed all-pairs table, so there is no processor
ceiling), and congestion pricing is a single bincount /
``np.add.reduceat`` pass over an arbitrary *bucket* axis — one bucket
per phase for the event engine, ``candidates x phases`` buckets for the
batched engine (``repro.sim.batch``) — so thousands of phases across a
whole tuner beam are priced in one call, at 1024 or 131072 processors
alike.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.machine import DegradedMachine, MachineSpec

#: Default per-message latencies by level depth, outermost first. The
#: outermost fabric (DCI / inter-node Ethernet) is ~an order of magnitude
#: slower to enter than the intra-node links. Both are scaled to the
#: repo's scaled-down problem sizes (the registry's canonical workloads
#: move KB..MB faces, not the GB payloads of the paper's full runs) so
#: the per-message setup term does not drown the byte costs the volume
#: models price; pass explicit ``alphas`` to ``Topology.from_spec`` for
#: full-scale latency studies.
DEFAULT_ALPHA_OUTER = 2e-7      # seconds, inter-node message setup
DEFAULT_ALPHA_INNER = 5e-8      # seconds, intra-node / on-fabric setup

#: Dense-bincount ceiling for congestion pricing: when
#: ``n_buckets * n_ports`` exceeds this, the sparse sorted-key
#: ``np.add.reduceat`` path is used instead (same float results —
#: both sum each port's bytes in transfer order).
_DENSE_PORT_CELLS = 1 << 23


@dataclasses.dataclass(frozen=True)
class Topology:
    """The level tree with per-level (alpha, beta) parameters.

    ``alphas``/``betas`` are outermost-first, one entry per level of
    ``spec.shape``; ``betas`` defaults to ``spec.level_bws``.

    ``degraded`` carries the machine's fault state
    (:class:`~repro.core.machine.DegradedMachine`): transfers touching a
    dead processor are refused (``ValueError`` — a masked proc is
    unplaceable, not slow), and a port with contention factor ``c`` drains
    bytes ``c`` times slower (alpha is unaffected). A trivial degradation
    is normalized to ``None`` by :meth:`from_spec`, so a healthy-equivalent
    ``DegradedMachine`` prices bit-identically to the healthy topology.
    """

    spec: MachineSpec
    alphas: tuple[float, ...]
    betas: tuple[float, ...]
    degraded: DegradedMachine | None = None

    @classmethod
    def from_spec(cls, spec: MachineSpec,
                  alphas: tuple[float, ...] | None = None,
                  degraded: DegradedMachine | None = None) -> "Topology":
        k = len(spec.shape)
        if alphas is None:
            alphas = ((DEFAULT_ALPHA_OUTER,) + (DEFAULT_ALPHA_INNER,) * (k - 1)
                      if k > 1 else (DEFAULT_ALPHA_INNER,))
        if len(alphas) != k:
            raise ValueError(
                f"alphas needs one latency per level: got {len(alphas)} "
                f"for {k} levels"
            )
        if degraded is not None:
            if degraded.spec != spec:
                raise ValueError(
                    "degraded view describes a different machine than spec"
                )
            if degraded.is_trivial:
                degraded = None       # healthy-equivalent: keep bit-identity
        return cls(spec=spec, alphas=tuple(alphas), betas=spec.level_bws,
                   degraded=degraded)

    # ------------------------------------------------------- degraded state
    def _dead_array(self) -> np.ndarray:
        """Dead processor ids as an int64 array (cached; empty if healthy)."""
        arr = getattr(self, "_dead_cache", None)
        if arr is None:
            dead = self.degraded.dead_procs if self.degraded else ()
            arr = np.asarray(dead, dtype=np.int64)
            object.__setattr__(self, "_dead_cache", arr)
        return arr

    def _contention_flat(self) -> "tuple[np.ndarray, np.ndarray] | None":
        """Per-port contention factors flattened across levels: returns
        ``(flat, offsets)`` with ``flat[offsets[L] + port]`` the level-L
        factor, or ``None`` when no port is contended (cached)."""
        cached = getattr(self, "_cont_cache", "unset")
        if cached == "unset":
            if self.degraded is None or self.degraded.contention is None:
                cached = None
            else:
                rows = [np.asarray(self.degraded.port_contention(lvl),
                                   dtype=np.float64)
                        for lvl in range(len(self.spec.shape))]
                offsets = np.r_[
                    0, np.cumsum([r.size for r in rows])
                ].astype(np.int64)
                cached = (np.concatenate(rows), offsets)
            object.__setattr__(self, "_cont_cache", cached)
        return cached

    def check_placeable(self, procs: np.ndarray) -> None:
        """Raise ``ValueError`` if any processor in ``procs`` is dead."""
        dead = self._dead_array()
        if dead.size == 0:
            return
        procs = np.asarray(procs, dtype=np.int64).reshape(-1)
        bad = np.isin(procs, dead)
        if bad.any():
            hit = sorted(set(procs[bad].tolist()))[:8]
            raise ValueError(
                f"placement touches dead processor(s) {hit}: masked procs "
                f"are unplaceable on this degraded machine"
            )

    # -------------------------------------------------------------- routing
    @property
    def nprocs(self) -> int:
        return self.spec.nprocs

    @property
    def port_strides(self) -> tuple[int, ...]:
        """Flat-id divisor per level: ``proc // stride[L]`` is the flat
        index of the level-(L+1) subtree (= port id) containing ``proc``."""
        return self.spec.level_strides

    def coords(self, procs: np.ndarray) -> np.ndarray:
        """(n, k) level coordinates of flat processor ids (row-major)."""
        procs = np.asarray(procs, dtype=np.int64)
        return np.stack(
            np.unravel_index(procs, self.spec.shape), axis=-1
        ).reshape(procs.shape + (len(self.spec.shape),))

    def crossing_levels(self, src: np.ndarray, dst: np.ndarray) -> np.ndarray:
        """Outermost level where src and dst coordinates differ (the fabric
        the message crosses); ``k`` (= number of levels) for src == dst,
        i.e. a local copy that never touches the network.

        Pure stride arithmetic — ``src // stride[L]`` is the flat index
        of the level-(L+1) subtree, and subtree indices differ exactly
        from the outermost differing coordinate inward, so sweeping the
        levels innermost-first and overwriting leaves the outermost
        match. O(k) vectorized ops per call, no precomputed table and no
        processor-count ceiling.
        """
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        k = len(self.spec.shape)
        out = np.full(np.broadcast_shapes(src.shape, dst.shape), k,
                      dtype=np.int64)
        for lvl in range(k - 1, -1, -1):
            s = self.spec.level_strides[lvl]
            np.copyto(out, lvl, where=(src // s) != (dst // s))
        return out

    def transfer_time(self, nbytes: float, level: int) -> float:
        """Uncontended point-to-point time for one message at one level."""
        return self.alphas[level] + float(nbytes) / self.betas[level]

    # ----------------------------------------------------------- congestion
    def bucket_times(self, src: np.ndarray, dst: np.ndarray,
                     nbytes: np.ndarray, bucket: np.ndarray,
                     n_buckets: int) -> np.ndarray:
        """Congestion-priced completion time of ``n_buckets`` independent
        transfer sets in one vectorized pass.

        ``bucket`` maps each transfer to its set (a phase for the event
        engine; ``candidate * n_phases + phase`` for the batched engine).
        Within each bucket the transfers run concurrently: every level-L
        crossing loads the egress port of its source subtree and the
        ingress port of its destination subtree (full duplex), and the
        bucket completes when its most-loaded port drains::

            time[b] = max over ports ( msgs * alpha[L] + bytes / beta[L] )

        Port loads are accumulated with ``np.bincount`` (or, past the
        dense ceiling, a sorted-key ``np.add.reduceat`` segment pass);
        both sum each port's bytes in transfer order, so the result is
        bit-identical to the legacy per-transfer accumulation.
        """
        src = np.asarray(src, dtype=np.int64)
        nbytes = np.broadcast_to(
            np.asarray(nbytes, dtype=np.float64), src.shape
        ).reshape(-1)
        src = src.reshape(-1)
        dst = np.asarray(dst, dtype=np.int64).reshape(-1)
        bucket = np.asarray(bucket, dtype=np.int64).reshape(-1)
        n_buckets = int(n_buckets)
        out = np.zeros(n_buckets, dtype=np.float64)
        if src.size == 0:
            return out
        if self.degraded is not None and self.degraded.dead_procs:
            self.check_placeable(src)
            self.check_placeable(dst)
        k = len(self.spec.shape)
        levels = self.crossing_levels(src, dst).astype(np.int64)
        valid = levels < k               # local copies never hit the fabric
        if not valid.all():
            levels, bucket = levels[valid], bucket[valid]
            src, dst, nbytes = src[valid], dst[valid], nbytes[valid]
        if src.size == 0:
            return out
        # One unified (level, direction, bucket, port) key per port load:
        # the whole pass — every level, both full-duplex directions, all
        # buckets — is two bincounts (or one sorted reduceat sweep). Each
        # level contributes only its true port count (level 0 of a
        # (nodes, gpus) machine has `nodes` NICs, not `nprocs`).
        strides = np.asarray(self.port_strides, dtype=np.int64)
        nports = np.asarray(self.spec.level_ports, dtype=np.int64)
        per_lvl = 2 * n_buckets * nports
        offsets = np.r_[0, np.cumsum(per_lvl)]
        cells = int(offsets[-1])
        t_np = nports[levels]
        base = offsets[levels] + bucket * t_np
        dir_off = n_buckets * t_np
        eg_port = src // strides[levels]
        in_port = dst // strides[levels]
        key = np.concatenate([base + eg_port, base + dir_off + in_port])
        cont = self._contention_flat()
        if cont is None:
            w = np.concatenate([nbytes, nbytes])
        else:
            # A contended port drains bytes `c` times slower: scale each
            # transfer's byte load by its port's factor before summing.
            # Alpha (message setup) is unaffected, so the msgs counts below
            # stay untouched.
            flat, cont_off = cont
            w = np.concatenate([
                nbytes * flat[cont_off[levels] + eg_port],
                nbytes * flat[cont_off[levels] + in_port],
            ])
        # Dense bincount when the port table is reasonably filled; the
        # sorted sparse sweep when transfers are much sparser than the
        # table (zeroing/scanning empty cells would dominate).
        if cells <= _DENSE_PORT_CELLS and cells <= max(4096, 8 * key.size):
            load = np.bincount(key, weights=w, minlength=cells)
            msgs = np.bincount(key, minlength=cells)
            for lvl in range(k):
                sl = slice(offsets[lvl], offsets[lvl + 1])
                t = (msgs[sl] * self.alphas[lvl]
                     + load[sl] / self.betas[lvl])
                np.maximum(
                    out,
                    t.reshape(2, n_buckets, nports[lvl]).max(axis=(0, 2)),
                    out=out,
                )
            return out
        # Sparse path: stable sort keeps equal keys in transfer order.
        # reduceat's pairwise float summation can differ from bincount's
        # sequential accumulation by rounding ulps — far inside the 1e-9
        # engine-agreement contract benchmarks/sim_eval.py enforces.
        order = np.argsort(key, kind="stable")
        sk, sw = key[order], w[order]
        starts = np.r_[0, np.flatnonzero(np.diff(sk)) + 1]
        load = np.add.reduceat(sw, starts)
        msgs = np.diff(np.r_[starts, sk.size])
        cell = sk[starts]
        lvl = np.searchsorted(offsets, cell, side="right") - 1
        t = (msgs * np.asarray(self.alphas)[lvl]
             + load / np.asarray(self.betas)[lvl])
        # Fold per-port times to per-(level, direction, bucket) maxima
        # (contiguous runs of the sorted keys), then into the buckets.
        c_np = nports[lvl]
        group_bucket = (cell - offsets[lvl]) % (n_buckets * c_np) // c_np
        group = (cell - offsets[lvl]) // c_np + 2 * n_buckets * lvl
        g_starts = np.r_[0, np.flatnonzero(np.diff(group)) + 1]
        g_max = np.maximum.reduceat(t, g_starts)
        np.maximum.at(out, group_bucket[g_starts], g_max)
        return out

    def phase_time(self, src: np.ndarray, dst: np.ndarray,
                   nbytes: np.ndarray) -> float:
        """Time for one set of concurrent transfers under port contention.

        For each level ``L``, the transfers crossing at ``L`` load the
        egress port of the subtree ``src[:L+1]`` and the ingress port of
        ``dst[:L+1]``; the phase completes when the most-loaded port
        drains: ``max over ports (msgs * alpha[L] + bytes / beta[L])``.
        Same-processor transfers are free (no network crossing).
        """
        src = np.asarray(src, dtype=np.int64).reshape(-1)
        bucket = np.zeros(src.shape, dtype=np.int64)
        return float(self.bucket_times(src, dst, nbytes, bucket, 1)[0])

    def phase_times(self, phases: Sequence) -> np.ndarray:
        """Congestion-priced durations of a whole phase list in one pass
        (one bucket per phase). Equivalent to ``[phase_time(ph.src,
        ph.dst, ph.nbytes) for ph in phases]`` but without the per-phase
        Python loop — the event engine's schedule pricing."""
        n = len(phases)
        if n == 0:
            return np.zeros(0, dtype=np.float64)
        sizes = [ph.src.size for ph in phases]
        if not any(sizes):
            return np.zeros(n, dtype=np.float64)
        src = np.concatenate([ph.src for ph in phases])
        dst = np.concatenate([ph.dst for ph in phases])
        nbytes = np.concatenate([
            np.broadcast_to(np.asarray(ph.nbytes, np.float64), ph.src.shape)
            for ph in phases
        ])
        bucket = np.repeat(np.arange(n, dtype=np.int64), sizes)
        return self.bucket_times(src, dst, nbytes, bucket, n)


__all__ = [
    "DEFAULT_ALPHA_INNER",
    "DEFAULT_ALPHA_OUTER",
    "Topology",
]
