"""Discrete-event engine: compute segments overlapped with comm streams.

The unit of simulation is a *task* — a segment of known duration bound to
one serial resource (the lockstep SPMD ``compute`` stream or the shared
``network`` stream) with dependency edges. A heap-based event queue pops
the earliest completion, marks dependents ready, and dispatches every
ready task whose resource is free; ties resolve deterministically by task
key, so a simulation is a pure function of its inputs.

:func:`simulate_steps` builds the step-loop task graph for an application:

  * ``compute[s]`` depends on ``compute[s-1]`` (one accelerator stream) and
    on ``comm[s - backpressure]`` completing — the ``Backpressure``
    directive realized exactly as the training loop realizes it: at most
    ``backpressure`` steps may be in flight before dispatch blocks on the
    oldest step's completion;
  * ``comm[s][p]`` (phase ``p`` of step ``s``) depends on ``comm[s][p-1]``
    and, for the first phase, on ``compute[s]``; all comm segments share
    the serial ``network`` resource, so communication of step ``s``
    overlaps compute of steps ``s+1 .. s+backpressure-1``.

Phase durations come from :meth:`Topology.phase_time`, i.e. they carry the
exact port-contention cost of the tile->processor placement.

:func:`simulate_steps_with_faults` runs the same step loop under a fault
schedule (:class:`FaultEvent`): transient link slowdowns re-price the
phases dispatched inside their window on a contended
:class:`~repro.core.machine.DegradedMachine` view, and a node death that
intersects the placement halts the run at the death timestamp with a
typed :class:`NodeFailure` outcome — never a silently wrong timeline.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Hashable, Sequence

from repro.core.machine import DegradedMachine
from repro.sim.collectives import Phase
from repro.sim.topology import Topology

COMPUTE = "compute"
NETWORK = "network"

FAULT_KINDS = ("node-death", "link-slowdown")


@dataclasses.dataclass(frozen=True)
class Task:
    """One schedulable segment: fixed duration on a serial resource."""

    key: Hashable
    duration: float
    resource: str
    deps: tuple[Hashable, ...] = ()
    step: int = -1
    label: str = ""


@dataclasses.dataclass(frozen=True)
class Segment:
    """One executed task on the timeline."""

    key: Hashable
    resource: str
    start: float
    end: float
    step: int
    label: str

    def row(self) -> dict:
        return {
            "resource": self.resource,
            "step": self.step,
            "label": self.label,
            "start": self.start,
            "end": self.end,
        }


@dataclasses.dataclass
class Timeline:
    """The executed schedule: segments plus derived step metrics."""

    segments: list[Segment]
    makespan: float
    steps: int

    def step_interval(self, step: int) -> tuple[float, float]:
        segs = [s for s in self.segments if s.step == step]
        return (min(s.start for s in segs), max(s.end for s in segs))

    @property
    def max_in_flight(self) -> int:
        """Peak number of steps simultaneously active (dispatched, not yet
        fully retired) — the quantity ``Backpressure`` bounds."""
        events: list[tuple[float, int]] = []
        for s in range(self.steps):
            t0, t1 = self.step_interval(s)
            events.append((t0, 1))
            events.append((t1, -1))
        peak = cur = 0
        # Retirements at time t free a slot before dispatches at time t.
        for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
            cur += delta
            peak = max(peak, cur)
        return peak

    def busy(self, resource: str) -> float:
        return sum(s.end - s.start for s in self.segments
                   if s.resource == resource)

    def per_step_time(self) -> float:
        """Steady-state seconds per step: the marginal cost of the last
        step when more than one ran, else the makespan."""
        if self.steps <= 1:
            return self.makespan
        prev_end = self.step_interval(self.steps - 2)[1]
        return max(self.makespan - prev_end, 0.0) or self.makespan / self.steps

    def rows(self) -> list[dict]:
        return [s.row() for s in self.segments]


def _run_tasks(tasks: Sequence[Task],
               duration_fn: "Callable[[Task, float], float] | None" = None,
               halt_at: float | None = None) -> tuple[Timeline, bool]:
    """The event-queue walk behind :func:`simulate_tasks`.

    ``duration_fn(task, now)`` resolves a task's duration at dispatch
    time (fault windows re-price comm phases this way); ``halt_at``
    aborts the walk at a simulated timestamp — in-flight segments are
    clipped there and the truncated timeline returns with ``halted=True``
    instead of the usual cycle check.
    """
    by_key = {t.key: t for t in tasks}
    missing = {d for t in tasks for d in t.deps if d not in by_key}
    if missing:
        raise ValueError(f"tasks depend on unknown keys: {sorted(map(str, missing))}")
    remaining = {t.key: len(t.deps) for t in tasks}
    dependents: dict[Hashable, list[Hashable]] = {}
    for t in tasks:
        for d in t.deps:
            dependents.setdefault(d, []).append(t.key)

    order = {t.key: i for i, t in enumerate(tasks)}   # deterministic ties
    ready: dict[str, list[tuple[int, Hashable]]] = {}
    for t in tasks:
        if remaining[t.key] == 0:
            heapq.heappush(ready.setdefault(t.resource, []),
                           (order[t.key], t.key))

    free_at: dict[str, float] = {}
    events: list[tuple[float, int, Hashable]] = []   # (end, order, key)
    segments: list[Segment] = []
    now = 0.0
    done = 0

    def dispatch() -> None:
        # A resource takes work only when idle, picking the ready task
        # with the lowest creation order — so an earlier step's next phase
        # is never queue-jumped by a later step that became ready while
        # the resource was busy.
        for res, heap in ready.items():
            while heap and free_at.get(res, 0.0) <= now:
                _, key = heapq.heappop(heap)
                t = by_key[key]
                dur = (t.duration if duration_fn is None
                       else float(duration_fn(t, now)))
                end = now + dur
                free_at[res] = end
                segments.append(Segment(key, res, now, end, t.step, t.label))
                heapq.heappush(events, (end, order[key], key))

    dispatch()
    while events:
        now, _, key = heapq.heappop(events)
        if halt_at is not None and now >= halt_at:
            # The fault fires before this completion: clip every
            # in-flight segment at the fault instant and stop.
            clipped = [
                dataclasses.replace(s, end=min(s.end, halt_at))
                for s in segments if s.start < halt_at
            ]
            steps = max((t.step for t in tasks), default=-1) + 1
            return (Timeline(segments=clipped, makespan=halt_at,
                             steps=steps), True)
        done += 1
        for dep_key in dependents.get(key, ()):
            remaining[dep_key] -= 1
            if remaining[dep_key] == 0:
                t = by_key[dep_key]
                heapq.heappush(ready.setdefault(t.resource, []),
                               (order[dep_key], dep_key))
        dispatch()
    if done != len(tasks):
        raise ValueError("dependency cycle: not every task could run")
    makespan = max((s.end for s in segments), default=0.0)
    steps = max((t.step for t in tasks), default=-1) + 1
    return (Timeline(segments=segments, makespan=makespan, steps=steps),
            False)


def simulate_tasks(tasks: Sequence[Task]) -> Timeline:
    """Run the dependency graph through the event queue; returns the
    executed timeline. Deterministic: ready ties dispatch in key order."""
    timeline, _ = _run_tasks(tasks)
    return timeline


def simulate_steps(
    phases: Sequence[Phase],
    topology: Topology,
    *,
    compute_s: float,
    steps: int = 3,
    backpressure: int = 2,
) -> Timeline:
    """Simulate ``steps`` iterations of (compute, comm phases) under the
    in-flight bound. ``phases`` is ONE step's schedule; every step repeats
    it. Phase durations are congestion-priced once (the schedule is
    identical each step) and reused."""
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if backpressure < 1:
        raise ValueError(f"backpressure must be >= 1, got {backpressure}")
    # The reference engine prices each phase independently — deliberately
    # the simple, legible formulation. The batched engine
    # (repro.sim.batch) prices whole candidate beams in one bucketed pass
    # and is validated against this path to 1e-9.
    durations = [
        topology.phase_time(ph.src, ph.dst, ph.nbytes) for ph in phases
    ]
    tasks: list[Task] = []
    for s in range(steps):
        deps: list[Hashable] = []
        if s > 0:
            deps.append(("compute", s - 1))
        gate = s - backpressure
        if gate >= 0:
            deps.append(("comm_done", gate))
        tasks.append(Task(
            key=("compute", s), duration=compute_s, resource=COMPUTE,
            deps=tuple(deps), step=s, label="compute",
        ))
        prev: Hashable = ("compute", s)
        for p, (ph, dur) in enumerate(zip(phases, durations)):
            key = ("comm", s, p)
            tasks.append(Task(
                key=key, duration=dur, resource=NETWORK, deps=(prev,),
                step=s, label=ph.label,
            ))
            prev = key
        # Zero-duration completion marker so the backpressure gate has a
        # single key whether or not the step communicates.
        tasks.append(Task(
            key=("comm_done", s), duration=0.0, resource=NETWORK,
            deps=(prev,), step=s, label="step_done",
        ))
    return simulate_tasks(tasks)


# ------------------------------------------------------------------- faults
@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault at simulated time ``t``.

    ``kind="node-death"``: the processors in ``procs`` die permanently at
    ``t``. A run whose placement uses any of them halts there with a
    :class:`NodeFailure`; a run that never touches them is unaffected.

    ``kind="link-slowdown"``: background traffic steals bandwidth at one
    machine level for ``duration`` seconds — every port in ``ports``
    (``None`` = all of the level's ports) drains bytes ``factor`` times
    slower. Comm phases *dispatched* inside the window pay the contended
    price for their whole transfer (dispatch-time resolution — the
    engine's serial network stream never preempts a running phase).
    """

    t: float
    kind: str
    procs: tuple[int, ...] = ()
    level: int = 0
    factor: float = 1.0
    duration: float = float("inf")
    ports: tuple[int, ...] | None = None

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}"
            )
        if self.t < 0:
            raise ValueError(f"fault time must be >= 0, got {self.t}")
        if self.kind == "node-death" and not self.procs:
            raise ValueError("node-death needs at least one processor")
        if self.kind == "link-slowdown":
            if self.factor < 1.0:
                raise ValueError(
                    f"slowdown factor must be >= 1.0, got {self.factor}"
                )
            if self.duration <= 0:
                raise ValueError(
                    f"slowdown duration must be > 0, got {self.duration}"
                )
        object.__setattr__(self, "procs",
                           tuple(sorted({int(p) for p in self.procs})))


@dataclasses.dataclass(frozen=True)
class NodeFailure:
    """Typed outcome of a fatal fault: when, during which step, and which
    processors died. Returned instead of a silently wrong timeline."""

    time: float
    step: int
    procs: tuple[int, ...]


@dataclasses.dataclass
class FaultyRun:
    """A step-loop run under fault injection: the (possibly truncated)
    degraded timeline plus the failure that ended it, if any."""

    timeline: Timeline
    failure: NodeFailure | None = None

    @property
    def survived(self) -> bool:
        return self.failure is None

    def per_step_time(self) -> float:
        """Steady-state step time of a surviving run; a failed run has no
        steady state, so this refuses instead of answering wrongly."""
        if self.failure is not None:
            raise ValueError(
                f"run died at t={self.failure.time:.3g}s (step "
                f"{self.failure.step}); a failed run has no step time"
            )
        return self.timeline.per_step_time()


def _window_topology(topology: Topology,
                     active: Sequence[FaultEvent]) -> Topology:
    """The topology as seen inside a set of overlapping slowdown windows:
    the base degraded view (if any) composed with each window's per-port
    contention."""
    spec = topology.spec
    deg = topology.degraded or DegradedMachine.healthy(spec)
    for ev in active:
        ports = (range(spec.level_ports[ev.level]) if ev.ports is None
                 else ev.ports)
        deg = deg.merged(DegradedMachine.contend(
            spec, ev.level, {int(p): float(ev.factor) for p in ports}))
    return Topology.from_spec(spec, alphas=topology.alphas, degraded=deg)


def simulate_steps_with_faults(
    phases: Sequence[Phase],
    topology: Topology,
    *,
    compute_s: float,
    steps: int = 3,
    backpressure: int = 2,
    faults: Sequence[FaultEvent] = (),
    placement: Sequence[int] | None = None,
) -> FaultyRun:
    """:func:`simulate_steps` under a fault schedule.

    Link-slowdown events re-price the phases dispatched inside their
    window on the contended machine view (composed with the topology's
    own static degradation, so a degraded machine can degrade further);
    a node-death event intersecting ``placement`` (every death is fatal
    when no placement is given) halts the run at its timestamp and the
    result carries a typed :class:`NodeFailure`. With no faults the
    timeline is bit-identical to :func:`simulate_steps`.
    """
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if backpressure < 1:
        raise ValueError(f"backpressure must be >= 1, got {backpressure}")
    if placement is None:
        used = None
    else:
        flat = (placement.reshape(-1).tolist()
                if hasattr(placement, "reshape") else placement)
        used = {int(p) for p in flat}
    halt_at = None
    dead: tuple[int, ...] = ()
    for ev in faults:
        if ev.kind != "node-death":
            continue
        fatal = used is None or used.intersection(ev.procs)
        if fatal and (halt_at is None or ev.t < halt_at):
            halt_at, dead = ev.t, ev.procs
    slowdowns = sorted((ev for ev in faults if ev.kind == "link-slowdown"),
                       key=lambda ev: ev.t)
    base_durations = [
        topology.phase_time(ph.src, ph.dst, ph.nbytes) for ph in phases
    ]
    window_cache: dict[tuple[int, ...], list[float]] = {}

    def priced_in_windows(active: tuple[int, ...]) -> list[float]:
        hit = window_cache.get(active)
        if hit is None:
            topo = _window_topology(topology,
                                    [slowdowns[i] for i in active])
            hit = window_cache[active] = [
                topo.phase_time(ph.src, ph.dst, ph.nbytes) for ph in phases
            ]
        return hit

    def duration_fn(task: Task, now: float) -> float:
        key = task.key
        if not (isinstance(key, tuple) and key and key[0] == "comm"):
            return task.duration
        active = tuple(
            i for i, ev in enumerate(slowdowns)
            if ev.t <= now < ev.t + ev.duration
        )
        if not active:
            return task.duration
        return priced_in_windows(active)[key[2]]

    tasks: list[Task] = []
    for s in range(steps):
        deps: list[Hashable] = []
        if s > 0:
            deps.append(("compute", s - 1))
        gate = s - backpressure
        if gate >= 0:
            deps.append(("comm_done", gate))
        tasks.append(Task(
            key=("compute", s), duration=compute_s, resource=COMPUTE,
            deps=tuple(deps), step=s, label="compute",
        ))
        prev: Hashable = ("compute", s)
        for p, (ph, dur) in enumerate(zip(phases, base_durations)):
            key = ("comm", s, p)
            tasks.append(Task(
                key=key, duration=dur, resource=NETWORK, deps=(prev,),
                step=s, label=ph.label,
            ))
            prev = key
        tasks.append(Task(
            key=("comm_done", s), duration=0.0, resource=NETWORK,
            deps=(prev,), step=s, label="step_done",
        ))
    timeline, halted = _run_tasks(
        tasks,
        duration_fn=duration_fn if slowdowns else None,
        halt_at=halt_at,
    )
    if not halted:
        return FaultyRun(timeline=timeline, failure=None)
    fail_step = max((s.step for s in timeline.segments), default=0)
    return FaultyRun(
        timeline=timeline,
        failure=NodeFailure(time=float(halt_at), step=int(fail_step),
                            procs=dead),
    )


__all__ = [
    "COMPUTE",
    "FAULT_KINDS",
    "FaultEvent",
    "FaultyRun",
    "NETWORK",
    "NodeFailure",
    "Segment",
    "Task",
    "Timeline",
    "simulate_steps",
    "simulate_steps_with_faults",
    "simulate_tasks",
]
