"""Discrete-event engine: compute segments overlapped with comm streams.

The unit of simulation is a *task* — a segment of known duration bound to
one serial resource (the lockstep SPMD ``compute`` stream or the shared
``network`` stream) with dependency edges. A heap-based event queue pops
the earliest completion, marks dependents ready, and dispatches every
ready task whose resource is free; ties resolve deterministically by task
key, so a simulation is a pure function of its inputs.

:func:`simulate_steps` builds the step-loop task graph for an application:

  * ``compute[s]`` depends on ``compute[s-1]`` (one accelerator stream) and
    on ``comm[s - backpressure]`` completing — the ``Backpressure``
    directive realized exactly as the training loop realizes it: at most
    ``backpressure`` steps may be in flight before dispatch blocks on the
    oldest step's completion;
  * ``comm[s][p]`` (phase ``p`` of step ``s``) depends on ``comm[s][p-1]``
    and, for the first phase, on ``compute[s]``; all comm segments share
    the serial ``network`` resource, so communication of step ``s``
    overlaps compute of steps ``s+1 .. s+backpressure-1``.

Phase durations come from :meth:`Topology.phase_time`, i.e. they carry the
exact port-contention cost of the tile->processor placement.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Hashable, Sequence

from repro.sim.collectives import Phase
from repro.sim.topology import Topology

COMPUTE = "compute"
NETWORK = "network"


@dataclasses.dataclass(frozen=True)
class Task:
    """One schedulable segment: fixed duration on a serial resource."""

    key: Hashable
    duration: float
    resource: str
    deps: tuple[Hashable, ...] = ()
    step: int = -1
    label: str = ""


@dataclasses.dataclass(frozen=True)
class Segment:
    """One executed task on the timeline."""

    key: Hashable
    resource: str
    start: float
    end: float
    step: int
    label: str

    def row(self) -> dict:
        return {
            "resource": self.resource,
            "step": self.step,
            "label": self.label,
            "start": self.start,
            "end": self.end,
        }


@dataclasses.dataclass
class Timeline:
    """The executed schedule: segments plus derived step metrics."""

    segments: list[Segment]
    makespan: float
    steps: int

    def step_interval(self, step: int) -> tuple[float, float]:
        segs = [s for s in self.segments if s.step == step]
        return (min(s.start for s in segs), max(s.end for s in segs))

    @property
    def max_in_flight(self) -> int:
        """Peak number of steps simultaneously active (dispatched, not yet
        fully retired) — the quantity ``Backpressure`` bounds."""
        events: list[tuple[float, int]] = []
        for s in range(self.steps):
            t0, t1 = self.step_interval(s)
            events.append((t0, 1))
            events.append((t1, -1))
        peak = cur = 0
        # Retirements at time t free a slot before dispatches at time t.
        for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
            cur += delta
            peak = max(peak, cur)
        return peak

    def busy(self, resource: str) -> float:
        return sum(s.end - s.start for s in self.segments
                   if s.resource == resource)

    def per_step_time(self) -> float:
        """Steady-state seconds per step: the marginal cost of the last
        step when more than one ran, else the makespan."""
        if self.steps <= 1:
            return self.makespan
        prev_end = self.step_interval(self.steps - 2)[1]
        return max(self.makespan - prev_end, 0.0) or self.makespan / self.steps

    def rows(self) -> list[dict]:
        return [s.row() for s in self.segments]


def simulate_tasks(tasks: Sequence[Task]) -> Timeline:
    """Run the dependency graph through the event queue; returns the
    executed timeline. Deterministic: ready ties dispatch in key order."""
    by_key = {t.key: t for t in tasks}
    missing = {d for t in tasks for d in t.deps if d not in by_key}
    if missing:
        raise ValueError(f"tasks depend on unknown keys: {sorted(map(str, missing))}")
    remaining = {t.key: len(t.deps) for t in tasks}
    dependents: dict[Hashable, list[Hashable]] = {}
    for t in tasks:
        for d in t.deps:
            dependents.setdefault(d, []).append(t.key)

    order = {t.key: i for i, t in enumerate(tasks)}   # deterministic ties
    ready: dict[str, list[tuple[int, Hashable]]] = {}
    for t in tasks:
        if remaining[t.key] == 0:
            heapq.heappush(ready.setdefault(t.resource, []),
                           (order[t.key], t.key))

    free_at: dict[str, float] = {}
    events: list[tuple[float, int, Hashable]] = []   # (end, order, key)
    segments: list[Segment] = []
    now = 0.0
    done = 0

    def dispatch() -> None:
        # A resource takes work only when idle, picking the ready task
        # with the lowest creation order — so an earlier step's next phase
        # is never queue-jumped by a later step that became ready while
        # the resource was busy.
        for res, heap in ready.items():
            while heap and free_at.get(res, 0.0) <= now:
                _, key = heapq.heappop(heap)
                t = by_key[key]
                end = now + t.duration
                free_at[res] = end
                segments.append(Segment(key, res, now, end, t.step, t.label))
                heapq.heappush(events, (end, order[key], key))

    dispatch()
    while events:
        now, _, key = heapq.heappop(events)
        done += 1
        for dep_key in dependents.get(key, ()):
            remaining[dep_key] -= 1
            if remaining[dep_key] == 0:
                t = by_key[dep_key]
                heapq.heappush(ready.setdefault(t.resource, []),
                               (order[dep_key], dep_key))
        dispatch()
    if done != len(tasks):
        raise ValueError("dependency cycle: not every task could run")
    makespan = max((s.end for s in segments), default=0.0)
    steps = max((t.step for t in tasks), default=-1) + 1
    return Timeline(segments=segments, makespan=makespan, steps=steps)


def simulate_steps(
    phases: Sequence[Phase],
    topology: Topology,
    *,
    compute_s: float,
    steps: int = 3,
    backpressure: int = 2,
) -> Timeline:
    """Simulate ``steps`` iterations of (compute, comm phases) under the
    in-flight bound. ``phases`` is ONE step's schedule; every step repeats
    it. Phase durations are congestion-priced once (the schedule is
    identical each step) and reused."""
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if backpressure < 1:
        raise ValueError(f"backpressure must be >= 1, got {backpressure}")
    # The reference engine prices each phase independently — deliberately
    # the simple, legible formulation. The batched engine
    # (repro.sim.batch) prices whole candidate beams in one bucketed pass
    # and is validated against this path to 1e-9.
    durations = [
        topology.phase_time(ph.src, ph.dst, ph.nbytes) for ph in phases
    ]
    tasks: list[Task] = []
    for s in range(steps):
        deps: list[Hashable] = []
        if s > 0:
            deps.append(("compute", s - 1))
        gate = s - backpressure
        if gate >= 0:
            deps.append(("comm_done", gate))
        tasks.append(Task(
            key=("compute", s), duration=compute_s, resource=COMPUTE,
            deps=tuple(deps), step=s, label="compute",
        ))
        prev: Hashable = ("compute", s)
        for p, (ph, dur) in enumerate(zip(phases, durations)):
            key = ("comm", s, p)
            tasks.append(Task(
                key=key, duration=dur, resource=NETWORK, deps=(prev,),
                step=s, label=ph.label,
            ))
            prev = key
        # Zero-duration completion marker so the backpressure gate has a
        # single key whether or not the step communicates.
        tasks.append(Task(
            key=("comm_done", s), duration=0.0, resource=NETWORK,
            deps=(prev,), step=s, label="step_done",
        ))
    return simulate_tasks(tasks)


__all__ = [
    "COMPUTE",
    "NETWORK",
    "Segment",
    "Task",
    "Timeline",
    "simulate_steps",
    "simulate_tasks",
]
