"""Persistent cross-run pricing cache: placements priced once, ever.

The tuner's Phase 3 re-prices the same placements constantly — across
repeat invocations (the service loop of the ASI proposer/evaluator
cycle re-tunes the same (app, grid, machine) point per proposal), across
processes, and across engines that share arithmetic. Pricing is a pure
function of ``(schedule, machine, placement)``, and the placement enters
only through its isomorphism class (`repro.sim.batch
.canonical_assignment`), so the result is cacheable under a compact
digest key — no schedule build, no device dispatch, just a dict lookup
backed by an append-only file.

Layout: one file per *table* under the cache root, where a table is the
digest of everything that determines a step time except the placement —
pattern, grid, machine spec, payload width, compute leg, backpressure,
steps, and the pricing engine's value tag (``numpy-f64`` / ``jax-f64`` /
``jax-f32``: engines agree to tolerance but not bit-for-bit, and the
cache promises bit-stability, so each tag owns its rows). Rows are fixed
28-byte records::

    [16-byte blake2b of the canonical assignment][f64 seconds][crc32]

after a 8-byte ``RPRICE01`` header. The CRC covers digest+value, so a
torn or bit-flipped record is detected and the load stops there — the
intact prefix stays usable, the damaged tail re-prices live (counted in
``stats()["dropped"]``). A file with the wrong magic or version is
treated as empty and overwritten on the next write. Records are
append-only and idempotent (a duplicate digest just re-asserts the same
value), so crashed runs never corrupt earlier rows.

``clear_caches()``/``cache_stats()`` in :mod:`repro.sim.collectives`
cover every live :class:`PriceCache` (registered weakly): clearing drops
the in-memory tables — the disk store survives, that is the point — and
stats aggregate hit/miss/write/dropped counters.
"""
from __future__ import annotations

import struct
import weakref
import zlib
from hashlib import blake2b
from pathlib import Path
from typing import Iterable

from repro.sim.collectives import register_cache

_MAGIC = b"RPRICE01"
_REC = struct.Struct("<16sdI")

#: Digest width of table keys and row keys (blake2b truncated).
DIGEST_BYTES = 16

_INSTANCES: "weakref.WeakSet[PriceCache]" = weakref.WeakSet()
_STAT_KEYS = ("hits", "misses", "writes", "dropped")


def digest(*parts: bytes) -> bytes:
    """16-byte blake2b over length-framed parts (framing keeps
    ``(b"ab", b"c")`` and ``(b"a", b"bc")`` distinct)."""
    h = blake2b(digest_size=DIGEST_BYTES)
    for part in parts:
        h.update(len(part).to_bytes(8, "little"))
        h.update(part)
    return h.digest()


def _crc(row: bytes, value: float) -> int:
    return zlib.crc32(row + struct.pack("<d", value))


class PriceCache:
    """Append-only on-disk store of ``row digest -> step seconds``,
    sharded into per-table files and mirrored in memory once touched.

    ``get``/``put`` take the 16-byte table and row digests directly —
    build them with :func:`digest` (the cost model's
    ``SimulatedTimeCostModel.price_table_key`` assembles the table side).
    Writes go through to disk immediately; reads load a table's file
    lazily on first access and serve from memory after.
    """

    def __init__(self, root: str | Path) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._tables: dict[bytes, dict[bytes, float]] = {}
        #: Tables whose file carried damage at load time: appending past
        #: a tear would be unreadable (loads stop there), so the next
        #: write rewrites these files whole — self-healing.
        self._damaged: set[bytes] = set()
        self.stats_counters = {k: 0 for k in _STAT_KEYS}
        _INSTANCES.add(self)

    # ------------------------------------------------------------------ io
    def _path(self, table: bytes) -> Path:
        return self.root / f"{table.hex()}.price"

    def _load(self, table: bytes) -> dict[bytes, float]:
        rows = self._tables.get(table)
        if rows is not None:
            return rows
        rows = self._tables[table] = {}
        path = self._path(table)
        try:
            blob = path.read_bytes()
        except OSError:
            return rows
        if not blob.startswith(_MAGIC):
            # Stale version or foreign file: ignore it wholesale; the
            # next write re-creates it under the current format.
            self.stats_counters["dropped"] += 1
            self._damaged.add(table)
            return rows
        body = blob[len(_MAGIC):]
        for off in range(0, len(body) - len(body) % _REC.size, _REC.size):
            row, value, crc = _REC.unpack_from(body, off)
            if crc != _crc(row, value):
                # Torn/corrupt record: keep the intact prefix, drop the
                # rest (fixed-size framing cannot re-synchronize past a
                # tear) — those placements simply re-price live.
                self.stats_counters["dropped"] += 1
                self._damaged.add(table)
                break
            rows[row] = value
        else:
            if len(body) % _REC.size:
                self.stats_counters["dropped"] += 1
                self._damaged.add(table)
        return rows

    # -------------------------------------------------------------- access
    def get(self, table: bytes, row: bytes) -> float | None:
        """The cached seconds for one placement digest, or None."""
        value = self._load(table).get(row)
        if value is None:
            self.stats_counters["misses"] += 1
        else:
            self.stats_counters["hits"] += 1
        return value

    def put(self, table: bytes, row: bytes, value: float) -> None:
        self.put_many(table, [(row, value)])

    def put_many(self, table: bytes,
                 items: Iterable[tuple[bytes, float]]) -> None:
        """Insert rows and append them to the table's file in one write
        (the tuner prices in groups; one append per group, not per
        placement). Already-present digests are skipped — append-only
        files never restate a row."""
        rows = self._load(table)
        fresh = [(row, float(value)) for row, value in items
                 if row not in rows]
        if not fresh:
            return
        path = self._path(table)
        rows.update(fresh)
        if table in self._damaged:
            # Appending past a tear would be unreadable (loads stop at
            # the damage), so rewrite the file whole from the intact
            # rows — the write heals the table.
            blob = _MAGIC + b"".join(
                _REC.pack(row, value, _crc(row, value))
                for row, value in rows.items())
            path.write_bytes(blob)
            self._damaged.discard(table)
        else:
            header = b"" if path.exists() else _MAGIC
            blob = b"".join(_REC.pack(row, value, _crc(row, value))
                            for row, value in fresh)
            with open(path, "ab") as fh:
                fh.write(header + blob)
        self.stats_counters["writes"] += len(fresh)

    # ------------------------------------------------------------ lifecycle
    def clear(self) -> None:
        """Drop the in-memory mirror and zero counters; the disk store
        is untouched (the next ``get`` reloads it — that persistence is
        the cache's reason to exist)."""
        self._tables.clear()
        for k in self.stats_counters:
            self.stats_counters[k] = 0

    def stats(self) -> dict:
        """Counters plus the loaded in-memory population."""
        return {
            **self.stats_counters,
            "tables": len(self._tables),
            "rows": sum(len(t) for t in self._tables.values()),
        }


def _caches_clear() -> None:
    for cache in list(_INSTANCES):
        cache.clear()


def _caches_stats() -> dict:
    out = {k: 0 for k in _STAT_KEYS}
    out.update(tables=0, rows=0)
    for cache in list(_INSTANCES):
        for k, v in cache.stats().items():
            out[k] += v
    return out


register_cache("price_cache", _caches_clear, _caches_stats)

__all__ = ["DIGEST_BYTES", "PriceCache", "digest"]
