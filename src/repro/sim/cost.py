"""Time-domain cost: the simulator packaged as a PR-3 ``CostModel``.

Two entry points:

  * :func:`simulate_app` — one application's mapped step through the
    full pipeline (``dsl.parse -> Mapper -> translate.to_spmd``), its
    declared :class:`CollectivePattern` expanded against the *exact*
    tile->processor assignment, executed on the event-queue engine. This
    is what ``python -m repro.apps.run --simulate`` prints.

  * :class:`SimulatedTimeCostModel` — the same machinery behind the
    ``CostModel.cost(grid) -> float`` protocol, returning predicted
    seconds per step instead of element counts, so the mapper autotuner
    (``repro.search.tuner``) optimizes simulated time **unchanged**:
    :func:`time_tuned_app` wraps an Application so ``tune_app`` searches
    on seconds. Scoring runs on the batched analytic-envelope engine
    (``repro.sim.batch``, 1e-9-validated against the event queue;
    ``engine="event"`` pins a model to the exact reference), the tuner's
    beam placements price in one grouped pass via :meth:`beam_pricer`,
    volume models stay the validity filter (a grid the volume model
    rejects is never simulated), and ``benchmarks/sim_eval.py`` asserts
    registry-wide that time-optimal winners never regress the Table 2
    volume oracles.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.commvolume import CostModel
from repro.core.machine import GPU, DegradedMachine, MachineSpec
from repro.sim.batch import (
    BatchSimulator,
    batch_simulator,
    canonical_assignment,
)
from repro.sim.collectives import (
    CollectivePattern,
    Phase,
    _pattern_key,
    build_phases,
    schedule_transfer_bound,
)
from repro.sim.price_cache import PriceCache, digest
from repro.sim.engine import Timeline, simulate_steps
from repro.sim.topology import Topology

DEFAULT_STEPS = 3
DEFAULT_ELEM_BYTES = 4

# Candidate grids whose packed schedule would exceed this many wire
# transfers are rejected by SimulatedTimeCostModel with ValueError (the
# same channel volume infeasibility uses), so the tuner never pays a
# multi-GB schedule build for a grid that cannot win. 2^23 transfers is
# ~2s of build; every grid of every registry app at <= 1024 procs is
# orders of magnitude below it (max ~1M), so paper-scale behavior is
# unchanged, while a (1, 16384) panel grid (~2.7e8 transfers) is pruned.
MAX_SCHEDULE_TRANSFERS = 1 << 23


def spec_for(machine_shape: Sequence[int], kind: str = GPU) -> MachineSpec:
    """A MachineSpec for an app's ``(nodes, gpus)`` machine policy shape."""
    shape = tuple(int(s) for s in machine_shape)
    names = ("node", "gpu", "lane", "sublane")[: len(shape)]
    if len(names) < len(shape):
        names = tuple(f"l{i}" for i in range(len(shape)))
    return MachineSpec(shape=shape, level_names=tuple(names), kind=kind)


def _node_split(machine_shape: Sequence[int], grid: tuple[int, ...],
                local_axes: Sequence[int] = ()) -> tuple[int, ...] | None:
    """Per-axis node factors for the default placement of ``grid`` on a
    two-level machine.

    Among all divisible ordered factorizations of the node count, prefer
    (in order): the smallest node factor on ``local_axes`` — the axes a
    pattern declares its heavy collective groups run along, which an
    expert mapper keeps on the fast intra-node fabric (e.g. Solomonik's
    ``c`` replication axis, the analogue of placing TP inside a node and
    DP across nodes) — then the minimal cross-node surface, then
    lexicographic order for determinism. Returns ``None`` when the
    machine degenerates to one level or no divisible split exists.
    """
    from repro.core.commvolume import halo_surface_volume
    from repro.core.decompose import enumerate_factorizations

    if len(machine_shape) != 2:
        # Deeper hierarchies take the flat fallback; only the canonical
        # two-level (nodes, gpus) machines get a hierarchical split.
        return None
    nodes, gpus = (int(s) for s in machine_shape)
    if nodes <= 1 or gpus <= 1:
        return None
    best: tuple[tuple, tuple[int, ...]] | None = None
    for nf in enumerate_factorizations(nodes, len(grid)):
        if any(g % f for g, f in zip(grid, nf)):
            continue
        local_pen = 1
        for a in local_axes:
            local_pen *= nf[a]
        key = (local_pen, halo_surface_volume(grid, nf), nf)
        if best is None or key < best[0]:
            best = (key, nf)
    return None if best is None else best[1]


def default_assignment(machine_shape: Sequence[int],
                       grid: Sequence[int],
                       local_axes: Sequence[int] = ()) -> np.ndarray:
    """The default placement of a tile grid on a two-level machine:
    hierarchical block/block (contiguous per-axis blocks per node, blocks
    of the remainder within a node — the Fig. 12 shape the tuner's
    default candidate materializes) when a divisible node split exists,
    flat row-major block otherwise."""
    grid = tuple(int(g) for g in grid)
    coords = np.indices(grid)
    nf = _node_split(machine_shape, grid, local_axes)
    if nf is None:
        return np.arange(int(np.prod(grid)), dtype=np.int64).reshape(grid)
    gpus = int(machine_shape[1])
    gf = tuple(g // f for g, f in zip(grid, nf))
    node = np.zeros(grid, dtype=np.int64)
    gpu = np.zeros(grid, dtype=np.int64)
    for a in range(len(grid)):
        node = node * nf[a] + coords[a] // gf[a]
        gpu = gpu * gf[a] + coords[a] % gf[a]
    return node * gpus + gpu


def pattern_with_options(pattern: CollectivePattern,
                         opts: dict) -> CollectivePattern:
    """Fold tuner option axes into the pattern parameters. Currently the
    only option that changes the wire schedule is circuit's ZCMEM
    placement of the shared charge region, which removes a device round
    trip (the Table 2 discount)."""
    if pattern.kind == "gather_scatter" and opts.get("arg1") == "ZCMEM":
        params = dict(pattern.params)
        params["discount"] = float(params.get("zc_discount", 0.75))
        return CollectivePattern(pattern.kind, params)
    return pattern


def inter_node_fraction(phases: Sequence[Phase], topo: Topology) -> float:
    """Fraction of scheduled wire bytes crossing the outermost level."""
    total = cross = 0.0
    for ph in phases:
        if ph.src.size == 0:
            continue
        levels = topo.crossing_levels(ph.src, ph.dst)
        total += float(ph.nbytes.sum())
        cross += float(ph.nbytes[levels == 0].sum())
    return cross / total if total else 0.0


@dataclasses.dataclass(frozen=True)
class SimulatedTimeCostModel(CostModel):
    """Predicted seconds per step of a candidate grid on a real fabric.

    Drops into every ``CostModel`` consumer unchanged: the tuner's beam
    search, ``decompose.optimal_factorization(objective=...)``, and the
    leaderboards all rank on seconds. ``base`` (the app's volume model)
    is the validity filter — candidates it rejects with ``ValueError``
    are never simulated, keeping the two objectives' feasible sets
    identical. ``assignment_fn`` maps a candidate grid to its
    tile->processor assignment; the default is the tuner's default
    placement on ``spec.shape``.
    """

    pattern: CollectivePattern
    spec: MachineSpec
    step_flops: float
    base: CostModel | None = None
    assignment_fn: Callable[[tuple[int, ...]], np.ndarray] | None = None
    elem_bytes: int = DEFAULT_ELEM_BYTES
    steps: int = DEFAULT_STEPS
    backpressure: int = 2
    #: "batched" NumPy envelope | "batched-jax" device-compiled envelope
    #: (same numbers to <=1e-6 relative; see docs/simulator.md) | "event"
    #: exact queue.
    engine: str = "batched"
    #: Pricing precision of the batched-jax engine ("float64" matches the
    #: NumPy reference bit-for-bit under the parity gates; "float32" is
    #: the opt-in lossy mode). Ignored by the host engines.
    dtype: str = "float64"
    #: Fault state of the machine (dead procs + per-port contention). A
    #: trivial view is normalized to None so a healthy-equivalent model
    #: shares the healthy model's identity, cache tables, and prices
    #: bit for bit.
    degraded: DegradedMachine | None = None
    #: Optional persistent price store (``repro.sim.price_cache``):
    #: placements whose canonical form was ever priced under this model's
    #: table key short-circuit to a dict lookup — across processes.
    #: Excluded from equality/hash (a cache is an accelerator, not part
    #: of the model's identity).
    cache: PriceCache | None = dataclasses.field(
        default=None, compare=False, repr=False)
    name = "simulated_time"

    def __post_init__(self) -> None:
        if self.engine not in ("batched", "batched-jax", "event"):
            raise ValueError(
                f"engine must be 'batched', 'batched-jax' or 'event', "
                f"got {self.engine!r}"
            )
        if self.degraded is not None:
            if self.degraded.spec != self.spec:
                raise ValueError(
                    "degraded view describes a different machine than spec"
                )
            if self.degraded.is_trivial:
                object.__setattr__(self, "degraded", None)

    @property
    def value_tag(self) -> str:
        """Which bit-for-bit value family this model prices in. The
        price cache promises byte-stable reads, and the engines agree
        only to tolerance (NumPy vs XLA f64 ~1e-15, f32 ~1e-5), so each
        family owns its own cache tables."""
        if self.engine == "batched-jax":
            return "jax-f32" if self.dtype == "float32" else "jax-f64"
        return "event-f64" if self.engine == "event" else "numpy-f64"

    def price_table_key(self, grid: Sequence[int]) -> bytes:
        """The price-cache table digest for one candidate grid: every
        determinant of a step time except the placement. Computable
        without building the schedule — that is what lets a warm cache
        skip the schedule build *and* the pricing."""
        grid = tuple(int(g) for g in grid)
        compute_s = self.step_flops / (self.spec.nprocs
                                       * self.spec.peak_flops)
        parts = [
            repr(_pattern_key(self.pattern)).encode(),
            repr(grid).encode(),
            repr(self.spec).encode(),
            repr((self.elem_bytes, self.steps, self.backpressure,
                  float(compute_s))).encode(),
            self.value_tag.encode(),
        ]
        if self.degraded is not None:
            # Only non-trivial degradations contribute, so every healthy
            # model keeps its pre-existing table digests (and their
            # on-disk caches) unchanged.
            parts.append(repr((self.degraded.dead_procs,
                               self.degraded.contention)).encode())
        return digest(*parts)

    def price_row_key(self, grid: Sequence[int],
                      assign: np.ndarray) -> bytes:
        """The cache row digest of one placement: its isomorphism-class
        representative's bytes (congestion pricing is invariant under
        per-level relabeling, so the whole class shares one row). A
        degraded machine breaks that symmetry — dead procs and non-uniform
        port contention distinguish relabelings — so its rows key on the
        raw placement bytes instead."""
        a = np.asarray(assign, dtype=np.int64)
        if self.degraded is not None:
            return digest(a.tobytes())
        canon = canonical_assignment(a, self.spec.shape)
        return digest(canon.tobytes())

    def _validate(self, factors: Sequence[int]) -> tuple[int, ...]:
        grid = tuple(int(f) for f in factors)
        if self.base is not None:
            self.base.cost(grid)        # validity: propagate ValueError
        if int(np.prod(grid)) != self.spec.nprocs:
            raise ValueError(
                f"grid {grid} does not cover {self.spec.nprocs} processors"
            )
        bound = schedule_transfer_bound(self.pattern, grid)
        if bound > MAX_SCHEDULE_TRANSFERS:
            raise ValueError(
                f"grid {grid} expands to ~{bound:.2g} wire transfers per "
                f"step (> {MAX_SCHEDULE_TRANSFERS}); too large to "
                f"simulate — such a skewed decomposition is never "
                f"time-competitive at this scale"
            )
        return grid

    def _default_assignment(self, grid: tuple[int, ...]) -> np.ndarray:
        if self.assignment_fn is not None:
            return np.asarray(self.assignment_fn(grid))
        return default_assignment(
            self.spec.shape, grid,
            self.pattern.params.get("local_axes", ()),
        )

    def cost(self, factors: Sequence[int]) -> float:
        grid = self._validate(factors)
        assign = self._default_assignment(grid)
        if self.engine == "event":
            return self.simulate(grid, assign).per_step_time()
        if self.cache is not None:
            table = self.price_table_key(grid)
            row = self.price_row_key(grid, assign)
            hit = self.cache.get(table, row)
            if hit is not None:
                return hit
            value = self.batch(grid).step_time(assign)
            self.cache.put(table, row, value)
            return value
        return self.batch(grid).step_time(assign)

    def batch(self, grid: tuple[int, ...]) -> BatchSimulator:
        """The analytic-envelope engine for one candidate grid (memoized
        packed schedule; prices whole assignment stacks in one call).
        Under ``engine="batched-jax"`` this is the device-compiled
        :class:`~repro.sim.jax_backend.JaxBatchSimulator` twin."""
        eng = batch_simulator(
            self.pattern, self.spec, grid,
            step_flops=self.step_flops, elem_bytes=self.elem_bytes,
            backpressure=self.backpressure, steps=self.steps,
            degraded=self.degraded,
        )
        if self.engine == "batched-jax":
            from repro.sim.jax_backend import to_jax

            return to_jax(eng, dtype=self.dtype)
        return eng

    def beam_pricer(self, factors: Sequence[int]) -> BatchSimulator | None:
        """The batch engine for pricing a beam of placements of one grid
        (the tuner groups these into one registry-wide pass via
        ``sim.batch.price_stacks``, which lets ``batched-jax`` engines
        price their stacks as standalone compiled programs); ``None``
        when this model is pinned to the exact event engine."""
        if self.engine == "event":
            return None
        return self.batch(self._validate(factors))

    def price_assignments(self, factors: Sequence[int],
                          assignments: np.ndarray) -> np.ndarray:
        """(N,) predicted seconds per step for a stack of bijective
        placements of one grid. Batched models price the whole stack in
        one ``candidates x phases x ports`` pass; event models replay
        each placement through the exact queue (the reference both
        engines are benchmarked against)."""
        grid = self._validate(factors)
        if self.engine == "event":
            a = np.asarray(assignments, dtype=np.int64)
            a = a.reshape(a.shape[0], *grid)
            return np.array([
                self.simulate(grid, row).per_step_time() for row in a
            ])
        return self.batch(grid).step_times(assignments)

    def simulate(self, grid: tuple[int, ...], assign: np.ndarray) -> Timeline:
        """The exact event-queue reference for one placement (used for
        ``--simulate`` timelines and engine cross-validation)."""
        topo = Topology.from_spec(self.spec, degraded=self.degraded)
        phases = build_phases(self.pattern, grid, assign,
                              elem_bytes=self.elem_bytes)
        compute_s = self.step_flops / (self.spec.nprocs * self.spec.peak_flops)
        return simulate_steps(
            phases, topo, compute_s=compute_s, steps=self.steps,
            backpressure=self.backpressure,
        )


# --------------------------------------------------------------- application
@dataclasses.dataclass
class SimReport:
    """One simulated application step: the --simulate deliverable."""

    app: str
    procs: int
    machine_shape: tuple[int, ...]
    grid: tuple[int, ...]
    pattern: str
    backpressure: int
    compute_s: float
    comm_s: float                    # network busy time per simulated step
    step_time_s: float               # steady-state seconds per step
    makespan_s: float
    flat_step_time_s: float          # machine.modeled_step_time fast path
    inter_node_bytes_frac: float
    n_phases: int
    max_in_flight: int
    timeline: Timeline
    note: str = ""

    def summary(self) -> dict:
        return {
            "note": self.note,
            "app": self.app,
            "procs": self.procs,
            "machine": list(self.machine_shape),
            "grid": list(self.grid),
            "pattern": self.pattern,
            "backpressure": self.backpressure,
            "compute_s": self.compute_s,
            "comm_s": self.comm_s,
            "step_time_s": self.step_time_s,
            "makespan_s": self.makespan_s,
            "flat_step_time_s": self.flat_step_time_s,
            "inter_node_bytes_frac": self.inter_node_bytes_frac,
            "n_phases": self.n_phases,
            "max_in_flight": self.max_in_flight,
            "timeline": self.timeline.rows(),
        }


def simulate_app(app, procs: int | None = None, *,
                 steps: int = DEFAULT_STEPS,
                 elem_bytes: int = DEFAULT_ELEM_BYTES) -> SimReport:
    """Simulate one registry application's mapped step end to end.

    Runs the real pipeline (``app.spmd_plan`` = parse -> Mapper ->
    translate), reshapes the plan's device permutation into the exact
    tile->processor assignment, expands the app's declared collective
    pattern against it, and executes ``steps`` iterations on the engine
    honoring the plan's ``Backpressure`` depth.
    """
    from repro.core.machine import modeled_step_time

    pattern = getattr(app, "collective", None)
    if pattern is None:
        raise ValueError(
            f"application {app.name!r} declares no collective pattern; "
            f"set Application.collective to simulate it"
        )
    n = app.procs(procs)
    note = ""
    try:
        app.tile_grid(n)
    except ValueError:
        # Same fallback + user-visible note as the tuner's _feasible_procs.
        note = (f"procs {n} infeasible for {app.name}; "
                f"using default {app.default_procs}")
        n = app.default_procs
    plan = app.spmd_plan(n)
    grid = tuple(plan.meta["tile_grid"])
    assign = np.asarray(plan.meta["device_permutation"]).reshape(grid)
    machine_shape = tuple(int(s) for s in app.machine_shape(n))
    spec = spec_for(machine_shape)
    topo = Topology.from_spec(spec)
    phases = build_phases(pattern, grid, assign, elem_bytes=elem_bytes)
    compute_s = app.step_flops(n) / (n * spec.peak_flops)
    timeline = simulate_steps(
        phases, topo, compute_s=compute_s, steps=steps,
        backpressure=plan.backpressure,
    )
    return SimReport(
        app=app.name,
        procs=n,
        machine_shape=machine_shape,
        grid=grid,
        pattern=pattern.kind,
        backpressure=plan.backpressure,
        compute_s=compute_s,
        comm_s=timeline.busy("network") / max(steps, 1),
        step_time_s=timeline.per_step_time(),
        makespan_s=timeline.makespan,
        flat_step_time_s=modeled_step_time(
            app.step_flops(n), app.comm_volume(n), n, elem_bytes=elem_bytes,
        ),
        inter_node_bytes_frac=inter_node_fraction(phases, topo),
        n_phases=len(phases),
        max_in_flight=timeline.max_in_flight,
        timeline=timeline,
        note=note,
    )


def time_search_space(app, *, steps: int = DEFAULT_STEPS,
                      elem_bytes: int = DEFAULT_ELEM_BYTES,
                      engine: str = "batched", dtype: str = "float64",
                      cache: PriceCache | None = None,
                      degraded: DegradedMachine | None = None):
    """The app's SearchSpace with its volume objective swapped for the
    simulator — same grids, options, distributions and orders; only
    ``cost_model`` changes, so the tuner runs unchanged. ``engine``
    picks the batched analytic envelope (default), its device-compiled
    JAX twin (``"batched-jax"``), or the exact event queue
    (``"event"``, the reference the envelope is validated against);
    ``dtype`` selects the JAX engine's precision and ``cache`` threads a
    persistent :class:`~repro.sim.price_cache.PriceCache` through every
    produced model. ``degraded`` prices every candidate on a degraded
    machine (its spec must match the app's machine shape at the tuned
    processor count — remap tunes fix the shape via a machine_shape
    override)."""
    base_space = app.search_space
    if base_space is None:
        raise ValueError(f"application {app.name!r} declares no search space")
    pattern = getattr(app, "collective", None)
    if pattern is None:
        raise ValueError(f"application {app.name!r} declares no collective")

    def cost_model(procs: int, opts: dict) -> SimulatedTimeCostModel:
        shape = tuple(int(s) for s in app.machine_shape(procs))
        spec = spec_for(shape)
        if degraded is not None and degraded.spec != spec:
            raise ValueError(
                f"degraded machine {degraded.spec.shape} does not match "
                f"{app.name!r}'s machine shape {shape} at {procs} procs; "
                f"fix the shape (e.g. a machine_shape override) before "
                f"tuning degraded"
            )
        return SimulatedTimeCostModel(
            pattern=pattern_with_options(pattern, opts),
            spec=spec,
            step_flops=float(app.step_flops(procs)),
            base=base_space.cost_model(procs, opts),
            elem_bytes=elem_bytes,
            steps=steps,
            engine=engine,
            dtype=dtype,
            cache=cache,
            degraded=degraded,
        )

    return dataclasses.replace(base_space, cost_model=cost_model)


def time_tuned_app(app, *, steps: int = DEFAULT_STEPS,
                   elem_bytes: int = DEFAULT_ELEM_BYTES,
                   engine: str = "batched", dtype: str = "float64",
                   cache: PriceCache | None = None,
                   degraded: DegradedMachine | None = None):
    """A copy of ``app`` whose tuner searches predicted seconds. The
    legacy volume-pair oracle is dropped from the copy (its units are
    elements, not seconds); ``benchmarks/sim_eval.py`` re-checks the
    winner against the volume oracle explicitly."""
    return dataclasses.replace(
        app,
        search_space=time_search_space(app, steps=steps,
                                       elem_bytes=elem_bytes, engine=engine,
                                       dtype=dtype, cache=cache,
                                       degraded=degraded),
        tuning=None,
    )


__all__ = [
    "DEFAULT_ELEM_BYTES",
    "DEFAULT_STEPS",
    "MAX_SCHEDULE_TRANSFERS",
    "SimReport",
    "SimulatedTimeCostModel",
    "default_assignment",
    "inter_node_fraction",
    "pattern_with_options",
    "simulate_app",
    "spec_for",
    "time_search_space",
    "time_tuned_app",
]
