"""Mapple core: processor-space algebra, decompose solver, translation."""
from repro.core.tuples import Tup
from repro.core.pspace import ProcSpace, Processor
from repro.core.machine import (
    Machine, MachineSpec, GPU, TPU, CPU, FBMEM, ZCMEM, SYSMEM,
    V5E_POD, V5E_TWO_PODS, PAPER_CLUSTER,
    PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK, HBM_BYTES,
)
from repro.core.decompose import (
    optimal_factorization,
    greedy_factorization,
    enumerate_factorizations,
    halo_objective,
    transpose_objective,
)
from repro.core.commvolume import (
    halo_surface_volume,
    aniso_halo_volume,
    transpose_volume,
    MatmulProblem,
)
from repro.core.mapper import (
    Mapper,
    block_mapper,
    cyclic_mapper,
    block_cyclic_mapper,
    linear_cyclic_mapper,
    hierarchical_block_mapper,
    linearize_cyclic_mapper,
    special_linearize3d_mapper,
    conditional_linearize3d_mapper,
)
from repro.core.translate import (
    MappingPlan, LayoutSpec, mesh_from_mapper, to_spmd,
)
from repro.core import dsl

__all__ = [
    "Tup", "ProcSpace", "Processor", "Machine", "MachineSpec",
    "GPU", "TPU", "CPU", "FBMEM", "ZCMEM", "SYSMEM",
    "V5E_POD", "V5E_TWO_PODS", "PAPER_CLUSTER",
    "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW_PER_LINK", "HBM_BYTES",
    "optimal_factorization", "greedy_factorization",
    "enumerate_factorizations", "halo_objective", "transpose_objective",
    "halo_surface_volume", "aniso_halo_volume", "transpose_volume",
    "MatmulProblem",
    "Mapper", "block_mapper", "cyclic_mapper", "block_cyclic_mapper",
    "linear_cyclic_mapper", "hierarchical_block_mapper",
    "linearize_cyclic_mapper", "special_linearize3d_mapper",
    "conditional_linearize3d_mapper",
    "MappingPlan", "LayoutSpec", "mesh_from_mapper", "to_spmd", "dsl",
]
