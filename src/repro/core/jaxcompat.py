"""Version portability shims for the JAX APIs this repo leans on.

The translation layer targets `shard_map`, which moved twice across JAX
releases:

  * jax >= 0.6: top-level ``jax.shard_map(..., check_vma=...)``
  * jax 0.4.x:  ``jax.experimental.shard_map.shard_map(..., check_rep=...)``

Every shard_map call in the repo routes through :func:`shard_map` so the
pipeline runs on whichever JAX the environment bakes in.
"""
from __future__ import annotations

from typing import Any, Callable

import jax


def shard_map(f: Callable[..., Any], *, mesh: Any, in_specs: Any,
              out_specs: Any, check_vma: bool = False) -> Callable[..., Any]:
    """`jax.shard_map` on new JAX, `jax.experimental.shard_map` on 0.4.x.

    The validity-check kwarg is dispatched by signature, not JAX version:
    releases where ``jax.shard_map`` already existed but the kwarg was
    still ``check_rep`` are handled too.
    """
    impl = getattr(jax, "shard_map", None)
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl  # 0.4.x

    import inspect

    params = inspect.signature(impl).parameters
    if "check_vma" in params:
        kw = {"check_vma": check_vma}
    elif "check_rep" in params:
        kw = {"check_rep": check_vma}
    else:
        kw = {}
    return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
