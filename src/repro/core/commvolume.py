"""Communication-volume models (paper Sec. 4.2, Sec. 7.2 + framework models).

These are the analytic objectives that ``decompose`` minimizes, plus the
per-application volumes used by the benchmark harnesses to reproduce the
paper's performance deltas as communication ratios, and the LM-parallelism
cost model used by the beyond-paper auto-sharder.

All volumes are in *elements* unless a dtype size is applied by the caller.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


def _prod(xs: Sequence[float]) -> float:
    return math.prod(xs) if xs else 1.0


# ------------------------------------------------------------- paper Sec. 4.2
def hyperrect_surface(extents: Sequence[float]) -> float:
    """SA(x_1..x_k) = 2 * prod(x) * sum(1/x)  (paper Sec. 4.2)."""
    p = _prod(extents)
    return 2.0 * p * sum(1.0 / x for x in extents)


def halo_surface_volume(lengths: Sequence[int], factors: Sequence[int]) -> float:
    """Exact interior-surface volume of Sec. 4.2:

        2*S = SA(w_1..w_k) * d  -  SA(l_1..l_k),   w_m = l_m / d_m.

    Returns S (elements crossing interior processor boundaries, counted once).
    """
    w = [l / f for l, f in zip(lengths, factors)]
    d = _prod(factors)
    return 0.5 * (hyperrect_surface(w) * d - hyperrect_surface(lengths))


def aniso_halo_volume(
    lengths: Sequence[int], factors: Sequence[int], halo: Sequence[float]
) -> float:
    """Sec. 7.2.1: V = sum_n d_n * h_n * prod_{m != n} l_m."""
    k = len(lengths)
    total = 0.0
    for n in range(k):
        rest = _prod([lengths[m] for m in range(k) if m != n])
        total += factors[n] * halo[n] * rest
    return total


def transpose_volume(
    lengths: Sequence[int], factors: Sequence[int], transpose_dims: Sequence[int]
) -> float:
    """Sec. 7.2.2: total all-to-all volume for transposes along given dims.

    V*_n = (1 - 1/d_n) * prod(w) * d  with prod(w)*d = prod(l).
    """
    lprod = _prod(lengths)
    return sum((1.0 - 1.0 / factors[n]) * lprod for n in transpose_dims)


# --------------------------------------------------- matmul algorithm volumes
# Per-algorithm total communication volume (elements moved between
# processors) for C[m,n] += A[m,k] @ B[k,n]. These are the standard
# published costs; used by benchmarks/mapper_tuning.py and
# benchmarks/heuristic_gap.py to reproduce the paper's Fig. 13/Table 2
# effects analytically, and validated at small scale by the shard_map
# implementations in src/repro/matmul/.


@dataclasses.dataclass(frozen=True)
class MatmulProblem:
    m: int
    n: int
    k: int

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k


def cannon_volume(p: MatmulProblem, grid: tuple[int, int]) -> float:
    """Cannon's on a (q, q) torus: q shift rounds of A and B tiles."""
    q1, q2 = grid
    if q1 != q2:
        raise ValueError("Cannon's algorithm requires a square grid")
    q = q1
    tile_a = (p.m / q) * (p.k / q)
    tile_b = (p.k / q) * (p.n / q)
    # Initial skew (<= q/2 hops each) + q-1 shift rounds, every processor.
    rounds = q - 1
    return q * q * rounds * (tile_a + tile_b)


def summa_volume(p: MatmulProblem, grid: tuple[int, int], panel: int = 1) -> float:
    """SUMMA on (pr, pc): row/col broadcasts of panels over k steps."""
    pr, pc = grid
    # Every processor receives A panels (m/pr * k) from its row and
    # B panels (k * n/pc) from its column over the full k dimension.
    recv_per_proc = (p.m / pr) * p.k + p.k * (p.n / pc)
    # Subtract locally-owned panels.
    local = (p.m / pr) * (p.k / pc) + (p.k / pr) * (p.n / pc)
    return pr * pc * max(recv_per_proc - local, 0.0)


def pumma_volume(p: MatmulProblem, grid: tuple[int, int]) -> float:
    """PUMMA has SUMMA-like asymptotic volume (block-cyclic panels)."""
    return summa_volume(p, grid)


def johnson_volume(p: MatmulProblem, grid: tuple[int, int, int]) -> float:
    """Johnson's 3D algorithm on (q, q, q): one broadcast of A and B tiles
    along the third dim + one reduction of C partials."""
    q1, q2, q3 = grid
    tile_a = (p.m / q1) * (p.k / q3)
    tile_b = (p.k / q3) * (p.n / q2)
    tile_c = (p.m / q1) * (p.n / q2)
    nproc = q1 * q2 * q3
    return nproc * (tile_a + tile_b + tile_c)


def solomonik_volume(p: MatmulProblem, grid: tuple[int, int, int]) -> float:
    """Solomonik 2.5D on (q, q, c): c-fold replication; shifts shrink by c."""
    q1, q2, c = grid
    q = q1
    tile_a = (p.m / q) * (p.k / q)
    tile_b = (p.k / q) * (p.n / q)
    tile_c = (p.m / q) * (p.n / q)
    rounds = max(q // c - 1, 0)
    shift = q * q * c * rounds * (tile_a + tile_b)
    # Broadcast of initial replicas + final C reduction over the c axis.
    repl = (c - 1) * (p.m * p.k + p.k * p.n)
    reduce_c = (c - 1) * p.m * p.n
    return shift + repl + reduce_c


def cosma_volume(p: MatmulProblem, nproc: int) -> float:
    """COSMA's near-optimal volume: ~ 2 * m*n*k / sqrt(S_opt) with the
    red-blue pebbling bound; we report the grid-derived volume for the
    grid COSMA's heuristic picks (greedy divide of the largest dim)."""
    g = cosma_grid(p, nproc)
    return johnson_volume(p, g)


def cosma_grid(p: MatmulProblem, nproc: int) -> tuple[int, int, int]:
    """COSMA-style grid: repeatedly assign prime factors to the dimension
    with the largest per-processor extent (communication-avoiding split)."""
    from repro.core.decompose import prime_factorization

    dims = [float(p.m), float(p.n), float(p.k)]
    grid = [1, 1, 1]
    for f in sorted(prime_factorization(nproc), reverse=True):
        j = max(range(3), key=lambda i: dims[i] / grid[i])
        grid[j] *= f
    return tuple(grid)  # type: ignore[return-value]


# ----------------------------------------------------- LM parallelism volumes
@dataclasses.dataclass(frozen=True)
class LMCommModel:
    """Per-training-step communication volume (bytes) of an LM step under a
    (dp, tp, ep, pp) factorization. Used by the auto-sharder's decompose
    objective (the beyond-paper integration of the paper's Sec. 7.2 insight:
    only the objective changes, the enumerator is reused).
    """

    param_bytes: float          # total parameter bytes (dense path)
    act_bytes_per_layer: float  # batch*seq*d_model*dtype on one replica
    n_layers: int
    moe_param_bytes: float = 0.0   # routed-expert parameter bytes
    moe_tokens_bytes: float = 0.0  # per-layer dispatched token bytes (EP a2a)
    n_moe_layers: int = 0

    def step_volume(self, dp: int, tp: int, ep: int = 1) -> float:
        """Total inter-chip bytes moved per optimization step (ring costs)."""
        vol = 0.0
        # DP gradient all-reduce: ring 2*(dp-1)/dp over the dp-sharded grads.
        if dp > 1:
            vol += 2.0 * (dp - 1) / dp * self.param_bytes
        # TP: per layer, fwd+bwd each do ~2 all-reduces (Megatron) of the
        # activation shard: 4 * 2*(tp-1)/tp * act/dp per layer.
        if tp > 1:
            per_layer = 4.0 * 2.0 * (tp - 1) / tp * (self.act_bytes_per_layer / dp)
            vol += per_layer * self.n_layers
        # EP all-to-all: dispatch + combine, fwd + bwd = 4 movements of the
        # routed token bytes, scaled by the fraction leaving the shard.
        if ep > 1 and self.n_moe_layers:
            per_layer = 4.0 * (1.0 - 1.0 / ep) * (self.moe_tokens_bytes / dp)
            vol += per_layer * self.n_moe_layers
        return vol
