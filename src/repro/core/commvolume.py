"""Communication-volume models (paper Sec. 4.2, Sec. 7.2 + framework models).

These are the analytic objectives that ``decompose`` minimizes, plus the
per-application volumes used by the benchmark harnesses to reproduce the
paper's performance deltas as communication ratios, and the LM-parallelism
cost model used by the beyond-paper auto-sharder.

Every model is also packaged behind the :class:`CostModel` protocol — a
callable from a candidate factor tuple to a scalar volume — so halo,
transpose, the six matmul costs and the LM step model are interchangeable
objectives: ``decompose.optimal_factorization(objective=...)``, the mapper
autotuner (``repro.search``) and the auto-sharder all consume the same
objects.

All volumes are in *elements* unless a dtype size is applied by the caller.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence


def _prod(xs: Sequence[float]) -> float:
    return math.prod(xs) if xs else 1.0


# ------------------------------------------------------------- paper Sec. 4.2
def hyperrect_surface(extents: Sequence[float]) -> float:
    """SA(x_1..x_k) = 2 * prod(x) * sum(1/x)  (paper Sec. 4.2)."""
    p = _prod(extents)
    return 2.0 * p * sum(1.0 / x for x in extents)


def halo_surface_volume(lengths: Sequence[int], factors: Sequence[int]) -> float:
    """Exact interior-surface volume of Sec. 4.2:

        2*S = SA(w_1..w_k) * d  -  SA(l_1..l_k),   w_m = l_m / d_m.

    Returns S (elements crossing interior processor boundaries, counted once).
    """
    w = [l / f for l, f in zip(lengths, factors)]
    d = _prod(factors)
    return 0.5 * (hyperrect_surface(w) * d - hyperrect_surface(lengths))


def aniso_halo_volume(
    lengths: Sequence[int], factors: Sequence[int], halo: Sequence[float]
) -> float:
    """Sec. 7.2.1: V = sum_n d_n * h_n * prod_{m != n} l_m."""
    k = len(lengths)
    total = 0.0
    for n in range(k):
        rest = _prod([lengths[m] for m in range(k) if m != n])
        total += factors[n] * halo[n] * rest
    return total


def transpose_volume(
    lengths: Sequence[int], factors: Sequence[int], transpose_dims: Sequence[int]
) -> float:
    """Sec. 7.2.2: total all-to-all volume for transposes along given dims.

    V*_n = (1 - 1/d_n) * prod(w) * d  with prod(w)*d = prod(l).
    """
    lprod = _prod(lengths)
    return sum((1.0 - 1.0 / factors[n]) * lprod for n in transpose_dims)


# --------------------------------------------------- matmul algorithm volumes
# Per-algorithm total communication volume (elements moved between
# processors) for C[m,n] += A[m,k] @ B[k,n]. These are the standard
# published costs; used by benchmarks/mapper_tuning.py and
# benchmarks/heuristic_gap.py to reproduce the paper's Fig. 13/Table 2
# effects analytically, and validated at small scale by the shard_map
# implementations in src/repro/matmul/.


@dataclasses.dataclass(frozen=True)
class MatmulProblem:
    m: int
    n: int
    k: int

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.n * self.k


def cannon_volume(p: MatmulProblem, grid: tuple[int, int]) -> float:
    """Cannon's on a (q, q) torus: q shift rounds of A and B tiles."""
    q1, q2 = grid
    if q1 != q2:
        raise ValueError("Cannon's algorithm requires a square grid")
    q = q1
    tile_a = (p.m / q) * (p.k / q)
    tile_b = (p.k / q) * (p.n / q)
    # Initial skew (<= q/2 hops each) + q-1 shift rounds, every processor.
    rounds = q - 1
    return q * q * rounds * (tile_a + tile_b)


def summa_volume(p: MatmulProblem, grid: tuple[int, int], panel: int = 1) -> float:
    """SUMMA on (pr, pc): row/col broadcasts of panels over k steps."""
    pr, pc = grid
    # Every processor receives A panels (m/pr * k) from its row and
    # B panels (k * n/pc) from its column over the full k dimension.
    recv_per_proc = (p.m / pr) * p.k + p.k * (p.n / pc)
    # Subtract locally-owned panels.
    local = (p.m / pr) * (p.k / pc) + (p.k / pr) * (p.n / pc)
    return pr * pc * max(recv_per_proc - local, 0.0)


def pumma_volume(p: MatmulProblem, grid: tuple[int, int]) -> float:
    """PUMMA has SUMMA-like asymptotic volume (block-cyclic panels)."""
    return summa_volume(p, grid)


def johnson_volume(p: MatmulProblem, grid: tuple[int, int, int]) -> float:
    """Johnson's 3D algorithm on (q, q, q): one broadcast of A and B tiles
    along the third dim + one reduction of C partials."""
    q1, q2, q3 = grid
    tile_a = (p.m / q1) * (p.k / q3)
    tile_b = (p.k / q3) * (p.n / q2)
    tile_c = (p.m / q1) * (p.n / q2)
    nproc = q1 * q2 * q3
    return nproc * (tile_a + tile_b + tile_c)


def solomonik_volume(p: MatmulProblem, grid: tuple[int, int, int]) -> float:
    """Solomonik 2.5D on (q, q, c): c-fold replication; shifts shrink by c."""
    q1, q2, c = grid
    if q1 != q2:
        raise ValueError("Solomonik's 2.5D algorithm requires a (q, q, c) grid")
    if c < 1:
        raise ValueError(f"replication factor must be >= 1, got {c}")
    q = q1
    tile_a = (p.m / q) * (p.k / q)
    tile_b = (p.k / q) * (p.n / q)
    rounds = max(q // c - 1, 0)
    shift = q * q * c * rounds * (tile_a + tile_b)
    # Broadcast of initial replicas + final C reduction over the c axis.
    repl = (c - 1) * (p.m * p.k + p.k * p.n)
    reduce_c = (c - 1) * p.m * p.n
    return shift + repl + reduce_c


def cosma_volume(p: MatmulProblem, nproc: int) -> float:
    """COSMA's near-optimal volume: ~ 2 * m*n*k / sqrt(S_opt) with the
    red-blue pebbling bound; we report the grid-derived volume for the
    grid COSMA's heuristic picks (greedy divide of the largest dim)."""
    g = cosma_grid(p, nproc)
    return johnson_volume(p, g)


def cosma_grid(p: MatmulProblem, nproc: int) -> tuple[int, int, int]:
    """COSMA-style grid: repeatedly assign prime factors to the dimension
    with the largest per-processor extent (communication-avoiding split)."""
    from repro.core.decompose import prime_factorization

    dims = [float(p.m), float(p.n), float(p.k)]
    grid = [1, 1, 1]
    for f in sorted(prime_factorization(nproc), reverse=True):
        j = max(range(3), key=lambda i: dims[i] / grid[i])
        grid[j] *= f
    return tuple(grid)  # type: ignore[return-value]


# ----------------------------------------------------- LM parallelism volumes
@dataclasses.dataclass(frozen=True)
class LMCommModel:
    """Per-training-step communication volume (bytes) of an LM step under a
    (dp, tp, ep, pp) factorization. Used by the auto-sharder's decompose
    objective (the beyond-paper integration of the paper's Sec. 7.2 insight:
    only the objective changes, the enumerator is reused).
    """

    param_bytes: float          # total parameter bytes (dense path)
    act_bytes_per_layer: float  # batch*seq*d_model*dtype on one replica
    n_layers: int
    moe_param_bytes: float = 0.0   # routed-expert parameter bytes
    moe_tokens_bytes: float = 0.0  # per-layer dispatched token bytes (EP a2a)
    n_moe_layers: int = 0

    def step_volume(self, dp: int, tp: int, ep: int = 1) -> float:
        """Total inter-chip bytes moved per optimization step (ring costs)."""
        vol = 0.0
        # DP gradient all-reduce: ring 2*(dp-1)/dp over the dp-sharded grads.
        if dp > 1:
            vol += 2.0 * (dp - 1) / dp * self.param_bytes
        # TP: per layer, fwd+bwd each do ~2 all-reduces (Megatron) of the
        # activation shard: 4 * 2*(tp-1)/tp * act/dp per layer.
        if tp > 1:
            per_layer = 4.0 * 2.0 * (tp - 1) / tp * (self.act_bytes_per_layer / dp)
            vol += per_layer * self.n_layers
        # EP all-to-all: dispatch + combine, fwd + bwd = 4 movements of the
        # routed token bytes, scaled by the fraction leaving the shard.
        if ep > 1 and self.n_moe_layers:
            per_layer = 4.0 * (1.0 - 1.0 / ep) * (self.moe_tokens_bytes / dp)
            vol += per_layer * self.n_moe_layers
        return vol


# --------------------------------------------------------- CostModel protocol
class CostModel:
    """An interchangeable communication objective over candidate factor tuples.

    ``cost(factors)`` maps one ordered factor tuple — a processor grid for
    the application models, a ``(dp, tp[, ep])`` parallelism split for the
    LM model — to a modeled communication volume. Instances are callables,
    so a CostModel drops unchanged into
    ``decompose.optimal_factorization(objective=...)`` and
    ``ProcSpace.decompose(objective=...)``; the mapper autotuner
    (``repro.search``) and the auto-sharder score candidates through the
    same interface.

    Implementations raise ``ValueError`` for factor tuples the model cannot
    use (wrong arity, Cannon's square-grid requirement, ...); enumerative
    consumers catch it and skip the candidate.

    The protocol is unit-agnostic: the models in this module return
    communication volumes (elements or bytes), while
    ``repro.sim.cost.SimulatedTimeCostModel`` returns predicted *seconds*
    from the discrete-event simulator — the same consumers rank either.
    """

    name: str = "cost"

    def cost(self, factors: Sequence[int]) -> float:
        raise NotImplementedError

    def __call__(self, factors: Sequence[int]) -> float:
        return self.cost(factors)


@dataclasses.dataclass(frozen=True)
class HaloCostModel(CostModel):
    """Sec. 4.2 halo exchange: exact interior-surface volume (isotropic) or
    the Sec. 7.2.1 anisotropic form when per-dim ``halo`` weights are given,
    scaled by the number of exchanged ``fields``."""

    lengths: tuple[int, ...]
    halo: tuple[float, ...] | None = None
    fields: int = 1
    name = "halo"

    def cost(self, factors: Sequence[int]) -> float:
        if len(factors) != len(self.lengths):
            raise ValueError(
                f"grid rank {len(factors)} != iteration rank {len(self.lengths)}"
            )
        if self.halo is None:
            return self.fields * halo_surface_volume(self.lengths, factors)
        return self.fields * aniso_halo_volume(self.lengths, factors, self.halo)


@dataclasses.dataclass(frozen=True)
class TransposeCostModel(CostModel):
    """Sec. 7.2.2 mixed objective: anisotropic halo volume plus the
    all-to-all volume of transposes along ``transpose_dims``."""

    lengths: tuple[int, ...]
    transpose_dims: tuple[int, ...]
    halo: tuple[float, ...] | None = None
    name = "transpose"

    def cost(self, factors: Sequence[int]) -> float:
        if len(factors) != len(self.lengths):
            raise ValueError(
                f"grid rank {len(factors)} != iteration rank {len(self.lengths)}"
            )
        h = self.halo if self.halo is not None else (1.0,) * len(self.lengths)
        return aniso_halo_volume(self.lengths, factors, h) + transpose_volume(
            self.lengths, factors, self.transpose_dims
        )


_MATMUL_VOLUMES = {
    "cannon": cannon_volume,
    "summa": summa_volume,
    "pumma": pumma_volume,
    "johnson": johnson_volume,
    "solomonik": solomonik_volume,
    # COSMA candidates are scored with the 3D (Johnson) volume at the
    # candidate grid; cosma_volume() is that cost at COSMA's heuristic grid.
    "cosma": johnson_volume,
}


@dataclasses.dataclass(frozen=True)
class MatmulCostModel(CostModel):
    """Published total communication volume of one distributed matmul
    algorithm as a function of the candidate processor grid."""

    problem: MatmulProblem
    algorithm: str

    def __post_init__(self) -> None:
        if self.algorithm not in _MATMUL_VOLUMES:
            raise ValueError(
                f"unknown matmul algorithm {self.algorithm!r}; "
                f"known: {sorted(_MATMUL_VOLUMES)}"
            )

    @property
    def name(self) -> str:  # type: ignore[override]
        return self.algorithm

    def cost(self, factors: Sequence[int]) -> float:
        return _MATMUL_VOLUMES[self.algorithm](self.problem, tuple(factors))


@dataclasses.dataclass(frozen=True)
class GatherScatterCostModel(CostModel):
    """Circuit-style gather/scatter: all_gather(V) + psum_scatter(Q) ring
    volume, with an optional discount for zero-copy (ZCMEM) placement of
    the shared state (the paper's Table 2 circuit tuning)."""

    nodes_per_piece: int
    discount: float = 1.0
    name = "gather_scatter"

    def cost(self, factors: Sequence[int]) -> float:
        (procs,) = factors
        base = 2.0 * (procs - 1) * (self.nodes_per_piece * procs)
        return self.discount * base


@dataclasses.dataclass(frozen=True)
class LMStepCostModel(CostModel):
    """The auto-sharder's objective: per-step LM communication under a
    ``(dp, tp)`` or ``(dp, tp, ep)`` factorization of the chip count."""

    model: LMCommModel
    name = "lm_step"

    def cost(self, factors: Sequence[int]) -> float:
        if len(factors) == 2:
            dp, tp = factors
            ep = 1
        elif len(factors) == 3:
            dp, tp, ep = factors
        else:
            raise ValueError(f"expected (dp, tp[, ep]), got {tuple(factors)}")
        return self.model.step_volume(dp, tp, ep)
