"""Translation layer: Mapple mappers -> JAX SPMD artifacts (paper Sec. 5).

Legion realizes a mapper by invoking SHARD/MAP callbacks per task launch.
XLA is SPMD-static, so the faithful TPU translation pre-evaluates the
mapping function over the whole tile grid *once* and bakes the result into
the `jax.sharding.Mesh`:

  * JAX assigns block ``i`` of a sharded axis to mesh position ``i``;
  * therefore ANY bijective Mapple tile->processor map is realized by
    permuting the flat device list before reshaping it into the mesh.

Block distributions are identity permutations; cyclic / hierarchical /
systolic (Cannon, Solomonik) maps become non-trivial permutations. The
remaining Mapple directives translate to:

  Region      -> NamedSharding memory_kind ('device' | 'pinned_host')
  Layout      -> operand dim-order permutation hints
  GarbageCollect -> buffer donation sets (donate_argnums)
  Backpressure   -> bounded async dispatch depth in the step loop
                    (and the simulator's in-flight step bound)

The resulting :class:`MappingPlan` is also the simulator's input contract
(``repro.sim.cost.simulate_app``): ``meta['device_permutation']``
reshaped to ``meta['tile_grid']`` is the exact tile->processor
assignment the collective schedules expand against, and
``backpressure`` bounds the engine's in-flight step depth.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Mapping, Sequence

import numpy as np

from repro.core.mapper import Mapper
from repro.core.machine import FBMEM


@dataclasses.dataclass(frozen=True)
class LayoutSpec:
    """The paper's Layout directive: ordering + alignment per operand."""

    order: str = "C"          # "C" (row-major) | "F" (column-major)
    alignment: int = 128      # bytes; TPU lanes want 128-element tiles
    soa: bool = True          # Struct-of-Arrays preferred on TPU


@dataclasses.dataclass
class MappingPlan:
    """Everything the launcher needs to execute a step under a mapper."""

    mesh: Any                                    # jax.sharding.Mesh
    axis_names: tuple[str, ...]
    in_specs: dict[str, Any]                     # operand -> PartitionSpec
    out_specs: dict[str, Any]
    memory_kinds: dict[str, str] = dataclasses.field(default_factory=dict)
    layouts: dict[str, LayoutSpec] = dataclasses.field(default_factory=dict)
    donate: tuple[str, ...] = ()
    backpressure: int = 2                        # max in-flight steps
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    def sharding(self, operand: str):
        """NamedSharding for an operand, honoring its Region memory kind."""
        import jax

        spec = self.in_specs.get(operand) or self.out_specs.get(operand)
        kind = self.memory_kinds.get(operand, FBMEM)
        try:
            return jax.sharding.NamedSharding(self.mesh, spec, memory_kind=kind)
        except (ValueError, TypeError):
            # Backend without memory-kind support (CPU tests): fall back.
            return jax.sharding.NamedSharding(self.mesh, spec)


def device_permutation(mapper: Mapper, tile_grid: Sequence[int], nprocs: int
                       ) -> np.ndarray:
    """Flat tile order -> device id (bijective), from the mapping function."""
    return mapper.tile_permutation(tile_grid, nprocs)


def mesh_from_mapper(
    mapper: Mapper,
    tile_grid: Sequence[int],
    axis_names: Sequence[str],
    devices: Sequence[Any] | None = None,
):
    """Build a Mesh whose device order realizes ``mapper`` (Sec. 5 analogue).

    ``tile_grid`` is the processor-grid the computation is tiled over (one
    tile per device); ``mapper`` maps tile coordinates to physical devices.
    """
    import jax

    if devices is None:
        devices = jax.devices()
    tile_grid = tuple(int(t) for t in tile_grid)
    n = int(np.prod(tile_grid))
    if n != len(devices):
        raise ValueError(
            f"tile grid {tile_grid} needs {n} devices, got {len(devices)}"
        )
    perm = device_permutation(mapper, tile_grid, n)
    dev_arr = np.asarray(devices, dtype=object)[perm].reshape(tile_grid)
    return jax.sharding.Mesh(dev_arr, tuple(axis_names))


def owned_tiles(mapper: Mapper, ispace: Sequence[int], nprocs: int
                ) -> dict[int, list[tuple[int, ...]]]:
    """Many-to-one case: tiles owned by each device (cyclic distributions).

    Used by shard_map bodies that iterate over their owned tiles when the
    iteration grid is larger than the processor grid. Consumes the cached
    vectorized assignment grid and groups points with one stable argsort
    (per-device point order stays row-major, as the kernels expect).
    """
    grid = mapper.assignment_grid(ispace)
    flat = grid.reshape(-1)
    if flat.size and (flat.min() < 0 or flat.max() >= nprocs):
        raise ValueError(
            f"mapper {mapper.name} assigns device ids outside [0, {nprocs})"
        )
    order = np.argsort(flat, kind="stable")
    pts = np.stack(np.unravel_index(order, grid.shape), axis=1)
    bounds = np.searchsorted(flat[order], np.arange(nprocs + 1))
    return {
        d: [tuple(int(x) for x in row) for row in pts[bounds[d]:bounds[d + 1]]]
        for d in range(nprocs)
    }


#: Mapple directives don't distinguish inputs from outputs, so the default
#: operand-spec derivation uses a NAMING CONVENTION: exactly ``out`` or
#: ``out<digits>`` is an output operand; everything else (``arg0``,
#: ``output_mask``, ...) is an input. Matched exactly — never by prefix —
#: so input names that merely start with "out" are not silently dropped.
_OUT_OPERAND = re.compile(r"^out\d*$")


def is_output_operand(name: str) -> bool:
    return _OUT_OPERAND.fullmatch(name) is not None


def declared_operands(program, task: str) -> tuple[str, ...]:
    """Operand names a task's Region/Layout/GarbageCollect directives declare.

    This is the ground truth for default operand specs in :func:`to_spmd` —
    the previous hardcoded ``arg0``/``arg1`` defaults apply only when the
    program declares nothing for the task. Outputs are recognized by the
    :data:`_OUT_OPERAND` naming convention.
    """
    names = (
        {arg for (t, arg) in program.regions if t == task}
        | {arg for (t, arg) in program.layouts if t == task}
        | {arg for (t, arg) in program.garbage_collect if t == task}
    )
    return tuple(sorted(names))


def to_spmd(
    program,                      # repro.core.dsl.MapperProgram
    task: str,
    tile_grid: Sequence[int],
    axis_names: Sequence[str],
    operand_specs: Mapping[str, Any] | None = None,
    out_operand_specs: Mapping[str, Any] | None = None,
    devices: Sequence[Any] | None = None,
) -> MappingPlan:
    """End-to-end translation entry point: parsed Mapple program -> SPMD plan.

    The full pipeline step used by the app registry
    (``dsl.parse -> Mapper -> to_spmd -> commvolume``). Unlike
    :func:`plan_from_program` this always succeeds on machines with too few
    physical devices: the mapping function is still evaluated over the whole
    tile grid and validated as a bijection, and the resulting device
    permutation is recorded in ``meta['device_permutation']``; the concrete
    ``jax.sharding.Mesh`` is only materialized when enough devices exist
    (``mesh`` is ``None`` on an abstract plan).
    """
    mapper_name = program.index_task_maps.get(task)
    if mapper_name is None:
        raise KeyError(f"no IndexTaskMap for task {task!r}")
    mapper = program.mappers[mapper_name]
    tile_grid = tuple(int(t) for t in tile_grid)
    n = int(np.prod(tile_grid))
    perm = device_permutation(mapper, tile_grid, n)

    mesh = None
    if devices is None:
        try:
            import jax

            devices = jax.devices()
        except Exception:
            devices = []
    if len(devices) >= n:
        import jax

        dev_arr = np.asarray(
            list(devices[:n]), dtype=object
        )[perm].reshape(tile_grid)
        mesh = jax.sharding.Mesh(dev_arr, tuple(axis_names))

    if operand_specs is None or out_operand_specs is None:
        try:
            from jax.sharding import PartitionSpec as P

            default_spec = P(*axis_names)
        except Exception:
            default_spec = tuple(axis_names)
        declared = declared_operands(program, task)
        if operand_specs is None:
            names = tuple(a for a in declared if not is_output_operand(a))
            operand_specs = {arg: default_spec for arg in names or ("arg0", "arg1")}
        if out_operand_specs is None:
            outs = tuple(a for a in declared if is_output_operand(a)) or ("out",)
            out_operand_specs = {arg: default_spec for arg in outs}

    memory_kinds = {
        arg: mem for (t, arg), (_, mem) in program.regions.items() if t == task
    }
    layouts = {
        arg: spec for (t, arg), spec in program.layouts.items() if t == task
    }
    donate = tuple(arg for (t, arg) in program.garbage_collect if t == task)
    return MappingPlan(
        mesh=mesh,
        axis_names=tuple(axis_names),
        in_specs=dict(operand_specs),
        out_specs=dict(out_operand_specs),
        memory_kinds=memory_kinds,
        layouts=layouts,
        donate=donate,
        backpressure=program.backpressure.get(task, 2),
        meta={
            "mapper": mapper_name,
            "task": task,
            "tile_grid": tile_grid,
            "nprocs": n,
            "device_permutation": perm,
            "mapper_ir": mapper.describe(),
        },
    )


def plan_from_program(
    program,                      # repro.core.dsl.MapperProgram
    task: str,
    tile_grid: Sequence[int],
    axis_names: Sequence[str],
    operand_specs: Mapping[str, Any],
    out_operand_specs: Mapping[str, Any],
    devices: Sequence[Any] | None = None,
) -> MappingPlan:
    """Assemble a MappingPlan for ``task`` from a parsed Mapple program."""
    mapper_name = program.index_task_maps.get(task)
    if mapper_name is None:
        raise KeyError(f"no IndexTaskMap for task {task!r}")
    mapper = program.mappers[mapper_name]
    mesh = mesh_from_mapper(mapper, tile_grid, axis_names, devices)
    memory_kinds = {
        arg: mem for (t, arg), (_, mem) in program.regions.items() if t == task
    }
    layouts = {
        arg: spec for (t, arg), spec in program.layouts.items() if t == task
    }
    donate = tuple(arg for (t, arg) in program.garbage_collect if t == task)
    return MappingPlan(
        mesh=mesh,
        axis_names=tuple(axis_names),
        in_specs=dict(operand_specs),
        out_specs=dict(out_operand_specs),
        memory_kinds=memory_kinds,
        layouts=layouts,
        donate=donate,
        backpressure=program.backpressure.get(task, 2),
        meta={"mapper": mapper_name, "task": task},
    )
