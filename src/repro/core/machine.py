"""Hierarchical machine model — the Mapple ``Machine(GPU)`` abstraction.

The paper models a machine as a multi-dimensional processor space
(e.g. nodes x GPUs-per-node). On TPU the analogous hierarchy is
pods x chips (with chips arranged in an ICI torus inside a pod and a
slower DCI fabric between pods). :func:`Machine` returns the *root*
:class:`~repro.core.pspace.ProcSpace` on which all transformation
primitives operate.

Hardware constants are TPU v5e per the assignment:
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.pspace import ProcSpace

# ----------------------------------------------------------------- constants
PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link (intra-pod)
ICI_LINKS_PER_CHIP = 4          # 2D torus in v5e pods
DCI_BW_PER_CHIP = 6.0e9         # bytes/s per chip cross-pod (modeled)
HBM_BYTES = 16 * 2**30          # 16 GiB per v5e chip

# Processor "kinds" (the paper's Machine(GPU) / Machine(CPU)).
GPU = "tpu"     # accelerator chips -- named GPU for paper fidelity
TPU = "tpu"
CPU = "cpu"     # host cores (offload target)

# Memory kinds (paper's FBMEM / ZCMEM / SYSMEM -> TPU memory spaces).
FBMEM = "device"         # HBM
ZCMEM = "pinned_host"    # host memory visible to the device
SYSMEM = "unpinned_host"


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Physical description of the target machine.

    ``link_bws`` is the per-level interconnect bandwidth tuple, outermost
    level first: bytes/s one *port* (an endpoint's injection path) can
    push through that level's fabric. When omitted it is derived from the
    legacy two-fabric constants: the outermost level of a multi-level
    machine gets ``dci_bw`` (one NIC), every other level the per-chip
    ICI aggregate ``ici_bw * ici_links``.
    """

    shape: tuple[int, ...]                 # e.g. (2, 256) pods x chips
    level_names: tuple[str, ...]           # e.g. ("pod", "chip")
    kind: str = TPU
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW_PER_LINK
    ici_links: int = ICI_LINKS_PER_CHIP
    dci_bw: float = DCI_BW_PER_CHIP
    hbm_bytes: int = HBM_BYTES
    link_bws: tuple[float, ...] | None = None   # per-level, outermost first

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.level_names):
            raise ValueError(
                f"shape {self.shape} and level_names {self.level_names} "
                f"must have the same rank"
            )
        if self.link_bws is not None:
            if len(self.link_bws) != len(self.shape):
                raise ValueError(
                    f"link_bws needs one bandwidth per level: got "
                    f"{len(self.link_bws)} for {len(self.shape)} levels"
                )
            if any(bw <= 0 for bw in self.link_bws):
                raise ValueError(f"link bandwidths must be > 0: {self.link_bws}")

    @property
    def nprocs(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out

    @property
    def level_strides(self) -> tuple[int, ...]:
        """Row-major flat-id stride per level, outermost first: dividing a
        flat processor id by ``level_strides[L]`` yields the flat index of
        the level-(L+1) subtree containing it — the port id the simulator
        charges for a level-L crossing."""
        strides = []
        acc = 1
        for extent in reversed(self.shape):
            strides.append(acc)
            acc *= extent
        return tuple(reversed(strides))

    @property
    def level_ports(self) -> tuple[int, ...]:
        """Number of ports at each level, outermost first: one port per
        level-(L+1) subtree, so ``nprocs // level_strides[L]``. Level 0 of
        a (nodes, gpus) machine has ``nodes`` NICs, not ``nprocs``."""
        n = self.nprocs
        return tuple(n // s for s in self.level_strides)

    @property
    def level_bws(self) -> tuple[float, ...]:
        """Per-level port bandwidth, outermost first (always full-rank)."""
        if self.link_bws is not None:
            return self.link_bws
        k = len(self.shape)
        chip = self.ici_bw * self.ici_links
        if k == 1:
            return (chip,)
        return (self.dci_bw,) + (chip,) * (k - 1)

    def link_bw(self, level: int) -> float:
        """Port bandwidth of the interconnect at level (0 = outermost)."""
        if not 0 <= level < len(self.shape):
            raise ValueError(
                f"level {level} out of range for a {len(self.shape)}-level "
                f"machine {self.shape}"
            )
        return self.level_bws[level]


def modeled_step_time(flops_total: float, comm_elems: float, chips: int,
                      *, elem_bytes: int = 4,
                      spec: "MachineSpec | None" = None) -> float:
    """Modeled step time on a FLAT fabric: compute and communication
    overlap, the shorter leg costs a 10% tax. The single time model behind
    the Table 2 speedups (benchmarks/mapper_tuning.py) and the
    heuristic-gap margins (benchmarks/heuristic_gap.py) — shared so the
    two harnesses can never drift onto different fabric assumptions.

    This is the documented fast-path fallback of the discrete-event
    simulator (``repro.sim``): it equals the simulator's flat-topology
    special case (all processors on one level, uniform all-to-neighbour
    traffic) up to the 10% overlap tax — asserted by
    ``tests/test_sim.py::test_flat_topology_matches_modeled_step_time``.
    Hierarchy-aware questions (inter-node vs intra-node bytes) go to the
    simulator; this stays the cheap single-formula answer. ``spec`` routes
    the bandwidth through the per-level ``MachineSpec.link_bw`` tuple
    (innermost level); the default keeps the legacy v5e flat fabric.
    """
    if spec is None:
        link = ICI_BW_PER_LINK * ICI_LINKS_PER_CHIP
        peak = PEAK_FLOPS_BF16
    else:
        link = spec.link_bw(len(spec.shape) - 1)
        peak = spec.peak_flops
    compute = flops_total / (chips * peak)
    comm = comm_elems * elem_bytes / (chips * link)
    return max(compute, comm) + 0.1 * min(compute, comm)


# Canonical machines used across the repo.
V5E_POD = MachineSpec(shape=(16, 16), level_names=("data", "model"))
V5E_TWO_PODS = MachineSpec(shape=(2, 16, 16), level_names=("pod", "data", "model"))
PAPER_CLUSTER = MachineSpec(
    shape=(2, 4), level_names=("node", "gpu"), kind=GPU,
)  # the paper's running example: 2 nodes x 4 V100s


def Machine(kind: str = TPU, spec: MachineSpec | None = None,
            shape: Sequence[int] | None = None) -> ProcSpace:
    """The paper's ``Machine(GPU)`` entry point.

    Returns the root processor space. Defaults to the paper's running
    2-node x 4-GPU example so DSL snippets from the paper run verbatim;
    production code passes an explicit spec or shape.
    """
    if shape is not None:
        shp = tuple(int(s) for s in shape)
    elif spec is not None:
        shp = spec.shape
    else:
        shp = PAPER_CLUSTER.shape
    return ProcSpace(shp, shp)
