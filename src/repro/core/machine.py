"""Hierarchical machine model — the Mapple ``Machine(GPU)`` abstraction.

The paper models a machine as a multi-dimensional processor space
(e.g. nodes x GPUs-per-node). On TPU the analogous hierarchy is
pods x chips (with chips arranged in an ICI torus inside a pod and a
slower DCI fabric between pods). :func:`Machine` returns the *root*
:class:`~repro.core.pspace.ProcSpace` on which all transformation
primitives operate.

Hardware constants are TPU v5e per the assignment:
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.pspace import ProcSpace

# ----------------------------------------------------------------- constants
PEAK_FLOPS_BF16 = 197e12        # per chip, bf16
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW_PER_LINK = 50e9          # bytes/s per link (intra-pod)
ICI_LINKS_PER_CHIP = 4          # 2D torus in v5e pods
DCI_BW_PER_CHIP = 6.0e9         # bytes/s per chip cross-pod (modeled)
HBM_BYTES = 16 * 2**30          # 16 GiB per v5e chip

# Processor "kinds" (the paper's Machine(GPU) / Machine(CPU)).
GPU = "tpu"     # accelerator chips -- named GPU for paper fidelity
TPU = "tpu"
CPU = "cpu"     # host cores (offload target)

# Memory kinds (paper's FBMEM / ZCMEM / SYSMEM -> TPU memory spaces).
FBMEM = "device"         # HBM
ZCMEM = "pinned_host"    # host memory visible to the device
SYSMEM = "unpinned_host"


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Physical description of the target machine.

    ``link_bws`` is the per-level interconnect bandwidth tuple, outermost
    level first: bytes/s one *port* (an endpoint's injection path) can
    push through that level's fabric. When omitted it is derived from the
    legacy two-fabric constants: the outermost level of a multi-level
    machine gets ``dci_bw`` (one NIC), every other level the per-chip
    ICI aggregate ``ici_bw * ici_links``.
    """

    shape: tuple[int, ...]                 # e.g. (2, 256) pods x chips
    level_names: tuple[str, ...]           # e.g. ("pod", "chip")
    kind: str = TPU
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW_PER_LINK
    ici_links: int = ICI_LINKS_PER_CHIP
    dci_bw: float = DCI_BW_PER_CHIP
    hbm_bytes: int = HBM_BYTES
    link_bws: tuple[float, ...] | None = None   # per-level, outermost first

    def __post_init__(self) -> None:
        if len(self.shape) != len(self.level_names):
            raise ValueError(
                f"shape {self.shape} and level_names {self.level_names} "
                f"must have the same rank"
            )
        if self.link_bws is not None:
            if len(self.link_bws) != len(self.shape):
                raise ValueError(
                    f"link_bws needs one bandwidth per level: got "
                    f"{len(self.link_bws)} for {len(self.shape)} levels"
                )
            if any(bw <= 0 for bw in self.link_bws):
                raise ValueError(f"link bandwidths must be > 0: {self.link_bws}")

    @property
    def nprocs(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out

    @property
    def level_strides(self) -> tuple[int, ...]:
        """Row-major flat-id stride per level, outermost first: dividing a
        flat processor id by ``level_strides[L]`` yields the flat index of
        the level-(L+1) subtree containing it — the port id the simulator
        charges for a level-L crossing."""
        strides = []
        acc = 1
        for extent in reversed(self.shape):
            strides.append(acc)
            acc *= extent
        return tuple(reversed(strides))

    @property
    def level_ports(self) -> tuple[int, ...]:
        """Number of ports at each level, outermost first: one port per
        level-(L+1) subtree, so ``nprocs // level_strides[L]``. Level 0 of
        a (nodes, gpus) machine has ``nodes`` NICs, not ``nprocs``."""
        n = self.nprocs
        return tuple(n // s for s in self.level_strides)

    @property
    def level_bws(self) -> tuple[float, ...]:
        """Per-level port bandwidth, outermost first (always full-rank)."""
        if self.link_bws is not None:
            return self.link_bws
        k = len(self.shape)
        chip = self.ici_bw * self.ici_links
        if k == 1:
            return (chip,)
        return (self.dci_bw,) + (chip,) * (k - 1)

    def link_bw(self, level: int) -> float:
        """Port bandwidth of the interconnect at level (0 = outermost)."""
        if not 0 <= level < len(self.shape):
            raise ValueError(
                f"level {level} out of range for a {len(self.shape)}-level "
                f"machine {self.shape}"
            )
        return self.level_bws[level]


@dataclasses.dataclass(frozen=True)
class DegradedMachine:
    """A degraded *view* over a :class:`MachineSpec`: dead processors plus
    per-level port contention.

    ``dead_procs`` are flat processor ids (row-major over ``spec.shape``)
    that are unplaceable — a plan that puts work on one is invalid and the
    simulator refuses to price it. ``contention`` is one tuple per level
    (outermost first), one slowdown factor per *port* at that level
    (``spec.level_ports``): a factor ``c >= 1`` means background traffic is
    stealing that port's bandwidth, so bytes drain ``c`` times slower.
    Message latency (alpha) is unaffected — contention is a bandwidth
    phenomenon. ``contention=None`` means every factor is exactly 1.0.

    A trivial view (no dead procs, all factors 1.0) must price
    bit-identically to the healthy machine; ``Topology.from_spec``
    normalizes it to ``None`` to guarantee that.
    """

    spec: MachineSpec
    dead_procs: tuple[int, ...] = ()
    contention: tuple[tuple[float, ...], ...] | None = None

    def __post_init__(self) -> None:
        dead = tuple(sorted({int(p) for p in self.dead_procs}))
        object.__setattr__(self, "dead_procs", dead)
        n = self.spec.nprocs
        for p in dead:
            if not 0 <= p < n:
                raise ValueError(f"dead proc {p} out of range for {n} procs")
        if len(dead) >= n:
            raise ValueError("cannot kill every processor")
        if self.contention is not None:
            ports = self.spec.level_ports
            if len(self.contention) != len(ports):
                raise ValueError(
                    f"contention needs one tuple per level: got "
                    f"{len(self.contention)} for {len(ports)} levels"
                )
            norm = []
            for lvl, (row, nport) in enumerate(zip(self.contention, ports)):
                row = tuple(float(c) for c in row)
                if len(row) != nport:
                    raise ValueError(
                        f"contention level {lvl} needs {nport} port factors, "
                        f"got {len(row)}"
                    )
                if any(c < 1.0 for c in row):
                    raise ValueError(
                        f"contention factors must be >= 1.0 (level {lvl}: {row})"
                    )
                norm.append(row)
            object.__setattr__(self, "contention", tuple(norm))

    # ------------------------------------------------------------- queries
    @property
    def is_trivial(self) -> bool:
        """True when this view prices identically to the healthy machine."""
        if self.dead_procs:
            return False
        if self.contention is None:
            return True
        return all(c == 1.0 for row in self.contention for c in row)

    @property
    def n_alive(self) -> int:
        return self.spec.nprocs - len(self.dead_procs)

    def alive_procs(self) -> tuple[int, ...]:
        dead = set(self.dead_procs)
        return tuple(p for p in range(self.spec.nprocs) if p not in dead)

    def port_contention(self, level: int) -> tuple[float, ...]:
        """Per-port slowdown factors at ``level`` (all 1.0 when unset)."""
        nport = self.spec.level_ports[level]
        if self.contention is None:
            return (1.0,) * nport
        return self.contention[level]

    # -------------------------------------------------------- constructors
    @classmethod
    def healthy(cls, spec: MachineSpec) -> "DegradedMachine":
        return cls(spec=spec)

    @classmethod
    def fail_procs(cls, spec: MachineSpec,
                   procs: Sequence[int]) -> "DegradedMachine":
        return cls(spec=spec, dead_procs=tuple(int(p) for p in procs))

    @classmethod
    def fail_nodes(cls, spec: MachineSpec, level: int,
                   nodes: Sequence[int]) -> "DegradedMachine":
        """Kill whole level-``level`` subtrees (e.g. full nodes): every
        processor whose flat id falls inside one of the named subtrees."""
        stride = spec.level_strides[level]
        nport = spec.level_ports[level]
        dead = []
        for node in nodes:
            node = int(node)
            if not 0 <= node < nport:
                raise ValueError(
                    f"level-{level} subtree {node} out of range "
                    f"(machine has {nport})"
                )
            dead.extend(range(node * stride, (node + 1) * stride))
        return cls(spec=spec, dead_procs=tuple(dead))

    @classmethod
    def contend(cls, spec: MachineSpec, level: int,
                factors: dict[int, float]) -> "DegradedMachine":
        """Background traffic on specific ports of one level:
        ``factors[port] = c`` slows that port's byte drain by ``c``x."""
        rows = []
        for lvl, nport in enumerate(spec.level_ports):
            row = [1.0] * nport
            if lvl == level:
                for port, c in factors.items():
                    port = int(port)
                    if not 0 <= port < nport:
                        raise ValueError(
                            f"port {port} out of range for level {lvl} "
                            f"({nport} ports)"
                        )
                    row[port] = float(c)
            rows.append(tuple(row))
        return cls(spec=spec, contention=tuple(rows))

    def merged(self, other: "DegradedMachine") -> "DegradedMachine":
        """Compose two degradations of the same machine: union of dead
        procs, product of per-port contention factors."""
        if other.spec != self.spec:
            raise ValueError("cannot merge degradations of different machines")
        dead = tuple(set(self.dead_procs) | set(other.dead_procs))
        if self.contention is None and other.contention is None:
            cont = None
        else:
            a = [self.port_contention(lvl)
                 for lvl in range(len(self.spec.shape))]
            b = [other.port_contention(lvl)
                 for lvl in range(len(self.spec.shape))]
            cont = tuple(
                tuple(x * y for x, y in zip(ra, rb)) for ra, rb in zip(a, b)
            )
        return DegradedMachine(spec=self.spec, dead_procs=dead, contention=cont)


def modeled_step_time(flops_total: float, comm_elems: float, chips: int,
                      *, elem_bytes: int = 4,
                      spec: "MachineSpec | None" = None) -> float:
    """Modeled step time on a FLAT fabric: compute and communication
    overlap, the shorter leg costs a 10% tax. The single time model behind
    the Table 2 speedups (benchmarks/mapper_tuning.py) and the
    heuristic-gap margins (benchmarks/heuristic_gap.py) — shared so the
    two harnesses can never drift onto different fabric assumptions.

    This is the documented fast-path fallback of the discrete-event
    simulator (``repro.sim``): it equals the simulator's flat-topology
    special case (all processors on one level, uniform all-to-neighbour
    traffic) up to the 10% overlap tax — asserted by
    ``tests/test_sim.py::test_flat_topology_matches_modeled_step_time``.
    Hierarchy-aware questions (inter-node vs intra-node bytes) go to the
    simulator; this stays the cheap single-formula answer. ``spec`` routes
    the bandwidth through the per-level ``MachineSpec.link_bw`` tuple
    (innermost level); the default keeps the legacy v5e flat fabric.
    """
    if spec is None:
        link = ICI_BW_PER_LINK * ICI_LINKS_PER_CHIP
        peak = PEAK_FLOPS_BF16
    else:
        link = spec.link_bw(len(spec.shape) - 1)
        peak = spec.peak_flops
    compute = flops_total / (chips * peak)
    comm = comm_elems * elem_bytes / (chips * link)
    return max(compute, comm) + 0.1 * min(compute, comm)


# Canonical machines used across the repo.
V5E_POD = MachineSpec(shape=(16, 16), level_names=("data", "model"))
V5E_TWO_PODS = MachineSpec(shape=(2, 16, 16), level_names=("pod", "data", "model"))
PAPER_CLUSTER = MachineSpec(
    shape=(2, 4), level_names=("node", "gpu"), kind=GPU,
)  # the paper's running example: 2 nodes x 4 V100s


def Machine(kind: str = TPU, spec: MachineSpec | None = None,
            shape: Sequence[int] | None = None) -> ProcSpace:
    """The paper's ``Machine(GPU)`` entry point.

    Returns the root processor space. Defaults to the paper's running
    2-node x 4-GPU example so DSL snippets from the paper run verbatim;
    production code passes an explicit spec or shape.
    """
    if shape is not None:
        shp = tuple(int(s) for s in shape)
    elif spec is not None:
        shp = spec.shape
    else:
        shp = PAPER_CLUSTER.shape
    return ProcSpace(shp, shp)
