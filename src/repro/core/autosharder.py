"""Decompose-driven mesh planning for LM training/serving (beyond-paper).

The paper's Sec. 7.2 observation — *only the objective changes, the same
enumerator applies* — is exactly what a production LM framework needs to
pick its parallelism factorization. This module reuses the paper's optimal
enumerator (`enumerate_factorizations`) with a communication objective built
from the LM step (DP grad all-reduce, TP activation collectives, EP
all-to-all), subject to hardware-integrality constraints (tp | heads,
ep | experts, dp | batch).

This is the "Mapple as a first-class feature" integration: the launcher
asks the planner for a `MeshPlan`, the same way the matmul benchmarks ask
`decompose` for a processor grid.
"""
from __future__ import annotations

import dataclasses

from repro.core.commvolume import LMCommModel, LMStepCostModel
from repro.core.decompose import enumerate_factorizations


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A chosen factorization of the chip count into parallelism axes."""

    dp: int
    tp: int
    ep: int = 1
    step_comm_bytes: float = 0.0
    candidates_considered: int = 0

    @property
    def shape(self) -> tuple[int, int]:
        return (self.dp, self.tp)


@dataclasses.dataclass(frozen=True)
class LMWorkload:
    """Iteration-space description of one LM step, for the planner."""

    global_batch: int
    seq_len: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    param_count: float
    dtype_bytes: int = 2
    n_experts: int = 0            # routed experts (0 = dense)
    n_moe_layers: int = 0
    topk: int = 0
    ffn_mult_bytes: float = 0.0   # routed expert param bytes

    def comm_model(self) -> LMCommModel:
        act = self.global_batch * self.seq_len * self.d_model * self.dtype_bytes
        moe_tok = (
            self.global_batch * self.seq_len * self.topk * self.d_model
            * self.dtype_bytes
        )
        return LMCommModel(
            param_bytes=self.param_count * 4.0,   # fp32 grads all-reduced
            act_bytes_per_layer=float(act),
            n_layers=self.n_layers,
            moe_param_bytes=self.ffn_mult_bytes,
            moe_tokens_bytes=float(moe_tok),
            n_moe_layers=self.n_moe_layers,
        )


def plan_mesh(
    n_chips: int,
    wl: LMWorkload,
    *,
    use_ep: bool | None = None,
    max_tp: int = 64,
) -> MeshPlan:
    """Pick (dp, tp[, ep]) minimizing modeled step communication.

    Constraints (integrality, the paper's l_m/w_m in N analogue):
      * dp divides global_batch;
      * tp divides n_kv_heads (so KV heads shard evenly) and d_model;
      * ep divides n_experts; ep and tp share the 'model' axis here, so
        we require ep == tp for MoE archs when use_ep (experts ride the
        model axis — one-axis EP, the deployment-standard layout).
    """
    objective = LMStepCostModel(wl.comm_model())
    moe = wl.n_experts > 0 if use_ep is None else use_ep
    k = 2
    best: tuple[float, tuple[int, ...]] | None = None
    considered = 0
    for f in enumerate_factorizations(n_chips, k):
        dp, tp = f
        considered += 1
        if tp > max_tp or dp > wl.global_batch:
            continue
        if wl.global_batch % dp != 0:
            continue
        if tp > 1 and (wl.n_heads % tp != 0 or wl.d_model % tp != 0):
            continue
        ep = tp if (moe and wl.n_experts % tp == 0) else 1
        cost = objective((dp, tp, ep))
        key = (cost, f)
        if best is None or key < best:
            best = key
    if best is None:
        raise ValueError(f"no feasible (dp, tp) factorization of {n_chips}")
    dp, tp = best[1]
    ep = tp if (moe and wl.n_experts % tp == 0) else 1
    return MeshPlan(dp=dp, tp=tp, ep=ep, step_comm_bytes=best[0],
                    candidates_considered=considered)


def plan_report(n_chips: int, wl: LMWorkload) -> str:
    """Human-readable planning table (used by examples/)."""
    objective = LMStepCostModel(wl.comm_model())
    rows = []
    for f in sorted(enumerate_factorizations(n_chips, 2)):
        dp, tp = f
        if wl.global_batch % dp or (tp > 1 and wl.n_heads % tp):
            continue
        ep = tp if wl.n_experts and wl.n_experts % tp == 0 else 1
        rows.append((objective((dp, tp, ep)), dp, tp, ep))
    rows.sort()
    lines = [f"{'bytes/step':>14}  {'dp':>5} {'tp':>4} {'ep':>4}"]
    for cost, dp, tp, ep in rows[:12]:
        lines.append(f"{cost:14.3e}  {dp:5d} {tp:4d} {ep:4d}")
    return "\n".join(lines)
