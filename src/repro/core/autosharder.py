"""Decompose-driven mesh planning for LM training/serving (beyond-paper).

The paper's Sec. 7.2 observation — *only the objective changes, the same
enumerator applies* — is exactly what a production LM framework needs to
pick its parallelism factorization. This module reuses the paper's optimal
enumerator (`enumerate_factorizations`) with a communication objective built
from the LM step (DP grad all-reduce, TP activation collectives, EP
all-to-all), subject to hardware-integrality constraints (tp | heads,
ep | experts, dp | batch).

This is the "Mapple as a first-class feature" integration: the launcher
asks the planner for a `MeshPlan`, the same way the matmul benchmarks ask
`decompose` for a processor grid.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.commvolume import LMCommModel, LMStepCostModel
from repro.core.decompose import enumerate_factorizations


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A chosen factorization of the chip count into parallelism axes."""

    dp: int
    tp: int
    ep: int = 1
    step_comm_bytes: float = 0.0
    candidates_considered: int = 0

    @property
    def shape(self) -> tuple[int, int]:
        return (self.dp, self.tp)


@dataclasses.dataclass(frozen=True)
class LMWorkload:
    """Iteration-space description of one LM step, for the planner."""

    global_batch: int
    seq_len: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    param_count: float
    dtype_bytes: int = 2
    n_experts: int = 0            # routed experts (0 = dense)
    n_moe_layers: int = 0
    topk: int = 0
    ffn_mult_bytes: float = 0.0   # routed expert param bytes

    def comm_model(self) -> LMCommModel:
        act = self.global_batch * self.seq_len * self.d_model * self.dtype_bytes
        moe_tok = (
            self.global_batch * self.seq_len * self.topk * self.d_model
            * self.dtype_bytes
        )
        return LMCommModel(
            param_bytes=self.param_count * 4.0,   # fp32 grads all-reduced
            act_bytes_per_layer=float(act),
            n_layers=self.n_layers,
            moe_param_bytes=self.ffn_mult_bytes,
            moe_tokens_bytes=float(moe_tok),
            n_moe_layers=self.n_moe_layers,
        )


@dataclasses.dataclass(frozen=True)
class MeshCostModel(LMStepCostModel):
    """:func:`plan_mesh`'s objective *and* its feasibility constraints on
    the :class:`~repro.core.commvolume.CostModel` protocol: an infeasible
    ``(dp, tp)`` raises ``ValueError`` instead of silently pricing, so the
    tuner's enumerative machinery (``feasible_procs`` /
    ``nearest_feasible_procs``) answers "can ``n`` chips host this
    workload?" the same way it answers it for the registry apps."""

    wl: LMWorkload = None
    max_tp: int = 64
    use_ep: bool | None = None
    name = "lm_mesh"

    @property
    def moe(self) -> bool:
        return self.wl.n_experts > 0 if self.use_ep is None else self.use_ep

    def ep_for(self, tp: int) -> int:
        return tp if (self.moe and self.wl.n_experts % tp == 0) else 1

    def cost(self, factors: Sequence[int]) -> float:
        if len(factors) != 2:
            raise ValueError(f"expected a (dp, tp) grid, got {tuple(factors)}")
        dp, tp = (int(x) for x in factors)
        wl = self.wl
        if tp > self.max_tp:
            raise ValueError(f"tp={tp} exceeds max_tp={self.max_tp}")
        if dp > wl.global_batch or wl.global_batch % dp != 0:
            raise ValueError(f"dp={dp} does not divide batch {wl.global_batch}")
        if tp > 1 and (wl.n_heads % tp != 0 or wl.d_model % tp != 0):
            raise ValueError(f"tp={tp} does not shard heads/d_model evenly")
        return super().cost((dp, tp, self.ep_for(tp)))


def mesh_search_space(wl: LMWorkload, *, max_tp: int = 64,
                      use_ep: bool | None = None):
    """The ``(dp, tp)`` mesh as a tuner :class:`~repro.search.space.SearchSpace`
    — :func:`repro.runtime.resilience.elastic_plan` routes survivor-count
    feasibility through this instead of a power-of-two shortcut."""
    from repro.search.space import SearchSpace

    model = MeshCostModel(model=wl.comm_model(), wl=wl, max_tp=max_tp,
                          use_ep=use_ep)
    return SearchSpace(rank=2, cost_model=lambda procs, opts: model)


def plan_mesh(
    n_chips: int,
    wl: LMWorkload,
    *,
    use_ep: bool | None = None,
    max_tp: int = 64,
) -> MeshPlan:
    """Pick (dp, tp[, ep]) minimizing modeled step communication.

    Constraints (integrality, the paper's l_m/w_m in N analogue):
      * dp divides global_batch;
      * tp divides n_kv_heads (so KV heads shard evenly) and d_model;
      * ep divides n_experts; ep and tp share the 'model' axis here, so
        we require ep == tp for MoE archs when use_ep (experts ride the
        model axis — one-axis EP, the deployment-standard layout).
    """
    objective = MeshCostModel(model=wl.comm_model(), wl=wl, max_tp=max_tp,
                              use_ep=use_ep)
    best: tuple[float, tuple[int, ...]] | None = None
    considered = 0
    for f in enumerate_factorizations(n_chips, 2):
        considered += 1
        try:
            cost = objective.cost(f)
        except ValueError:
            continue
        key = (cost, f)
        if best is None or key < best:
            best = key
    if best is None:
        raise ValueError(f"no feasible (dp, tp) factorization of {n_chips}")
    dp, tp = best[1]
    return MeshPlan(dp=dp, tp=tp, ep=objective.ep_for(tp),
                    step_comm_bytes=best[0],
                    candidates_considered=considered)


def plan_report(n_chips: int, wl: LMWorkload) -> str:
    """Human-readable planning table (used by examples/)."""
    objective = LMStepCostModel(wl.comm_model())
    rows = []
    for f in sorted(enumerate_factorizations(n_chips, 2)):
        dp, tp = f
        if wl.global_batch % dp or (tp > 1 and wl.n_heads % tp):
            continue
        ep = tp if wl.n_experts and wl.n_experts % tp == 0 else 1
        rows.append((objective((dp, tp, ep)), dp, tp, ep))
    rows.sort()
    lines = [f"{'bytes/step':>14}  {'dp':>5} {'tp':>4} {'ep':>4}"]
    for cost, dp, tp, ep in rows[:12]:
        lines.append(f"{cost:14.3e}  {dp:5d} {tp:4d} {ep:4d}")
    return "\n".join(lines)
