"""Processor-space algebra: the core of the Mapple DSL.

Implements the transformation primitives of Fig. 6 of the paper
("Mapple: A DSL for Mapping Distributed Heterogeneous Parallel Programs"):

    split(i, d)            -- split dim i into (d, s_i/d)
    merge(p, q)            -- fuse dims p and q into one dim at p
    swap(p, q)             -- exchange two dims
    slice(i, low, high)    -- restrict dim i to [low, high) with offset
    decompose(i, T)        -- optimally factor dim i against iteration extents T

Each transformed :class:`ProcSpace` knows how to map its own indices back to
the *root* space indices (the machine's physical coordinates), exactly as the
paper defines the semantics: "mappings from the indices of the transformed
processor space to the indices of the original processor space".

All spaces are immutable; primitives return new spaces sharing the same root.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from repro.core.tuples import Tup

Index = tuple[int, ...]


def _prod(xs: Sequence[int]) -> int:
    return math.prod(xs) if xs else 1


@dataclasses.dataclass(frozen=True)
class Processor:
    """A concrete processor: coordinates in the root (physical) space.

    ``coords`` are the root-space coordinates; ``flat`` is the row-major
    linearization, which the JAX translation layer uses as the device id.
    """

    coords: tuple[int, ...]
    root_shape: tuple[int, ...]

    @property
    def flat(self) -> int:
        fid = 0
        for c, s in zip(self.coords, self.root_shape):
            fid = fid * s + c
        return fid

    @property
    def node(self) -> int:
        """First root coordinate (node / pod index in a 2-level machine)."""
        return self.coords[0]

    @property
    def proc(self) -> int:
        """Last root coordinate (processor-within-node in a 2-level machine)."""
        return self.coords[-1]

    def __iter__(self):
        return iter(self.coords)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Processor{self.coords}"


class ProcSpace:
    """An n-dimensional view of a machine's processors.

    ``shape``   -- extents of this (possibly transformed) view.
    ``to_root`` -- function mapping an index in this view to root coordinates.
    """

    def __init__(
        self,
        shape: Sequence[int],
        root_shape: Sequence[int],
        to_root: Callable[[Index], Index] | None = None,
    ) -> None:
        self._shape = tuple(int(s) for s in shape)
        self._root_shape = tuple(int(s) for s in root_shape)
        if any(s <= 0 for s in self._shape):
            raise ValueError(f"non-positive extent in shape {self._shape}")
        self._to_root = to_root if to_root is not None else (lambda idx: idx)

    # ------------------------------------------------------------------ views
    @property
    def shape(self) -> tuple[int, ...]:
        return self._shape

    @property
    def root_shape(self) -> tuple[int, ...]:
        return self._root_shape

    @property
    def ndim(self) -> int:
        return len(self._shape)

    @property
    def size(self) -> Tup:
        """Extents as a :class:`Tup` supporting elementwise arithmetic.

        Mirrors the paper's ``m.size`` (e.g. ``ipoint * m.size / ispace``).
        """
        return Tup(self._shape)

    @property
    def nprocs(self) -> int:
        return _prod(self._shape)

    def __len__(self) -> int:
        return self._shape[0]

    # ------------------------------------------------------------ index logic
    def _check_index(self, idx: Index) -> None:
        if len(idx) != self.ndim:
            raise IndexError(
                f"index {idx} has rank {len(idx)}, space has rank {self.ndim}"
            )
        for a, s in zip(idx, self._shape):
            if not 0 <= a < s:
                raise IndexError(f"index {idx} out of bounds for shape {self._shape}")

    def to_root(self, idx: Index) -> Index:
        idx = tuple(int(a) for a in idx)
        self._check_index(idx)
        return self._to_root(idx)

    def __getitem__(self, key):
        """Index the space.

        * full-rank int tuple (or ``m[*idx]`` unpacking / a :class:`Tup`)
          -> :class:`Processor` at those coordinates;
        * a ``slice`` -> :class:`Tup` of the sliced extents (the paper's
          ``m_4d[:-1]`` idiom, which coerces a space to its size tuple);
        * a single int on a 1-D space -> :class:`Processor`;
        * a single int on an n-D space -> that dimension's extent (the
          paper's ``pspace[dim]`` idiom in helper functions).
        """
        if isinstance(key, slice):
            return Tup(self._shape[key])
        if isinstance(key, Tup):
            key = tuple(key)
        if isinstance(key, (int,)) and not isinstance(key, bool):
            if self.ndim == 1:
                key = (key,)
            else:
                return self._shape[key]
        if isinstance(key, tuple):
            idx = tuple(int(k) for k in key)
            root = self.to_root(idx)
            return Processor(root, self._root_shape)
        raise TypeError(f"cannot index ProcSpace with {key!r}")

    # ------------------------------------------------------------- primitives
    def split(self, i: int, d: int) -> "ProcSpace":
        """Fig. 6: m' = m.split(i, d); shape (..., d, s_i/d, ...).

        Index semantics: ``m'[.., a_i, a_{i+1}, ..] = m[.., a_i + a_{i+1}*d, ..]``
        (the first new dim is the fast-varying component of the original dim).
        """
        s = self._shape
        if not 0 <= i < self.ndim:
            raise IndexError(f"split dim {i} out of range for rank {self.ndim}")
        if d <= 0 or s[i] % d != 0:
            raise ValueError(f"split factor {d} does not divide extent {s[i]}")
        new_shape = s[:i] + (d, s[i] // d) + s[i + 1:]
        parent = self._to_root

        def to_root(a: Index) -> Index:
            b = a[:i] + (a[i] + a[i + 1] * d,) + a[i + 2:]
            return parent(b)

        return ProcSpace(new_shape, self._root_shape, to_root)

    def merge(self, p: int, q: int) -> "ProcSpace":
        """Fig. 6: fuse dims p and q into a single dim of extent s_p*s_q at p.

        Index semantics:
        ``m'[.., a_p, ..] = m[.., a_p mod s_p, .., floor(a_p / s_p), ..]``
        so that ``merge`` is the exact inverse of ``split`` (proved in the
        paper Sec. 3.3 and property-tested in tests/test_pspace.py).
        """
        if p == q:
            raise ValueError("merge requires two distinct dimensions")
        if p > q:
            # Normalize: merged dim lands at min(p, q); the paper writes p < q.
            raise ValueError("merge expects p < q (merged dim lands at p)")
        s = self._shape
        if not (0 <= p < self.ndim and 0 <= q < self.ndim):
            raise IndexError(f"merge dims ({p},{q}) out of range")
        sp, sq = s[p], s[q]
        new_shape = s[:p] + (sp * sq,) + s[p + 1:q] + s[q + 1:]
        parent = self._to_root

        def to_root(a: Index) -> Index:
            ap = a[p]
            lo, hi = ap % sp, ap // sp
            # Rebuild the pre-merge index: dims < q keep their positions
            # (with the fused value split back), dims >= q shift right by one.
            b = list(a[:p]) + [lo] + list(a[p + 1:q]) + [hi] + list(a[q:])
            # a has rank n-1; the slice a[p+1:q] are the dims strictly between
            # p and q, and a[q:] are the post-q dims (shifted left by one in a).
            return parent(tuple(b))

        return ProcSpace(new_shape, self._root_shape, to_root)

    def swap(self, p: int, q: int) -> "ProcSpace":
        """Fig. 6: exchange dims p and q."""
        s = list(self._shape)
        if not (0 <= p < self.ndim and 0 <= q < self.ndim):
            raise IndexError(f"swap dims ({p},{q}) out of range")
        s[p], s[q] = s[q], s[p]
        parent = self._to_root

        def to_root(a: Index) -> Index:
            b = list(a)
            b[p], b[q] = a[q], a[p]
            return parent(tuple(b))

        return ProcSpace(tuple(s), self._root_shape, to_root)

    def slice(self, i: int, low: int, high: int) -> "ProcSpace":
        """Fig. 6: restrict dim i to the half-open range [low, high).

        Index semantics: ``m'[.., a_i, ..] = m[.., a_i + low, ..]``.
        """
        s = self._shape
        if not 0 <= i < self.ndim:
            raise IndexError(f"slice dim {i} out of range")
        if not (0 <= low < high <= s[i]):
            raise ValueError(f"slice bounds [{low},{high}) invalid for extent {s[i]}")
        new_shape = s[:i] + (high - low,) + s[i + 1:]
        parent = self._to_root

        def to_root(a: Index) -> Index:
            b = a[:i] + (a[i] + low,) + a[i + 1:]
            return parent(b)

        return ProcSpace(new_shape, self._root_shape, to_root)

    def decompose(self, i: int, lengths, *, objective=None, halo=None) -> "ProcSpace":
        """Sec. 4: optimally factor dim i against iteration extents ``lengths``.

        Splits extent d_i into k = len(lengths) factors (d_1..d_k) minimizing
        the communication-volume objective  sum_m d_m / l_m  (or a caller-
        supplied objective, e.g. anisotropic halo weights per Sec. 7.2),
        then applies the equivalent sequence of ``split`` transformations:

            m_{n+1} = m_n.split(i + n - 1, d_{i_n})  for 1 <= n < k.
        """
        from repro.core.decompose import optimal_factorization

        lengths = tuple(int(x) for x in lengths)
        factors = optimal_factorization(
            self._shape[i], lengths, objective=objective, halo=halo
        )
        return self.decompose_with(i, factors)

    def decompose_with(self, i: int, factors: Sequence[int]) -> "ProcSpace":
        """Apply a pre-computed factorization (the split-sequence expansion)."""
        factors = tuple(int(f) for f in factors)
        if _prod(factors) != self._shape[i]:
            raise ValueError(
                f"factors {factors} do not multiply to extent {self._shape[i]}"
            )
        space = self
        for n, f in enumerate(factors[:-1]):
            space = space.split(i + n, f)
        return space

    # ------------------------------------------------------------------ misc
    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"ProcSpace(shape={self._shape}, root={self._root_shape})"
