"""Processor-space algebra: the core of the Mapple DSL.

Implements the transformation primitives of Fig. 6 of the paper
("Mapple: A DSL for Mapping Distributed Heterogeneous Parallel Programs"):

    split(i, d)            -- split dim i into (d, s_i/d)
    merge(p, q)            -- fuse dims p and q into one dim at p
    swap(p, q)             -- exchange two dims
    slice(i, low, high)    -- restrict dim i to [low, high) with offset
    decompose(i, T)        -- optimally factor dim i against iteration extents T

A transformed :class:`ProcSpace` is *data*, not code: it records its root
shape plus the list of applied transformation ops (the mapping IR). The ops
know how to map indices of the transformed space back to the *root* space
indices (the machine's physical coordinates), exactly as the paper defines
the semantics: "mappings from the indices of the transformed processor
space to the indices of the original processor space" — both one point at
a time (:meth:`ProcSpace.to_root`) and vectorized over a whole batch of
points with pure NumPy index arithmetic (:meth:`ProcSpace.to_root_batch`).

Because the transformation program is explicit, spaces are printable
(:meth:`ProcSpace.describe`) and serializable (:meth:`ProcSpace.to_ir` /
:meth:`ProcSpace.from_ir`) — see docs/mapping_ir.md.

All spaces are immutable; primitives return new spaces sharing the same root.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.tuples import Tup, as_index_component

Index = tuple[int, ...]


def _prod(xs: Sequence[int]) -> int:
    return math.prod(xs) if xs else 1


# ------------------------------------------------------------------ the IR
@dataclasses.dataclass(frozen=True)
class Op:
    """One recorded transformation: maps indices of the space it produced
    back to indices of the space it was applied to (view -> parent)."""

    def apply(self, idx: Index) -> Index:  # pragma: no cover - abstract
        raise NotImplementedError

    def apply_batch(self, idx: np.ndarray) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError

    def spec(self) -> tuple:  # pragma: no cover - abstract
        """JSON-able (opname, *args) tuple for serialization."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Split(Op):
    """Fig. 6 split(i, d): ``m'[.., a_i, a_{i+1}, ..] = m[.., a_i + a_{i+1}*d, ..]``."""

    dim: int
    factor: int

    def apply(self, idx: Index) -> Index:
        i, d = self.dim, self.factor
        return idx[:i] + (idx[i] + idx[i + 1] * d,) + idx[i + 2:]

    def apply_batch(self, idx: np.ndarray) -> np.ndarray:
        i, d = self.dim, self.factor
        out = np.empty((idx.shape[0], idx.shape[1] - 1), dtype=idx.dtype)
        out[:, :i] = idx[:, :i]
        out[:, i] = idx[:, i] + idx[:, i + 1] * d
        out[:, i + 1:] = idx[:, i + 2:]
        return out

    def spec(self) -> tuple:
        return ("split", self.dim, self.factor)

    def __str__(self) -> str:
        return f"split({self.dim}, {self.factor})"


@dataclasses.dataclass(frozen=True)
class Merge(Op):
    """Fig. 6 merge(p, q): ``m'[.., a_p, ..] = m[.., a_p mod s_p, .., a_p / s_p, ..]``.

    ``extent_p`` is the extent of dim p at the time the merge was applied
    (needed to unfuse the combined coordinate).
    """

    p: int
    q: int
    extent_p: int

    def apply(self, idx: Index) -> Index:
        p, q, sp = self.p, self.q, self.extent_p
        ap = idx[p]
        # idx has rank n-1; idx[p+1:q] are the dims strictly between p and q,
        # and idx[q:] are the post-q dims (shifted left by one in idx).
        return idx[:p] + (ap % sp,) + idx[p + 1:q] + (ap // sp,) + idx[q:]

    def apply_batch(self, idx: np.ndarray) -> np.ndarray:
        p, q, sp = self.p, self.q, self.extent_p
        out = np.empty((idx.shape[0], idx.shape[1] + 1), dtype=idx.dtype)
        out[:, :p] = idx[:, :p]
        out[:, p] = idx[:, p] % sp
        out[:, p + 1:q] = idx[:, p + 1:q]
        out[:, q] = idx[:, p] // sp
        out[:, q + 1:] = idx[:, q:]
        return out

    def spec(self) -> tuple:
        return ("merge", self.p, self.q)

    def __str__(self) -> str:
        return f"merge({self.p}, {self.q})"


@dataclasses.dataclass(frozen=True)
class Swap(Op):
    """Fig. 6 swap(p, q): exchange two dims."""

    p: int
    q: int

    def apply(self, idx: Index) -> Index:
        b = list(idx)
        b[self.p], b[self.q] = idx[self.q], idx[self.p]
        return tuple(b)

    def apply_batch(self, idx: np.ndarray) -> np.ndarray:
        out = idx.copy()
        out[:, self.p] = idx[:, self.q]
        out[:, self.q] = idx[:, self.p]
        return out

    def spec(self) -> tuple:
        return ("swap", self.p, self.q)

    def __str__(self) -> str:
        return f"swap({self.p}, {self.q})"


@dataclasses.dataclass(frozen=True)
class Slice(Op):
    """Fig. 6 slice(i, low, high): ``m'[.., a_i, ..] = m[.., a_i + low, ..]``."""

    dim: int
    low: int
    high: int

    def apply(self, idx: Index) -> Index:
        i = self.dim
        return idx[:i] + (idx[i] + self.low,) + idx[i + 1:]

    def apply_batch(self, idx: np.ndarray) -> np.ndarray:
        out = idx.copy()
        out[:, self.dim] += self.low
        return out

    def spec(self) -> tuple:
        return ("slice", self.dim, self.low, self.high)

    def __str__(self) -> str:
        return f"slice({self.dim}, {self.low}, {self.high})"


@dataclasses.dataclass(frozen=True)
class Decompose(Op):
    """Sec. 4 decompose: dim i factored into ``factors`` (a split sequence).

    Semantically identical to applying ``split(i, f_0)``, ``split(i+1, f_1)``,
    ... — the k view coordinates recombine little-endian mixed-radix:
    ``a_i = sum_j x_{i+j} * prod(factors[:j])``.
    """

    dim: int
    factors: tuple[int, ...]

    def apply(self, idx: Index) -> Index:
        i, k = self.dim, len(self.factors)
        combined, stride = 0, 1
        for j, f in enumerate(self.factors):
            combined += idx[i + j] * stride
            stride *= f
        return idx[:i] + (combined,) + idx[i + k:]

    def apply_batch(self, idx: np.ndarray) -> np.ndarray:
        i, k = self.dim, len(self.factors)
        out = np.empty((idx.shape[0], idx.shape[1] - k + 1), dtype=idx.dtype)
        out[:, :i] = idx[:, :i]
        combined = np.zeros(idx.shape[0], dtype=idx.dtype)
        stride = 1
        for j, f in enumerate(self.factors):
            combined += idx[:, i + j] * stride
            stride *= f
        out[:, i] = combined
        out[:, i + 1:] = idx[:, i + k:]
        return out

    def spec(self) -> tuple:
        return ("decompose", self.dim, list(self.factors))

    def __str__(self) -> str:
        return f"decompose({self.dim}, {self.factors})"


_OP_NAMES = {"split", "merge", "swap", "slice", "decompose"}


@dataclasses.dataclass(frozen=True)
class Processor:
    """A concrete processor: coordinates in the root (physical) space.

    ``coords`` are the root-space coordinates; ``flat`` is the row-major
    linearization, which the JAX translation layer uses as the device id.
    """

    coords: tuple[int, ...]
    root_shape: tuple[int, ...]

    @property
    def flat(self) -> int:
        fid = 0
        for c, s in zip(self.coords, self.root_shape):
            fid = fid * s + c
        return fid

    @property
    def node(self) -> int:
        """First root coordinate (node / pod index in a 2-level machine)."""
        return self.coords[0]

    @property
    def proc(self) -> int:
        """Last root coordinate (processor-within-node in a 2-level machine)."""
        return self.coords[-1]

    def __iter__(self):
        return iter(self.coords)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Processor{self.coords}"


class ProcessorBatch:
    """A batch of processors: root coordinates for B points at once.

    ``coords`` has shape (B, root_ndim); ``flat`` is the (B,) row-major
    device-id vector — what the vectorized mapper evaluation consumes.
    """

    __slots__ = ("coords", "root_shape")

    def __init__(self, coords: np.ndarray, root_shape: tuple[int, ...]) -> None:
        self.coords = coords
        self.root_shape = root_shape

    @property
    def flat(self) -> np.ndarray:
        fid = np.zeros(self.coords.shape[0], dtype=np.int64)
        for j, s in enumerate(self.root_shape):
            fid = fid * s + self.coords[:, j]
        return fid

    def __len__(self) -> int:
        return self.coords.shape[0]

    def __getitem__(self, b: int) -> Processor:
        return Processor(tuple(int(c) for c in self.coords[b]), self.root_shape)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"ProcessorBatch(n={len(self)}, root={self.root_shape})"


class ProcSpace:
    """An n-dimensional view of a machine's processors.

    ``shape`` -- extents of this (possibly transformed) view.
    ``ops``   -- the recorded transformation program mapping view indices
                 back to root coordinates (applied last-op-first).
    """

    def __init__(
        self,
        shape: Sequence[int],
        root_shape: Sequence[int] | None = None,
        ops: Sequence[Op] = (),
    ) -> None:
        self._shape = tuple(int(s) for s in shape)
        self._root_shape = (
            self._shape if root_shape is None
            else tuple(int(s) for s in root_shape)
        )
        if any(s <= 0 for s in self._shape):
            raise ValueError(f"non-positive extent in shape {self._shape}")
        self._ops = tuple(ops)

    # ------------------------------------------------------------------ views
    @property
    def shape(self) -> tuple[int, ...]:
        return self._shape

    @property
    def root_shape(self) -> tuple[int, ...]:
        return self._root_shape

    @property
    def ops(self) -> tuple[Op, ...]:
        """The transformation IR: root shape + these ops define the space."""
        return self._ops

    @property
    def ndim(self) -> int:
        return len(self._shape)

    @property
    def size(self) -> Tup:
        """Extents as a :class:`Tup` supporting elementwise arithmetic.

        Mirrors the paper's ``m.size`` (e.g. ``ipoint * m.size / ispace``).
        """
        return Tup(self._shape)

    @property
    def nprocs(self) -> int:
        return _prod(self._shape)

    def __len__(self) -> int:
        return self._shape[0]

    # ------------------------------------------------------------ index logic
    def _check_index(self, idx: Index) -> None:
        if len(idx) != self.ndim:
            raise IndexError(
                f"index {idx} has rank {len(idx)}, space has rank {self.ndim}"
            )
        for a, s in zip(idx, self._shape):
            if not 0 <= a < s:
                raise IndexError(f"index {idx} out of bounds for shape {self._shape}")

    def to_root(self, idx: Index) -> Index:
        idx = tuple(int(a) for a in idx)
        self._check_index(idx)
        for op in reversed(self._ops):
            idx = op.apply(idx)
        return idx

    def to_root_batch(self, idx: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`to_root`: (B, ndim) int array -> (B, root_ndim).

        Pure NumPy index arithmetic per recorded op — no per-point Python.
        """
        idx = np.asarray(idx, dtype=np.int64)
        if idx.ndim != 2 or idx.shape[1] != self.ndim:
            raise IndexError(
                f"batch index has shape {idx.shape}, expected (B, {self.ndim})"
            )
        shape = np.asarray(self._shape, dtype=np.int64)
        if ((idx < 0) | (idx >= shape)).any():
            raise IndexError(f"batch index out of bounds for shape {self._shape}")
        for op in reversed(self._ops):
            idx = op.apply_batch(idx)
        return idx

    def _batch_getitem(self, key: tuple) -> ProcessorBatch:
        """Index with a tuple of (B,) arrays / scalars -> ProcessorBatch."""
        cols = np.broadcast_arrays(
            *(as_index_component(np.asarray(k)) for k in key)
        )
        batch = np.stack([np.atleast_1d(c) for c in cols], axis=1)
        return ProcessorBatch(self.to_root_batch(batch), self._root_shape)

    def __getitem__(self, key):
        """Index the space.

        * full-rank int tuple (or ``m[*idx]`` unpacking / a :class:`Tup`)
          -> :class:`Processor` at those coordinates;
        * a ``slice`` -> :class:`Tup` of the sliced extents (the paper's
          ``m_4d[:-1]`` idiom, which coerces a space to its size tuple);
        * a single int on a 1-D space -> :class:`Processor`;
        * a single int on an n-D space -> that dimension's extent (the
          paper's ``pspace[dim]`` idiom in helper functions);
        * any component being a NumPy array (a batched :class:`Tup`
          coordinate) -> :class:`ProcessorBatch` over the whole batch.
        """
        if isinstance(key, slice):
            return Tup(self._shape[key])
        if isinstance(key, Tup):
            key = tuple(key)
        if isinstance(key, np.ndarray) and key.ndim == 1 and self.ndim == 1:
            key = (key,)
        if isinstance(key, (int, np.integer)) and not isinstance(key, bool):
            if self.ndim == 1:
                key = (key,)
            else:
                return self._shape[key]
        if isinstance(key, tuple):
            if any(isinstance(k, np.ndarray) and k.ndim > 0 for k in key):
                return self._batch_getitem(key)
            idx = tuple(int(k) for k in key)
            root = self.to_root(idx)
            return Processor(root, self._root_shape)
        raise TypeError(f"cannot index ProcSpace with {key!r}")

    # ------------------------------------------------------------- primitives
    def _derive(self, shape: Sequence[int], op: Op) -> "ProcSpace":
        return ProcSpace(shape, self._root_shape, self._ops + (op,))

    def split(self, i: int, d: int) -> "ProcSpace":
        """Fig. 6: m' = m.split(i, d); shape (..., d, s_i/d, ...).

        Index semantics: ``m'[.., a_i, a_{i+1}, ..] = m[.., a_i + a_{i+1}*d, ..]``
        (the first new dim is the fast-varying component of the original dim).
        """
        s = self._shape
        if not 0 <= i < self.ndim:
            raise IndexError(f"split dim {i} out of range for rank {self.ndim}")
        if d <= 0 or s[i] % d != 0:
            raise ValueError(f"split factor {d} does not divide extent {s[i]}")
        new_shape = s[:i] + (d, s[i] // d) + s[i + 1:]
        return self._derive(new_shape, Split(i, d))

    def merge(self, p: int, q: int) -> "ProcSpace":
        """Fig. 6: fuse dims p and q into a single dim of extent s_p*s_q at p.

        Index semantics:
        ``m'[.., a_p, ..] = m[.., a_p mod s_p, .., floor(a_p / s_p), ..]``
        so that ``merge`` is the exact inverse of ``split`` (proved in the
        paper Sec. 3.3 and property-tested in tests/test_pspace.py).
        """
        if p == q:
            raise ValueError("merge requires two distinct dimensions")
        if p > q:
            # Normalize: merged dim lands at min(p, q); the paper writes p < q.
            raise ValueError("merge expects p < q (merged dim lands at p)")
        s = self._shape
        if not (0 <= p < self.ndim and 0 <= q < self.ndim):
            raise IndexError(f"merge dims ({p},{q}) out of range")
        sp, sq = s[p], s[q]
        new_shape = s[:p] + (sp * sq,) + s[p + 1:q] + s[q + 1:]
        return self._derive(new_shape, Merge(p, q, sp))

    def swap(self, p: int, q: int) -> "ProcSpace":
        """Fig. 6: exchange dims p and q."""
        s = list(self._shape)
        if not (0 <= p < self.ndim and 0 <= q < self.ndim):
            raise IndexError(f"swap dims ({p},{q}) out of range")
        s[p], s[q] = s[q], s[p]
        return self._derive(tuple(s), Swap(p, q))

    def slice(self, i: int, low: int, high: int) -> "ProcSpace":
        """Fig. 6: restrict dim i to the half-open range [low, high).

        Index semantics: ``m'[.., a_i, ..] = m[.., a_i + low, ..]``.
        """
        s = self._shape
        if not 0 <= i < self.ndim:
            raise IndexError(f"slice dim {i} out of range")
        if not (0 <= low < high <= s[i]):
            raise ValueError(f"slice bounds [{low},{high}) invalid for extent {s[i]}")
        new_shape = s[:i] + (high - low,) + s[i + 1:]
        return self._derive(new_shape, Slice(i, low, high))

    def decompose(self, i: int, lengths, *, objective=None, halo=None) -> "ProcSpace":
        """Sec. 4: optimally factor dim i against iteration extents ``lengths``.

        Splits extent d_i into k = len(lengths) factors (d_1..d_k) minimizing
        the communication-volume objective  sum_m d_m / l_m  (or a caller-
        supplied objective, e.g. anisotropic halo weights per Sec. 7.2),
        then applies the equivalent sequence of ``split`` transformations:

            m_{n+1} = m_n.split(i + n - 1, d_{i_n})  for 1 <= n < k.
        """
        from repro.core.decompose import optimal_factorization

        lengths = tuple(int(x) for x in lengths)
        factors = optimal_factorization(
            self._shape[i], lengths, objective=objective, halo=halo
        )
        return self.decompose_with(i, factors)

    def decompose_with(self, i: int, factors: Sequence[int]) -> "ProcSpace":
        """Apply a pre-computed factorization (the split-sequence expansion,
        recorded as a single :class:`Decompose` op)."""
        factors = tuple(int(f) for f in factors)
        if not 0 <= i < self.ndim:
            raise IndexError(f"decompose dim {i} out of range")
        if _prod(factors) != self._shape[i]:
            raise ValueError(
                f"factors {factors} do not multiply to extent {self._shape[i]}"
            )
        if len(factors) <= 1:
            return self
        s = self._shape
        new_shape = s[:i] + factors + s[i + 1:]
        return self._derive(new_shape, Decompose(i, factors))

    # ------------------------------------------------------- IR introspection
    def describe(self) -> str:
        """The transformation program as text, e.g.
        ``root(2, 4).merge(0, 1).split(0, 4)``."""
        root = ", ".join(str(s) for s in self._root_shape)
        return f"root({root})" + "".join(f".{op}" for op in self._ops)

    def to_ir(self) -> dict:
        """JSON-able IR: ``{"root_shape": [...], "ops": [[name, ...], ...]}``."""
        return {
            "root_shape": list(self._root_shape),
            "ops": [list(op.spec()) for op in self._ops],
        }

    @classmethod
    def from_ir(cls, ir: dict) -> "ProcSpace":
        """Rebuild a space by replaying a serialized transformation program."""
        space = cls(ir["root_shape"])
        for op in ir["ops"]:
            name, *args = op
            if name not in _OP_NAMES:
                raise ValueError(f"unknown IR op {name!r}")
            if name == "decompose":
                space = space.decompose_with(args[0], tuple(args[1]))
            else:
                space = getattr(space, name)(*args)
        return space

    # ------------------------------------------------------------------ misc
    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"ProcSpace(shape={self._shape}, root={self._root_shape}, "
            f"ops={len(self._ops)})"
        )
