"""The ``decompose`` primitive's solver (paper Sec. 4) and baselines.

Problem: factor a processor count ``d`` into k ordered natural factors
(d_1, .., d_k), one per iteration-space dimension (l_1, .., l_k), minimizing
communication volume. Paper Sec. 4.2 reduces halo (nearest-neighbour)
communication to the objective

    minimize  sum_m  d_m / l_m        s.t.  prod_m d_m = d.

Sec. 7.2 generalizes to anisotropic halos (weights h_m) and transposes
(all-to-all along a subset of dims); only the objective changes, the same
enumerator applies.

The enumerator (Sec. 4.3) is exhaustive and therefore *optimal*: for
d = p_1^a_1 * ... * p_t^a_t it enumerates, per prime, all stars-and-bars
placements of the a_j copies over the k dims, and takes the Cartesian
product — prod_j C(a_j + k - 1, k - 1) candidates, tiny in practice.

``greedy_factorization`` is Algorithm 1 of the paper (the Chapel-style
heuristic): iteration-space *oblivious*, provably suboptimal (Sec. 4.1).
"""
from __future__ import annotations

import functools
import itertools
import math
from typing import Callable, Iterable, Iterator, Sequence


# --------------------------------------------------------------------- primes
def prime_factorization(d: int) -> list[int]:
    """Sorted (ascending) list of prime factors of ``d`` with multiplicity."""
    if d < 1:
        raise ValueError(f"cannot factor {d}")
    out: list[int] = []
    n = d
    f = 2
    while f * f <= n:
        while n % f == 0:
            out.append(f)
            n //= f
        f += 1 if f == 2 else 2
    if n > 1:
        out.append(n)
    return out


def _compositions(total: int, k: int) -> Iterator[tuple[int, ...]]:
    """All non-negative integer solutions to x_1 + ... + x_k = total."""
    if k == 1:
        yield (total,)
        return
    for first in range(total + 1):
        for rest in _compositions(total - first, k - 1):
            yield (first,) + rest


def enumerate_factorizations(d: int, k: int) -> Iterator[tuple[int, ...]]:
    """All ordered k-tuples of naturals whose product is ``d`` (Sec. 4.3)."""
    primes = prime_factorization(d) if d > 1 else []
    groups: dict[int, int] = {}
    for p in primes:
        groups[p] = groups.get(p, 0) + 1
    per_prime = [
        [(p, comp) for comp in _compositions(a, k)] for p, a in sorted(groups.items())
    ]
    if not per_prime:
        yield (1,) * k
        return
    for combo in itertools.product(*per_prime):
        factors = [1] * k
        for p, comp in combo:
            for dim, exp in enumerate(comp):
                factors[dim] *= p ** exp
        yield tuple(factors)


def count_factorizations(d: int, k: int) -> int:
    """Closed form prod_j C(a_j + k - 1, k - 1) — used in tests/docs."""
    primes = prime_factorization(d) if d > 1 else []
    groups: dict[int, int] = {}
    for p in primes:
        groups[p] = groups.get(p, 0) + 1
    out = 1
    for a in groups.values():
        out *= math.comb(a + k - 1, k - 1)
    return out


# ----------------------------------------------------------------- objectives
def halo_objective(lengths: Sequence[int], halo: Sequence[float] | None = None
                   ) -> Callable[[Sequence[int]], float]:
    """Paper objective: sum_m h_m * d_m / l_m (isotropic when h == 1).

    Derivation (Sec. 4.2 / 7.2.1): communication volume
    V = (sum_n h_n / w_n) * prod(l) with w_n = l_n / d_n, so minimizing V
    is minimizing sum_n h_n * d_n / l_n.
    """
    h = tuple(halo) if halo is not None else (1.0,) * len(lengths)
    ls = tuple(float(x) for x in lengths)

    def obj(factors: Sequence[int]) -> float:
        return sum(hm * dm / lm for hm, dm, lm in zip(h, factors, ls))

    return obj


def transpose_objective(
    lengths: Sequence[int],
    transpose_dims: Iterable[int],
    halo: Sequence[float] | None = None,
) -> Callable[[Sequence[int]], float]:
    """Sec. 7.2.2: halo volume + all-to-all volume along ``transpose_dims``.

    V_total = V_halo + sum_{n in T} (1 - 1/d_n) * prod(w) * d
    with prod(w) * d = prod(l) constant, so the transpose term reduces to
    prod(l) * sum_{n in T} (1 - 1/d_n). We keep absolute volumes so mixed
    objectives weigh halo and transpose terms consistently.
    """
    tset = set(transpose_dims)
    ls = tuple(float(x) for x in lengths)
    h = tuple(halo) if halo is not None else (1.0,) * len(lengths)
    lprod = math.prod(ls)

    def obj(factors: Sequence[int]) -> float:
        halo_v = lprod * sum(
            hm * dm / lm for hm, dm, lm in zip(h, factors, ls)
        )
        transpose_v = lprod * sum(
            (1.0 - 1.0 / factors[n]) for n in tset
        )
        return halo_v + transpose_v

    return obj


# -------------------------------------------------------------------- solvers
def optimal_factorization(
    d: int,
    lengths: Sequence[int],
    *,
    objective: Callable[[Sequence[int]], float] | None = None,
    halo: Sequence[float] | None = None,
    require_divisible: bool = False,
) -> tuple[int, ...]:
    """The ``decompose`` solver: exhaustive, optimal (Sec. 4.3).

    ``objective``: maps a candidate factor tuple to a cost (default: the
    paper's halo objective over ``lengths``, optionally anisotropic via
    ``halo`` weights). Ties break toward factorizations that divide the
    iteration extents evenly, then lexicographically for determinism.

    ``require_divisible``: restrict to factorizations where every d_m
    divides l_m (the paper's integrality constraint l_m/w_m in N); if no
    candidate satisfies it, falls back to the unconstrained optimum.
    """
    lengths = tuple(int(x) for x in lengths)
    k = len(lengths)
    if k == 0:
        raise ValueError("decompose needs at least one iteration dimension")
    obj = objective if objective is not None else halo_objective(lengths, halo)

    def divisible(f: Sequence[int]) -> bool:
        return all(l % dm == 0 for l, dm in zip(lengths, f))

    best: tuple[float, int, tuple[int, ...]] | None = None
    best_div: tuple[float, int, tuple[int, ...]] | None = None
    for f in enumerate_factorizations(d, k):
        key = (float(obj(f)), 0 if divisible(f) else 1, f)
        if best is None or key < best:
            best = key
        if divisible(f) and (best_div is None or key < best_div):
            best_div = key
    assert best is not None
    if require_divisible and best_div is not None:
        return best_div[2]
    return best[2]


def greedy_factorization(d: int, k: int) -> tuple[int, ...]:
    """Algorithm 1 of the paper — the *suboptimal* baseline heuristic.

    Iteration-space-oblivious: assigns each prime factor (ascending) to the
    dimension with the smallest running product, then sorts descending.
    """
    primes = prime_factorization(d) if d > 1 else []
    factors = [1] * k
    for p in primes:
        j = min(range(k), key=lambda i: factors[i])
        factors[j] *= p
    factors.sort(reverse=True)
    return tuple(factors)


def greedy_workload_factorization(d: int, lengths: Sequence[int]) -> tuple[int, ...]:
    """The greedy strawman of Sec. 4.3's closing example: assign primes to
    minimize the max spread of the workload vector at each step. Suboptimal
    (e.g. d=72, l=(8,9) -> workload (4/3, 3/4) vs optimal (1,1))."""
    primes = sorted(prime_factorization(d) if d > 1 else [], reverse=True)
    k = len(lengths)
    factors = [1] * k

    def spread(fs: Sequence[int]) -> float:
        w = [l / f for l, f in zip(lengths, fs)]
        return max(w) - min(w)

    for p in primes:
        best_j, best_s = 0, None
        for j in range(k):
            trial = list(factors)
            trial[j] *= p
            s = spread(trial)
            if best_s is None or s < best_s:
                best_j, best_s = j, s
        factors[best_j] *= p
    return tuple(factors)


@functools.lru_cache(maxsize=4096)
def cached_optimal(d: int, lengths: tuple[int, ...],
                   halo: tuple[float, ...] | None = None,
                   require_divisible: bool = False) -> tuple[int, ...]:
    """Memoized entry point for hot paths (grid planning in the launchers).

    ``require_divisible`` honors the paper's integrality constraint
    (every d_m divides l_m) — the shard_map launchers need it because XLA
    shards must tile the array evenly; falls back to the unconstrained
    optimum when no divisible factorization exists.
    """
    return optimal_factorization(
        d, lengths, halo=halo, require_divisible=require_divisible
    )
