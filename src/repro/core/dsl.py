"""Textual front-end for the Mapple DSL (paper Fig. 1a / Fig. 18 grammar).

Parses declarative Mapple programs such as::

    m = Machine(GPU)
    m1 = m.merge(0, 1).split(0, 4)

    def block2d(Tuple ipoint, Tuple ispace):
        idx = ipoint * m.size / ispace
        return m[*idx]

    IndexTaskMap loop0 block2d
    TaskMap task_small CPU
    Region task_init arg0 GPU FBMEM
    Layout task_finish arg1 CPU C_order align=128
    GarbageCollect systolic arg2
    Backpressure systolic 1

Mapping-function bodies are Python-ish with tuple arithmetic (the paper's
``Tuple`` type) plus the C ternary ``cond ? a : b`` which we desugar. They
are compiled with an empty ``__builtins__`` and a whitelisted namespace
(Machine, Tuple, declared spaces, helper primitives) — the DSL is *not*
general Python.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

from repro.core import machine as machine_mod
from repro.core.mapper import (
    Mapper,
    block_primitive,
    cyclic_primitive,
)
from repro.core.pspace import ProcSpace
from repro.core.translate import LayoutSpec
from repro.core.tuples import Tup

_TERNARY = re.compile(r"(?P<c>[^?\n=]+)\?(?P<a>[^:\n]+):(?P<b>.+)")
_SIG_TYPE = re.compile(r"\b(Tuple|int|float)\s+(\w+)")
_STAR_SUB = re.compile(r"\[\s*\*\s*(\w+)\s*\]")

DIRECTIVES = (
    "IndexTaskMap", "TaskMap", "Region", "Layout",
    "GarbageCollect", "Backpressure",
)


@dataclasses.dataclass
class MapperProgram:
    """Parse result: declared spaces, mapping functions, and directives."""

    spaces: dict[str, ProcSpace] = dataclasses.field(default_factory=dict)
    mappers: dict[str, Mapper] = dataclasses.field(default_factory=dict)
    index_task_maps: dict[str, str] = dataclasses.field(default_factory=dict)
    task_maps: dict[str, str] = dataclasses.field(default_factory=dict)
    regions: dict[tuple[str, str], tuple[str, str]] = dataclasses.field(
        default_factory=dict
    )
    layouts: dict[tuple[str, str], LayoutSpec] = dataclasses.field(
        default_factory=dict
    )
    garbage_collect: set[tuple[str, str]] = dataclasses.field(default_factory=set)
    backpressure: dict[str, int] = dataclasses.field(default_factory=dict)
    source: str = ""

    def loc(self) -> int:
        """Non-blank, non-comment lines — the paper's Table 1 metric."""
        return sum(
            1
            for ln in self.source.splitlines()
            if ln.strip() and not ln.strip().startswith("#")
        )


def _desugar_ternary(line: str) -> str:
    """`x = c ? a : b`  ->  `x = (a) if (c) else (b)` (rhs only)."""
    if "?" not in line or ":" not in line.split("?", 1)[1]:
        return line
    if "=" in line:
        lhs, rhs = line.split("=", 1)
    else:
        lhs, rhs = None, line
    m = _TERNARY.fullmatch(rhs.strip())
    if not m:
        return line
    py = f"({m.group('a').strip()}) if ({m.group('c').strip()}) else ({m.group('b').strip()})"
    return f"{lhs}= {py}" if lhs is not None else py


def _clean_signature(line: str) -> str:
    """Strip C-style parameter types: def f(Tuple a, int b): -> def f(a, b):"""
    return _SIG_TYPE.sub(r"\2", line)


def _desugar_star_subscript(line: str) -> str:
    """`m[*idx]` -> `m[tuple(idx)]` — starred subscripts (the paper's tuple
    unpacking idiom) only became Python syntax in 3.11; ProcSpace accepts
    the equivalent tuple/Tup index directly."""
    return _STAR_SUB.sub(r"[tuple(\1)]", line)


class _SafeNamespace(dict):
    """Evaluation namespace: whitelisted names only, no builtins."""

    ALLOWED_GLOBALS: dict[str, Any] = {
        "Machine": machine_mod.Machine,
        "Tuple": Tup,
        "GPU": machine_mod.GPU,
        "TPU": machine_mod.TPU,
        "CPU": machine_mod.CPU,
        "block_primitive": block_primitive,
        "cyclic_primitive": cyclic_primitive,
        "tuple": tuple,
        "range": range,
        "len": len,
        "min": min,
        "max": max,
        "abs": abs,
    }

    def __init__(self) -> None:
        super().__init__(self.ALLOWED_GLOBALS)
        self["__builtins__"] = {}


def parse(source: str, *,
          machine_factory: Callable[..., ProcSpace] | None = None) -> MapperProgram:
    """Parse a Mapple program into a :class:`MapperProgram`.

    ``machine_factory`` overrides ``Machine`` so the same program text can
    target different physical machines (the paper's tuning workflow).
    """
    prog = MapperProgram(source=source)
    ns = _SafeNamespace()
    if machine_factory is not None:
        ns["Machine"] = machine_factory

    lines = source.splitlines()
    i = 0
    while i < len(lines):
        raw = lines[i]
        line = raw.strip()
        if not line or line.startswith("#"):
            i += 1
            continue

        head = line.split()[0]
        if head in DIRECTIVES:
            _parse_directive(prog, line)
            i += 1
            continue

        if line.startswith("def "):
            block = [_clean_signature(_desugar_ternary(raw))]
            i += 1
            while i < len(lines) and (
                lines[i].startswith((" ", "\t")) or not lines[i].strip()
            ):
                block.append(_desugar_star_subscript(_desugar_ternary(lines[i])))
                i += 1
            _compile_mapping_fn(prog, ns, "\n".join(block))
            continue

        if "=" in line:
            # Space declaration / transformation chain.
            name, expr = (s.strip() for s in line.split("=", 1))
            value = eval(  # noqa: S307 - restricted namespace, no builtins
                expr, ns
            )
            ns[name] = value
            if isinstance(value, ProcSpace):
                prog.spaces[name] = value
            i += 1
            continue

        raise SyntaxError(f"unrecognized Mapple statement: {line!r}")
    return prog


def _compile_mapping_fn(prog: MapperProgram, ns: _SafeNamespace, block: str) -> None:
    code = compile(block, "<mapple>", "exec")
    exec(code, ns)  # noqa: S102 - restricted namespace
    fn_name = block.split("(")[0].split()[-1]
    raw_fn = ns[fn_name]

    def fn(ipoint: Tup, ispace: Tup):
        return raw_fn(ipoint, ispace)

    # Snapshot the spaces declared so far: the mapper body closes over them,
    # and they carry the transformation IR that Mapper.describe() prints.
    # The compiled body also runs unchanged on a batched Tup (vectorized
    # grid evaluation) because all Tup/ProcSpace operations broadcast.
    prog.mappers[fn_name] = Mapper(fn_name, fn, spaces=dict(prog.spaces))


def _parse_directive(prog: MapperProgram, line: str) -> None:
    parts = line.split()
    head, rest = parts[0], parts[1:]
    if head == "IndexTaskMap":
        task, mapper = rest
        if mapper not in prog.mappers:
            raise NameError(f"IndexTaskMap references unknown mapper {mapper!r}")
        prog.index_task_maps[task] = mapper
    elif head == "TaskMap":
        task, kind = rest
        prog.task_maps[task] = kind.lower()
    elif head == "Region":
        task, arg, _proc_kind, memkind = rest
        mem = {
            "FBMEM": machine_mod.FBMEM,
            "ZCMEM": machine_mod.ZCMEM,
            "SYSMEM": machine_mod.SYSMEM,
        }.get(memkind.upper(), memkind.lower())
        prog.regions[(task, arg)] = (_proc_kind.lower(), mem)
    elif head == "Layout":
        task, arg, _proc, order, *opts = rest
        align = 128
        soa = True
        for opt in opts:
            if opt.startswith("align="):
                align = int(opt.split("=", 1)[1])
            elif opt in ("SoA", "soa"):
                soa = True
            elif opt in ("AoS", "aos"):
                soa = False
        prog.layouts[(task, arg)] = LayoutSpec(
            order="F" if order.upper().startswith("F") else "C",
            alignment=align,
            soa=soa,
        )
    elif head == "GarbageCollect":
        task, arg = rest
        prog.garbage_collect.add((task, arg))
    elif head == "Backpressure":
        task, depth = rest
        prog.backpressure[task] = int(depth)
    else:  # pragma: no cover - guarded by caller
        raise SyntaxError(f"unknown directive {head}")
