"""Mapping functions and the Mapper object (paper Figs. 3, 4, 7, 12).

A *mapping function* takes an iteration point and the iteration space and
returns a :class:`Processor` (root coordinates). A :class:`Mapper` bundles
the transformed processor space(s) with the function, and can evaluate the
full iteration grid into a device-assignment array (what the JAX
translation layer consumes).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import numpy as np

from repro.core.pspace import ProcSpace, Processor
from repro.core.tuples import Tup

MapFn = Callable[[Tup, Tup], Processor]


@dataclasses.dataclass
class Mapper:
    """A named index mapping: iteration space -> processor space."""

    name: str
    fn: MapFn

    def __call__(self, ipoint: Sequence[int], ispace: Sequence[int]) -> Processor:
        return self.fn(Tup(ipoint), Tup(ispace))

    # -------------------------------------------------------------- analysis
    def assignment_grid(self, ispace: Sequence[int]) -> np.ndarray:
        """Flat device id for every iteration point; shape = ispace."""
        ispace_t = Tup(ispace)
        out = np.empty(tuple(ispace), dtype=np.int64)
        for pt in itertools.product(*(range(s) for s in ispace)):
            out[pt] = self.fn(Tup(pt), ispace_t).flat
        return out

    def is_bijective_on(self, ispace: Sequence[int], nprocs: int) -> bool:
        grid = self.assignment_grid(ispace)
        return grid.size == nprocs and len(np.unique(grid)) == nprocs

    def tile_permutation(self, ispace: Sequence[int], nprocs: int) -> np.ndarray:
        """Row-major tile order -> device id permutation (must be bijective).

        This is the object the JAX translation uses to build the Mesh: JAX
        assigns block i of a sharded axis to mesh position i, so realizing an
        arbitrary Mapple map means permuting the device list.
        """
        grid = self.assignment_grid(ispace)
        flat = grid.reshape(-1)
        if len(np.unique(flat)) != nprocs or flat.size != nprocs:
            raise ValueError(
                f"mapper {self.name} is not a bijection from {tuple(ispace)} "
                f"onto {nprocs} processors; cannot realize as a mesh permutation"
            )
        return flat


# ------------------------------------------------------------ Fig. 7 library
def block_mapper(m: ProcSpace, name: str = "block") -> Mapper:
    """blockND: idx = ipoint * m.size / ispace (Fig. 3 / Fig. 7)."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        idx = ipoint * m.size / ispace
        return m[tuple(idx)]

    return Mapper(name, fn)


def cyclic_mapper(m: ProcSpace, name: str = "cyclic") -> Mapper:
    """cyclicND: idx = ipoint % m.size."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        idx = ipoint % m.size
        return m[tuple(idx)]

    return Mapper(name, fn)


def block_cyclic_mapper(m: ProcSpace, name: str = "blockcyclic") -> Mapper:
    """block-cyclic: idx = ipoint / m.size % m.size (Fig. 7)."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        idx = ipoint / m.size % m.size
        return m[tuple(idx)]

    return Mapper(name, fn)


def linear_cyclic_mapper(m2d: ProcSpace, name: str = "linearCyclic") -> Mapper:
    """Fig. 4: merge the 2D space to 1D, round-robin the linearized point."""
    m1 = m2d.merge(0, 1)

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        linearized = ipoint.linearize(ispace)
        return m1[(linearized % m1.size[0],)]

    return Mapper(name, fn)


# --------------------------------------------------------- Fig. 12 primitives
def block_primitive(ipoint: Tup, ispace: Tup, psize: Tup, dim1: int, dim2: int) -> int:
    return ipoint[dim1] * psize[dim2] // ispace[dim1]


def cyclic_primitive(ipoint: Tup, ispace: Tup, psize: Tup, dim1: int, dim2: int) -> int:
    return ipoint[dim1] % psize[dim2]


def hierarchical_block_mapper(
    m2d: ProcSpace, ispace: Sequence[int], name: str = "hierarchical_block"
) -> Mapper:
    """Fig. 12 hierarchical_block{2,3}D, generalized to any rank.

    decompose the node dim against the iteration space, then decompose the
    per-node processor dim against the *per-node* sub iteration space; block
    over the node factors, cyclic over the intra-node factors.
    """
    k = len(ispace)
    m_nodes = m2d.decompose(0, ispace)                   # k node factors + gpu dim
    node_factors = Tup(m_nodes.shape[:k])
    sub_ispace = Tup(ispace) / node_factors              # per-node sub space
    m_full = m_nodes.decompose(k, tuple(sub_ispace))     # + k gpu factors
    psize = m_full.size

    def fn(ipoint: Tup, ispace_t: Tup) -> Processor:
        upper = tuple(
            block_primitive(ipoint, ispace_t, psize, i, i) for i in range(k)
        )
        lower = tuple(
            cyclic_primitive(ipoint, ispace_t, psize, i, i + k) for i in range(k)
        )
        return m_full[upper + lower]

    return Mapper(name, fn)


def linearize_cyclic_mapper(m2d: ProcSpace, name: str = "linearize_cyclic") -> Mapper:
    """Fig. 12 Solomonik's function 2: column-major linearize, cyclic over
    node then gpu dims of the original 2D space."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        linearized = (
            ipoint[0]
            + ispace[0] * ipoint[1]
            + ispace[0] * ispace[1] * (ipoint[2] if len(ipoint) > 2 else 0)
        )
        node_idx = linearized % m2d.size[0]
        gpu_idx = (linearized // m2d.size[0]) % m2d.size[1]
        return m2d[(node_idx, gpu_idx)]

    return Mapper(name, fn)


def special_linearize3d_mapper(m2d: ProcSpace, name: str = "special_linearize3D") -> Mapper:
    """Fig. 12 COSMA mapper: decompose nodes as equally as possible, then
    linearize with the resulting grid strides, cyclic over nodes."""
    m5 = m2d.decompose(0, (1, 1, 1))  # equal split (all lengths equal)

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        gx = m5.size[2]
        gy = m5.size[1]
        linearized = ipoint[0] + ipoint[1] * gx + ipoint[2] * gx * gy
        return m2d[(linearized % m2d.size[0], 0)]

    return Mapper(name, fn)


def conditional_linearize3d_mapper(
    m2d: ProcSpace, name: str = "conditional_linearize3D"
) -> Mapper:
    """Fig. 12 Johnson's mapper: stride by the larger of ispace[0]/ispace[2]."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        grid_size = ispace[0] if ispace[0] > ispace[2] else ispace[2]
        linearized = (
            ipoint[0] + ipoint[1] * grid_size + ipoint[2] * grid_size * grid_size
        )
        return m2d[(linearized % m2d.size[0], 0)]

    return Mapper(name, fn)


def transformed_block_mapper(m: ProcSpace, name: str) -> Mapper:
    """block over an arbitrarily transformed space (block1D_x etc.)."""
    return block_mapper(m, name)
