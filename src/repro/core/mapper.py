"""Mapping functions and the Mapper object (paper Figs. 3, 4, 7, 12).

A *mapping function* takes an iteration point and the iteration space and
returns a :class:`Processor` (root coordinates). A :class:`Mapper` bundles
the transformed processor space(s) with the function, and can evaluate the
full iteration grid into a device-assignment array (what the JAX
translation layer consumes).

Grid evaluation is vectorized: the mapping function is called ONCE with a
batched :class:`Tup` covering every iteration point, and the processor
spaces replay their recorded transformation IR with pure NumPy index
arithmetic (:meth:`ProcSpace.to_root_batch`). Bodies that are
data-dependent on the iteration point (e.g. branch on ``ipoint``) cannot
broadcast; those fall back automatically to the per-point interpreter.
Evaluated grids are cached per ``ispace`` so bijectivity checks, mesh
permutations and owned-tile queries share one evaluation.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import numpy as np

from repro.core.pspace import ProcSpace, Processor, ProcessorBatch
from repro.core.tuples import Tup

MapFn = Callable[[Tup, Tup], Processor]


@dataclasses.dataclass
class Mapper:
    """A named index mapping: iteration space -> processor space."""

    name: str
    fn: MapFn
    spaces: dict[str, ProcSpace] = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )
    _grid_cache: dict[tuple[int, ...], np.ndarray] = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False
    )
    #: Which path produced the most recent (non-cached) grid evaluation:
    #: "vectorized" or "per-point". Lets callers detect a silent fallback —
    #: benchmarks/mapping_eval.py fails if a vectorizable mapper regressed
    #: to the per-point interpreter.
    last_eval_path: str | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False
    )

    def __call__(self, ipoint: Sequence[int], ispace: Sequence[int]) -> Processor:
        return self.fn(Tup(ipoint), Tup(ispace))

    # -------------------------------------------------------------- analysis
    def assignment_grid(
        self,
        ispace: Sequence[int],
        *,
        vectorized: bool = True,
        use_cache: bool = True,
    ) -> np.ndarray:
        """Flat device id for every iteration point; shape = ispace.

        Vectorized (one batched call of ``fn``) with automatic per-point
        fallback for data-dependent bodies. The result is cached per
        ``ispace`` and marked read-only; pass ``use_cache=False`` to force
        a fresh evaluation (benchmarks), ``vectorized=False`` to force the
        per-point path (equivalence checks). The per-point path never
        touches the cache — otherwise a scalar-vs-batch cross-check could
        be handed the cached vectorized grid and compare it with itself.
        """
        key = tuple(int(s) for s in ispace)
        use_cache = use_cache and vectorized
        if use_cache:
            cached = self._grid_cache.get(key)
            if cached is not None:
                return cached
        grid = None
        if vectorized:
            try:
                grid = self._grid_vectorized(key)
            except Exception:
                grid = None  # data-dependent body: per-point fallback below
        self.last_eval_path = "vectorized" if grid is not None else "per-point"
        if grid is None:
            grid = self._grid_per_point(key)
        grid.flags.writeable = False
        if use_cache:
            self._grid_cache[key] = grid
        return grid

    def _grid_vectorized(self, ispace: tuple[int, ...]) -> np.ndarray | None:
        ipoints = Tup.grid(ispace)
        result = self.fn(ipoints, Tup(ispace))
        if not isinstance(result, (Processor, ProcessorBatch)):
            return None
        flat = np.asarray(result.flat, dtype=np.int64)
        n = ipoints.batch_size
        if flat.ndim == 0:  # body ignored ipoint entirely: constant map
            flat = np.full(n, int(flat), dtype=np.int64)
        if flat.shape != (n,):
            return None
        return flat.reshape(ispace).copy()

    def _grid_per_point(self, ispace: tuple[int, ...]) -> np.ndarray:
        ispace_t = Tup(ispace)
        out = np.empty(ispace, dtype=np.int64)
        for pt in itertools.product(*(range(s) for s in ispace)):
            out[pt] = self.fn(Tup(pt), ispace_t).flat
        return out

    def is_bijective_on(self, ispace: Sequence[int], nprocs: int) -> bool:
        grid = self.assignment_grid(ispace)
        return grid.size == nprocs and len(np.unique(grid)) == nprocs

    def tile_permutation(self, ispace: Sequence[int], nprocs: int) -> np.ndarray:
        """Row-major tile order -> device id permutation (must be bijective).

        This is the object the JAX translation uses to build the Mesh: JAX
        assigns block i of a sharded axis to mesh position i, so realizing an
        arbitrary Mapple map means permuting the device list.
        """
        grid = self.assignment_grid(ispace)
        flat = grid.reshape(-1)
        if len(np.unique(flat)) != nprocs or flat.size != nprocs:
            raise ValueError(
                f"mapper {self.name} is not a bijection from {tuple(ispace)} "
                f"onto {nprocs} processors; cannot realize as a mesh permutation"
            )
        return flat

    # --------------------------------------------------------- introspection
    def describe(self) -> str:
        """The mapper as an inspectable program: its name plus the recorded
        transformation IR of every processor space it closes over."""
        lines = [f"mapper {self.name}"]
        for nm, sp in self.spaces.items():
            lines.append(f"  {nm} = {sp.describe()}")
        return "\n".join(lines)


# ------------------------------------------------------------ Fig. 7 library
def block_mapper(m: ProcSpace, name: str = "block") -> Mapper:
    """blockND: idx = ipoint * m.size / ispace (Fig. 3 / Fig. 7)."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        idx = ipoint * m.size / ispace
        return m[tuple(idx)]

    return Mapper(name, fn, spaces={"m": m})


def cyclic_mapper(m: ProcSpace, name: str = "cyclic") -> Mapper:
    """cyclicND: idx = ipoint % m.size."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        idx = ipoint % m.size
        return m[tuple(idx)]

    return Mapper(name, fn, spaces={"m": m})


def block_cyclic_mapper(m: ProcSpace, name: str = "blockcyclic") -> Mapper:
    """block-cyclic: idx = ipoint / m.size % m.size (Fig. 7)."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        idx = ipoint / m.size % m.size
        return m[tuple(idx)]

    return Mapper(name, fn, spaces={"m": m})


def linear_cyclic_mapper(m2d: ProcSpace, name: str = "linearCyclic") -> Mapper:
    """Fig. 4: merge the 2D space to 1D, round-robin the linearized point."""
    m1 = m2d.merge(0, 1)

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        linearized = ipoint.linearize(ispace)
        return m1[(linearized % m1.size[0],)]

    return Mapper(name, fn, spaces={"m": m2d, "m1": m1})


# --------------------------------------------------------- Fig. 12 primitives
def block_primitive(ipoint: Tup, ispace: Tup, psize: Tup, dim1: int, dim2: int):
    return ipoint[dim1] * psize[dim2] // ispace[dim1]


def cyclic_primitive(ipoint: Tup, ispace: Tup, psize: Tup, dim1: int, dim2: int):
    return ipoint[dim1] % psize[dim2]


def hierarchical_block_mapper(
    m2d: ProcSpace, ispace: Sequence[int], name: str = "hierarchical_block"
) -> Mapper:
    """Fig. 12 hierarchical_block{2,3}D, generalized to any rank.

    decompose the node dim against the iteration space, then decompose the
    per-node processor dim against the *per-node* sub iteration space; block
    over the node factors, cyclic over the intra-node factors.
    """
    k = len(ispace)
    m_nodes = m2d.decompose(0, ispace)                   # k node factors + gpu dim
    node_factors = Tup(m_nodes.shape[:k])
    sub_ispace = Tup(ispace) / node_factors              # per-node sub space
    m_full = m_nodes.decompose(k, tuple(sub_ispace))     # + k gpu factors
    psize = m_full.size

    def fn(ipoint: Tup, ispace_t: Tup) -> Processor:
        upper = tuple(
            block_primitive(ipoint, ispace_t, psize, i, i) for i in range(k)
        )
        lower = tuple(
            cyclic_primitive(ipoint, ispace_t, psize, i, i + k) for i in range(k)
        )
        return m_full[upper + lower]

    return Mapper(name, fn, spaces={"m": m2d, "mf": m_full})


def _column_major_linearize(ipoint: Tup, ispace: Tup):
    """Column-major (first-dim-fastest) linearization at ANY matching rank.

    Replaces the old hardcoded rank-3 expression, which guarded ``ipoint[2]``
    but silently dropped dims beyond the third and assumed rank-3 strides.
    """
    if len(ipoint) != len(ispace):
        raise ValueError(
            f"rank mismatch: point rank {len(ipoint)} vs space rank {len(ispace)}"
        )
    linearized, stride = 0, 1
    for d in range(len(ipoint)):
        linearized = linearized + ipoint[d] * stride
        stride = stride * ispace[d]
    return linearized


def linearize_cyclic_mapper(m2d: ProcSpace, name: str = "linearize_cyclic") -> Mapper:
    """Fig. 12 Solomonik's function 2: column-major linearize, cyclic over
    node then gpu dims of the original 2D space."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        linearized = _column_major_linearize(ipoint, ispace)
        node_idx = linearized % m2d.size[0]
        gpu_idx = (linearized // m2d.size[0]) % m2d.size[1]
        return m2d[(node_idx, gpu_idx)]

    return Mapper(name, fn, spaces={"m": m2d})


def special_linearize3d_mapper(m2d: ProcSpace, name: str = "special_linearize3D") -> Mapper:
    """Fig. 12 COSMA mapper: decompose nodes as equally as possible, then
    linearize with the resulting grid strides, cyclic over nodes."""
    m5 = m2d.decompose(0, (1, 1, 1))  # equal split (all lengths equal)

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        gx = m5.size[2]
        gy = m5.size[1]
        linearized = ipoint[0] + ipoint[1] * gx + ipoint[2] * gx * gy
        return m2d[(linearized % m2d.size[0], 0)]

    return Mapper(name, fn, spaces={"m": m2d, "m5": m5})


def conditional_linearize3d_mapper(
    m2d: ProcSpace, name: str = "conditional_linearize3D"
) -> Mapper:
    """Fig. 12 Johnson's mapper: stride by the larger of ispace[0]/ispace[2]."""

    def fn(ipoint: Tup, ispace: Tup) -> Processor:
        grid_size = ispace[0] if ispace[0] > ispace[2] else ispace[2]
        linearized = (
            ipoint[0] + ipoint[1] * grid_size + ipoint[2] * grid_size * grid_size
        )
        return m2d[(linearized % m2d.size[0], 0)]

    return Mapper(name, fn, spaces={"m": m2d})


def transformed_block_mapper(m: ProcSpace, name: str) -> Mapper:
    """block over an arbitrarily transformed space (block1D_x etc.)."""
    return block_mapper(m, name)
