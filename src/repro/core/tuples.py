"""Elementwise integer tuple arithmetic — the paper's ``Tuple`` type.

Mapple mapping functions are written with tuple arithmetic, e.g.::

    idx = ipoint * m.size / ispace      # block2D  (Fig. 7)
    idx = ipoint % m.size               # cyclic2D
    idx = ipoint / m.size % m.size      # block-cyclic

All operators are elementwise; division is floor division (the paper's
index arithmetic is over naturals). Scalars broadcast.

A :class:`Tup` may also be *batched*: any component may be a NumPy array
carrying a leading batch axis, in which case every operator broadcasts
elementwise per component over the whole batch. This is how the mapper
layer evaluates a mapping function over a full iteration grid in one
vectorized pass (see docs/mapping_ir.md) — the same DSL body runs
unchanged on a scalar point or on B points at once.
"""
from __future__ import annotations

from typing import Iterable, Sequence, Union

import numpy as np

Scalar = int
TupLike = Union["Tup", Sequence[int], Scalar]


def as_index_component(v):
    """One index/Tup component: a Python int, or a batch axis as an int64
    array. This is THE coercion rule for fractional DSL index values —
    shared by Tup and ProcSpace's batched indexing so the scalar and
    batched paths can never diverge."""
    if isinstance(v, np.ndarray) and v.ndim > 0:
        if v.dtype.kind == "f":
            # Match int()'s truncation; DSL values are naturals, so == floor.
            v = np.trunc(v)
        return v.astype(np.int64, copy=False)
    return int(v)


def _coerce(other: TupLike, n: int) -> tuple:
    if isinstance(other, Tup):
        vals = other._vals
    elif isinstance(other, (list, tuple)):
        vals = tuple(as_index_component(v) for v in other)
    elif isinstance(other, (int, np.integer)):
        return (int(other),) * n
    else:
        # ProcSpace coerces via its .size (duck-typed to avoid circular import)
        size = getattr(other, "size", None)
        if isinstance(size, Tup):
            vals = size._vals
        else:
            raise TypeError(f"cannot coerce {other!r} to Tup")
    if len(vals) != n:
        raise ValueError(f"rank mismatch: {n} vs {len(vals)}")
    return vals


class Tup:
    """Immutable integer tuple with elementwise arithmetic.

    Components are Python ints, or (B,)-shaped int64 arrays when batched.
    """

    __slots__ = ("_vals",)

    def __init__(self, vals: Iterable[int]) -> None:
        object.__setattr__(self, "_vals", tuple(as_index_component(v) for v in vals))

    # -------------------------------------------------------------- protocol
    def __iter__(self):
        return iter(self._vals)

    def __len__(self) -> int:
        return len(self._vals)

    def __getitem__(self, key):
        if isinstance(key, slice):
            return Tup(self._vals[key])
        return self._vals[key]

    def __eq__(self, other) -> bool:
        if isinstance(other, Tup):
            return self._vals == other._vals
        if isinstance(other, tuple):
            return self._vals == other
        return NotImplemented

    def __hash__(self) -> int:
        return hash(self._vals)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Tup{self._vals}"

    # ---------------------------------------------------------------- batching
    @property
    def is_batched(self) -> bool:
        return any(isinstance(v, np.ndarray) for v in self._vals)

    @property
    def batch_size(self) -> int | None:
        """Leading batch extent, or None for a scalar Tup."""
        for v in self._vals:
            if isinstance(v, np.ndarray):
                return int(v.shape[0])
        return None

    @classmethod
    def grid(cls, extents: Sequence[int]) -> "Tup":
        """Batched Tup enumerating every point of ``extents`` in row-major
        order — rank len(extents), batch size prod(extents)."""
        extents = tuple(int(e) for e in extents)
        idx = np.indices(extents, dtype=np.int64).reshape(len(extents), -1)
        return cls(idx)

    # ------------------------------------------------------------ arithmetic
    def _zip(self, other: TupLike, op) -> "Tup":
        o = _coerce(other, len(self._vals))
        return Tup(op(a, b) for a, b in zip(self._vals, o))

    def __mul__(self, other):
        return self._zip(other, lambda a, b: a * b)

    __rmul__ = __mul__

    def __add__(self, other):
        return self._zip(other, lambda a, b: a + b)

    __radd__ = __add__

    def __sub__(self, other):
        return self._zip(other, lambda a, b: a - b)

    def __rsub__(self, other):
        o = _coerce(other, len(self._vals))
        return Tup(b - a for a, b in zip(self._vals, o))

    def __floordiv__(self, other):
        return self._zip(other, lambda a, b: a // b)

    # The paper writes `/` for natural-number division.
    __truediv__ = __floordiv__

    def __rfloordiv__(self, other):
        o = _coerce(other, len(self._vals))
        return Tup(b // a for a, b in zip(self._vals, o))

    __rtruediv__ = __rfloordiv__

    def __mod__(self, other):
        return self._zip(other, lambda a, b: a % b)

    # ----------------------------------------------------------- conveniences
    def prod(self):
        out = 1
        for v in self._vals:
            out = out * v
        return out

    def linearize(self, extents: TupLike):
        """Row-major linearization of this point within ``extents``."""
        ex = _coerce(extents, len(self._vals))
        out = 0
        for v, e in zip(self._vals, ex):
            out = out * e + v
        return out

    def as_tuple(self) -> tuple:
        return self._vals
