"""Elementwise integer tuple arithmetic — the paper's ``Tuple`` type.

Mapple mapping functions are written with tuple arithmetic, e.g.::

    idx = ipoint * m.size / ispace      # block2D  (Fig. 7)
    idx = ipoint % m.size               # cyclic2D
    idx = ipoint / m.size % m.size      # block-cyclic

All operators are elementwise; division is floor division (the paper's
index arithmetic is over naturals). Scalars broadcast.
"""
from __future__ import annotations

from typing import Iterable, Sequence, Union

Scalar = int
TupLike = Union["Tup", Sequence[int], Scalar]


def _coerce(other: TupLike, n: int) -> tuple[int, ...]:
    if isinstance(other, Tup):
        vals = other._vals
    elif isinstance(other, (list, tuple)):
        vals = tuple(int(v) for v in other)
    elif isinstance(other, int):
        return (int(other),) * n
    else:
        # ProcSpace coerces via its .size (duck-typed to avoid circular import)
        size = getattr(other, "size", None)
        if isinstance(size, Tup):
            vals = size._vals
        else:
            raise TypeError(f"cannot coerce {other!r} to Tup")
    if len(vals) != n:
        raise ValueError(f"rank mismatch: {n} vs {len(vals)}")
    return vals


class Tup:
    """Immutable integer tuple with elementwise arithmetic."""

    __slots__ = ("_vals",)

    def __init__(self, vals: Iterable[int]) -> None:
        object.__setattr__(self, "_vals", tuple(int(v) for v in vals))

    # -------------------------------------------------------------- protocol
    def __iter__(self):
        return iter(self._vals)

    def __len__(self) -> int:
        return len(self._vals)

    def __getitem__(self, key):
        if isinstance(key, slice):
            return Tup(self._vals[key])
        return self._vals[key]

    def __eq__(self, other) -> bool:
        if isinstance(other, Tup):
            return self._vals == other._vals
        if isinstance(other, tuple):
            return self._vals == other
        return NotImplemented

    def __hash__(self) -> int:
        return hash(self._vals)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Tup{self._vals}"

    # ------------------------------------------------------------ arithmetic
    def _zip(self, other: TupLike, op) -> "Tup":
        o = _coerce(other, len(self._vals))
        return Tup(op(a, b) for a, b in zip(self._vals, o))

    def __mul__(self, other):
        return self._zip(other, lambda a, b: a * b)

    __rmul__ = __mul__

    def __add__(self, other):
        return self._zip(other, lambda a, b: a + b)

    __radd__ = __add__

    def __sub__(self, other):
        return self._zip(other, lambda a, b: a - b)

    def __rsub__(self, other):
        o = _coerce(other, len(self._vals))
        return Tup(b - a for a, b in zip(self._vals, o))

    def __floordiv__(self, other):
        return self._zip(other, lambda a, b: a // b)

    # The paper writes `/` for natural-number division.
    __truediv__ = __floordiv__

    def __rfloordiv__(self, other):
        o = _coerce(other, len(self._vals))
        return Tup(b // a for a, b in zip(self._vals, o))

    __rtruediv__ = __rfloordiv__

    def __mod__(self, other):
        return self._zip(other, lambda a, b: a % b)

    # ----------------------------------------------------------- conveniences
    def prod(self) -> int:
        out = 1
        for v in self._vals:
            out *= v
        return out

    def linearize(self, extents: TupLike) -> int:
        """Row-major linearization of this point within ``extents``."""
        ex = _coerce(extents, len(self._vals))
        out = 0
        for v, e in zip(self._vals, ex):
            out = out * e + v
        return out

    def as_tuple(self) -> tuple[int, ...]:
        return self._vals
