"""Training substrate: optimizer, train step, loop."""
from repro.training.optimizer import AdamWConfig, AdamWState, init, update
from repro.training.loop import TrainLoop, TrainState, init_state, make_train_step
