"""Training loop: jitted step, bounded async dispatch, checkpoint cadence.

The dispatch bound is the paper's ``Backpressure`` directive put to work:
at most ``backpressure`` steps are in flight before the loop blocks on the
oldest result — keeping host memory bounded and absorbing transient
stragglers without a barrier every step.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.training import optimizer as opt_mod
from repro.runtime import compression


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: opt_mod.AdamWState
    error: Any = None               # compression error feedback (optional)

    def as_tree(self) -> dict:
        tree = {"params": self.params, "opt_mu": self.opt.mu,
                "opt_nu": self.opt.nu, "opt_step": self.opt.step}
        if self.error is not None:
            tree["error"] = self.error
        return tree

    @classmethod
    def from_tree(cls, tree: dict) -> "TrainState":
        return cls(
            params=tree["params"],
            opt=opt_mod.AdamWState(
                step=jnp.asarray(tree["opt_step"]),
                mu=tree["opt_mu"], nu=tree["opt_nu"],
            ),
            error=tree.get("error"),
        )


def make_train_step(model, opt_cfg: opt_mod.AdamWConfig, *,
                    use_pallas: bool = False, remat: bool = True,
                    compress_grads: bool = False) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). Pure; jit it
    (or pjit with shardings) at the call site."""

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, use_pallas=use_pallas, remat=remat)
        )(state.params)
        error = state.error
        compress = None
        if compress_grads and error is not None:
            grads, error = compression.compress_tree(grads, error)
        params, opt_state, metrics = opt_mod.update(
            opt_cfg, grads, state.opt, state.params, compress=compress
        )
        metrics = {"loss": loss, **metrics}
        return TrainState(params, opt_state, error), metrics

    return train_step


def init_state(model, key, opt_cfg: opt_mod.AdamWConfig, *,
               compress_grads: bool = False) -> TrainState:
    params = model.init(key)
    opt_state = opt_mod.init(params)
    error = compression.init_error(params) if compress_grads else None
    return TrainState(params, opt_state, error)


@dataclasses.dataclass
class TrainLoop:
    step_fn: Callable                     # jitted (state, batch) -> (state, m)
    pipeline: Any                         # repro.data pipeline
    backpressure: int = 2
    checkpoint_manager: Any = None
    save_every: int = 0

    def run(self, state: TrainState, start_step: int, n_steps: int,
            *, n_shards: int = 1, log_every: int = 10,
            on_step: Callable | None = None) -> tuple[TrainState, list[dict]]:
        in_flight: collections.deque = collections.deque()
        history: list[dict] = []
        t0 = time.perf_counter()
        for step in range(start_step, n_steps):
            batch = self.pipeline.batch(step)
            state, metrics = self.step_fn(state, batch)
            in_flight.append((step, metrics))
            # Backpressure: bound async dispatch depth.
            while len(in_flight) > self.backpressure:
                s, m = in_flight.popleft()
                m = {k: float(v) for k, v in m.items()}
                m["step"] = s
                history.append(m)
                if on_step is not None:
                    on_step(s, m)
                if log_every and s % log_every == 0:
                    dt = time.perf_counter() - t0
                    print(f"step {s:5d} loss {m['loss']:.4f} "
                          f"gnorm {m['grad_norm']:.3f} ({dt:.1f}s)")
            if (
                self.checkpoint_manager is not None
                and self.save_every
                and (step + 1) % self.save_every == 0
            ):
                jax.block_until_ready(state.params)
                self.checkpoint_manager.save(
                    step + 1, state.as_tree(), {"cursor": step + 1}
                )
        while in_flight:
            s, m = in_flight.popleft()
            m = {k: float(v) for k, v in m.items()}
            m["step"] = s
            history.append(m)
            if on_step is not None:
                on_step(s, m)
        return state, history
