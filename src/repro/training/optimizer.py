"""AdamW with global-norm clipping, cosine schedule, mixed precision.

Built from scratch (no optax in the image). Optimizer states follow the
parameter shardings; an optional ZeRO-1 mode re-shards the moments over the
data axis (states are elementwise, so any even sharding is valid — the
all-gather happens implicitly at the param update).

Optional gradient compression (int8 error-feedback) hooks in before the DP
all-reduce; see repro/runtime/compression.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment  (pytree like params)
    nu: Any          # second moment


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(tree)
        )
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def update(cfg: AdamWConfig, grads, state: AdamWState, params,
           compress: Callable | None = None):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if compress is not None:
        grads = compress(grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32
        )
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.mu)
    flat_v = jax.tree_util.tree_leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
