"""Pipeline parallelism across pods (GPipe-style, shard_map + ppermute).

Multi-pod meshes pay DCI prices for cross-pod collectives; pipelining
sends only ACTIVATIONS across the pod boundary instead of gradient
all-reduces. The layer stack is split into one contiguous stage per pod;
microbatches stream through the classic skewed schedule:

    t:        0    1    2    3   ...
    stage 0:  m0   m1   m2   m3
    stage 1:       m0   m1   m2

Implemented as a shard_map over the 'pod' axis whose body runs the local
stage and collective_permutes activations to the next stage. Bubble
fraction = (S-1)/(M+S-1). jax.grad differentiates straight through (the
transpose of ppermute is the reverse permute), giving a correct (GPipe,
all-microbatch-stash) backward.

This module is self-contained and validated against the unpipelined
reference on 8 fake devices (tests/test_pipeline.py); it is the
distribution feature the 'pod' axis exists for at 1000+ nodes.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.jaxcompat import shard_map


def split_stages(stacked_params: Any, n_stages: int) -> Any:
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-major."""

    def reshape(p):
        L = p.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return p.reshape((n_stages, L // n_stages) + p.shape[1:])

    return jax.tree.map(reshape, stacked_params)


def pipelined_apply(
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    mesh,
    *,
    pod_axis: str = "pod",
    n_microbatches: int,
):
    """Build fn(stage_params, x) -> y running the layer stack pipelined.

    ``layer_fn(layer_params, x) -> x`` applies ONE layer. ``stage_params``
    is the (S, L/S, ...) tree from split_stages, sharded over the pod axis
    on dim 0; ``x`` is (M*Bm, ...) microbatch-major, replicated across the
    pod axis (each stage uses only its schedule slice).
    """
    n_stages = int(mesh.shape[pod_axis])

    def stage_apply(local_stack, x):
        def body(h, layer_params):
            return layer_fn(layer_params, h), None

        out, _ = jax.lax.scan(body, x, local_stack)
        return out

    def body(stage_stack, x_all):
        # stage_stack: (1, L/S, ...) local slice; x_all: (M, Bm, ...).
        local = jax.tree.map(lambda p: p[0], stage_stack)
        stage = jax.lax.axis_index(pod_axis)
        M = x_all.shape[0]
        T = M + n_stages - 1
        carry_in = jnp.zeros_like(x_all[0])
        outputs = jnp.zeros_like(x_all)

        def step(t, state):
            carry, outputs = state
            # stage 0 ingests microbatch t (when valid); others take the
            # activation handed over at the previous tick.
            mb_idx = jnp.clip(t, 0, M - 1)
            feed = jnp.where(
                stage == 0,
                jax.lax.dynamic_index_in_dim(x_all, mb_idx, 0, keepdims=False),
                carry,
            )
            out = stage_apply(local, feed)
            # hand to the next stage (ring; the wraparound write is masked)
            nxt = jax.lax.ppermute(
                out, pod_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)],
            )
            # last stage emits microbatch (t - (S-1)) at tick t
            emit_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            valid = (stage == n_stages - 1) & (t >= n_stages - 1)
            cur = jax.lax.dynamic_index_in_dim(outputs, emit_idx, 0,
                                               keepdims=False)
            newval = jnp.where(valid, out, cur)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, newval, emit_idx, 0
            )
            return (nxt, outputs)

        _, outputs = jax.lax.fori_loop(0, T, step, (carry_in, outputs))
        # Make the result identical on every pod (the last stage owns it).
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, 0.0), pod_axis
        )
        return outputs

    # P(pod_axis) acts as a pytree prefix: dim 0 (the stage dim) of every
    # parameter leaf shards over the pod axis.
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(pod_axis), P()),
        out_specs=P(),
        check_vma=False,
    )

    def apply(stage_params, x_microbatched):
        return fn(stage_params, x_microbatched)

    return apply


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
