"""Unified application registry for the nine paper workloads.

The paper evaluates Mapple on six distributed matmul algorithms (Cannon,
SUMMA, PUMMA, Johnson, Solomonik, COSMA) and three scientific applications
(circuit, 2D stencil, PENNANT). This module gives every one of them the
same declarative shape — an :class:`Application` — and a single registry
through which each is parsed, mapped, translated and costed:

    dsl.parse(app.mapple_source(procs))        # the Mapple mapper program
      -> program.mappers[...]                  # Mapper object
      -> translate.to_spmd(program, ...)       # device permutation / Mesh
      -> app.comm_volume(procs)                # closed-form volume model

Every benchmark driver (`benchmarks/loc_table.py`, `mapper_tuning.py`,
`heuristic_gap.py`, `decompose_sweep.py`) and the end-to-end runner
(`python -m repro.apps.run`) iterates this registry instead of hard-coding
app lists; new workloads plug in by calling :func:`register`.

This module is importable without JAX — only the execution hooks in
``repro.apps.validate`` touch devices.
"""
from __future__ import annotations

import dataclasses
import math
from pathlib import Path
from typing import Callable, Iterator

from repro.core import dsl
from repro.core.dsl import MapperProgram
from repro.core.machine import GPU, Machine
from repro.core.mapper import Mapper
from repro.core.pspace import ProcSpace
from repro.core.translate import MappingPlan, to_spmd
from repro.search.space import SearchSpace
from repro.sim.collectives import CollectivePattern

REPO_ROOT = Path(__file__).resolve().parents[3]

MATMUL = "matmul"
SCIENCE = "science"


@dataclasses.dataclass(frozen=True)
class Application:
    """One paper workload, described declaratively.

    The callables take a processor count so the same description scales
    from the paper's 2x4-GPU running example to full pods; each may raise
    ``ValueError`` for processor counts the algorithm cannot use (e.g.
    Cannon needs a square count).
    """

    name: str
    kind: str                                   # MATMUL | SCIENCE
    pattern: str                                # dominant comm pattern
    description: str
    default_procs: int
    axis_names: tuple[str, ...]
    machine_shape: Callable[[int], tuple[int, ...]]
    tile_grid: Callable[[int], tuple[int, ...]]
    mapple_template: Callable[[int], str]       # procs -> Mapple source
    comm_volume: Callable[[int], float]         # elements moved per step
    step_flops: Callable[[int], float]          # modeled compute per step
    # (default-mapper volume, tuned-mapper volume) — the Table 2 pair, kept
    # as a REGRESSION ORACLE: the autotuner must rediscover (or beat) the
    # tuned volume; tests assert it, nothing trusts it as ground truth.
    tuning: Callable[[int], tuple[float, float]] | None = None
    # Candidate axes + cost model for the mapper autotuner (repro.search).
    search_space: SearchSpace | None = None
    # The wire-level communication pattern the app's step emits, consumed
    # by the discrete-event simulator (repro.sim) to price a mapping in
    # seconds against the exact tile->processor assignment.
    collective: CollectivePattern | None = None
    lowlevel_fixture: str = ""                  # repo-relative baseline path
    validate: str | None = None                 # hook in repro.apps.validate
    meta: dict = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------ pipeline
    def machine(self, procs: int | None = None) -> ProcSpace:
        return Machine(GPU, shape=self.machine_shape(self.procs(procs)))

    def procs(self, procs: int | None = None) -> int:
        return self.default_procs if procs is None else int(procs)

    def mapple_source(self, procs: int | None = None) -> str:
        return self.mapple_template(self.procs(procs))

    def program(self, procs: int | None = None) -> MapperProgram:
        n = self.procs(procs)
        shape = self.machine_shape(n)
        return dsl.parse(
            self.mapple_source(n),
            machine_factory=lambda *a, **k: Machine(GPU, shape=shape),
        )

    def mapper(self, procs: int | None = None) -> Mapper:
        prog = self.program(procs)
        name = prog.index_task_maps[self.name]
        return prog.mappers[name]

    def spmd_plan(self, procs: int | None = None, devices=None) -> MappingPlan:
        """parse -> map -> translate, returning the full SPMD plan."""
        n = self.procs(procs)
        return to_spmd(
            self.program(n),
            self.name,
            self.tile_grid(n),
            self.axis_names,
            devices=devices,
        )

    # ------------------------------------------------------------- metrics
    def mapple_loc(self, procs: int | None = None) -> int:
        return self.program(procs).loc()

    def lowlevel_path(self) -> Path:
        p = REPO_ROOT / self.lowlevel_fixture
        if not p.exists():
            # Installed (site-packages) layout: fall back to a repo checkout
            # in the working directory.
            cwd_p = Path.cwd() / self.lowlevel_fixture
            if cwd_p.exists():
                return cwd_p
        return p

    def lowlevel_loc(self) -> int:
        """LoC of the raw baseline; 0 when the fixture isn't available
        (e.g. running from an installed package without the repo)."""
        p = self.lowlevel_path()
        return count_python_loc(p) if p.exists() else 0


_REGISTRY: dict[str, Application] = {}


def register(app: Application) -> Application:
    if app.name in _REGISTRY:
        raise ValueError(f"application {app.name!r} already registered")
    _REGISTRY[app.name] = app
    return app


def get(name: str) -> Application:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown application {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> list[str]:
    return list(_REGISTRY)


def iter_apps(kind: str | None = None, pattern: str | None = None
              ) -> Iterator[Application]:
    for app in _REGISTRY.values():
        if kind is not None and app.kind != kind:
            continue
        if pattern is not None and app.pattern != pattern:
            continue
        yield app


# ----------------------------------------------------------------- LoC metric
def count_python_loc(path: Path) -> int:
    """Non-blank, non-comment, non-docstring lines (paper Table 1 metric)."""
    out = 0
    in_docstring = False
    for raw in path.read_text().splitlines():
        ln = raw.strip()
        if not ln:
            continue
        if ln.startswith('"""') or ln.endswith('"""'):
            if ln.count('"""') == 1:
                in_docstring = not in_docstring
            continue
        if in_docstring or ln.startswith("#"):
            continue
        out += 1
    return out


# ---------------------------------------------------------------- grid maths
def square_grid(procs: int) -> tuple[int, int]:
    q = math.isqrt(procs)
    if q * q != procs:
        raise ValueError(f"needs a square processor count, got {procs}")
    return (q, q)


def cube_grid(procs: int) -> tuple[int, int, int]:
    q = round(procs ** (1.0 / 3.0))
    if q ** 3 != procs:
        raise ValueError(f"needs a cubic processor count, got {procs}")
    return (q, q, q)


def replicated_grid(procs: int) -> tuple[int, int, int]:
    """Solomonik (q, q, c): prefer the most-replicated valid c <= q."""
    best: tuple[int, int, int] | None = None
    for c in range(1, procs + 1):
        if procs % c != 0:
            continue
        q = math.isqrt(procs // c)
        if q * q * c == procs and c <= q and q % c == 0:
            best = (q, q, c)
    if best is None:
        raise ValueError(f"cannot form a (q, q, c) grid from {procs} devices")
    return best


def two_level_machine(procs: int, gpus_per_node: int = 4) -> tuple[int, int]:
    """(nodes, gpus) factorization of a flat processor count."""
    g = gpus_per_node
    while g > 1 and procs % g:
        g //= 2
    return (max(procs // g, 1), g)
