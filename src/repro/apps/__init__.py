"""Unified registry + end-to-end pipeline for the nine paper applications.

    from repro.apps import get, iter_apps, names

    app = get("summa")
    plan = app.spmd_plan(procs=64)         # parse -> map -> translate
    volume = app.comm_volume(64)           # closed-form comm model

CLI: ``python -m repro.apps.run --app summa --procs 64`` (or ``--all``).
"""
from repro.apps.registry import (  # noqa: F401
    MATMUL,
    SCIENCE,
    Application,
    count_python_loc,
    get,
    iter_apps,
    names,
    register,
)
from repro.apps import definitions  # noqa: F401  (registers the nine apps)
from repro.apps.definitions import PAPER_APPS  # noqa: F401
