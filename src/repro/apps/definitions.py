"""The nine paper applications, registered declaratively.

Six matmul algorithms (Sec. 6.1-6.2) and three scientific workloads
(Sec. 6.3). Each definition carries:

  * the Mapple DSL mapper program (Fig. 12 of the paper), rendered for a
    given processor count;
  * machine / tile-grid policies scaling the paper's 2-node running
    example to arbitrary processor counts;
  * the closed-form communication-volume model (Sec. 4.2 / published
    matmul costs) the benchmarks reproduce analytically;
  * the Table 2 tuning experiment (default vs tuned mapper volumes);
  * the low-level raw-JAX baseline fixture whose LoC Table 1 compares.

Importing this module populates ``repro.apps.registry``.
"""
from __future__ import annotations

import math

from repro.core.commvolume import (
    GatherScatterCostModel,
    HaloCostModel,
    MatmulCostModel,
    MatmulProblem,
    cannon_volume,
    cosma_grid,
    cosma_volume,
    halo_surface_volume,
    johnson_volume,
    solomonik_volume,
    summa_volume,
)
from repro.core.decompose import (
    cached_optimal,
    greedy_factorization,
    optimal_factorization,
)
from repro.apps.registry import (
    MATMUL,
    SCIENCE,
    Application,
    cube_grid,
    register,
    replicated_grid,
    square_grid,
    two_level_machine,
)
from repro.search.space import SearchSpace
from repro.sim.collectives import CollectivePattern

# Default problem sizes (scaled-down analogues of the paper's runs).
MATMUL_PROBLEM = MatmulProblem(4096, 4096, 4096)
STENCIL_LENGTHS = (1024, 8192)      # 1:8 aspect — where decompose pays off
PENNANT_ZONES = (2048, 16384)
PENNANT_FIELDS = 3          # p, u, v halos exchanged per hydro step
CIRCUIT_NODES_PER_PIECE = 64
CIRCUIT_WIRES_PER_PIECE = 96


def _matmul_machine(procs: int) -> tuple[int, int]:
    """(nodes, gpus) for the 2D matmul algorithms; the paper's default
    machine is 2 nodes x 2 GPUs at four processors."""
    return two_level_machine(procs, 2 if procs <= 8 else 4)


def _science_machine(procs: int) -> tuple[int, int]:
    return two_level_machine(procs, 4)


def _stencil_grid(lengths):
    lengths = tuple(int(x) for x in lengths)

    def grid(procs: int) -> tuple[int, ...]:
        # Memoized hot path: the runner / tuner re-derive this grid often.
        # Integrality-constrained like the science/ launchers, so the
        # analysis grid always matches the grid the kernels execute on.
        return tuple(int(x) for x in cached_optimal(
            procs, lengths, require_divisible=True))

    return grid


# --------------------------------------------------------------- Mapple DSL
# Fig. 12 mapper programs, rendered per processor count. Directives mirror
# the raw fixtures' memory/donation/backpressure choices exactly.

HB2D_TEMPLATE = """\
m = Machine(GPU)
mn = m.decompose(0, ({gx}, {gy}))
mf = mn.decompose(2, ({gx} / mn.size[0], {gy} / mn.size[1]))

def {task}_map(Tuple ipoint, Tuple ispace):
    n0 = block_primitive(ipoint, ispace, mf.size, 0, 0)
    n1 = block_primitive(ipoint, ispace, mf.size, 1, 1)
    g0 = cyclic_primitive(ipoint, ispace, mf.size, 0, 2)
    g1 = cyclic_primitive(ipoint, ispace, mf.size, 1, 3)
    return mf[n0, n1, g0, g1]

IndexTaskMap {task} {task}_map
"""


def _cannon_mapple(procs: int) -> str:
    gx, gy = square_grid(procs)
    return (
        HB2D_TEMPLATE.format(task="cannon", gx=gx, gy=gy)
        + "Region cannon arg0 GPU FBMEM\n"
        + "Region cannon arg1 GPU FBMEM\n"
        + "GarbageCollect cannon arg2\n"
        + "Backpressure cannon 1\n"
    )


def _summa_mapple(procs: int) -> str:
    gx, gy = square_grid(procs)
    return (
        HB2D_TEMPLATE.format(task="summa", gx=gx, gy=gy)
        + "Region summa arg0 GPU FBMEM\n"
        + "Region summa arg1 GPU FBMEM\n"
        + "Backpressure summa 2\n"
    )


def _pumma_mapple(procs: int) -> str:
    return """\
m = Machine(GPU)
m1 = m.merge(0, 1)

def pumma_map(Tuple ipoint, Tuple ispace):
    linearized = ipoint.linearize(ispace)
    return m1[linearized % m1.size[0]]

IndexTaskMap pumma pumma_map
Region pumma arg0 GPU FBMEM
Backpressure pumma 2
"""


def _johnson_mapple(procs: int) -> str:
    return """\
m = Machine(GPU)

def johnson_map(Tuple ipoint, Tuple ispace):
    grid_size = ispace[0] > ispace[2] ? ispace[0] : ispace[2]
    linearized = ipoint[0] + ipoint[1] * grid_size + ipoint[2] * grid_size * grid_size
    return m[linearized % m.size[0], 0]

IndexTaskMap johnson johnson_map
Region johnson arg0 GPU FBMEM
Backpressure johnson 2
"""


def _solomonik_mapple(procs: int) -> str:
    return """\
m = Machine(GPU)

def solomonik_map(Tuple ipoint, Tuple ispace):
    linearized = ipoint[0] + ispace[0] * ipoint[1] + ispace[0] * ispace[1] * ipoint[2]
    node_idx = linearized % m.size[0]
    gpu_idx = linearized / m.size[0] % m.size[1]
    return m[node_idx, gpu_idx]

IndexTaskMap solomonik solomonik_map
Region solomonik arg0 GPU FBMEM
GarbageCollect solomonik arg2
Backpressure solomonik 1
"""


def _cosma_mapple(procs: int) -> str:
    return """\
m = Machine(GPU)
m5 = m.decompose(0, (1, 1, 1))

def cosma_map(Tuple ipoint, Tuple ispace):
    linearized = ipoint[0] + ipoint[1] * m5.size[2] + ipoint[2] * m5.size[2] * m5.size[1]
    return m[linearized % m.size[0], 0]

IndexTaskMap cosma cosma_map
Region cosma arg0 GPU FBMEM
Backpressure cosma 2
"""


DECOMPOSE_TEMPLATE = """\
m = Machine(GPU)
m2 = m.merge(0, 1).decompose(0, ({nx}, {ny}))

def {task}_map(Tuple ipoint, Tuple ispace):
    idx = ipoint * m2.size / ispace
    return m2[*idx]

IndexTaskMap {task} {task}_map
Region {task} arg0 GPU FBMEM
Backpressure {task} 2
"""


def _stencil_mapple(procs: int) -> str:
    nx, ny = STENCIL_LENGTHS
    return DECOMPOSE_TEMPLATE.format(task="stencil", nx=nx, ny=ny)


def _pennant_mapple(procs: int) -> str:
    nx, ny = PENNANT_ZONES
    return DECOMPOSE_TEMPLATE.format(task="pennant", nx=nx, ny=ny)


def _circuit_mapple(procs: int) -> str:
    return """\
m = Machine(GPU)
m1 = m.merge(0, 1)

def circuit_map(Tuple ipoint, Tuple ispace):
    idx = ipoint * m1.size / ispace
    return m1[*idx]

IndexTaskMap circuit circuit_map
Region circuit arg0 GPU FBMEM
Region circuit arg1 CPU ZCMEM
Backpressure circuit 2
"""


# ------------------------------------------------------------ volume models
def _cannon_tuning(procs: int) -> tuple[float, float]:
    v = cannon_volume(MATMUL_PROBLEM, square_grid(procs))
    return (v, v)                      # Cannon's map is already the tuned one


def _summa_tuning(procs: int) -> tuple[float, float]:
    g = square_grid(procs)
    return (summa_volume(MATMUL_PROBLEM, g),
            summa_volume(MATMUL_PROBLEM, g, panel=4))


def _pumma_tuning(procs: int) -> tuple[float, float]:
    v = summa_volume(MATMUL_PROBLEM, square_grid(procs))
    return (v, v)


def _johnson_tuning(procs: int) -> tuple[float, float]:
    return (johnson_volume(MATMUL_PROBLEM, cube_grid(procs)),
            johnson_volume(MATMUL_PROBLEM, cosma_grid(MATMUL_PROBLEM, procs)))


def _solomonik_tuning(procs: int) -> tuple[float, float]:
    q = math.isqrt(procs)
    default = solomonik_volume(MATMUL_PROBLEM, (q, q, 1)) if q * q == procs \
        else solomonik_volume(MATMUL_PROBLEM, replicated_grid(procs))
    return (default, solomonik_volume(MATMUL_PROBLEM, replicated_grid(procs)))


def _cosma_tuning(procs: int) -> tuple[float, float]:
    return (johnson_volume(
                MATMUL_PROBLEM, tuple(greedy_factorization(procs, 3))),
            cosma_volume(MATMUL_PROBLEM, procs))


def _halo_volume(lengths, fields: int):
    def vol(procs: int) -> float:
        return fields * halo_surface_volume(
            lengths, optimal_factorization(procs, lengths)
        )

    return vol


def _halo_tuning(lengths, fields: int):
    def tuning(procs: int) -> tuple[float, float]:
        return (
            fields * halo_surface_volume(
                lengths, greedy_factorization(procs, 2)),
            fields * halo_surface_volume(
                lengths, optimal_factorization(procs, lengths)),
        )

    return tuning


def _circuit_volume(procs: int) -> float:
    """all_gather(V) + psum_scatter(Q): ring cost (p-1) * n each way."""
    n_nodes = CIRCUIT_NODES_PER_PIECE * procs
    return 2.0 * (procs - 1) * n_nodes


def _circuit_tuning(procs: int) -> tuple[float, float]:
    # ZCMEM placement of the shared charge removes a device round trip
    # (modeled as in the paper's Table 2 circuit row).
    v = _circuit_volume(procs)
    return (v, 0.75 * v)


# ------------------------------------------------------------- search spaces
# Candidate axes + cost objective per app for the mapper autotuner
# (repro.search). The legacy ``tuning`` pairs above stay as regression
# oracles the tuner must rediscover; the search space is what it actually
# explores: grid factorizations (validity-filtered), block/cyclic
# distribution choices and transform orderings over the machine hierarchy,
# plus app-specific option axes (circuit's memory placement).


def _render_directives(*lines: str):
    def render(task: str, opts: dict[str, str]) -> str:
        return "".join(ln.format(task=task, **opts) + "\n" for ln in lines)

    return render


def _square_ok(grid: tuple[int, ...]) -> bool:
    return grid[0] == grid[1]


def _replicated_ok(grid: tuple[int, ...]) -> bool:
    q1, q2, c = grid
    return q1 == q2 and 1 <= c <= q1 and q1 % c == 0


def _solomonik_default_grid(procs: int) -> tuple[int, int, int]:
    q = math.isqrt(procs)
    if q * q == procs:
        return (q, q, 1)
    return replicated_grid(procs)


def _matmul_space(algorithm: str, *, rank: int, grid_ok=None, default_grid=None,
                  directives=None) -> SearchSpace:
    # directives=None: the renderer's standard Region/Backpressure fallback
    # (repro.search.space.standard_directives) applies.
    return SearchSpace(
        rank=rank,
        cost_model=lambda procs, opts: MatmulCostModel(MATMUL_PROBLEM, algorithm),
        grid_ok=grid_ok,
        default_grid=default_grid,
        directives=directives,
    )


CANNON_SPACE = _matmul_space(
    "cannon", rank=2, grid_ok=_square_ok, default_grid=square_grid,
    directives=_render_directives(
        "Region {task} arg0 GPU FBMEM",
        "Region {task} arg1 GPU FBMEM",
        "GarbageCollect {task} arg2",
        "Backpressure {task} 1",
    ),
)
SUMMA_SPACE = _matmul_space(
    "summa", rank=2, default_grid=square_grid,
    directives=_render_directives(
        "Region {task} arg0 GPU FBMEM",
        "Region {task} arg1 GPU FBMEM",
        "Backpressure {task} 2",
    ),
)
PUMMA_SPACE = _matmul_space("pumma", rank=2, default_grid=square_grid)
JOHNSON_SPACE = _matmul_space("johnson", rank=3, default_grid=cube_grid)
SOLOMONIK_SPACE = _matmul_space(
    "solomonik", rank=3, grid_ok=_replicated_ok,
    default_grid=_solomonik_default_grid,
    directives=_render_directives(
        "Region {task} arg0 GPU FBMEM",
        "GarbageCollect {task} arg2",
        "Backpressure {task} 1",
    ),
)
COSMA_SPACE = _matmul_space(
    "cosma", rank=3, default_grid=lambda p: tuple(greedy_factorization(p, 3)),
)

CIRCUIT_SPACE = SearchSpace(
    rank=1,
    cost_model=lambda procs, opts: GatherScatterCostModel(
        CIRCUIT_NODES_PER_PIECE,
        discount=0.75 if opts.get("arg1") == "ZCMEM" else 1.0,
    ),
    option_axes=(("arg1", ("ZCMEM", "FBMEM")),),
    default_grid=lambda p: (p,),
    default_options=(("arg1", "FBMEM"),),
    directives=_render_directives(
        "Region {task} arg0 GPU FBMEM",
        "Region {task} arg1 CPU {arg1}",
        "Backpressure {task} 2",
    ),
)


def _halo_space(lengths: tuple[int, ...], fields: int) -> SearchSpace:
    return SearchSpace(
        rank=len(lengths),
        cost_model=lambda procs, opts: HaloCostModel(lengths, fields=fields),
        default_grid=lambda p: greedy_factorization(p, len(lengths)),
    )


STENCIL_SPACE = _halo_space(STENCIL_LENGTHS, 1)
PENNANT_SPACE = _halo_space(PENNANT_ZONES, PENNANT_FIELDS)


# --------------------------------------------------------- collective patterns
# Wire-level schedules for the simulator (repro.sim): what one step of the
# app actually puts on the fabric, parameterized by the static problem
# constants; everything grid-dependent is derived from the mapper's
# assignment grid inside repro.sim.collectives.build_phases.
_MATMUL_DIMS = {"m": MATMUL_PROBLEM.m, "n": MATMUL_PROBLEM.n,
                "k": MATMUL_PROBLEM.k}
SHIFT_PATTERN = CollectivePattern("shift", dict(_MATMUL_DIMS))
PANEL_PATTERN = CollectivePattern("panel_broadcast", dict(_MATMUL_DIMS))
BCAST3D_PATTERN = CollectivePattern("bcast_reduce_3d", dict(_MATMUL_DIMS))
# The c replication axis (axis 2) carries the 2.5D broadcast/reduce;
# expert placement keeps it on the intra-node fabric (local_axes).
SHIFT25D_PATTERN = CollectivePattern(
    "replicated_shift", {**_MATMUL_DIMS, "local_axes": (2,)},
)
CIRCUIT_PATTERN = CollectivePattern(
    "gather_scatter", {"nodes_per_piece": CIRCUIT_NODES_PER_PIECE},
)
STENCIL_PATTERN = CollectivePattern(
    "halo", {"lengths": STENCIL_LENGTHS, "fields": 1},
)
PENNANT_PATTERN = CollectivePattern(
    "halo", {"lengths": PENNANT_ZONES, "fields": PENNANT_FIELDS},
)


# -------------------------------------------------------------- registration
register(Application(
    name="cannon",
    kind=MATMUL,
    pattern="shift",
    description="Cannon's systolic matmul on a (q, q) torus",
    default_procs=4,
    axis_names=("x", "y"),
    machine_shape=_matmul_machine,
    tile_grid=square_grid,
    mapple_template=_cannon_mapple,
    comm_volume=lambda p: cannon_volume(MATMUL_PROBLEM, square_grid(p)),
    step_flops=lambda p: MATMUL_PROBLEM.flops,
    tuning=_cannon_tuning,
    search_space=CANNON_SPACE,
    collective=SHIFT_PATTERN,
    lowlevel_fixture="benchmarks/lowlevel/cannon_raw.py",
    validate="matmul",
    meta={"problem": MATMUL_PROBLEM},
))

register(Application(
    name="summa",
    kind=MATMUL,
    pattern="broadcast",
    description="SUMMA panel-broadcast matmul on a (q, q) grid",
    default_procs=4,
    axis_names=("x", "y"),
    machine_shape=_matmul_machine,
    tile_grid=square_grid,
    mapple_template=_summa_mapple,
    comm_volume=lambda p: summa_volume(MATMUL_PROBLEM, square_grid(p)),
    step_flops=lambda p: MATMUL_PROBLEM.flops,
    tuning=_summa_tuning,
    search_space=SUMMA_SPACE,
    collective=PANEL_PATTERN,
    lowlevel_fixture="benchmarks/lowlevel/summa_raw.py",
    validate="matmul",
    meta={"problem": MATMUL_PROBLEM},
))

register(Application(
    name="pumma",
    kind=MATMUL,
    pattern="broadcast",
    description="PUMMA block-cyclic panel matmul on a (q, q) grid",
    default_procs=4,
    axis_names=("x", "y"),
    machine_shape=_matmul_machine,
    tile_grid=square_grid,
    mapple_template=_pumma_mapple,
    comm_volume=lambda p: summa_volume(MATMUL_PROBLEM, square_grid(p)),
    step_flops=lambda p: MATMUL_PROBLEM.flops,
    tuning=_pumma_tuning,
    search_space=PUMMA_SPACE,
    collective=PANEL_PATTERN,
    lowlevel_fixture="benchmarks/lowlevel/pumma_raw.py",
    validate="matmul",
    meta={"problem": MATMUL_PROBLEM},
))

register(Application(
    name="johnson",
    kind=MATMUL,
    pattern="allreduce3d",
    description="Johnson's 3D matmul on a (q, q, q) cube",
    default_procs=8,
    axis_names=("x", "y", "z"),
    machine_shape=lambda p: (p, 1),
    tile_grid=cube_grid,
    mapple_template=_johnson_mapple,
    comm_volume=lambda p: johnson_volume(MATMUL_PROBLEM, cube_grid(p)),
    step_flops=lambda p: MATMUL_PROBLEM.flops,
    tuning=_johnson_tuning,
    search_space=JOHNSON_SPACE,
    collective=BCAST3D_PATTERN,
    lowlevel_fixture="benchmarks/lowlevel/johnson_raw.py",
    validate="matmul",
    meta={"problem": MATMUL_PROBLEM},
))

register(Application(
    name="solomonik",
    kind=MATMUL,
    pattern="allreduce3d",
    description="Solomonik's 2.5D matmul on a (q, q, c) grid",
    default_procs=8,
    axis_names=("x", "y", "z"),
    machine_shape=_science_machine,
    tile_grid=replicated_grid,
    mapple_template=_solomonik_mapple,
    comm_volume=lambda p: solomonik_volume(MATMUL_PROBLEM, replicated_grid(p)),
    step_flops=lambda p: MATMUL_PROBLEM.flops,
    tuning=_solomonik_tuning,
    search_space=SOLOMONIK_SPACE,
    collective=SHIFT25D_PATTERN,
    lowlevel_fixture="benchmarks/lowlevel/solomonik_raw.py",
    validate="matmul",
    meta={"problem": MATMUL_PROBLEM},
))

register(Application(
    name="cosma",
    kind=MATMUL,
    pattern="allreduce3d",
    description="COSMA communication-optimal matmul (derived grid)",
    default_procs=8,
    axis_names=("x", "y", "z"),
    machine_shape=lambda p: (p, 1),
    tile_grid=lambda p: cosma_grid(MATMUL_PROBLEM, p),
    mapple_template=_cosma_mapple,
    comm_volume=lambda p: cosma_volume(MATMUL_PROBLEM, p),
    step_flops=lambda p: MATMUL_PROBLEM.flops,
    tuning=_cosma_tuning,
    search_space=COSMA_SPACE,
    collective=BCAST3D_PATTERN,
    lowlevel_fixture="benchmarks/lowlevel/cosma_raw.py",
    validate="matmul",
    meta={"problem": MATMUL_PROBLEM},
))

register(Application(
    name="circuit",
    kind=SCIENCE,
    pattern="graph",
    description="Legion circuit simulation (gather V / scatter Q per step)",
    default_procs=8,
    axis_names=("x",),
    machine_shape=_science_machine,
    tile_grid=lambda p: (p,),
    mapple_template=_circuit_mapple,
    comm_volume=_circuit_volume,
    step_flops=lambda p: 12.0 * CIRCUIT_WIRES_PER_PIECE * p,
    tuning=_circuit_tuning,
    search_space=CIRCUIT_SPACE,
    collective=CIRCUIT_PATTERN,
    lowlevel_fixture="benchmarks/lowlevel/circuit_raw.py",
    validate="circuit",
    meta={"nodes_per_piece": CIRCUIT_NODES_PER_PIECE},
))

register(Application(
    name="stencil",
    kind=SCIENCE,
    pattern="halo",
    description="2D 5-point Jacobi stencil, decompose-partitioned",
    default_procs=8,
    axis_names=("x", "y"),
    machine_shape=_science_machine,
    tile_grid=_stencil_grid(STENCIL_LENGTHS),
    mapple_template=_stencil_mapple,
    comm_volume=_halo_volume(STENCIL_LENGTHS, 1),
    step_flops=lambda p: 5.0 * STENCIL_LENGTHS[0] * STENCIL_LENGTHS[1],
    tuning=_halo_tuning(STENCIL_LENGTHS, 1),
    search_space=STENCIL_SPACE,
    collective=STENCIL_PATTERN,
    lowlevel_fixture="benchmarks/lowlevel/stencil_raw.py",
    validate="stencil",
    meta={"lengths": STENCIL_LENGTHS, "flops_per_point": 5.0,
          "halo_fields": 1},
))

register(Application(
    name="pennant",
    kind=SCIENCE,
    pattern="halo",
    description="PENNANT staggered-grid hydro proxy (3-field halo)",
    default_procs=8,
    axis_names=("x", "y"),
    machine_shape=_science_machine,
    tile_grid=_stencil_grid(PENNANT_ZONES),
    mapple_template=_pennant_mapple,
    comm_volume=_halo_volume(PENNANT_ZONES, PENNANT_FIELDS),
    step_flops=lambda p: 20.0 * PENNANT_ZONES[0] * PENNANT_ZONES[1],
    tuning=_halo_tuning(PENNANT_ZONES, PENNANT_FIELDS),
    search_space=PENNANT_SPACE,
    collective=PENNANT_PATTERN,
    lowlevel_fixture="benchmarks/lowlevel/pennant_raw.py",
    validate="pennant",
    meta={"lengths": PENNANT_ZONES, "flops_per_point": 20.0,
          "halo_fields": PENNANT_FIELDS},
))

PAPER_APPS = (
    "cannon", "summa", "pumma", "johnson", "solomonik", "cosma",
    "circuit", "stencil", "pennant",
)
