"""End-to-end driver for the nine paper applications.

Runs every selected app through the full pipeline —

    dsl.parse  ->  Mapper  ->  translate.to_spmd  ->  commvolume

— and prints the paper's Table-style LoC and communication-volume summary.

    PYTHONPATH=src python -m repro.apps.run --app summa --procs 64
    PYTHONPATH=src python -m repro.apps.run --all
    PYTHONPATH=src python -m repro.apps.run --all --execute   # + numerics
    PYTHONPATH=src python -m repro.apps.run --all --tune      # autotuner
    PYTHONPATH=src python -m repro.apps.run --all --simulate  # sim timeline

``--execute`` additionally runs each app's distributed kernel on fake CPU
devices and checks it against its single-device reference (the flag must
set XLA_FLAGS before JAX initializes, so use it from a fresh process).

``--tune`` runs the mapper autotuner (``repro.search``) over each selected
app's declared search space: candidates are scored with the app's cost
model, beam-pruned, evaluated through the vectorized batch path, and the
winning Mapple program + candidate leaderboard are printed. The legacy
hand-tuned volume pair is checked as a regression oracle. ``--tune
--time`` swaps the objective for the batched discrete-event simulator
(predicted seconds per step, every beam placement batch-priced) — fast
enough to search the registry at 1024+ processors; ``--backend jax``
prices the beams through the device-compiled JAX engine instead of the
NumPy reference (same winners, <=1e-6-relative identical seconds):

    PYTHONPATH=src python -m repro.apps.run --all --tune --time --procs 1024
    PYTHONPATH=src python -m repro.apps.run --all --tune --time --backend jax

``--pipeline``/``--no-pipeline`` (with ``--tune --time``) force the
streaming producer/consumer Phase 3 on or off (default: auto — stream
when the pricing engine is ``batched-jax``; identical numbers either
way). ``--cache-dir DIR`` persists placement prices under
``DIR/prices`` (and, under ``--backend jax``, XLA compiles under
``DIR/xla``) so re-tunes serve from disk:

    PYTHONPATH=src python -m repro.apps.run --all --tune --time \\
        --backend jax --pipeline --cache-dir ~/.cache/repro-tune

``--simulate`` runs each selected app's mapped step through the
discrete-event simulator (``repro.sim``): the plan's device permutation
becomes the exact tile->processor assignment, the app's declared
collective pattern expands into a wire schedule, and the engine prints
the resulting per-step timeline (compute/network segments, in-flight
depth, inter-node byte fraction).

``--json PATH`` (with ``--tune`` or ``--simulate``) additionally writes
the machine-readable results — for ``--tune`` the winner program/IR and
full leaderboard per app, so sim-vs-volume winner comparisons can be
scripted.
"""
from __future__ import annotations

import argparse
import os
import sys


def analyze(app, procs: int | None) -> dict:
    """One app through parse -> map -> translate -> commvolume."""
    from repro.core.translate import to_spmd

    n = app.procs(procs)
    note = ""
    try:
        app.tile_grid(n)
    except ValueError:
        note = f"(procs {n} unusable; using default {app.default_procs})"
        n = app.default_procs
    program = app.program(n)
    plan = to_spmd(program, app.name, app.tile_grid(n), app.axis_names)
    perm = plan.meta["device_permutation"]
    return {
        "app": app.name,
        "kind": app.kind,
        "procs": n,
        "machine": app.machine_shape(n),
        "grid": plan.meta["tile_grid"],
        "mapper": plan.meta["mapper"],
        "bijective": len(set(perm)) == len(perm),
        "mesh": plan.mesh is not None,
        "mapple_loc": program.loc(),
        "lowlevel_loc": app.lowlevel_loc(),
        "comm_volume": app.comm_volume(n),
        "step_flops": app.step_flops(n),
        "backpressure": plan.backpressure,
        "memory_kinds": plan.memory_kinds,
        "donate": plan.donate,
        "operands": tuple(sorted(plan.in_specs)),
        "mapper_ir": plan.meta["mapper_ir"],
        "note": note,
    }


def _finish(procs: int | None, json_rows: list, failures: list[str],
            json_path: str | None, report) -> int:
    """Shared mode epilogue: JSON envelope + failure report + exit code."""
    if json_path:
        import json
        from pathlib import Path

        Path(json_path).write_text(json.dumps(
            {"procs_requested": procs, "apps": json_rows}, indent=2) + "\n")
        report(f"wrote {json_path}")
    if failures:
        for f in failures:
            print(f"ERROR: {f}", file=sys.stderr)
        return 1
    return 0


def tune(selection, procs: int | None, report=print,
         json_path: str | None = None, time_domain: bool = False,
         backend: str = "numpy", pipeline: bool | None = None,
         cache_dir: str | None = None,
         warm_start_from: str | None = None) -> int:
    """Run the autotuner over the selected apps; nonzero on any failure.

    ``time_domain`` swaps each app's volume objective for the batched
    simulator (``repro.sim.cost.time_tuned_app``): candidates are scored
    in predicted seconds and every surviving beam variant's actual
    placement is batch-priced (the ``placed_s`` leaderboard column).
    ``backend`` picks the pricing engine for the time objective —
    ``"numpy"`` (the bit-exact reference) or ``"jax"`` (the
    device-compiled twin, <=1e-6-relative identical; see
    docs/simulator.md "Backends"). ``pipeline`` forces Phase 3's
    streaming producer/consumer shape on (True) or off (False; None
    auto-selects it for the JAX engine), and ``cache_dir`` points the
    persistent price cache + JAX compilation cache at a directory so
    repeat tunes skip pricing and XLA compiles across processes.
    ``warm_start_from`` points at a plan-cache directory (the tuning
    service's ``--cache-dir``, same on-disk format): cached winners near
    each requested scale seed the beam, and every winner tuned here is
    stored back for the service (and future batch runs) to reuse.
    """
    import time

    from repro.search.tuner import (
        feasible_procs,
        nearest_feasible_procs,
        report_lines,
        tune_app,
    )

    price_cache = None
    if cache_dir is not None:
        from repro.sim.price_cache import PriceCache

        price_cache = PriceCache(os.path.join(cache_dir, "prices"))
        report(f"price cache: {price_cache.root}")
    plan_cache = None
    if warm_start_from is not None:
        if not time_domain:
            raise ValueError("warm_start_from requires time_domain=True "
                             "(plan payloads carry placed seconds)")
        from repro.serving.plan_cache import PlanCache

        plan_cache = PlanCache(os.path.join(warm_start_from, "plans"))
        report(f"plan cache: {plan_cache.root}")
    if time_domain and backend == "jax":
        from repro.sim.jax_backend import enable_compilation_cache, \
            platform_info

        if cache_dir is not None:
            enable_compilation_cache(os.path.join(cache_dir, "xla"))
        info = platform_info()
        devices = ",".join(info["devices"]) or "-"
        report(f"jax backend: platform={info['platform']} "
               f"devices={info['device_count']}x[{devices}]")
        if info["pallas_interpret"]:
            report("warning: JAX resolved to CPU — the Pallas kernel "
                   "path would run in interpret mode (slow); pricing "
                   "uses the plain XLA jit here, and accelerator-grade "
                   "throughput needs a TPU/GPU runtime")

    failures = []
    tuned = 0
    json_rows = []
    t0 = time.perf_counter()
    for app in selection:
        if app.search_space is None:
            report(f"[{app.name}] no search space declared; skipping")
            continue
        if procs is not None:
            # Validate the requested scale up front against the cheap
            # volume space — a count that factors into no feasible tile
            # grid would otherwise surface as an opaque failure deep
            # inside the search.
            n = app.procs(procs)
            if not feasible_procs(app.search_space, n):
                near = nearest_feasible_procs(app.search_space, n)
                hint = (f" (nearest valid: {', '.join(map(str, near))})"
                        if near else "")
                failures.append(
                    f"{app.name}: --procs {n} does not factor into a "
                    f"feasible tile grid for this app{hint}"
                )
                report(f"[{app.name}] --procs {n} infeasible; "
                       f"skipping{hint}")
                continue
        if time_domain:
            if getattr(app, "collective", None) is None:
                report(f"[{app.name}] no collective pattern declared; "
                       f"skipping")
                continue
            from repro.sim.cost import time_tuned_app

            engine = "batched-jax" if backend == "jax" else "batched"
            app = time_tuned_app(app, engine=engine, cache=price_cache)
        warm_seeds = ()
        plan_coords = None
        if plan_cache is not None:
            from repro.serving.mapsvc import plan_key_for, warm_seeds_for

            n_res, key, tag = plan_key_for(app, procs, engine=engine)
            plan_coords = (key, tag)
            warm_seeds = warm_seeds_for(plan_cache, app.name, n_res,
                                        app.search_space)
        rep = tune_app(app, procs, pipeline=pipeline, warm_start=warm_seeds)
        if plan_coords is not None:
            from repro.serving.mapsvc import plan_from_report

            key, tag = plan_coords
            plan_cache.put(key, plan_from_report(
                rep, value_tag_=tag, provenance="cold").payload())
        tuned += 1
        for line in report_lines(rep):
            report(line)
        report("")
        if json_path:
            json_rows.append({
                **rep.summary(),
                "best_source": rep.best_source,
                "leaderboard": [s.row() for s in rep.leaderboard],
            })
        if not rep.verified:
            failures.append(f"{app.name}: rendered DSL diverged from the IR")
        if not rep.oracle_ok:
            if rep.best.volume > rep.oracle[1] * (1 + 1e-9):
                failures.append(
                    f"{app.name}: tuner failed to rediscover the hand-tuned "
                    f"volume (best {rep.best.volume:.6g} vs oracle "
                    f"{rep.oracle[1]:.6g})"
                )
            else:
                failures.append(
                    f"{app.name}: default candidate volume "
                    f"{rep.default.volume:.6g} disagrees with the oracle "
                    f"default {rep.oracle[0]:.6g}"
                )
    report(f"tuned {tuned} of {len(selection)} app(s) in "
           f"{time.perf_counter() - t0:.2f}s")
    return _finish(procs, json_rows, failures, json_path, report)


def simulate(selection, procs: int | None, report=print,
             json_path: str | None = None) -> int:
    """Run the discrete-event simulator over the selected apps."""
    from repro.sim.cost import simulate_app

    rows = []
    failures = []
    report(
        f"{'app':10s} {'procs':>5s} {'grid':>10s} {'pattern':>16s} "
        f"{'bp':>3s} {'compute_s':>10s} {'comm_s':>10s} {'step_s':>10s} "
        f"{'flat_s':>10s} {'xnode%':>7s} {'inflt':>5s}"
    )
    for app in selection:
        if getattr(app, "collective", None) is None:
            report(f"[{app.name}] no collective pattern declared; skipping")
            continue
        try:
            rep = simulate_app(app, procs)
        except ValueError as e:
            failures.append(f"{app.name}: {e}")
            continue
        rows.append(rep)
        grid = "x".join(str(g) for g in rep.grid)
        report(
            f"{rep.app:10s} {rep.procs:5d} {grid:>10s} {rep.pattern:>16s} "
            f"{rep.backpressure:3d} {rep.compute_s:10.3e} {rep.comm_s:10.3e} "
            f"{rep.step_time_s:10.3e} {rep.flat_step_time_s:10.3e} "
            f"{rep.inter_node_bytes_frac * 100:6.1f}% {rep.max_in_flight:5d}"
            + (f"  {rep.note}" if rep.note else "")
        )
    max_lines = 24
    for rep in rows:
        report(f"\n[{rep.app}] step timeline "
               f"({rep.n_phases} comm phases/step, first step shown):")
        segs = [s for s in rep.timeline.segments
                if s.step == 0 and s.label != "step_done"]
        for seg in segs[:max_lines]:
            report(f"  {seg.resource:8s} {seg.start * 1e3:9.4f}ms "
                   f"-> {seg.end * 1e3:9.4f}ms  {seg.label}")
        if len(segs) > max_lines:
            report(f"  ... {len(segs) - max_lines} more segments "
                   f"(--json for the full timeline)")
    return _finish(procs, [r.summary() for r in rows], failures,
                   json_path, report)


def report_table(rows, report=print) -> None:
    report(
        f"{'app':10s} {'procs':>5s} {'grid':>12s} {'mapple':>7s} "
        f"{'low-level':>10s} {'ratio':>6s} {'comm(elem)':>11s} "
        f"{'bijective':>9s}"
    )
    for r in rows:
        grid = "x".join(str(g) for g in r["grid"])
        if r["lowlevel_loc"]:
            raw_loc = f"{r['lowlevel_loc']:10d}"
            ratio = f"{r['lowlevel_loc'] / max(r['mapple_loc'], 1):6.1f}"
        else:                       # fixture unavailable (installed pkg)
            raw_loc, ratio = f"{'-':>10s}", f"{'-':>6s}"
        report(
            f"{r['app']:10s} {r['procs']:5d} {grid:>12s} "
            f"{r['mapple_loc']:7d} {raw_loc} {ratio} "
            f"{r['comm_volume']:11.3g} {str(r['bijective']):>9s} {r['note']}"
        )
    avg_m = sum(r["mapple_loc"] for r in rows) / len(rows)
    avg_r = sum(r["lowlevel_loc"] for r in rows) / len(rows)
    if avg_r:
        report(
            f"{'AVG':10s} {'':5s} {'':>12s} {avg_m:7.1f} {avg_r:10.1f} "
            f"{avg_r / avg_m:6.1f}"
        )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.apps.run", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("--app", default=None, help="one application by name")
    ap.add_argument("--all", action="store_true", help="all nine paper apps")
    ap.add_argument("--procs", type=int, default=None,
                    help="processor count (default: per-app paper scale)")
    ap.add_argument("--execute", action="store_true",
                    help="also run each kernel vs its reference on fake "
                         "CPU devices")
    ap.add_argument("--show-ir", action="store_true",
                    help="print each mapper's recorded transformation IR "
                         "(the inspectable ProcSpace op programs)")
    ap.add_argument("--tune", action="store_true",
                    help="run the mapper autotuner over each app's search "
                         "space and print the winning program + leaderboard")
    ap.add_argument("--time", action="store_true",
                    help="with --tune: search on batched-simulator seconds "
                         "instead of communication volume (placements are "
                         "batch-priced; works at 1024+ procs)")
    ap.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                    help="with --tune --time: pricing engine — 'numpy' "
                         "(bit-exact reference) or 'jax' (device-compiled, "
                         "<=1e-6-relative identical, fastest on arbitrary "
                         "placements; see docs/simulator.md)")
    ap.add_argument("--pipeline", dest="pipeline", action="store_true",
                    default=None,
                    help="with --tune --time: stream Phase 3 (host "
                         "candidate expansion overlaps device pricing; "
                         "default: auto — on for --backend jax)")
    ap.add_argument("--no-pipeline", dest="pipeline", action="store_false",
                    help="with --tune --time: force the strict-barrier "
                         "Phase 3 (expand everything, then one packed "
                         "pricing sweep)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="with --tune --time: persistent cache directory "
                         "— priced placements (DIR/prices) and, with "
                         "--backend jax, compiled XLA programs (DIR/xla) "
                         "are reused across processes")
    ap.add_argument("--warm-start-from", default=None, metavar="DIR",
                    help="with --tune --time: seed the beam from the plan "
                         "cache under DIR/plans (the tuning service's "
                         "--cache-dir; winners tuned here are stored back "
                         "— one shared on-disk format)")
    ap.add_argument("--simulate", action="store_true",
                    help="run each app's mapped step through the "
                         "discrete-event simulator and print the timeline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="with --tune/--simulate: write machine-readable "
                         "results (leaderboard + winner IR / timelines)")
    ap.add_argument("--list", action="store_true",
                    help="list registered applications")
    args = ap.parse_args(argv)

    if args.procs is not None and args.procs < 1:
        ap.error(f"--procs must be >= 1, got {args.procs}")
    if args.tune and (args.execute or args.show_ir or args.simulate):
        ap.error("--tune is a separate mode; run it without "
                 "--execute/--show-ir/--simulate")
    if args.time and not args.tune:
        ap.error("--time requires --tune")
    if args.backend != "numpy" and not args.time:
        ap.error("--backend requires --tune --time")
    if args.pipeline is not None and not args.time:
        ap.error("--pipeline/--no-pipeline requires --tune --time")
    if args.cache_dir is not None and not args.time:
        ap.error("--cache-dir requires --tune --time")
    if args.warm_start_from is not None and not args.time:
        ap.error("--warm-start-from requires --tune --time")
    if args.backend == "jax":
        from repro.sim.jax_backend import have_jax

        if not have_jax():
            ap.error("--backend jax needs jax installed in this "
                     "environment; use --backend numpy")
    if args.simulate and (args.execute or args.show_ir):
        ap.error("--simulate is a separate mode; run it without "
                 "--execute/--show-ir")
    if args.json and not (args.tune or args.simulate):
        ap.error("--json requires --tune or --simulate")

    if args.execute:
        # Must happen before JAX initializes its backends. Append to any
        # existing XLA_FLAGS rather than silently losing the device count.
        count = args.procs or 8
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={count}"
            ).strip()
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro import apps

    if args.list:
        for app in apps.iter_apps():
            print(f"{app.name:10s} [{app.kind}/{app.pattern}] "
                  f"{app.description}")
        return 0

    if args.app:
        try:
            selection = [apps.get(args.app)]
        except KeyError:
            ap.error(f"unknown app {args.app!r}; known: "
                     f"{', '.join(sorted(apps.names()))}")
    elif args.all:
        selection = list(apps.iter_apps())
    else:
        ap.error("pass --app NAME, --all, or --list")

    if args.tune:
        return tune(selection, args.procs, json_path=args.json,
                    time_domain=args.time, backend=args.backend,
                    pipeline=args.pipeline, cache_dir=args.cache_dir,
                    warm_start_from=args.warm_start_from)
    if args.simulate:
        return simulate(selection, args.procs, json_path=args.json)

    rows = [analyze(app, args.procs) for app in selection]
    report_table(rows)

    if args.show_ir:
        print("\nmapper transformation IR (root shape + recorded ops):")
        for r in rows:
            print(f"[{r['app']}] operands={','.join(r['operands'])}")
            for line in r["mapper_ir"].splitlines():
                print(f"  {line}")

    if not all(r["bijective"] for r in rows):
        print("ERROR: non-bijective mapping produced", file=sys.stderr)
        return 1

    if args.execute:
        from repro.apps import validate

        print(f"\n{'app':10s} {'procs':>5s} {'max_err':>10s} {'ok':>4s}")
        failed, ran = [], 0
        for app, row in zip(selection, rows):
            try:
                res = validate.run(app, row["procs"])
                ran += 1
                print(f"{app.name:10s} {row['procs']:5d} "
                      f"{res['max_err']:10.2e} {str(res['ok']):>4s}")
                if not res["ok"]:
                    failed.append(app.name)
            except validate.SkipValidation as e:
                print(f"{app.name:10s} {row['procs']:5d} {'—':>10s}  "
                      f"skip: {e}")
        if failed:
            print(f"ERROR: numeric check failed: {failed}", file=sys.stderr)
            return 1
        if not ran:
            print("ERROR: --execute validated nothing (no app had enough "
                  "devices)", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
