"""Small-scale numeric validation: DSL-mapped meshes drive real kernels.

Each hook builds the Mesh from the app's *parsed Mapple program* (via
``Application.spmd_plan``) — not from the library mapper functions — so a
passing check certifies the whole pipeline: DSL text -> Mapper ->
translated device permutation -> shard_map kernel -> matches the
single-device reference.

Requires enough (fake) devices: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` or via
``python -m repro.apps.run --execute``.
"""
from __future__ import annotations


class SkipValidation(RuntimeError):
    """Raised when the environment cannot execute this app (no devices)."""


def _grid_for(app, procs: int):
    import jax

    from repro.matmul.common import MatmulGrid

    plan = app.spmd_plan(procs, devices=jax.devices()[:procs])
    if plan.mesh is None:
        raise SkipValidation(
            f"needs {procs} devices, have {len(jax.devices())}"
        )
    return MatmulGrid(mesh=plan.mesh, axis_names=plan.axis_names), plan


def _matmul(app, procs: int) -> dict:
    import numpy as np

    from repro.matmul import ALGORITHMS
    from repro.matmul.common import make_inputs

    grid, _ = _grid_for(app, procs)
    size = 32 * max(grid.shape)
    a, b = make_inputs(size, size, size, seed=0)
    out = ALGORITHMS[app.name].matmul(a, b, grid)
    ref = np.asarray(a) @ np.asarray(b)
    err = float(np.max(np.abs(np.asarray(out) - ref)))
    return {"max_err": err, "ok": err < 1e-2 * size}


def _stencil(app, procs: int) -> dict:
    import jax.numpy as jnp
    import numpy as np

    from repro.science import stencil2d

    grid, _ = _grid_for(app, procs)
    gx, gy = grid.shape
    cfg = stencil2d.StencilConfig(nx=16 * gx, ny=16 * gy, steps=2)
    field = jnp.arange(cfg.nx * cfg.ny, dtype=jnp.float32).reshape(
        cfg.nx, cfg.ny
    ) / (cfg.nx * cfg.ny)
    out = stencil2d.run(field, grid, cfg)
    ref = stencil2d.reference(field, cfg)
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(ref))))
    return {"max_err": err, "ok": err < 1e-4}


def _pennant(app, procs: int) -> dict:
    import numpy as np

    from repro.science import pennant

    grid, _ = _grid_for(app, procs)
    gx, gy = grid.shape
    cfg = pennant.PennantConfig(nzx=16 * gx, nzy=16 * gy, steps=2)
    state = pennant.init_state(cfg, seed=0)
    outs = pennant.run(state, grid, cfg)
    refs = pennant.reference(state, cfg)
    err = max(
        float(np.max(np.abs(np.asarray(o) - np.asarray(r))))
        for o, r in zip(outs, refs)
    )
    return {"max_err": err, "ok": err < 1e-4}


def _circuit(app, procs: int) -> dict:
    import numpy as np

    from repro.science import circuit

    grid, _ = _grid_for(app, procs)
    cfg = circuit.CircuitConfig(pieces=procs, steps=2)
    state = circuit.generate(cfg, seed=0)
    out = circuit.run(state, grid, cfg)
    ref = circuit.reference(state, cfg)
    err = float(np.max(np.abs(np.asarray(out) - np.asarray(ref))))
    return {"max_err": err, "ok": err < 1e-3}


_HOOKS = {
    "matmul": _matmul,
    "stencil": _stencil,
    "pennant": _pennant,
    "circuit": _circuit,
}


def check_batched_equivalence(app, procs: int) -> None:
    """Certify the vectorized mapper path: the batched assignment grid must
    be bit-identical to the per-point interpreter before we trust the mesh
    built from it."""
    import numpy as np

    grid_shape = app.tile_grid(procs)
    mapper = app.mapper(procs)
    batched = mapper.assignment_grid(grid_shape, use_cache=False)
    scalar = mapper.assignment_grid(
        grid_shape, vectorized=False, use_cache=False
    )
    if not np.array_equal(batched, scalar):
        raise AssertionError(
            f"{app.name}: batched mapper evaluation diverges from the "
            f"per-point path on grid {grid_shape}"
        )


def run(app, procs: int | None = None) -> dict:
    """Execute one app's kernel under its DSL-derived mesh vs reference."""
    if app.validate is None:
        raise SkipValidation("no validation hook registered")
    n = app.procs(procs)
    check_batched_equivalence(app, n)
    return _HOOKS[app.validate](app, n)
