"""Parameter schema system: one source of truth for shapes, init and sharding.

Every model defines a *schema* — a nested dict whose leaves are
:class:`ParamDef` (shape + logical axes + initializer). From the schema we
derive, without drift:

  * ``init_params``   -> pytree of arrays
  * ``param_specs``   -> same-structure pytree of jax PartitionSpec, via a
                         :class:`ShardingRules` policy (the Mapple-planned
                         mapping of logical axes onto mesh axes).

Logical axes vocabulary: "embed", "q_fused", "kv_fused", "o_fused", "ffn",
"vocab", "experts", "layers", "heads", "state", "conv", None (unsharded).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


Initializer = Callable[[jax.Array, tuple[int, ...], Any], jax.Array]


def normal_init(stddev: float = 0.02) -> Initializer:
    def fn(key, shape, dtype):
        return (stddev * jax.random.normal(key, shape)).astype(dtype)

    return fn


def scaled_init(fan_in_axis: int = 0) -> Initializer:
    def fn(key, shape, dtype):
        fan_in = shape[fan_in_axis]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, shape)).astype(dtype)

    return fn


def zeros_init() -> Initializer:
    def fn(key, shape, dtype):
        return jnp.zeros(shape, dtype)

    return fn


def ones_init() -> Initializer:
    def fn(key, shape, dtype):
        return jnp.ones(shape, dtype)

    return fn


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Leaf of a model schema."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: Initializer = dataclasses.field(default_factory=scaled_init)
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


Schema = dict  # nested dict[str, Schema | ParamDef]


def _walk(schema: Schema, fn: Callable[[ParamDef, tuple[str, ...]], Any],
          path: tuple[str, ...] = ()) -> dict:
    out = {}
    for name, node in schema.items():
        if isinstance(node, ParamDef):
            out[name] = fn(node, path + (name,))
        elif isinstance(node, dict):
            out[name] = _walk(node, fn, path + (name,))
        else:
            raise TypeError(f"bad schema node at {path + (name,)}: {node!r}")
    return out


def init_params(key: jax.Array, schema: Schema, dtype=None) -> dict:
    """Materialize the schema into arrays (deterministic per path)."""
    leaves: list[tuple[ParamDef, tuple[str, ...]]] = []
    _walk(schema, lambda d, p: leaves.append((d, p)) or 0)
    keys = jax.random.split(key, max(len(leaves), 1))
    key_by_path = {p: k for (d, p), k in zip(leaves, keys)}

    def make(d: ParamDef, path):
        dt = dtype if dtype is not None else d.dtype
        return d.init(key_by_path[path], d.shape, dt)

    return _walk(schema, make)


def abstract_params(schema: Schema, dtype=None) -> dict:
    """ShapeDtypeStruct tree (for .lower() without allocation)."""

    def make(d: ParamDef, path):
        dt = dtype if dtype is not None else d.dtype
        return jax.ShapeDtypeStruct(d.shape, dt)

    return _walk(schema, make)


def param_count(schema: Schema) -> int:
    total = 0

    def add(d: ParamDef, path):
        nonlocal total
        n = 1
        for s in d.shape:
            n *= s
        total += n
        return 0

    _walk(schema, add)
    return total


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Policy mapping logical parameter axes to mesh axes.

    ``mode``:
      * "tp"    — Megatron tensor parallelism: fused head / ffn / vocab /
                  expert dims shard over ``model_axis``; requires
                  divisibility (checked per-leaf, falls back to replicate).
      * "fsdp"  — ZeRO-3 style: the first shardable dim of every weight
                  shards over ``model_axis``; XLA all-gathers per layer.
    Optionally ``fsdp_data``: additionally shard the first remaining dim
    over the data axis (2D "HSDP" sharding, a hillclimb lever).
    """

    mode: str = "tp"
    model_axis: str = "model"
    data_axis: str | tuple[str, ...] = "data"
    model_size: int = 16
    tp_axes: tuple[str, ...] = (
        "q_fused", "kv_fused", "o_fused", "ffn", "vocab", "experts", "heads",
    )
    fsdp_data: bool = False
    data_size: int = 16

    def spec_for(self, d: ParamDef) -> P:
        if self.mode == "tp":
            entries: list[Any] = []
            used_model = False
            for size, ax in zip(d.shape, d.axes):
                if (
                    not used_model
                    and ax in self.tp_axes
                    and size % self.model_size == 0
                ):
                    entries.append(self.model_axis)
                    used_model = True
                else:
                    entries.append(None)
            if not used_model:
                # Fall back to sharding 'embed' dims (row-parallel) if legal.
                for i, (size, ax) in enumerate(zip(d.shape, d.axes)):
                    if ax == "embed" and size % self.model_size == 0:
                        entries[i] = self.model_axis
                        break
            return P(*entries)
        if self.mode == "fsdp":
            entries = [None] * len(d.shape)
            placed_model = False
            for i, (size, ax) in enumerate(zip(d.shape, d.axes)):
                if ax == "layers":
                    continue  # never shard the scan axis
                if not placed_model and size % self.model_size == 0:
                    entries[i] = self.model_axis
                    placed_model = True
                elif (
                    self.fsdp_data
                    and placed_model
                    and entries[i] is None
                    and size % self.data_size == 0
                ):
                    entries[i] = self.data_axis
                    break
            return P(*entries)
        raise ValueError(f"unknown sharding mode {self.mode!r}")


def param_specs(schema: Schema, rules: ShardingRules) -> dict:
    return _walk(schema, lambda d, p: rules.spec_for(d))


def opt_spec_for(d: ParamDef, rules: ShardingRules) -> P:
    """ZeRO-1: optimizer moments take the param sharding PLUS the data axis
    on the first still-unsharded dim that divides it (elementwise states
    admit any even sharding; the re-gather rides the param update)."""
    base = list(rules.spec_for(d))
    while len(base) < len(d.shape):
        base.append(None)
    for i, (size, ax) in enumerate(zip(d.shape, d.axes)):
        if base[i] is None and ax != "layers" and size % rules.data_size == 0:
            base[i] = rules.data_axis
            break
    return P(*base)


def opt_specs(schema: Schema, rules: ShardingRules) -> dict:
    return _walk(schema, lambda d, p: opt_spec_for(d, rules))


def tree_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )
