"""Activation sharding constraints that degrade gracefully without a mesh.

Model code annotates activations with *logical* axes; under a mesh context
(the dry-run / production path) these become with_sharding_constraint calls,
on bare CPU tests they are no-ops. Batch axes may span ("pod", "data").
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"

# Activation policy: when set to "model", residual streams between layers
# are additionally sharded over the model axis on the SEQUENCE dim
# (Megatron-style sequence parallelism for remat storage). The launcher
# enables it for training shapes; tests/decode leave it off.
_ACT_SEQ_AXIS: str | None = None

# MoE dispatch groups: tokens are routed within G independent groups (one
# per data shard in production) so the dispatch buffer shards as
# (G='data', E='model', C, D) and the dispatch lowers to an EP all-to-all
# instead of a data-axis all-reduce of the full buffer. G=1 off-mesh.
_MOE_GROUPS: int = 1


def set_moe_groups(g: int) -> None:
    global _MOE_GROUPS
    _MOE_GROUPS = max(1, int(g))


def moe_groups() -> int:
    return _MOE_GROUPS


# Layer barrier: under FSDP, XLA hoists the loop-invariant parameter
# all-gathers out of the layer scan, materializing EVERY layer's full
# weights at once (tens of GiB). An optimization_barrier on the per-layer
# parameter slice pins the gather inside the loop body: one layer's
# weights live at a time (trading gather/compute overlap for memory).
_LAYER_BARRIER: bool = False


def set_layer_barrier(on: bool) -> None:
    global _LAYER_BARRIER
    _LAYER_BARRIER = bool(on)


def layer_barrier(tree):
    import jax.numpy as jnp

    try:
        from repro.launch.knobs import active

        bf16_gather = active().bf16_gather
    except Exception:
        bf16_gather = False
    if bf16_gather:
        # Cast BEFORE the (implicit) FSDP all-gather: the gather then moves
        # bf16 instead of fp32 — half the collective bytes per layer.
        tree = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 and p.ndim >= 2 else p,
            tree,
        )
    if not _LAYER_BARRIER:
        return tree
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    leaves = list(_diff_barrier(tuple(leaves)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


# optimization_barrier only gained a differentiation rule in newer JAX;
# a custom_vjp (barrier the cotangents symmetrically, matching upstream
# semantics) keeps the layer barrier usable under value_and_grad here.
@jax.custom_vjp
def _diff_barrier(leaves: tuple):
    return jax.lax.optimization_barrier(leaves)


def _diff_barrier_fwd(leaves: tuple):
    return _diff_barrier(leaves), None


def _diff_barrier_bwd(_, cts):
    return (jax.lax.optimization_barrier(cts),)


_diff_barrier.defvjp(_diff_barrier_fwd, _diff_barrier_bwd)


def set_sequence_sharding(axis: str | None) -> None:
    global _ACT_SEQ_AXIS
    _ACT_SEQ_AXIS = axis


def seq_axis() -> str | None:
    return _ACT_SEQ_AXIS


def residual(x: jax.Array) -> jax.Array:
    """Constraint for the (B, S, D) residual stream between layers."""
    return constrain(x, BATCH_AXES, _ACT_SEQ_AXIS, None)


def _current_mesh():
    """The mesh in scope: set_mesh context, else the legacy `with mesh:`."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and tuple(mesh.axis_names):
            return mesh
    except Exception:
        pass
    try:
        from jax._src import mesh as mesh_lib

        phys = mesh_lib.thread_resources.env.physical_mesh
        if phys is not None and not phys.empty:
            return phys
    except Exception:
        pass
    return None


def _mesh_axis_names() -> tuple[str, ...]:
    mesh = _current_mesh()
    return tuple(mesh.axis_names) if mesh is not None else ()


def _filter(entry, names: tuple[str, ...]):
    if entry is None:
        return None
    if isinstance(entry, (tuple, list)):
        kept = tuple(a for a in entry if a in names)
        return kept if kept else None
    return entry if entry in names else None


def constrain(x: jax.Array, *entries) -> jax.Array:
    """with_sharding_constraint(x, P(*entries)) filtered to live mesh axes.

    Entries may be axis names, tuples of names, or None. Sizes that do not
    divide evenly fall back to unsharded for that dim.
    """
    mesh = _current_mesh()
    if mesh is None:
        return x
    names = tuple(mesh.axis_names)
    try:
        sizes = {a: int(mesh.shape[a]) for a in names}
    except Exception:
        sizes = {}
    spec_entries = []
    for dim, e in zip(range(x.ndim), list(entries) + [None] * (x.ndim - len(entries))):
        f = _filter(e, names)
        if f is not None and sizes:
            total = 1
            for a in (f if isinstance(f, tuple) else (f,)):
                total *= sizes.get(a, 1)
            if total == 0 or x.shape[dim] % max(total, 1) != 0:
                f = None
        spec_entries.append(f)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec_entries))
    except Exception:
        return x


def batch_sharded(x: jax.Array) -> jax.Array:
    """Shard the leading batch dim over (pod, data)."""
    return constrain(x, BATCH_AXES)


def logits_sharded(x: jax.Array) -> jax.Array:
    """Shard the vocab (last) dim of logits over the model axis: the
    (B, S, V) cross-entropy intermediate is the largest single activation
    at 32k-vocab scales, so it must never be replicated."""
    entries = [BATCH_AXES] + [None] * (x.ndim - 2) + [MODEL_AXIS]
    return constrain(x, *entries)
