"""Core NN layers: norms, rotary embeddings, attention (naive / chunked /
decode), MLPs. Pure functions over schema-built param dicts.

Attention memory discipline: seq >= CHUNK_THRESHOLD routes through a
two-level online-softmax (flash-style) jnp implementation so the 32k
prefill never materializes an S^2 score tensor. The Pallas TPU kernel in
repro.kernels.flash_attention mirrors this math; `use_pallas=True` swaps
it in on TPU backends.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamDef, normal_init, ones_init, scaled_init, zeros_init
from repro.core.jaxcompat import shard_map

# Above this sequence length attention always takes the online-softmax
# chunked path: a naive (B,H,S,S) fp32 score tensor at S=4096 with
# unsharded heads (FSDP archs) is 28 GiB per device — never materialize it.
CHUNK_THRESHOLD = 2048
Q_CHUNK = 1024
KV_CHUNK = 1024
NEG_INF = -1e30


# ------------------------------------------------------------------- norms
def rmsnorm_schema(dim: int) -> dict:
    return {"scale": ParamDef((dim,), ("embed",), ones_init())}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


# ------------------------------------------------------------------ rotary
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                       # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- attention
def _mask_bias(q_pos, k_pos, window: int):
    """Causal (+ sliding window) additive bias; shapes broadcast."""
    causal = q_pos[..., :, None] >= k_pos[..., None, :]
    ok = causal
    if window > 0:
        ok = ok & (q_pos[..., :, None] - k_pos[..., None, :] < window)
    return jnp.where(ok, 0.0, NEG_INF)


def _repeat_kv(k: jax.Array, groups: int) -> jax.Array:
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def naive_attention(q, k, v, *, window: int = 0, scale: float | None = None):
    """q: (B,S,H,hd), k/v: (B,S,Kv,hd) -> (B,S,H,hd). For short seqs."""
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    k = _repeat_kv(k, H // Kv)
    v = _repeat_kv(v, H // Kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    pos = jnp.arange(S)
    scores = scores + _mask_bias(pos, pos, window)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(q, k, v, *, window: int = 0, scale: float | None = None,
                      q_chunk: int = Q_CHUNK, kv_chunk: int = KV_CHUNK,
                      q_offset=0):
    """Two-level online-softmax attention (flash-style, pure jnp).

    Never materializes more than (B, H, q_chunk, kv_chunk) of scores.
    ``q_offset``: global position of q[:, 0] (sequence-parallel shards).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]                 # may exceed Sq (SP: local q, full k/v)
    Kv = k.shape[2]
    hd_v = v.shape[-1]              # may differ from hd (MLA: 192 qk / 128 v)
    scale = scale if scale is not None else hd ** -0.5
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    assert Sq % q_chunk == 0 and Sk % kv_chunk == 0, (Sq, Sk, q_chunk, kv_chunk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    groups = H // Kv

    qr = q.reshape(B, nq, q_chunk, H, hd).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,hd)
    kr = k.reshape(B, nk, kv_chunk, Kv, hd).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, kv_chunk, Kv, hd_v).transpose(1, 0, 3, 2, 4)

    def q_step(qi, q_blk):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            ki, k_blk, v_blk = inputs
            acc, m, denom = carry
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            k_rep = jnp.repeat(k_blk, groups, axis=1)   # (B,H,kc,hd)
            v_rep = jnp.repeat(v_blk, groups, axis=1)
            s = (
                jnp.einsum("bhqd,bhkd->bhqk", q_blk, k_rep).astype(jnp.float32)
                * scale
            )
            s = s + _mask_bias(q_pos, k_pos, window)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            denom = denom * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(q.dtype), v_rep
            ).astype(jnp.float32)
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, H, q_chunk, hd_v), jnp.float32)
        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        d0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        (acc, m, denom), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0), (jnp.arange(nk), kr, vr)
        )
        out = acc / jnp.maximum(denom[..., None], 1e-30)
        return out.astype(q.dtype)                       # (B,H,qc,hd)

    outs = jax.lax.map(lambda args: q_step(*args), (jnp.arange(nq), qr))
    # (nq,B,H,qc,hd_v) -> (B, Sq, H, hd_v)
    return outs.transpose(1, 0, 3, 2, 4).reshape(B, Sq, H, hd_v)


def sp_attention(q, k, v, *, window: int = 0, scale: float | None = None):
    """Sequence-parallel attention: explicit shard_map over the mesh.

    q/k/v arrive seq-sharded over 'model'. Each device all-gathers K/V
    (bf16 — 2 gathers per layer) and runs the online-softmax kernel on its
    LOCAL q shard with the correct global position offset. Without this,
    the SPMD partitioner reshards the (B, H, qc, kc) fp32 score blocks of
    the chunk loop — tens of GiB of gathers per layer.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.models import sharding as shd

    mesh = shd._current_mesh()
    ep = int(mesh.shape["model"])
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    S_l = q.shape[1] // ep

    def body(q_l, k_l, v_l):
        k_f = jax.lax.all_gather(k_l, "model", axis=1, tiled=True)
        v_f = jax.lax.all_gather(v_l, "model", axis=1, tiled=True)
        q_offset = jax.lax.axis_index("model") * S_l
        return chunked_attention(
            q_l, k_f, v_f, window=window, scale=scale, q_offset=q_offset,
            q_chunk=min(Q_CHUNK, S_l),
        )

    spec = P(batch_axes if batch_axes else None, "model", None, None)
    return shard_map(
        body, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec,
        check_vma=False,
    )(q, k, v)


def _sp_attention_applicable(q, k) -> bool:
    from repro.models import sharding as shd

    try:
        from repro.launch.knobs import active

        if not active().sp_attention:
            return False
    except Exception:
        pass
    if shd.seq_axis() != "model":
        return False
    mesh = shd._current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return False
    ep = int(mesh.shape["model"])
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in batch_axes:
        dp *= int(mesh.shape[a])
    return (
        q.shape[1] % ep == 0
        and q.shape[0] % max(dp, 1) == 0
        and (q.shape[1] // ep) >= 128
    )


def attention(q, k, v, *, window: int = 0, scale: float | None = None,
              use_pallas: bool = False):
    if use_pallas:
        from repro.kernels import ops as kops

        return kops.flash_attention(q, k, v, window=window, scale=scale)
    if _sp_attention_applicable(q, k):
        return sp_attention(q, k, v, window=window, scale=scale)
    if q.shape[1] > CHUNK_THRESHOLD:
        return chunked_attention(q, k, v, window=window, scale=scale)
    return naive_attention(q, k, v, window=window, scale=scale)


def sp_decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                        scale: float | None = None):
    """Flash-decoding over a sequence-sharded KV cache (shard_map).

    When kv-heads don't divide the model axis the cache shards on its
    SEQUENCE dim; gathering K/V per layer costs GiBs per step. Instead,
    each device computes attention against its local cache slice and the
    shards merge with the online-softmax combine (pmax/psum of
    exp-weighted partials) — collective traffic is O(B*H*hd), not O(C).
    """
    from jax.sharding import PartitionSpec as P

    from repro.models import sharding as shd

    mesh = shd._current_mesh()
    ep = int(mesh.shape["model"])
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    B, C, Kv, hd = k_cache.shape
    H = q.shape[2]
    sc = scale if scale is not None else hd ** -0.5
    C_l = C // ep

    def body(q_l, k_l, v_l):
        shard = jax.lax.axis_index("model")
        k = _repeat_kv(k_l, H // Kv)
        v = _repeat_kv(v_l, H // Kv)
        s = jnp.einsum("bqhd,bkhd->bhqk", q_l, k).astype(jnp.float32) * sc
        slot = shard * C_l + jnp.arange(C_l)
        if window > 0:
            valid = slot[None, None, None, :] <= jnp.minimum(pos, C - 1)
            valid = jnp.where(pos >= C, jnp.ones_like(valid), valid)
        else:
            valid = slot[None, None, None, :] <= pos
        s = jnp.where(valid, s, NEG_INF)
        m_l = s.max(axis=-1)                              # (B,H,1)
        p = jnp.exp(s - m_l[..., None])
        d_l = p.sum(axis=-1)
        acc_l = jnp.einsum("bhqk,bkhd->bhqd", p.astype(q_l.dtype), v
                           ).astype(jnp.float32)
        # online-softmax merge across shards
        m = jax.lax.pmax(m_l, "model")
        w = jnp.exp(m_l - m)
        d = jax.lax.psum(d_l * w, "model")
        acc = jax.lax.psum(acc_l * w[..., None], "model")
        out = acc / jnp.maximum(d[..., None], 1e-30)
        # (B,H,1,hd) -> (B,1,H,hd)
        return out.transpose(0, 2, 1, 3).astype(q_l.dtype)

    bspec = batch_axes if batch_axes else None
    q_spec = P(bspec, None, None, None)
    kv_spec = P(bspec, "model", None, None)
    return shard_map(
        body, mesh=mesh, in_specs=(q_spec, kv_spec, kv_spec),
        out_specs=q_spec, check_vma=False,
    )(q, k_cache, v_cache)


def _sp_decode_applicable(q, k_cache) -> bool:
    from repro.models import sharding as shd

    try:
        from repro.launch.knobs import active

        if not active().sp_attention:
            return False
    except Exception:
        pass
    mesh = shd._current_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return False
    ep = int(mesh.shape["model"])
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in batch_axes:
        dp *= int(mesh.shape[a])
    B, C, Kv, _ = k_cache.shape
    # policy shards the cache seq dim only when kv heads don't divide
    return Kv % ep != 0 and C % ep == 0 and B % max(dp, 1) == 0


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     scale: float | None = None):
    """One-token attention against a cache.

    q: (B, 1, H, hd); k/v_cache: (B, C, Kv, hd); pos: scalar current index
    (number of tokens already in cache, 0-based insert position).
    For sliding windows the cache is a ring buffer of capacity C=window and
    slot validity is derived from pos.
    """
    if _sp_decode_applicable(q, k_cache):
        return sp_decode_attention(q, k_cache, v_cache, pos, window=window,
                                   scale=scale)
    B, C, Kv, hd = k_cache.shape
    H = q.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    k = _repeat_kv(k_cache, H // Kv)
    v = _repeat_kv(v_cache, H // Kv)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    slot = jnp.arange(C)
    if window > 0:
        # Ring buffer: slots hold tokens (pos - C, pos]; valid if < pos+1.
        valid = slot[None, None, None, :] <= jnp.minimum(pos, C - 1)
        # After wrap, every slot is valid.
        valid = jnp.where(pos >= C, jnp.ones_like(valid), valid)
    else:
        valid = slot[None, None, None, :] <= pos
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


# -------------------------------------------------------------------- MLPs
def swiglu_schema(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("embed", "ffn")),
        "w_up": ParamDef((d_model, d_ff), ("embed", "ffn")),
        "w_down": ParamDef((d_ff, d_model), ("ffn", "embed")),
    }


def swiglu(params, x):
    dtype = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dtype))


# --------------------------------------------------------------- embedding
def embedding_schema(vocab: int, d_model: int) -> dict:
    return {"table": ParamDef((vocab, d_model), ("vocab", "embed"),
                              normal_init(0.02))}


def embed(params, ids, dtype):
    return params["table"].astype(dtype)[ids]


def unembed(params, x, table=None):
    t = (table if table is not None else params["table"]).astype(x.dtype)
    return jnp.einsum("bsd,vd->bsv", x, t)
