"""Model configuration — one dataclass covering all ten assigned families."""
from __future__ import annotations

import dataclasses
import math
from typing import Any


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- attention options
    qkv_bias: bool = False
    sliding_window: int = 0        # 0 = full attention
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE options
    n_experts: int = 0             # routed experts (0 = dense FFN)
    n_shared_experts: int = 0
    topk: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim
    shared_d_ff: int = 0           # shared-expert hidden dim
    first_dense_layers: int = 0    # leading dense layers (deepseek style)
    # --- MLA options (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM options (rwkv / mamba side)
    ssm_state: int = 0
    ssm_heads: int = 0
    d_inner: int = 0
    conv_width: int = 4
    # --- modality frontend stubs
    stub_frontend: bool = False    # inputs are precomputed embeddings
    num_codebooks: int = 0         # musicgen: parallel output heads
    # --- numerics
    dtype: Any = "bfloat16"
    norm_eps: float = 1e-5
    vocab_round: int = 256

    # ------------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, self.vocab_round)

    @property
    def padded_experts(self) -> int:
        """Experts padded to shard evenly over a 16-way model axis."""
        if self.n_experts == 0:
            return 0
        return pad_to(self.n_experts, 16)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (bounded decode state)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (validated against schemas in tests)."""
        from repro.models import registry

        return registry.build(self).n_params

    # ---------------------------------------------------------- reductions
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small_heads = max(2, min(self.n_heads, 4))
        ratio = max(1, self.n_heads // max(self.n_kv_heads, 1))
        small_kv = max(1, small_heads // min(ratio, small_heads))
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=small_heads,
            n_kv_heads=small_kv,
            head_dim=64 // small_heads if self.head_dim == 0 else 16,
            d_ff=128,
            vocab_size=512,
            vocab_round=64,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            topk=min(self.topk, 2) if self.topk else 0,
            moe_d_ff=32 if self.moe_d_ff else 0,
            shared_d_ff=32 if self.shared_d_ff else 0,
            first_dense_layers=min(self.first_dense_layers, 1),
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_dim=16 if self.qk_nope_dim else 0,
            qk_rope_dim=8 if self.qk_rope_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_heads=0,
            d_inner=128 if self.d_inner else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            num_codebooks=self.num_codebooks,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
