"""Mixture-of-Experts FFN: top-k routing, capacity dispatch, EP sharding.

Sort-free capacity-based dispatch (GShard/Switch style, cumsum positions):
avoids the (tokens, experts, capacity) one-hot blowup by scattering through
flat indices — O(N*K*E) routing metadata, O(E*C*D) expert activations.
Routed experts are sharded over the 'model' mesh axis (expert parallelism);
XLA lowers the dispatch/combine scatters into all-to-alls. Expert counts
that do not divide the axis (qwen2-moe: 60) are padded with never-routed
dummy experts (masked at the router).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import ParamDef, normal_init
from repro.models.sharding import constrain
from repro.models import layers
from repro.core.jaxcompat import shard_map

CAPACITY_FACTOR = 1.25

# Below this per-group token count the dense dispatch path uses full
# capacity (C = Ng): routing is then exact (no overflow dropping), at the
# cost of a (G, E, Ng, D) buffer — negligible up to this bound. Above it
# the fixed-capacity production behavior applies, so outputs can differ
# across this boundary by design (dropped overflow tokens).
EXACT_DISPATCH_MAX_TOKENS = 512


def moe_schema(cfg: ModelConfig) -> dict:
    e = cfg.padded_experts
    d, f = cfg.d_model, cfg.moe_d_ff
    schema = {
        "router": ParamDef((d, e), ("embed", "experts"), normal_init(0.02)),
        "w_gate": ParamDef((e, d, f), ("experts", "embed", "ffn")),
        "w_up": ParamDef((e, d, f), ("experts", "embed", "ffn")),
        "w_down": ParamDef((e, f, d), ("experts", "ffn", "embed")),
    }
    if cfg.n_shared_experts:
        shared_ff = cfg.shared_d_ff or cfg.moe_d_ff * cfg.n_shared_experts
        schema["shared"] = layers.swiglu_schema(d, shared_ff)
    return schema


def capacity(n_tokens: int, n_experts: int, topk: int) -> int:
    c = int(n_tokens * topk * CAPACITY_FACTOR / n_experts)
    return max(4, (c + 3) // 4 * 4)


def moe_apply(params, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) -> (out, aux_loss). Dispatches to the explicit
    shard_map EP path (train/prefill under a mesh with sequence sharding)
    or the dense pjit path (no mesh / decode)."""
    from repro.models import sharding as shd

    mesh = shd._current_mesh()
    if mesh is not None and "model" in getattr(mesh, "axis_names", ()):
        ep = int(mesh.shape["model"])
        B, S, D = x.shape
        batch_axes = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
        dp = 1
        for a in batch_axes:
            dp *= int(mesh.shape[a])
        if (
            shd.seq_axis() == "model"
            and cfg.padded_experts % ep == 0
            and B % max(dp, 1) == 0
            and S % ep == 0
        ):
            return _moe_shard_map(params, x, cfg, mesh, batch_axes, ep, dp)
    return _moe_dense(params, x, cfg)


def _moe_shard_map(params, x, cfg: ModelConfig, mesh, batch_axes, ep, dp):
    """Expert parallelism with explicit all_to_all collectives (the
    DeepSpeed/GShard schedule, TPU-native): each device routes its own
    (batch x seq) token shard into per-expert send buckets with a local
    capacity, all_to_all's the buckets to the expert owners along the
    model axis, runs its local experts, and reverses the exchange."""
    import functools

    from jax.sharding import PartitionSpec as P

    E = cfg.padded_experts
    K = cfg.topk
    E_l = E // ep

    def body(x_l, router, wg, wu, wd):
        Bl, Sl, D = x_l.shape
        Nl = Bl * Sl
        dt = x_l.dtype
        xf = x_l.reshape(Nl, D)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        if E != cfg.n_experts:
            logits = jnp.where(jnp.arange(E) >= cfg.n_experts, -1e30, logits)
        probs = jax.nn.softmax(logits, axis=-1)                # (Nl, E)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9
        )
        # ---- aux loss from psum-averaged stats
        all_axes = tuple(batch_axes) + ("model",)
        n_dev = dp * ep
        me = jax.lax.psum(probs.mean(axis=0), all_axes) / n_dev
        counts = jnp.zeros((E,)).at[expert_idx.reshape(-1)].add(1.0)
        ce = jax.lax.psum(counts, all_axes) / (Nl * K * n_dev)
        aux = cfg.n_experts * jnp.sum(me * ce)
        aux = aux + jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * 1e-4

        # ---- local dispatch into per-expert send buckets
        C = capacity(Nl, cfg.n_experts, K)
        e_flat = expert_idx.reshape(-1)                        # (Nl*K,)
        tok_flat = jnp.repeat(jnp.arange(Nl), K)
        order = jnp.argsort(e_flat, stable=True)
        sorted_e = e_flat[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
        rank_sorted = jnp.arange(e_flat.shape[0]) - starts[sorted_e]
        pos_in_e = jnp.zeros_like(e_flat).at[order].set(rank_sorted)
        keep = pos_in_e < C
        w = (gate_vals.reshape(-1) * keep).astype(dt)
        safe_pos = jnp.where(keep, pos_in_e, C - 1)
        send = jnp.zeros((E, C, D), dt)
        send = send.at[e_flat, safe_pos].add(
            jnp.where(keep[:, None], xf[tok_flat], 0)
        )

        # ---- EP all_to_all: (E, C, D) -> (E_l, ep*C, D)
        recv = jax.lax.all_to_all(
            send.reshape(ep, E_l, C, D), "model", split_axis=0,
            concat_axis=0, tiled=False,
        )
        # recv: (ep, E_l, C, D) — senders stacked on axis 0.
        recv = recv.transpose(1, 0, 2, 3).reshape(E_l, ep * C, D)

        # ---- local expert FFN
        g = jnp.einsum("ecd,edf->ecf", recv, wg.astype(dt))
        u = jnp.einsum("ecd,edf->ecf", recv, wu.astype(dt))
        h = jax.nn.silu(g) * u
        y = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))

        # ---- reverse exchange: (E_l, ep*C, D) -> (E, C, D)
        y = y.reshape(E_l, ep, C, D).transpose(1, 0, 2, 3)
        y_back = jax.lax.all_to_all(
            y, "model", split_axis=0, concat_axis=0, tiled=False,
        )                                                      # (ep,E_l,C,D)
        y_back = y_back.reshape(E, C, D)

        # ---- combine
        gathered = y_back[e_flat, safe_pos] * w[:, None]
        out = jnp.zeros((Nl, D), dt).at[tok_flat].add(gathered)
        return out.reshape(Bl, Sl, D), aux

    x_spec = P(batch_axes if batch_axes else None, "model", None)
    router_spec = P(None, None)
    w_spec = P("model", None, None)
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, router_spec, w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    if cfg.n_shared_experts:
        out = out + layers.swiglu(params["shared"], x)
    return out, aux


def _moe_dense(params, x: jax.Array, cfg: ModelConfig):
    """Dense pjit path (no mesh, or decode steps with few tokens).

    Group-local dispatch: tokens are routed within G independent groups
    (G = number of data shards in production, set by the launcher via
    repro.models.sharding.set_moe_groups). The dispatch buffer is
    (G, E, C, D) sharded (data, model, -, -).
    """
    from repro.models.sharding import moe_groups

    B, S, D = x.shape
    E = cfg.padded_experts
    K = cfg.topk
    N = B * S
    G = moe_groups()
    if N % G != 0:
        G = 1
    Ng = N // G
    xg = constrain(x.reshape(G, Ng, D), ("pod", "data"))

    # ---- router (fp32 for numerics)
    logits = jnp.einsum(
        "gnd,de->gne", xg.astype(jnp.float32),
        params["router"].astype(jnp.float32),
    )
    if E != cfg.n_experts:                      # mask padded dummy experts
        pad_mask = jnp.arange(E) >= cfg.n_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                     # (G, Ng, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # (G, Ng, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # ---- aux losses (load balance + router z-loss), global
    me = probs.reshape(N, E).mean(axis=0)                       # (E,)
    ce = jnp.zeros((E,)).at[expert_idx.reshape(-1)].add(1.0) / (N * K)
    aux = cfg.n_experts * jnp.sum(me * ce)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * 1e-4
    aux = aux + zloss

    # ---- capacity-based dispatch (argsort ranking per group: no
    # (N*K, E) one-hot — at 1M tokens x 64 experts that tensor alone
    # would blow past HBM)
    C = capacity(Ng, cfg.n_experts, K)
    if Ng <= EXACT_DISPATCH_MAX_TOKENS:
        # Small-token path (decode steps, small-scale tests): full capacity.
        # Fixed-capacity dropping at tiny N would make teacher-forced decode
        # diverge from the forward pass; the (G, E, Ng, D) buffer is cheap
        # at this scale.
        C = Ng
    NgK = Ng * K
    e_flat = expert_idx.reshape(G, NgK)                         # (G, NgK)
    tok_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Ng), K)[None], (G, NgK)
    )
    order = jnp.argsort(e_flat, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(e_flat, order, axis=1)
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(E), side="left")
    )(sorted_e)                                                 # (G, E)
    rank_sorted = (
        jnp.arange(NgK)[None] - jnp.take_along_axis(starts, sorted_e, axis=1)
    )
    pos_in_e = jnp.zeros_like(e_flat)
    pos_in_e = jax.vmap(lambda p, o, r: p.at[o].set(r))(
        pos_in_e, order, rank_sorted
    )
    keep = pos_in_e < C
    w = (gate_vals.reshape(G, NgK) * keep).astype(x.dtype)

    g_idx = jnp.broadcast_to(jnp.arange(G)[:, None], (G, NgK))
    safe_pos = jnp.where(keep, pos_in_e, C - 1)
    contrib = jnp.where(
        keep[..., None],
        jnp.take_along_axis(xg, tok_flat[..., None], axis=1),
        0,
    )
    contrib = constrain(contrib, ("pod", "data"))
    buf = jnp.zeros((G, E, C, D), x.dtype)
    buf = buf.at[g_idx, e_flat, safe_pos].add(contrib)
    buf = constrain(buf, ("pod", "data"), "model")   # EP all-to-all boundary

    # ---- expert FFN (experts sharded over 'model', groups over 'data')
    dt = x.dtype
    gh = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(dt))
    uh = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(dt))
    h = jax.nn.silu(gh) * uh
    y = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(dt))
    y = constrain(y, ("pod", "data"), "model")

    # ---- combine back to tokens (reverse all-to-all)
    gathered = y[g_idx, e_flat, safe_pos] * w[..., None]
    gathered = constrain(gathered, ("pod", "data"))
    out = jnp.zeros((G, Ng, D), x.dtype)
    out = out.at[g_idx, tok_flat].add(gathered)
    out = constrain(out, ("pod", "data"))
    out = out.reshape(B, S, D)

    if cfg.n_shared_experts:
        out = out + layers.swiglu(params["shared"], x)
    return out, aux
