"""RWKV-6 "Finch" [arXiv:2404.05892] — attention-free RNN LM.

Per layer: a time-mix block (WKV6 recurrence with data-dependent decay) and
a channel-mix block. The WKV6 state is (heads, head_dim, head_dim) per
sequence — O(1) in sequence length, which is why this arch runs the
long_500k decode cell.

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(wdec_t))

Training uses lax.scan over time (the Pallas kernel in
repro.kernels.wkv6 implements the chunked TPU version of the same math).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.params import (
    ParamDef,
    Schema,
    abstract_params,
    init_params,
    normal_init,
    param_count,
    zeros_init,
)
from repro.models.sharding import (constrain, layer_barrier,
                                   logits_sharded, residual)

BATCH = ("pod", "data")
HEAD_DIM = 64
DECAY_LORA = 64

# WKV implementation for the training path: "scan" (paper-faithful
# per-step recurrence, the baseline), "chunked" (flash-linear-attention
# chunk-parallel form, the optimized path), or "auto".
WKV_IMPL = "scan"


def set_wkv_impl(impl: str) -> None:
    global WKV_IMPL
    assert impl in ("scan", "chunked", "auto")
    globals()["WKV_IMPL"] = impl


def n_rwkv_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // HEAD_DIM


def timemix_schema(cfg: ModelConfig) -> Schema:
    d = cfg.d_model
    return {
        "mu_r": ParamDef((d,), ("embed",), normal_init(0.01)),
        "mu_k": ParamDef((d,), ("embed",), normal_init(0.01)),
        "mu_v": ParamDef((d,), ("embed",), normal_init(0.01)),
        "mu_w": ParamDef((d,), ("embed",), normal_init(0.01)),
        "mu_g": ParamDef((d,), ("embed",), normal_init(0.01)),
        "w_r": ParamDef((d, d), ("embed", "q_fused")),
        "w_k": ParamDef((d, d), ("embed", "q_fused")),
        "w_v": ParamDef((d, d), ("embed", "q_fused")),
        "w_g": ParamDef((d, d), ("embed", "q_fused")),
        "w_o": ParamDef((d, d), ("o_fused", "embed")),
        # data-dependent decay: w0 + tanh(x @ A) @ B  (low-rank lora)
        "w0": ParamDef((d,), ("embed",), normal_init(0.01)),
        "wA": ParamDef((d, DECAY_LORA), ("embed", None)),
        "wB": ParamDef((DECAY_LORA, d), (None, "embed")),
        "u": ParamDef((d,), ("embed",), normal_init(0.01)),   # bonus
        "ln_scale": ParamDef((d,), ("embed",), normal_init(0.01)),
    }


def channelmix_schema(cfg: ModelConfig) -> Schema:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_r": ParamDef((d,), ("embed",), normal_init(0.01)),
        "mu_k": ParamDef((d,), ("embed",), normal_init(0.01)),
        "w_r": ParamDef((d, d), ("embed", "q_fused")),
        "w_k": ParamDef((d, f), ("embed", "ffn")),
        "w_v": ParamDef((f, d), ("ffn", "embed")),
    }


def block_schema(cfg: ModelConfig) -> Schema:
    return {
        "tm_norm": layers.rmsnorm_schema(cfg.d_model),
        "tm": timemix_schema(cfg),
        "cm_norm": layers.rmsnorm_schema(cfg.d_model),
        "cm": channelmix_schema(cfg),
    }


def _stack(schema: Schema, n: int) -> Schema:
    def rec(node):
        if isinstance(node, ParamDef):
            return ParamDef(
                (n,) + node.shape, ("layers",) + node.axes, node.init, node.dtype
            )
        return {k: rec(v) for k, v in node.items()}

    return rec(schema)


def model_schema(cfg: ModelConfig) -> Schema:
    return {
        "embed": layers.embedding_schema(cfg.padded_vocab, cfg.d_model),
        "layers": _stack(block_schema(cfg), cfg.n_layers),
        "final_norm": layers.rmsnorm_schema(cfg.d_model),
        "lm_head": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                            normal_init(0.02)),
    }


# ------------------------------------------------------------------- blocks
def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu


def wkv6_scan(r, k, v, w, u, state):
    """The WKV6 recurrence over time (jnp reference path).

    r,k,v,w: (B, S, H, N); u: (H, N); state: (B, H, N, N).
    Returns (y (B,S,H,N), final_state).
    """
    B, S, H, N = r.shape

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                       # (B,H,N)
        kv = k_t[..., :, None] * v_t[..., None, :]     # (B,H,N,N)
        y = jnp.einsum(
            "bhi,bhij->bhj", r_t, s + u[None, :, :, None] * kv
        )
        s = w_t[..., :, None] * s + kv
        return s, y

    rs, ks, vs, ws = (
        jnp.moveaxis(t, 1, 0) for t in (r, k, v, w)
    )
    state, ys = jax.lax.scan(step, state, (rs, ks, vs, ws))
    return jnp.moveaxis(ys, 0, 1), state


def wkv6_chunked(r, k, v, w, u, state, chunk: int = 64):
    """Chunk-parallel WKV6 (flash-linear-attention style).

    Within a chunk of length T_c, with per-channel decays w and cumulative
    products A_t = prod_{s<=t} w_s:

      S_end = diag(A_T) S_0 + sum_s diag(A_T / A_s) k_s v_s^T
      y_t   = (r_t A_{t-1}) . S_0
            + sum_{s<t} ((r_t A_{t-1} / A_s) . k_s) v_s      (masked matmul)
            + (r_t . u k_t) v_t                              (bonus diagonal)

    Inter-chunk state is carried by a scan over chunks; intra-chunk work is
    matmuls on (T_c, N) blocks — MXU-friendly, and the HBM traffic drops by
    ~T_c vs the per-step scan. fp32 throughout; 1/A is bounded because
    |chunk| * max(-log w) stays small for trained decays (same regime as
    the reference CUDA kernel).
    """
    B, S, H, N = r.shape
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    def reshape_c(t):
        return t.reshape(B, nc, chunk, H, N).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = (reshape_c(t) for t in (r, k, v, w))   # (nc,B,H,Tc,N)

    def chunk_step(S0, inp):
        r_b, k_b, v_b, w_b = inp                  # (B,H,Tc,N)
        logw = jnp.log(jnp.maximum(w_b, 1e-38))
        A = jnp.exp(jnp.cumsum(logw, axis=2))     # A_t, inclusive
        A_prev = A / w_b                          # A_{t-1}
        r_dec = r_b * A_prev                      # (B,H,Tc,N)
        k_inv = k_b / A
        # cross-chunk contribution
        y = jnp.einsum("bhtn,bhnm->bhtm", r_dec, S0)
        # intra-chunk pairwise (strictly causal)
        scores = jnp.einsum("bhtn,bhsn->bhts", r_dec, k_inv)
        mask = jnp.tril(jnp.ones((chunk, chunk)), -1)
        y = y + jnp.einsum("bhts,bhsm->bhtm", scores * mask, v_b)
        # bonus diagonal
        diag = jnp.einsum("bhtn,bhtn->bht", r_b, u[None, :, None, :] * k_b)
        y = y + diag[..., None] * v_b
        # state update
        S_new = A[:, :, -1, :, None] * S0 + jnp.einsum(
            "bhsn,bhsm->bhnm", k_b * (A[:, :, -1:, :] / A), v_b
        )
        return S_new, y

    state, ys = jax.lax.scan(chunk_step, state, (rc, kc, vc, wc))
    # ys: (nc, B, H, Tc, N) -> (B, S, H, N)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, N)
    return y, state


def timemix(params, x, cfg: ModelConfig, state=None, x_prev=None,
            use_pallas: bool = False):
    """x: (B,S,D). state: (B,H,N,N) initial WKV state (decode) or None."""
    B, S, D = x.shape
    H, N = n_rwkv_heads(cfg), HEAD_DIM
    dt = x.dtype
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xr = _lerp(x, x_prev, params["mu_r"].astype(dt))
    xk = _lerp(x, x_prev, params["mu_k"].astype(dt))
    xv = _lerp(x, x_prev, params["mu_v"].astype(dt))
    xw = _lerp(x, x_prev, params["mu_w"].astype(dt))
    xg = _lerp(x, x_prev, params["mu_g"].astype(dt))
    r = (xr @ params["w_r"].astype(dt)).reshape(B, S, H, N)
    k = (xk @ params["w_k"].astype(dt)).reshape(B, S, H, N)
    v = (xv @ params["w_v"].astype(dt)).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ params["w_g"].astype(dt))
    # data-dependent decay in (0, 1)
    wdec = (
        params["w0"].astype(jnp.float32)
        + jnp.tanh(xw.astype(jnp.float32) @ params["wA"].astype(jnp.float32))
        @ params["wB"].astype(jnp.float32)
    )
    w = jnp.exp(-jnp.exp(wdec)).reshape(B, S, H, N).astype(jnp.float32)
    u = params["u"].astype(jnp.float32).reshape(H, N)
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)
    if use_pallas:
        from repro.kernels import ops as kops

        y, state = kops.wkv6(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w, u, state,
        )
    elif (WKV_IMPL in ("chunked", "auto")) and S % 64 == 0 and S > 64:
        y, state = wkv6_chunked(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w, u, state,
        )
    else:
        y, state = wkv6_scan(
            r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w, u, state,
        )
    y = y.reshape(B, S, D).astype(dt)
    # per-head group norm (approximated by rms over head dim groups)
    y = layers.rmsnorm({"scale": params["ln_scale"]}, y, cfg.norm_eps)
    out = (y * g) @ params["w_o"].astype(dt)
    return out, state, x[:, -1]


def channelmix(params, x, cfg: ModelConfig, x_prev=None):
    dt = x.dtype
    if x_prev is None:
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    xr = _lerp(x, x_prev, params["mu_r"].astype(dt))
    xk = _lerp(x, x_prev, params["mu_k"].astype(dt))
    r = jax.nn.sigmoid(xr @ params["w_r"].astype(dt))
    k = jnp.square(jax.nn.relu(xk @ params["w_k"].astype(dt)))
    return r * (k @ params["w_v"].astype(dt)), x[:, -1]


# -------------------------------------------------------------------- model
@dataclasses.dataclass
class RWKV6LM:
    cfg: ModelConfig

    def __post_init__(self):
        self.schema = model_schema(self.cfg)
        self.n_params = param_count(self.schema)

    def init(self, key):
        return init_params(key, self.schema)

    def abstract(self):
        return abstract_params(self.schema)

    def hidden_states(self, params, tokens, *, use_pallas=False, remat=True):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = layers.embed(params["embed"], tokens, dt)
        x = residual(x)

        def body(x, layer_params):
            layer_params = layer_barrier(layer_params)
            h = layers.rmsnorm(layer_params["tm_norm"], x, cfg.norm_eps)
            out, _, _ = timemix(layer_params["tm"], h, cfg,
                                use_pallas=use_pallas)
            x = x + out
            h = layers.rmsnorm(layer_params["cm_norm"], x, cfg.norm_eps)
            out, _ = channelmix(layer_params["cm"], h, cfg)
            x = x + out
            return residual(x), None

        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, x, params["layers"])
        return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps), 0.0

    def logits(self, params, tokens, *, use_pallas=False, remat=True):
        x, aux = self.hidden_states(
            params, tokens, use_pallas=use_pallas, remat=remat
        )
        return logits_sharded(
            layers.unembed({"table": params["lm_head"]}, x)), aux

    def last_logits(self, params, tokens, *, use_pallas=False, remat=True):
        x, _ = self.hidden_states(params, tokens, use_pallas=use_pallas,
                                  remat=remat)
        return logits_sharded(
            layers.unembed({"table": params["lm_head"]}, x[:, -1:]))

    def loss(self, params, batch, *, use_pallas=False, remat=True):
        logits, _ = self.logits(params, batch["inputs"],
                                use_pallas=use_pallas, remat=remat)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    # -------------------------------------------------------------- decode
    def cache_spec(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        H, N = n_rwkv_heads(cfg), HEAD_DIM
        L, D = cfg.n_layers, cfg.d_model
        return {
            "wkv": jax.ShapeDtypeStruct((L, batch, H, N, N), jnp.float32),
            "tm_prev": jax.ShapeDtypeStruct((L, batch, D), jnp.dtype(cfg.dtype)),
            "cm_prev": jax.ShapeDtypeStruct((L, batch, D), jnp.dtype(cfg.dtype)),
        }

    def init_cache(self, batch: int, max_len: int) -> dict:
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_spec(batch, max_len)
        )

    def decode_step(self, params, cache, pos, tokens, *, use_pallas=False):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = layers.embed(params["embed"], tokens, dt)    # (B,1,D)

        def body(x, scanned):
            layer_params, wkv, tm_prev, cm_prev = scanned
            h = layers.rmsnorm(layer_params["tm_norm"], x, cfg.norm_eps)
            out, wkv_new, tm_new = timemix(
                layer_params["tm"], h, cfg, state=wkv,
                x_prev=tm_prev[:, None, :],
            )
            x = x + out
            h = layers.rmsnorm(layer_params["cm_norm"], x, cfg.norm_eps)
            out, cm_new = channelmix(
                layer_params["cm"], h, cfg, x_prev=cm_prev[:, None, :]
            )
            x = x + out
            return x, (wkv_new, tm_new, cm_new)

        x, (wkv, tm_prev, cm_prev) = jax.lax.scan(
            body, x, (params["layers"], cache["wkv"], cache["tm_prev"],
                      cache["cm_prev"])
        )
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.unembed({"table": params["lm_head"]}, x)
        return logits, {"wkv": wkv, "tm_prev": tm_prev, "cm_prev": cm_prev}
