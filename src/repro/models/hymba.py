"""Hymba [arXiv:2411.13676] — hybrid-head LM: parallel attention + Mamba.

Each layer runs a (sliding-window) attention head group and a Mamba (SSM)
head group *in parallel* on the same input, normalizes each output, and
averages them. Meta-tokens are omitted (noted in DESIGN.md): they change
prompt handling, not the distributed mapping this repo studies.

The Mamba side keeps O(1) decode state (conv tail + SSM state), and the
attention side uses a ring-buffer SWA cache, so this arch runs long_500k.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.params import (
    ParamDef,
    Schema,
    abstract_params,
    init_params,
    normal_init,
    ones_init,
    param_count,
    zeros_init,
)
from repro.models.sharding import (constrain, layer_barrier,
                                   logits_sharded, residual)
from repro.models.transformer import attention_schema, attention_block

BATCH = ("pod", "data")
SWA_WINDOW = 1024
DT_RANK = 48


def d_inner(cfg: ModelConfig) -> int:
    return cfg.d_inner or 2 * cfg.d_model


# ------------------------------------------------------------------- mamba
def mamba_schema(cfg: ModelConfig) -> Schema:
    d = cfg.d_model
    di = d_inner(cfg)
    n = cfg.ssm_state
    return {
        "w_in": ParamDef((d, 2 * di), ("embed", "ffn")),
        "conv": ParamDef((cfg.conv_width, di), ("conv", "ffn"),
                         normal_init(0.1)),
        "w_bc": ParamDef((di, 2 * n), ("ffn", None)),
        "w_dt": ParamDef((di, DT_RANK), ("ffn", None)),
        "w_dt_out": ParamDef((DT_RANK, di), (None, "ffn")),
        "dt_bias": ParamDef((di,), ("ffn",), zeros_init()),
        "A_log": ParamDef((di, n), ("ffn", "state"), normal_init(0.1)),
        "D": ParamDef((di,), ("ffn",), ones_init()),
        "w_out": ParamDef((di, d), ("ffn", "embed")),
    }


def _causal_conv(x, kernel, conv_state=None):
    """Depthwise causal conv1d. x: (B,S,di); kernel: (W,di).

    conv_state: (B, W-1, di) tail of previous inputs (decode) or None.
    Returns (y, new_conv_state).
    """
    W = kernel.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+W-1, di)
    y = sum(
        xp[:, i:i + x.shape[1], :] * kernel[i][None, None, :]
        for i in range(W)
    )
    return y, xp[:, -(W - 1):, :]


def mamba_mixer(params, x, cfg: ModelConfig, state=None, conv_state=None,
                use_pallas: bool = False):
    """Selective SSM. x: (B,S,D). state: (B,di,n) or None.

    Returns (out (B,S,D), new_state, new_conv_state). With use_pallas the
    zero-state training path runs the VMEM-resident Pallas kernel
    (kernels/mamba_scan.py); decode (state != None) stays on the scan.
    """
    B, S, D = x.shape
    dt_ = x.dtype
    di = d_inner(cfg)
    n = cfg.ssm_state
    xz = x @ params["w_in"].astype(dt_)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = _causal_conv(xs, params["conv"].astype(dt_), conv_state)
    xs = jax.nn.silu(xs)
    bc = xs @ params["w_bc"].astype(dt_)
    B_ssm, C_ssm = jnp.split(bc, 2, axis=-1)            # (B,S,n)
    dt_raw = (xs @ params["w_dt"].astype(dt_)) @ params["w_dt_out"].astype(dt_)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )                                                   # (B,S,di)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))   # (di,n)
    if state is None:
        if use_pallas:
            from repro.kernels import ops as kops

            y32, state = kops.mamba_scan(
                xs.astype(jnp.float32), dt,
                B_ssm.astype(jnp.float32), C_ssm.astype(jnp.float32), A,
            )
            y = y32.astype(dt_) + xs * params["D"].astype(dt_)
            y = y * jax.nn.silu(z)
            return y @ params["w_out"].astype(dt_), state, conv_state
        state = jnp.zeros((B, di, n), jnp.float32)

    # Discretize INSIDE the scan: materializing dA/dBx as (B,S,di,n)
    # tensors costs S x the state size (13+ GiB at train_4k) — the step
    # recomputes them from the (B,S,di)/(B,S,n) inputs instead.
    def step(h, inp):
        xs_t, dt_t, B_t, C_t = inp        # (B,di),(B,di),(B,n),(B,n)
        dA_t = jnp.exp(dt_t[:, :, None] * A[None])          # (B,di,n)
        dBx_t = dt_t[:, :, None] * B_t[:, None, :] * xs_t[:, :, None]
        h = dA_t * h + dBx_t
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs_s = jnp.moveaxis(xs.astype(jnp.float32), 1, 0)
    dts = jnp.moveaxis(dt, 1, 0)
    Bs = jnp.moveaxis(B_ssm.astype(jnp.float32), 1, 0)
    Cs = jnp.moveaxis(C_ssm.astype(jnp.float32), 1, 0)
    state, ys = jax.lax.scan(step, state, (xs_s, dts, Bs, Cs))
    y = jnp.moveaxis(ys, 0, 1).astype(dt_)              # (B,S,di)
    y = y + xs * params["D"].astype(dt_)
    y = y * jax.nn.silu(z)
    return y @ params["w_out"].astype(dt_), state, conv_state


# ------------------------------------------------------------------- layer
def block_schema(cfg: ModelConfig) -> Schema:
    return {
        "norm": layers.rmsnorm_schema(cfg.d_model),
        "attn": attention_schema(cfg),
        "attn_out_norm": layers.rmsnorm_schema(cfg.d_model),
        "mamba": mamba_schema(cfg),
        "mamba_out_norm": layers.rmsnorm_schema(cfg.d_model),
        "ffn_norm": layers.rmsnorm_schema(cfg.d_model),
        "mlp": layers.swiglu_schema(cfg.d_model, cfg.d_ff),
    }


def _stack(schema: Schema, n: int) -> Schema:
    def rec(node):
        if isinstance(node, ParamDef):
            return ParamDef(
                (n,) + node.shape, ("layers",) + node.axes, node.init, node.dtype
            )
        return {k: rec(v) for k, v in node.items()}

    return rec(schema)


def model_schema(cfg: ModelConfig) -> Schema:
    return {
        "embed": layers.embedding_schema(cfg.padded_vocab, cfg.d_model),
        "layers": _stack(block_schema(cfg), cfg.n_layers),
        "final_norm": layers.rmsnorm_schema(cfg.d_model),
        "lm_head": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                            normal_init(0.02)),
    }


@dataclasses.dataclass
class HymbaLM:
    cfg: ModelConfig

    def __post_init__(self):
        cfg = self.cfg
        if cfg.sliding_window == 0:
            cfg = dataclasses.replace(cfg, sliding_window=SWA_WINDOW)
            self.cfg = cfg
        self.schema = model_schema(cfg)
        self.n_params = param_count(self.schema)

    def init(self, key):
        return init_params(key, self.schema)

    def abstract(self):
        return abstract_params(self.schema)

    # ------------------------------------------------------------- forward
    def hidden_states(self, params, tokens, *, use_pallas=False, remat=True):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = layers.embed(params["embed"], tokens, dt)
        x = residual(x)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]

        def body(x, layer_params):
            layer_params = layer_barrier(layer_params)
            h = layers.rmsnorm(layer_params["norm"], x, cfg.norm_eps)
            a = attention_block(layer_params["attn"], h, cfg, positions,
                                use_pallas)
            m, _, _ = mamba_mixer(layer_params["mamba"], h, cfg,
                                  use_pallas=use_pallas)
            a = layers.rmsnorm(layer_params["attn_out_norm"], a, cfg.norm_eps)
            m = layers.rmsnorm(layer_params["mamba_out_norm"], m, cfg.norm_eps)
            x = x + 0.5 * (a + m)
            h = layers.rmsnorm(layer_params["ffn_norm"], x, cfg.norm_eps)
            x = x + layers.swiglu(layer_params["mlp"], h)
            return residual(x), None

        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, x, params["layers"])
        return layers.rmsnorm(params["final_norm"], x, cfg.norm_eps), 0.0

    def logits(self, params, tokens, *, use_pallas=False, remat=True):
        x, aux = self.hidden_states(params, tokens, use_pallas=use_pallas,
                                    remat=remat)
        return logits_sharded(
            layers.unembed({"table": params["lm_head"]}, x)), aux

    def last_logits(self, params, tokens, *, use_pallas=False, remat=True):
        x, _ = self.hidden_states(params, tokens, use_pallas=use_pallas,
                                  remat=remat)
        return logits_sharded(
            layers.unembed({"table": params["lm_head"]}, x[:, -1:]))

    def loss(self, params, batch, *, use_pallas=False, remat=True):
        logits, _ = self.logits(params, batch["inputs"],
                                use_pallas=use_pallas, remat=remat)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    # -------------------------------------------------------------- decode
    def cache_spec(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        C = min(max_len, cfg.sliding_window)
        hd = cfg.resolved_head_dim
        L = cfg.n_layers
        di = d_inner(cfg)
        dt = jnp.dtype(cfg.dtype)
        return {
            "k": jax.ShapeDtypeStruct((L, batch, C, cfg.n_kv_heads, hd), dt),
            "v": jax.ShapeDtypeStruct((L, batch, C, cfg.n_kv_heads, hd), dt),
            "ssm": jax.ShapeDtypeStruct((L, batch, di, cfg.ssm_state),
                                        jnp.float32),
            "conv": jax.ShapeDtypeStruct((L, batch, cfg.conv_width - 1, di), dt),
        }

    def init_cache(self, batch: int, max_len: int) -> dict:
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_spec(batch, max_len)
        )

    def decode_step(self, params, cache, pos, tokens, *, use_pallas=False):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = layers.embed(params["embed"], tokens, dt)
        positions = jnp.full((1, 1), pos, jnp.int32)
        C = cache["k"].shape[2]
        slot = pos % C

        def body(x, scanned):
            layer_params, k_c, v_c, ssm, conv = scanned
            h = layers.rmsnorm(layer_params["norm"], x, cfg.norm_eps)
            # --- attention side (ring-buffer SWA cache)
            ap = layer_params["attn"]
            B = x.shape[0]
            hd = cfg.resolved_head_dim
            H, Kv = cfg.n_heads, cfg.n_kv_heads
            q = layers.apply_rope(
                (h @ ap["wq"].astype(dt)).reshape(B, 1, H, hd), positions,
                cfg.rope_theta,
            )
            k = layers.apply_rope(
                (h @ ap["wk"].astype(dt)).reshape(B, 1, Kv, hd), positions,
                cfg.rope_theta,
            )
            v = (h @ ap["wv"].astype(dt)).reshape(B, 1, Kv, hd)
            k_c = jax.lax.dynamic_update_index_in_dim(k_c, k[:, 0], slot, axis=1)
            v_c = jax.lax.dynamic_update_index_in_dim(v_c, v[:, 0], slot, axis=1)
            a = layers.decode_attention(q, k_c, v_c, pos,
                                        window=cfg.sliding_window)
            a = a.reshape(B, 1, H * hd) @ ap["wo"].astype(dt)
            # --- mamba side
            m, ssm, conv = mamba_mixer(layer_params["mamba"], h, cfg,
                                       state=ssm, conv_state=conv)
            a = layers.rmsnorm(layer_params["attn_out_norm"], a, cfg.norm_eps)
            m = layers.rmsnorm(layer_params["mamba_out_norm"], m, cfg.norm_eps)
            x = x + 0.5 * (a + m)
            hh = layers.rmsnorm(layer_params["ffn_norm"], x, cfg.norm_eps)
            x = x + layers.swiglu(layer_params["mlp"], hh)
            return x, (k_c, v_c, ssm, conv)

        x, (k_c, v_c, ssm, conv) = jax.lax.scan(
            body, x,
            (params["layers"], cache["k"], cache["v"], cache["ssm"],
             cache["conv"]),
        )
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = layers.unembed({"table": params["lm_head"]}, x)
        return logits, {"k": k_c, "v": v_c, "ssm": ssm, "conv": conv}
