"""Model zoo: the ten assigned architectures, config-driven."""
from repro.models.config import ModelConfig, ShapeConfig, SHAPES
from repro.models.registry import build
from repro.models.params import (
    ParamDef,
    ShardingRules,
    abstract_params,
    init_params,
    param_count,
    param_specs,
)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "build",
    "ParamDef", "ShardingRules", "abstract_params", "init_params",
    "param_count", "param_specs",
]
