"""Decoder-only transformer covering the dense / MoE / MLA assigned archs.

One module, config-driven:
  * GQA attention with optional QKV bias (qwen2), sliding window (danube),
    and MLA latent attention (deepseek-v2-lite);
  * dense SwiGLU FFN, or shared+routed MoE FFN (deepseek, qwen2-moe) with
    leading dense layers;
  * stacked layer parameters + lax.scan + remat (framework-scale: compile
    time and HBM stay bounded at 48 layers);
  * modality-stub inputs (musicgen frames / pixtral patches): apply() takes
    precomputed embeddings instead of token ids;
  * decode path with KV (or MLA latent / SWA ring-buffer) caches.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers, moe
from repro.models.config import ModelConfig
from repro.models.params import (
    ParamDef,
    Schema,
    abstract_params,
    init_params,
    normal_init,
    param_count,
    scaled_init,
)
from repro.models.sharding import (constrain, layer_barrier,
                                   logits_sharded, residual)

BATCH = ("pod", "data")


def _stack(schema: Schema, n: int) -> Schema:
    """Add a leading 'layers' axis to every leaf (scan-stacked params)."""

    def rec(node):
        if isinstance(node, ParamDef):
            return ParamDef(
                (n,) + node.shape, ("layers",) + node.axes, node.init, node.dtype
            )
        return {k: rec(v) for k, v in node.items()}

    return rec(schema)


# ------------------------------------------------------------ layer schemas
def attention_schema(cfg: ModelConfig) -> Schema:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, Kv = cfg.n_heads, cfg.n_kv_heads
    if cfg.use_mla:
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        sch: Schema = {
            "wq": ParamDef((d, H * qk_dim), ("embed", "q_fused")),
            "w_dkv": ParamDef((d, cfg.kv_lora_rank + cfg.qk_rope_dim),
                              ("embed", None)),
            "kv_norm": layers.rmsnorm_schema(cfg.kv_lora_rank)["scale"],
            "w_uk": ParamDef((cfg.kv_lora_rank, H * cfg.qk_nope_dim),
                             (None, "q_fused")),
            "w_uv": ParamDef((cfg.kv_lora_rank, H * cfg.v_head_dim),
                             (None, "q_fused")),
            "wo": ParamDef((H * cfg.v_head_dim, d), ("o_fused", "embed")),
        }
        return sch
    sch = {
        "wq": ParamDef((d, H * hd), ("embed", "q_fused")),
        "wk": ParamDef((d, Kv * hd), ("embed", "kv_fused")),
        "wv": ParamDef((d, Kv * hd), ("embed", "kv_fused")),
        "wo": ParamDef((H * hd, d), ("o_fused", "embed")),
    }
    if cfg.qkv_bias:
        sch["bq"] = ParamDef((H * hd,), ("q_fused",), normal_init(0.0))
        sch["bk"] = ParamDef((Kv * hd,), ("kv_fused",), normal_init(0.0))
        sch["bv"] = ParamDef((Kv * hd,), ("kv_fused",), normal_init(0.0))
    return sch


def block_schema(cfg: ModelConfig, use_moe: bool) -> Schema:
    sch: Schema = {
        "attn_norm": layers.rmsnorm_schema(cfg.d_model),
        "attn": attention_schema(cfg),
        "ffn_norm": layers.rmsnorm_schema(cfg.d_model),
    }
    if use_moe:
        sch["moe"] = moe.moe_schema(cfg)
    else:
        sch["mlp"] = layers.swiglu_schema(cfg.d_model, cfg.d_ff)
    return sch


def model_schema(cfg: ModelConfig) -> Schema:
    sch: Schema = {}
    if not cfg.stub_frontend:
        sch["embed"] = layers.embedding_schema(cfg.padded_vocab, cfg.d_model)
    n_moe = cfg.n_layers - cfg.first_dense_layers if cfg.n_experts else 0
    n_dense = cfg.n_layers - n_moe
    if n_dense:
        sch["dense_layers"] = _stack(block_schema(cfg, use_moe=False), n_dense)
    if n_moe:
        sch["moe_layers"] = _stack(block_schema(cfg, use_moe=True), n_moe)
    sch["final_norm"] = layers.rmsnorm_schema(cfg.d_model)
    n_heads_out = max(cfg.num_codebooks, 1)
    if not cfg.tie_embeddings or cfg.stub_frontend:
        sch["lm_head"] = ParamDef(
            (n_heads_out * cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
            normal_init(0.02),
        )
    return sch


# ---------------------------------------------------------------- attention
def attention_block(params, x, cfg: ModelConfig, positions, use_pallas=False):
    B, S, D = x.shape
    dt = x.dtype
    H, Kv = cfg.n_heads, cfg.n_kv_heads
    if cfg.use_mla:
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        q = (x @ params["wq"].astype(dt)).reshape(B, S, H, qk_dim)
        q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
        q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
        ckv = x @ params["w_dkv"].astype(dt)
        c_kv, k_rope = jnp.split(ckv, [cfg.kv_lora_rank], axis=-1)
        c_kv = layers.rmsnorm({"scale": params["kv_norm"]}, c_kv, cfg.norm_eps)
        k_rope = layers.apply_rope(
            k_rope[:, :, None, :], positions, cfg.rope_theta
        )                                                    # (B,S,1,rope)
        k_nope = (c_kv @ params["w_uk"].astype(dt)).reshape(
            B, S, H, cfg.qk_nope_dim
        )
        v = (c_kv @ params["w_uv"].astype(dt)).reshape(B, S, H, cfg.v_head_dim)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, S, H, cfg.qk_rope_dim))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = layers.attention(
            q, k, v, window=cfg.sliding_window, use_pallas=use_pallas,
            scale=qk_dim ** -0.5,
        )
        out = out.reshape(B, S, H * cfg.v_head_dim)
        return out @ params["wo"].astype(dt)
    hd = cfg.resolved_head_dim
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Kv, hd)
    v = v.reshape(B, S, Kv, hd)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    out = layers.attention(
        q, k, v, window=cfg.sliding_window, use_pallas=use_pallas
    )
    out = constrain(out.reshape(B, S, H * hd), BATCH, None, "model")
    return out @ params["wo"].astype(dt)


def block_apply(params, x, cfg: ModelConfig, positions, use_moe: bool,
                use_pallas: bool = False):
    h = layers.rmsnorm(params["attn_norm"], x, cfg.norm_eps)
    x = x + attention_block(params["attn"], h, cfg, positions, use_pallas)
    x = residual(x)
    h = layers.rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
    if use_moe:
        y, aux = moe.moe_apply(params["moe"], h, cfg)
    else:
        y, aux = layers.swiglu(params["mlp"], h), 0.0
    x = x + y
    x = residual(x)
    return x, aux


# ------------------------------------------------------------- full forward
@dataclasses.dataclass
class DecoderLM:
    cfg: ModelConfig

    def __post_init__(self):
        self.schema = model_schema(self.cfg)
        self.n_params = param_count(self.schema)

    # -------------------------------------------------------------- params
    def init(self, key):
        return init_params(key, self.schema)

    def abstract(self):
        return abstract_params(self.schema)

    # ------------------------------------------------------------- forward
    def hidden_states(self, params, inputs, *, use_pallas=False, remat=True):
        """inputs: token ids (B,S) int32, or embeddings (B,S,D) for stubs."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.stub_frontend:
            x = inputs.astype(dt)
        else:
            x = layers.embed(params["embed"], inputs, dt)
        x = residual(x)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.arange(S)[None, :]

        def scan_stack(x, stacked, use_moe):
            def body(carry, layer_params):
                x, aux = carry
                layer_params = layer_barrier(layer_params)
                fn = functools.partial(
                    block_apply, cfg=cfg, positions=positions,
                    use_moe=use_moe, use_pallas=use_pallas,
                )
                if remat:
                    fn = jax.checkpoint(fn)
                x, aux_i = fn(layer_params, x)
                return (x, aux + aux_i), None

            (x, aux), _ = jax.lax.scan(body, (x, 0.0), stacked)
            return x, aux

        aux_total = 0.0
        if "dense_layers" in params:
            x, aux = scan_stack(x, params["dense_layers"], use_moe=False)
            aux_total += aux
        if "moe_layers" in params:
            x, aux = scan_stack(x, params["moe_layers"], use_moe=True)
            aux_total += aux
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, aux_total

    def logits(self, params, inputs, *, use_pallas=False, remat=True):
        cfg = self.cfg
        x, aux = self.hidden_states(
            params, inputs, use_pallas=use_pallas, remat=remat
        )
        if "lm_head" in params:
            table = params["lm_head"]
        else:
            table = params["embed"]["table"]
        logits = layers.unembed({"table": table}, x)
        if cfg.num_codebooks > 1:
            B, S, _ = logits.shape
            logits = logits.reshape(B, S, cfg.num_codebooks, cfg.padded_vocab)
        return logits_sharded(logits), aux

    def last_logits(self, params, inputs, *, use_pallas=False, remat=True):
        """Prefill entry point: logits at the LAST position only — the full
        (B, S, V) prefill logit tensor is never materialized."""
        cfg = self.cfg
        x, _ = self.hidden_states(
            params, inputs, use_pallas=use_pallas, remat=remat
        )
        x = x[:, -1:]
        table = params.get("lm_head")
        if table is None:
            table = params["embed"]["table"]
        logits = layers.unembed({"table": table}, x)
        if cfg.num_codebooks > 1:
            B = logits.shape[0]
            logits = logits.reshape(B, 1, cfg.num_codebooks, cfg.padded_vocab)
        return logits_sharded(logits)

    def loss(self, params, batch, *, use_pallas=False, remat=True):
        """batch: {"inputs": ids|embeds, "labels": (B,S[,n_codebooks])}."""
        cfg = self.cfg
        logits, aux = self.logits(
            params, batch["inputs"], use_pallas=use_pallas, remat=remat
        )
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        mask = (labels >= 0).astype(jnp.float32)
        safe = jnp.maximum(labels, 0)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        return loss + 0.01 * aux

    # -------------------------------------------------------------- decode
    def cache_spec(self, batch: int, max_len: int) -> dict:
        """Abstract KV cache shapes (ring buffer when sliding window)."""
        cfg = self.cfg
        C = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        dt = jnp.dtype(cfg.dtype)
        L = cfg.n_layers
        if cfg.use_mla:
            return {
                "ckv": jax.ShapeDtypeStruct((L, batch, C, cfg.kv_lora_rank), dt),
                "krope": jax.ShapeDtypeStruct((L, batch, C, cfg.qk_rope_dim), dt),
            }
        hd = cfg.resolved_head_dim
        return {
            "k": jax.ShapeDtypeStruct((L, batch, C, cfg.n_kv_heads, hd), dt),
            "v": jax.ShapeDtypeStruct((L, batch, C, cfg.n_kv_heads, hd), dt),
        }

    def init_cache(self, batch: int, max_len: int) -> dict:
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.cache_spec(batch, max_len)
        )

    def decode_step(self, params, cache, pos, token_or_embed, *,
                    use_pallas=False):
        """One decode step. pos: scalar int32 (tokens generated so far)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.stub_frontend:
            x = token_or_embed.astype(dt)              # (B, 1, D)
        else:
            x = layers.embed(params["embed"], token_or_embed, dt)  # (B,1,D)
        positions = jnp.full((1, 1), pos, jnp.int32)
        C = (
            cache["ckv"].shape[2] if cfg.use_mla else cache["k"].shape[2]
        )
        if cfg.sliding_window > 0:
            slot = pos % C                       # ring buffer
        else:
            slot = jnp.minimum(pos, C - 1)

        def layer(carry, scanned):
            x = carry
            layer_params, cache_layer = scanned
            h = layers.rmsnorm(layer_params["attn_norm"], x, cfg.norm_eps)
            attn_out, new_cache_layer = self._decode_attention(
                layer_params["attn"], h, cfg, positions, pos, slot, cache_layer
            )
            x = x + attn_out
            h = layers.rmsnorm(layer_params["ffn_norm"], x, cfg.norm_eps)
            if "moe" in layer_params:
                y, _ = moe.moe_apply(layer_params["moe"], h, cfg)
            else:
                y = layers.swiglu(layer_params["mlp"], h)
            return x + y, new_cache_layer

        # Assemble a single stacked layer tree (dense prefix + moe suffix).
        stacks = []
        if "dense_layers" in params:
            stacks.append(("dense_layers", params["dense_layers"]))
        if "moe_layers" in params:
            stacks.append(("moe_layers", params["moe_layers"]))
        if len(stacks) == 1:
            # Fast path: carry the cache and update in place — scanning the
            # cache as xs/ys double-buffers the full multi-GiB KV cache
            # (xs and stacked ys can never alias).
            def carry_layer(carry, scanned):
                x, full_cache, i = carry
                layer_params = scanned
                cache_layer = {
                    k: jax.lax.dynamic_index_in_dim(v, i, 0, keepdims=False)
                    for k, v in full_cache.items()
                }
                x, new_layer = layer(x, (layer_params, cache_layer))
                full_cache = {
                    k: jax.lax.dynamic_update_index_in_dim(
                        full_cache[k], new_layer[k], i, 0
                    )
                    for k in full_cache
                }
                return (x, full_cache, i + 1), None

            (x, new_cache, _), _ = jax.lax.scan(
                carry_layer, (x, cache, jnp.int32(0)), stacks[0][1]
            )
        else:
            offset = 0
            new_cache_parts = {k: [] for k in cache}
            for name, stacked in stacks:
                n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
                cache_slice = {
                    k: jax.lax.dynamic_slice_in_dim(v, offset, n, axis=0)
                    for k, v in cache.items()
                }
                x, updated = jax.lax.scan(layer, x, (stacked, cache_slice))
                for k in cache:
                    new_cache_parts[k].append(updated[k])
                offset += n
            new_cache = {
                k: jnp.concatenate(v, axis=0) if len(v) > 1 else v[0]
                for k, v in new_cache_parts.items()
            }
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        table = params.get("lm_head", None)
        if table is None:
            table = params["embed"]["table"]
        logits = layers.unembed({"table": table}, x)
        if cfg.num_codebooks > 1:
            B = logits.shape[0]
            logits = logits.reshape(B, 1, cfg.num_codebooks, cfg.padded_vocab)
        return logits, new_cache

    def _decode_attention(self, params, x, cfg, positions, pos, slot, cache):
        B = x.shape[0]
        dt = x.dtype
        H, Kv = cfg.n_heads, cfg.n_kv_heads
        if cfg.use_mla:
            qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
            q = (x @ params["wq"].astype(dt)).reshape(B, 1, H, qk_dim)
            q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
            q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)
            q = jnp.concatenate([q_nope, q_rope], axis=-1)
            ckv_new = x @ params["w_dkv"].astype(dt)
            c_kv, k_rope = jnp.split(ckv_new, [cfg.kv_lora_rank], axis=-1)
            c_kv = layers.rmsnorm({"scale": params["kv_norm"]}, c_kv,
                                  cfg.norm_eps)
            k_rope = layers.apply_rope(
                k_rope[:, :, None, :], positions, cfg.rope_theta
            )[:, :, 0, :]
            ckv_cache = jax.lax.dynamic_update_index_in_dim(
                cache["ckv"], c_kv[:, 0], slot, axis=1
            )
            kr_cache = jax.lax.dynamic_update_index_in_dim(
                cache["krope"], k_rope[:, 0], slot, axis=1
            )
            # Reconstruct K, V for all cached latents.
            k_nope = jnp.einsum(
                "bcr,rx->bcx", ckv_cache, params["w_uk"].astype(dt)
            ).reshape(B, -1, H, cfg.qk_nope_dim)
            v = jnp.einsum(
                "bcr,rx->bcx", ckv_cache, params["w_uv"].astype(dt)
            ).reshape(B, -1, H, cfg.v_head_dim)
            k = jnp.concatenate(
                [
                    k_nope,
                    jnp.broadcast_to(
                        kr_cache[:, :, None, :],
                        k_nope.shape[:3] + (cfg.qk_rope_dim,),
                    ),
                ],
                axis=-1,
            )
            out = layers.decode_attention(
                q, k, v, pos, window=cfg.sliding_window, scale=qk_dim ** -0.5
            )
            out = out.reshape(B, 1, H * cfg.v_head_dim)
            return out @ params["wo"].astype(dt), {
                "ckv": ckv_cache, "krope": kr_cache,
            }
        hd = cfg.resolved_head_dim
        q = x @ params["wq"].astype(dt)
        k = x @ params["wk"].astype(dt)
        v = x @ params["wv"].astype(dt)
        if cfg.qkv_bias:
            q = q + params["bq"].astype(dt)
            k = k + params["bk"].astype(dt)
            v = v + params["bv"].astype(dt)
        q = layers.apply_rope(q.reshape(B, 1, H, hd), positions, cfg.rope_theta)
        k = layers.apply_rope(k.reshape(B, 1, Kv, hd), positions, cfg.rope_theta)
        v = v.reshape(B, 1, Kv, hd)
        k_cache = jax.lax.dynamic_update_index_in_dim(
            cache["k"], k[:, 0], slot, axis=1
        )
        v_cache = jax.lax.dynamic_update_index_in_dim(
            cache["v"], v[:, 0], slot, axis=1
        )
        out = layers.decode_attention(
            q, k_cache, v_cache, pos, window=cfg.sliding_window
        )
        out = out.reshape(B, 1, H * hd)
        return out @ params["wo"].astype(dt), {"k": k_cache, "v": v_cache}
