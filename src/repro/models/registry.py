"""Model registry: config -> model object, by family."""
from __future__ import annotations

from repro.models.config import ModelConfig


def build(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        from repro.models.transformer import DecoderLM

        return DecoderLM(cfg)
    if cfg.family == "ssm":
        from repro.models.rwkv6 import RWKV6LM

        return RWKV6LM(cfg)
    if cfg.family == "hybrid":
        from repro.models.hymba import HymbaLM

        return HymbaLM(cfg)
    raise ValueError(f"unknown model family {cfg.family!r}")
