"""rwkv6-3b "Finch" [arXiv:2404.05892; hf] — attention-free, data-dep decay."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,                   # 2560 / 64 rwkv heads
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
)
