"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed top-4 + 4 shared.

60 routed experts are padded to 64 (never-routed dummies) so the expert
dim shards evenly over the 16-way model axis (see DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5632,                    # unused (all layers MoE); shared uses 5632
    vocab_size=151936,
    n_experts=60,
    n_shared_experts=4,
    topk=4,
    moe_d_ff=1408,
    shared_d_ff=5632,
    qkv_bias=True,
    rope_theta=1000000.0,
)
