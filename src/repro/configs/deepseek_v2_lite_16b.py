"""deepseek-v2-lite-16b [arXiv:2405.04434; hf] — MLA + MoE (64e top-6)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                   # first dense layer FFN
    vocab_size=102400,
    # MoE: 64 routed top-6 + 2 shared; layer 0 dense.
    n_experts=64,
    n_shared_experts=2,
    topk=6,
    moe_d_ff=1408,
    shared_d_ff=2816,
    first_dense_layers=1,
    # MLA
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
)
