"""musicgen-medium [arXiv:2306.05284] — decoder-only over EnCodec tokens.

Frontend stub: input_specs() provides precomputed frame embeddings;
the model trains 4 parallel codebook heads over vocab 2048.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    vocab_round=64,
    num_codebooks=4,
    stub_frontend=True,
    rope_theta=10000.0,
)
