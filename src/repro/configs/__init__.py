"""Assigned-architecture configs (public literature, exact dims).

``get_config(arch_id)`` returns the full config; ``--arch <id>`` in the
launchers resolves through this registry.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "h2o-danube-1.8b",
    "granite-3-2b",
    "qwen2-7b",
    "smollm-135m",
    "deepseek-v2-lite-16b",
    "qwen2-moe-a2.7b",
    "rwkv6-3b",
    "musicgen-medium",
    "hymba-1.5b",
    "pixtral-12b",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(ARCH_IDS)}"
        )
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
