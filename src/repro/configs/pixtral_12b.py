"""pixtral-12b [hf:mistralai/Pixtral-12B-2409] — ViT frontend (stub) +
mistral-nemo decoder backbone. input_specs() provides patch embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    stub_frontend=True,
    rope_theta=1000000.0,
)
