"""hymba-1.5b [arXiv:2411.13676] — parallel attention + mamba heads, SWA."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,             # padded to 32256
    ssm_state=16,
    d_inner=3200,
    conv_width=4,
    sliding_window=1024,
    rope_theta=10000.0,
)
