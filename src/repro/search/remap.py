"""Fault-aware remapping: recover a tuned mapping after machine failures.

When processors die mid-run, the healthy plan is unusable — its placement
puts tiles on processors that no longer exist — but re-tuning from
scratch prices thousands of analytic points before the beam even forms.
:func:`remap_plan` is the fast middle path:

1. **Survivor selection**: fold the failures into a
   :class:`~repro.core.machine.DegradedMachine` and pick the regular
   sub-machine (``a' nodes x g' procs``) that keeps the most usable
   processors while remaining feasible for the application's search
   space (:func:`submachine_options` ranks every choice).
2. **Warm, restricted search**: tune on the sub-machine shape, seeding
   the beam with the stale winner (and any plan-cache neighbours) refit
   via :func:`~repro.search.tuner.refit_candidate`, and — in ``"warm"``
   mode — restricting Phase 1 to those seeded points
   (``prepare_tune(restrict=...)``), so recovery latency is a handful
   of pricings instead of a full enumeration. Surviving port contention
   is translated onto the sub-machine so the search prices what the
   survivors will actually feel.
3. **Physical translation + audit**: the winner's logical placement is
   mapped through ``proc_map`` onto the surviving physical processors
   (never a dead one, by construction) and priced on the *original*
   degraded machine, next to the stale placement (``inf`` when it
   touches a dead processor) — the recovery-quality numbers
   ``benchmarks/resilience_bench.py`` gates on.

See docs/resilience.md for the full degraded-machine model.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, Iterator

import numpy as np

from repro.core.machine import DegradedMachine, MachineSpec
from repro.search.pipeline import price_jobs
from repro.search.space import Candidate, build_program
from repro.search.tuner import (
    DEFAULT_BEAM,
    DEFAULT_LEADERBOARD,
    TuningReport,
    prepare_tune,
    refit_candidate,
)
from repro.sim.batch import BatchSimulator
from repro.sim.collectives import packed_schedule
from repro.sim.cost import (
    DEFAULT_ELEM_BYTES,
    DEFAULT_STEPS,
    pattern_with_options,
    spec_for,
    time_tuned_app,
)
from repro.sim.topology import Topology

#: Ranked sub-machine choices examined before concluding no surviving
#: regular grid can host the application.
MAX_SUBMACHINE_TRIES = 64


# ------------------------------------------------------------------ failures
def degraded_from_failures(spec: MachineSpec, failures) -> DegradedMachine:
    """Fold heterogeneous failure evidence into one degraded view.

    Accepts a ready :class:`DegradedMachine`, a single failure, or an
    iterable mixing: ``DegradedMachine`` views (merged), objects with a
    ``.procs`` tuple (``sim.engine.NodeFailure``, node-death
    ``FaultEvent``), and bare processor ids. Transient link-slowdown
    events are skipped — they are weather, not a persistent machine
    state to remap around.
    """
    if isinstance(failures, DegradedMachine):
        if failures.spec != spec:
            raise ValueError(
                "degraded view describes a different machine than spec")
        return failures
    if not isinstance(failures, (list, tuple, set, frozenset)):
        failures = (failures,)
    view = DegradedMachine.healthy(spec)
    dead: list[int] = []
    for item in failures:
        if isinstance(item, DegradedMachine):
            view = view.merged(item)
        elif hasattr(item, "procs"):
            if getattr(item, "kind", "node-death") != "node-death":
                continue
            dead.extend(int(p) for p in item.procs)
        else:
            dead.append(int(item))
    if dead:
        view = view.merged(DegradedMachine.fail_procs(spec, dead))
    return view


# ------------------------------------------------------------ survivor grids
def submachine_options(degraded: DegradedMachine
                       ) -> Iterator[tuple[tuple[int, int], tuple[int, ...]]]:
    """Regular ``(a', g')`` sub-machines of the survivors, best first.

    Yields ``(sub_shape, proc_map)`` pairs: ``proc_map[j]`` is the
    physical processor hosting logical processor ``j`` of the
    sub-machine (node-major, so logical node ``i'`` occupies ``g'``
    alive slots of one physical node — level-0 crossings on the
    sub-machine are level-0 crossings on the real one). Ranked by
    usable processors, ties toward more processors per node (cheaper
    intra-node traffic)."""
    spec = degraded.spec
    if len(spec.shape) != 2:
        raise ValueError(
            f"remap supports (nodes, procs) machines, got shape {spec.shape}")
    nodes, gpus = (int(s) for s in spec.shape)
    dead = set(degraded.dead_procs)
    avail = [[g for g in range(gpus) if i * gpus + g not in dead]
             for i in range(nodes)]
    options: list[tuple[int, int, int]] = []
    for g in range(1, gpus + 1):
        a_max = sum(1 for row in avail if len(row) >= g)
        for a in range(a_max, 0, -1):
            options.append((a * g, g, a))
    options.sort(key=lambda t: (-t[0], -t[1]))
    for _n, g, a in options:
        ok = [i for i in range(nodes) if len(avail[i]) >= g][:a]
        pm = tuple(i * gpus + avail[i][k] for i in ok for k in range(g))
        yield (a, g), pm


def _mapped_degradation(degraded: DegradedMachine,
                        sub_shape: tuple[int, int],
                        proc_map: tuple[int, ...]) -> DegradedMachine | None:
    """The surviving port contention, seen from the sub-machine.

    Every logical node is one physical node, so the sub-machine's
    level-0 port ``i'`` drains through exactly the physical NIC of
    ``proc_map[i' * g']``'s node; level-1 (per-processor) ports map
    one-to-one through ``proc_map``. Dead processors never appear —
    the sub-machine is built from survivors only."""
    if degraded.contention is None:
        return None
    gpus = int(degraded.spec.shape[1])
    a, g = sub_shape
    row0 = tuple(degraded.contention[0][proc_map[i * g] // gpus]
                 for i in range(a))
    row1 = tuple(degraded.contention[1][p] for p in proc_map)
    view = DegradedMachine(spec=spec_for(sub_shape),
                           contention=(row0, row1))
    return None if view.is_trivial else view


# ----------------------------------------------------------------- utilities
def _candidate_of(plan) -> Candidate | None:
    """A ``Candidate`` from whatever shape a 'plan' arrives in —
    ``Candidate``, ``ScoredCandidate``, ``TuningReport``, a service
    ``MappingPlan`` or its JSON payload; ``None`` when unrecognizable."""
    if plan is None:
        return None
    if isinstance(plan, Candidate):
        return plan
    best = getattr(plan, "best", None)          # TuningReport
    if best is not None:
        plan = best
    cand = getattr(plan, "candidate", None)     # ScoredCandidate/MappingPlan
    if isinstance(cand, Candidate):
        return cand
    payload = None
    if isinstance(cand, dict):
        payload = cand
    elif isinstance(plan, dict):
        payload = plan.get("candidate", plan)
    if not isinstance(payload, dict):
        return None
    try:
        return Candidate(
            grid=tuple(int(g) for g in payload["grid"]),
            dist=tuple(str(d) for d in payload["dist"]),
            order=tuple(int(o) for o in payload["order"]),
            options=tuple((str(k), str(v)) for k, v in payload["options"]),
        )
    except (KeyError, TypeError, ValueError):
        return None


def price_on_degraded(app, degraded: DegradedMachine, candidate: Candidate,
                      placement, *, procs: int, steps: int = DEFAULT_STEPS,
                      elem_bytes: int = DEFAULT_ELEM_BYTES,
                      backpressure: int = 2) -> float:
    """Seconds per step of a *physical* placement on the degraded
    machine — ``inf`` when the placement touches a dead processor
    (a stale plan after a node death is not slow, it is impossible).
    ``procs`` is the number of processors doing the compute leg."""
    pattern = getattr(app, "collective", None)
    if pattern is None:
        raise ValueError(f"application {app.name!r} declares no collective")
    flat = np.asarray(placement, dtype=np.int64).reshape(1, -1)
    dead = set(degraded.dead_procs)
    if dead and dead.intersection(int(p) for p in flat[0]):
        return float("inf")
    spec = degraded.spec
    sim = BatchSimulator(
        topology=Topology.from_spec(spec, degraded=degraded),
        schedule=packed_schedule(
            pattern_with_options(pattern, dict(candidate.options)),
            tuple(int(g) for g in candidate.grid), elem_bytes=elem_bytes),
        compute_s=float(app.step_flops(procs)) / (procs * spec.peak_flops),
        backpressure=backpressure,
        steps=steps,
    )
    # fold=False: physical placements are injective into the full machine
    # but not bijective, and correctness beats the folding speedup for a
    # single audit pricing.
    return float(sim.step_times(flat, fold=False)[0])


# -------------------------------------------------------------------- result
@dataclasses.dataclass(frozen=True)
class RemapResult:
    """A recovered mapping plus its recovery-quality audit numbers."""

    app: str
    degraded: DegradedMachine
    sub_shape: tuple[int, int]
    #: ``proc_map[j]`` = physical processor of logical processor ``j``.
    proc_map: tuple[int, ...]
    procs: int                       # processors the remapped plan uses
    report: TuningReport             # the (restricted) search's full report
    #: Physical tile->processor grid; values index the ORIGINAL machine
    #: and never include a dead processor.
    placement: np.ndarray
    degraded_step_s: float           # remapped plan on the degraded machine
    stale_step_s: float              # old placement there (inf if impossible)
    mode: str                        # "warm" | "cold"
    elapsed_s: float

    @property
    def n_alive(self) -> int:
        return self.degraded.n_alive

    def summary(self) -> dict:
        best = self.report.best.candidate
        return {
            "app": self.app,
            "mode": self.mode,
            "n_alive": self.n_alive,
            "procs": int(self.procs),
            "sub_shape": list(self.sub_shape),
            "proc_map": [int(p) for p in self.proc_map],
            "grid": list(best.grid),
            "options": [[k, v] for k, v in best.options],
            "placement": self.placement.tolist(),
            "degraded_step_s": self.degraded_step_s,
            "stale_step_s": self.stale_step_s,
            "elapsed_s": self.elapsed_s,
        }


# ---------------------------------------------------------------------- core
def remap_plan(app, plan, failures, *, seeds: Iterable = (),
               mode: str = "warm", engine: str = "batched",
               dtype: str = "float64", cache=None, beam: int = DEFAULT_BEAM,
               leaderboard: int = DEFAULT_LEADERBOARD,
               steps: int = DEFAULT_STEPS,
               elem_bytes: int = DEFAULT_ELEM_BYTES,
               procs: int | None = None) -> RemapResult:
    """Warm-start a tuned plan onto the processors that survived.

    ``plan`` is the stale winner in any shape :func:`_candidate_of`
    understands (or ``None``); ``failures`` is anything
    :func:`degraded_from_failures` accepts; ``seeds`` adds plan-cache
    neighbours to the warm beam. ``mode="warm"`` restricts Phase 1 to
    the seeded points (the fast path), ``mode="cold"`` runs the full
    enumeration on the sub-machine — the baseline the resilience
    benchmark compares recovery latency against. Both modes search with
    surviving contention mapped onto the sub-machine and return the
    physically-translated placement audited on the original degraded
    machine."""
    t0 = time.perf_counter()
    if mode not in ("warm", "cold"):
        raise ValueError(f"mode must be 'warm' or 'cold', got {mode!r}")
    base_space = app.search_space
    if base_space is None:
        raise ValueError(f"application {app.name!r} declares no search space")
    n0 = app.procs(procs)
    if not base_space.grids(n0):
        n0 = app.default_procs
    shape0 = tuple(int(s) for s in app.machine_shape(n0))
    spec0 = spec_for(shape0)
    degraded = degraded_from_failures(spec0, failures)

    plan_cand = _candidate_of(plan)
    seed_cands = [plan_cand] if plan_cand is not None else []
    seed_cands += [c for c in (_candidate_of(s) for s in seeds)
                   if c is not None]

    chosen = None
    last_err: Exception | None = None
    for tried, (sub_shape, proc_map) in enumerate(
            submachine_options(degraded)):
        if tried >= MAX_SUBMACHINE_TRIES:
            break
        n = sub_shape[0] * sub_shape[1]
        if not base_space.grids(n):
            continue
        app_sub = dataclasses.replace(
            app, machine_shape=lambda p, s=sub_shape: s)
        mapped = _mapped_degradation(degraded, sub_shape, proc_map)
        tuned = time_tuned_app(app_sub, steps=steps, elem_bytes=elem_bytes,
                               engine=engine, dtype=dtype, cache=cache,
                               degraded=mapped)
        space_t = tuned.search_space
        refit = [r for r in (refit_candidate(space_t, c, n)
                             for c in seed_cands) if r is not None]
        try:
            pending = prepare_tune(
                tuned, n, beam=beam, leaderboard=leaderboard,
                warm_start=refit,
                restrict=(refit or None) if mode == "warm" else None)
            if pending.n != n:
                # The tuner's own infeasibility fallback kicked in —
                # this sub-machine cannot host the app at scale n.
                continue
            price_jobs(list(pending.jobs()))
            report = pending.finish()
        except ValueError as exc:
            last_err = exc
            continue
        chosen = (sub_shape, proc_map, n, report)
        break
    if chosen is None:
        hint = f" (last error: {last_err})" if last_err is not None else ""
        raise ValueError(
            f"no surviving regular sub-machine of {spec0.shape} can host "
            f"{app.name!r} ({degraded.n_alive} of {spec0.nprocs} processors "
            f"alive){hint}")

    sub_shape, proc_map, n, report = chosen
    best = report.best.candidate
    logical = np.asarray(
        report.best_program.mapper.assignment_grid(best.grid),
        dtype=np.int64)
    physical = np.asarray(proc_map, dtype=np.int64)[logical]
    degraded_step_s = price_on_degraded(
        app, degraded, best, physical, procs=n, steps=steps,
        elem_bytes=elem_bytes)

    stale_step_s = float("inf")
    if plan_cand is not None:
        try:
            prog0 = build_program(shape0, plan_cand, f"{app.name}_stale")
            assign0 = prog0.mapper.assignment_grid(plan_cand.grid,
                                                   use_cache=False)
            stale_step_s = price_on_degraded(
                app, degraded, plan_cand, assign0, procs=n0, steps=steps,
                elem_bytes=elem_bytes)
        except (ValueError, KeyError):
            stale_step_s = float("inf")

    return RemapResult(
        app=app.name,
        degraded=degraded,
        sub_shape=sub_shape,
        proc_map=tuple(int(p) for p in proc_map),
        procs=n,
        report=report,
        placement=physical,
        degraded_step_s=degraded_step_s,
        stale_step_s=stale_step_s,
        mode=mode,
        elapsed_s=time.perf_counter() - t0,
    )


__all__ = [
    "MAX_SUBMACHINE_TRIES",
    "RemapResult",
    "degraded_from_failures",
    "price_on_degraded",
    "remap_plan",
    "submachine_options",
]
