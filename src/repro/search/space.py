"""Candidate mapper search spaces — the autotuner's enumeration layer.

A :class:`SearchSpace` describes, per application, the axes along which
mapper programs may vary:

  * the **grid axis** — all ordered factorizations of the processor count
    into the app's tile-grid rank (``decompose.enumerate_factorizations``),
    optionally filtered by an algorithmic validity predicate (Cannon needs
    a square grid, Solomonik a ``(q, q, c)`` grid, ...);
  * the **distribution axis** — per tile-grid axis, block-over-nodes /
    cyclic-within-node (the Fig. 12 default) or cyclic-over-nodes /
    block-within-node;
  * the **order axis** — the machine-side decompose visit order, realized
    as recorded ``swap`` ops in the mapping IR (same volume, different
    tile->device permutation, hence different fabric locality);
  * optional app-specific **option axes** (e.g. circuit's ZCMEM vs FBMEM
    placement of the shared charge region).

Every candidate materializes as a PR-2 mapping-IR program — a
:class:`~repro.core.pspace.ProcSpace` transformation chain
(``decompose``/``swap`` over the two-level machine) plus a mapping
function built from the Fig. 12 block/cyclic primitives — so the tuner
scores it analytically with a :class:`~repro.core.commvolume.CostModel`
and evaluates it through the vectorized ``Mapper.assignment_grid`` batch
path. The winning candidate additionally renders to Mapple DSL source
(:func:`render_source`) for the ``--tune`` report.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

from repro.core.commvolume import CostModel
from repro.core.decompose import enumerate_factorizations, optimal_factorization
from repro.core.machine import GPU, Machine
from repro.core.mapper import Mapper
from repro.core.pspace import ProcSpace
from repro.core.tuples import Tup

#: Per-axis distribution choices over the two-level machine hierarchy.
BLOCK_CYCLIC = "bc"   # block over node factors, cyclic within a node (Fig. 12)
CYCLIC_BLOCK = "cb"   # cyclic over node factors, block within a node


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of a search space: a concrete mapper program, as data."""

    grid: tuple[int, ...]                         # tile grid, prod == procs
    dist: tuple[str, ...]                         # per-axis "bc" | "cb"
    order: tuple[int, ...]                        # machine-side visit order
    options: tuple[tuple[str, str], ...] = ()     # app-specific axes

    @property
    def opts(self) -> dict[str, str]:
        return dict(self.options)

    def describe(self) -> str:
        parts = ["x".join(str(g) for g in self.grid), "/".join(self.dist)]
        if self.order != tuple(range(len(self.grid))):
            parts.append("order=" + "".join(str(o) for o in self.order))
        parts.extend(f"{k}={v}" for k, v in self.options)
        return " ".join(parts)


@dataclasses.dataclass(frozen=True)
class CandidateProgram:
    """A candidate materialized as mapping IR: the transformed space, the
    node/intra-node factor split behind it, and the executable Mapper."""

    candidate: Candidate
    space: ProcSpace
    node_factors: tuple[int, ...]   # () when the machine hierarchy is flat
    proc_factors: tuple[int, ...]
    mapper: Mapper

    @property
    def hierarchical(self) -> bool:
        return bool(self.node_factors)


def node_split(machine_shape: Sequence[int],
               grid: Sequence[int]) -> tuple[int, ...] | None:
    """Factor the node count into per-axis counts dividing the tile grid.

    Returns ``None`` when the machine degenerates to one level (a single
    node, or one processor per node) or no divisible split exists — the
    candidate then uses the flat (merged) machine.
    """
    nodes, gpus = (int(s) for s in machine_shape)
    if nodes <= 1 or gpus <= 1:
        return None
    grid = tuple(int(g) for g in grid)
    nf = optimal_factorization(nodes, grid, require_divisible=True)
    if any(g % f for g, f in zip(grid, nf)):
        return None
    return nf


def _unpermute_swaps(order: Sequence[int]) -> list[tuple[int, int]]:
    """Swap sequence returning dims visited in ``order`` to identity order."""
    cur = list(order)
    swaps: list[tuple[int, int]] = []
    for i in range(len(cur)):
        j = cur.index(i)
        if j != i:
            swaps.append((i, j))
            cur[i], cur[j] = cur[j], cur[i]
    return swaps


def build_program(machine_shape: Sequence[int], cand: Candidate,
                  name: str) -> CandidateProgram:
    """Materialize a candidate as a ProcSpace IR program + Mapper.

    Hierarchical machines yield ``root(nodes, gpus).decompose(0, nf')
    .decompose(k, gf')[.swap(..)..]`` (primed tuples are in candidate
    ``order``; the swaps restore identity axis order, recording the order
    variant in the IR). Flat machines merge the two levels first.
    """
    machine_shape = tuple(int(s) for s in machine_shape)
    if len(machine_shape) != 2:
        raise ValueError(f"expected a two-level machine, got {machine_shape}")
    k = len(cand.grid)
    if sorted(cand.order) != list(range(k)):
        raise ValueError(f"order {cand.order} is not a permutation of 0..{k - 1}")
    root = Machine(GPU, shape=machine_shape)
    nf = node_split(machine_shape, cand.grid)

    if nf is None:
        flat = root.merge(0, 1)
        perm_grid = tuple(cand.grid[o] for o in cand.order)
        space = flat.decompose_with(0, perm_grid)
        for p, q in _unpermute_swaps(cand.order):
            space = space.swap(p, q)
        mapper = _flat_mapper(space, k, name)
        return CandidateProgram(cand, space, (), cand.grid, mapper)

    gf = tuple(g // f for g, f in zip(cand.grid, nf))
    perm_nf = tuple(nf[o] for o in cand.order)
    perm_gf = tuple(gf[o] for o in cand.order)
    space = root.decompose_with(0, perm_nf).decompose_with(k, perm_gf)
    for p, q in _unpermute_swaps(cand.order):
        space = space.swap(p, q)
    for p, q in _unpermute_swaps(cand.order):
        space = space.swap(k + p, k + q)
    mapper = _hierarchical_mapper(space, k, nf, gf, cand.dist, name)
    return CandidateProgram(cand, space, nf, gf, mapper)


def _flat_mapper(space: ProcSpace, k: int, name: str) -> Mapper:
    """Identity block map: tile coordinate i -> decomposed machine dim i."""

    def fn(ipoint: Tup, ispace: Tup):
        return space[tuple(ipoint[i] for i in range(k))]

    return Mapper(name, fn, spaces={"mf": space})


def _hierarchical_mapper(space: ProcSpace, k: int, nf: tuple[int, ...],
                         gf: tuple[int, ...], dist: tuple[str, ...],
                         name: str) -> Mapper:
    """Fig. 12-style two-level map with per-axis distribution choices.

    Axis i of extent g = nf[i] * gf[i] splits into a node coordinate and an
    intra-node coordinate; both variants are bijections of that axis. The
    body is pure broadcastable arithmetic, so the vectorized
    ``assignment_grid`` path evaluates it in one batched pass.
    """

    def fn(ipoint: Tup, ispace: Tup):
        uppers = []
        lowers = []
        for i in range(k):
            x = ipoint[i]
            if dist[i] == BLOCK_CYCLIC:
                uppers.append(x // gf[i])
                lowers.append(x % gf[i])
            else:
                uppers.append(x % nf[i])
                lowers.append(x // nf[i])
        return space[tuple(uppers) + tuple(lowers)]

    return Mapper(name, fn, spaces={"mf": space})


# ------------------------------------------------------------- search spaces
@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """The candidate axes + cost objective for one application.

    ``cost_model(procs, options)`` returns the :class:`CostModel` scoring a
    candidate grid under the given option choices — the same object the
    ``decompose`` solver accepts as an objective.
    """

    rank: int
    cost_model: Callable[[int, dict[str, str]], CostModel]
    grid_ok: Callable[[tuple[int, ...]], bool] | None = None
    option_axes: tuple[tuple[str, tuple[str, ...]], ...] = ()
    default_grid: Callable[[int], tuple[int, ...]] | None = None
    default_options: tuple[tuple[str, str], ...] = ()
    directives: Callable[[str, dict[str, str]], str] | None = None

    # ------------------------------------------------------------- candidates
    def grids(self, procs: int) -> list[tuple[int, ...]]:
        """All valid ordered grid factorizations of ``procs``."""
        out = {
            f for f in enumerate_factorizations(procs, self.rank)
            if self.grid_ok is None or self.grid_ok(f)
        }
        return sorted(out)

    def option_combos(self) -> list[tuple[tuple[str, str], ...]]:
        if not self.option_axes:
            return [()]
        names = [n for n, _ in self.option_axes]
        choice_lists = [choices for _, choices in self.option_axes]
        return [
            tuple(zip(names, combo))
            for combo in itertools.product(*choice_lists)
        ]

    def variants(self, grid: tuple[int, ...],
                 options: tuple[tuple[str, str], ...],
                 machine_shape: Sequence[int]) -> list[Candidate]:
        """Distribution x order variants of one grid, canonicalized so
        degenerate axes (factor 1 at either machine level) do not produce
        duplicate candidates."""
        k = len(grid)
        nf = node_split(machine_shape, grid)
        if nf is None:
            dist_combos = [(BLOCK_CYCLIC,) * k]
        else:
            gf = tuple(g // f for g, f in zip(grid, nf))
            per_axis = [
                (BLOCK_CYCLIC,) if nf[i] == 1 or gf[i] == 1
                else (BLOCK_CYCLIC, CYCLIC_BLOCK)
                for i in range(k)
            ]
            dist_combos = list(itertools.product(*per_axis))
        identity = tuple(range(k))
        orders = [identity]
        reverse = tuple(reversed(identity))
        # The reversed visit order is a distinct mapping whenever it
        # permutes the grid OR the node-factor split (a uniform grid can
        # still carry an asymmetric node split, e.g. (8, 8) over 2 nodes).
        distinct = grid != tuple(reversed(grid)) or (
            nf is not None and nf != tuple(reversed(nf))
        )
        if reverse != identity and distinct:
            orders.append(reverse)
        return [
            Candidate(grid=grid, dist=d, order=o, options=options)
            for d in dist_combos for o in orders
        ]

    def default_candidate(self, procs: int) -> Candidate | None:
        """The untuned baseline (the paper's Table 2 'default' mapper)."""
        grid: tuple[int, ...] | None = None
        if self.default_grid is not None:
            try:
                grid = tuple(int(g) for g in self.default_grid(procs))
            except ValueError:
                grid = None
        if grid is None:
            grids = self.grids(procs)
            if not grids:
                return None
            grid = grids[0]
        return Candidate(
            grid=grid,
            dist=(BLOCK_CYCLIC,) * len(grid),
            order=tuple(range(len(grid))),
            options=self.default_options,
        )


# ------------------------------------------------------------- DSL rendering
def standard_directives(task: str) -> str:
    """The default directive block (FBMEM placement, depth-2 backpressure)
    used when a search space declares no app-specific directives."""
    return f"Region {task} arg0 GPU FBMEM\nBackpressure {task} 2\n"


def render_source(task: str, program: CandidateProgram,
                  directives: str | None = None) -> str:
    """Render a candidate program as Mapple DSL source.

    The rendered program re-derives the same space through the DSL: the
    ``decompose`` calls pass the wanted factor tuples as iteration lengths
    (the solver's unique optimum for ``prod(lengths) == extent`` is the
    lengths themselves), and order variants render as explicit ``swap``
    chains. The tuner verifies the parsed source reproduces the winning
    permutation bit-for-bit.
    """
    cand = program.candidate
    k = len(cand.grid)

    def tup(vals: Sequence[int]) -> str:
        inner = ", ".join(str(v) for v in vals)
        return f"({inner},)" if len(vals) == 1 else f"({inner})"

    swaps = _unpermute_swaps(cand.order)
    lines = ["m = Machine(GPU)"]
    if program.hierarchical:
        nf, gf = program.node_factors, program.proc_factors
        perm_nf = tuple(nf[o] for o in cand.order)
        perm_gf = tuple(gf[o] for o in cand.order)
        mn = f"m.decompose(0, {tup(perm_nf)})"
        lines.append(f"mn = {mn}")
        mf = f"mn.decompose({k}, {tup(perm_gf)})"
        for p, q in swaps:
            mf += f".swap({p}, {q})"
        for p, q in swaps:
            mf += f".swap({k + p}, {k + q})"
        lines.append(f"mf = {mf}")
    else:
        expr = "m.merge(0, 1).decompose(0, {})".format(
            tup(tuple(cand.grid[o] for o in cand.order))
        )
        for p, q in swaps:
            expr += f".swap({p}, {q})"
        lines.append(f"mf = {expr}")
    lines.append("")
    lines.append(f"def {task}_tuned(Tuple ipoint, Tuple ispace):")
    returns = []
    if program.hierarchical:
        for i in range(k):
            n_prim, g_prim = (
                ("block_primitive", "cyclic_primitive")
                if cand.dist[i] == BLOCK_CYCLIC
                else ("cyclic_primitive", "block_primitive")
            )
            lines.append(
                f"    n{i} = {n_prim}(ipoint, ispace, mf.size, {i}, {i})"
            )
            lines.append(
                f"    g{i} = {g_prim}(ipoint, ispace, mf.size, {i}, {k + i})"
            )
        returns = [f"n{i}" for i in range(k)] + [f"g{i}" for i in range(k)]
    else:
        for i in range(k):
            lines.append(
                f"    i{i} = block_primitive(ipoint, ispace, mf.size, {i}, {i})"
            )
        returns = [f"i{i}" for i in range(k)]
    lines.append(f"    return mf[{', '.join(returns)}]")
    lines.append("")
    lines.append(f"IndexTaskMap {task} {task}_tuned")
    body = "\n".join(lines) + "\n"
    if directives is None:
        directives = standard_directives(task)
    return body + directives
