"""The mapper autotuner: cost-model-driven search over mapper IR programs.

Replaces the hand-coded ``(default, tuned)`` volume pairs of the Table 2
experiment with an actual search loop:

  1. enumerate every candidate grid x option combination of the app's
     :class:`~repro.search.space.SearchSpace` and score it analytically
     with the app's :class:`~repro.core.commvolume.CostModel`;
  2. prune to a beam of the lowest-volume survivors (volume dominates —
     distribution/order variants of a dominated grid can never win);
  3. expand the beam into distribution x order variants, materialize each
     as a mapping-IR program, and evaluate it through the vectorized
     ``Mapper.assignment_grid`` batch path (bijectivity + cross-node
     locality of nearest-neighbour hops), deduping placements that are
     isomorphic under per-level processor relabeling
     (``sim.batch.canonical_assignment`` — identical port loads can
     never rank differently); when the cost model is time-domain (it
     exposes ``price_assignments``), the surviving beam's *actual*
     placements are priced in one batched simulator call — the batch
     engine folds translation-symmetric schedule slabs to one
     representative per candidate and re-prices only the slabs a beam
     neighbor actually moved relative to its group's base candidate
     (``sim.batch.FOLD_STATS`` counts both), so the sweep stays cheap
     at 100k+ processors;
  4. rank by (placed seconds when simulated, else volume; then
     cross-node fraction) and render the winner back to Mapple DSL
     source, verifying the parsed source reproduces the winning
     permutation bit-for-bit.

The app's legacy ``tuning`` pair is treated as a *regression oracle*: the
tuner must rediscover (or beat) the hand-tuned volume; tests and the
Table 2 benchmark assert it, nothing trusts it as ground truth.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable

import numpy as np

from repro.core import dsl
from repro.core.machine import GPU, Machine
from repro.sim.batch import canonical_assignment
from repro.sim.price_cache import digest
from repro.search.pipeline import PriceJob, price_jobs, stream_priced
from repro.search.space import (
    BLOCK_CYCLIC,
    Candidate,
    CandidateProgram,
    SearchSpace,
    build_program,
    render_source,
)

DEFAULT_BEAM = 6          # lowest-volume (grid, options) pairs kept for eval
DEFAULT_LEADERBOARD = 12  # ranked candidates surfaced in the report


@dataclasses.dataclass
class ScoredCandidate:
    """One candidate with its analytic volume and (if evaluated through the
    batch path) its locality/bijectivity measurements."""

    candidate: Candidate
    volume: float
    evaluated: bool = False
    bijective: bool | None = None
    cross_node: float | None = None
    eval_path: str | None = None       # "vectorized" | "per-point"
    # Time-domain tuning only: the batched simulator's predicted seconds
    # for this variant's ACTUAL placement (Phase 1's `volume` slot holds
    # the grid's default-placement score).
    placed_cost: float | None = None

    @property
    def rank_cost(self) -> float:
        """What this candidate is ranked by: placed simulated seconds
        when the beam was batch-priced, the analytic score otherwise."""
        return self.volume if self.placed_cost is None else self.placed_cost

    def row(self) -> dict:
        return {
            "candidate": self.candidate.describe(),
            "grid": list(self.candidate.grid),
            "volume": self.volume,
            "evaluated": self.evaluated,
            "bijective": self.bijective,
            "cross_node": self.cross_node,
            "eval_path": self.eval_path,
            "placed_cost": self.placed_cost,
        }


@dataclasses.dataclass
class TuningReport:
    """The tuner's full result for one application at one scale."""

    app: str
    procs: int
    machine_shape: tuple[int, ...]
    candidates_considered: int       # grid x option points scored analytically
    variants_evaluated: int          # IR programs run through the batch path
    pruned: int                      # candidates dropped by the beam
    best: ScoredCandidate
    best_program: CandidateProgram
    best_source: str
    best_ir: str
    verified: bool                   # rendered DSL reproduces the permutation
    default: ScoredCandidate | None  # the untuned baseline, scored
    oracle: tuple[float, float] | None   # legacy (default, tuned) pair
    leaderboard: list[ScoredCandidate]
    elapsed_s: float
    note: str = ""
    #: Wall-clock of Phase 3 alone (variant expansion + placement
    #: pricing, producer/consumer or barrier) — the region ``pipeline``
    #: reshapes, and the one the pipeline benchmark compares.
    phase3_s: float = 0.0
    #: Warm-start seeds that actually joined the beam (0 for a cold
    #: search, or when every seed was already shortlisted — in which
    #: case the report is bit-identical to the cold one).
    warm_seeds: int = 0

    @property
    def oracle_ok(self) -> bool:
        """Regression check: search rediscovered (or beat) the hand-tuned
        volume, and reproduced the hand-coded default baseline exactly."""
        if self.oracle is None:
            return True
        v_def, v_tuned = self.oracle
        default_ok = self.default is None or self.default.volume == v_def
        return default_ok and self.best.volume <= v_tuned * (1 + 1e-9)

    def summary(self) -> dict:
        return {
            "app": self.app,
            "procs": self.procs,
            "machine": list(self.machine_shape),
            "candidates": self.candidates_considered,
            "evaluated": self.variants_evaluated,
            "pruned": self.pruned,
            "best": self.best.row(),
            "default": None if self.default is None else self.default.row(),
            "oracle": None if self.oracle is None else list(self.oracle),
            "oracle_ok": self.oracle_ok,
            "verified": self.verified,
            "best_ir": self.best_ir,
            "elapsed_s": self.elapsed_s,
            "phase3_s": self.phase3_s,
            "warm_seeds": self.warm_seeds,
            "note": self.note,
        }


def cross_node_fraction(node_grid: np.ndarray) -> float:
    """Fraction of nearest-neighbour hops (one hop per axis per tile, with
    wraparound — the shift/halo neighbour structure) crossing nodes."""
    total = cross = 0
    for axis in range(node_grid.ndim):
        if node_grid.shape[axis] == 1:
            continue
        rolled = np.roll(node_grid, -1, axis=axis)
        cross += int((rolled != node_grid).sum())
        total += node_grid.size
    return cross / total if total else 0.0


def _feasible_procs(space: SearchSpace, app, procs: int | None) -> tuple[int, str]:
    n = app.procs(procs)
    if space.grids(n):
        return n, ""
    note = f"procs {n} infeasible for {app.name}; using default {app.default_procs}"
    return app.default_procs, note


def feasible_procs(space: SearchSpace, n: int) -> bool:
    """True when at least one (grid, options) point of ``space`` prices at
    ``n`` processors — the exact Phase-1 feasibility test, so callers can
    validate a ``--procs`` request up front instead of failing deep
    inside the search."""
    grids = space.grids(n)
    if not grids:
        return False
    for options in space.option_combos():
        model = space.cost_model(n, dict(options))
        for grid in grids:
            try:
                float(model.cost(grid))
            except ValueError:
                continue
            return True
    return False


def nearest_feasible_procs(space: SearchSpace, n: int, *, count: int = 4,
                           max_delta: int = 4096) -> list[int]:
    """The ``count`` feasible processor counts nearest to ``n`` (within
    ``n ± max_delta``, nearest first) — the actionable half of the CLI's
    invalid ``--procs`` error."""
    found: list[int] = []
    for delta in range(1, max_delta + 1):
        for m in (n - delta, n + delta):
            if m >= 1 and feasible_procs(space, m):
                found.append(m)
        if len(found) >= count:
            break
    return found[:count]


def _admit_seed(space: SearchSpace, seed, n: int, grid_set: set,
                combos: set) -> tuple | None:
    """Validate one warm-start seed against the live search space.

    Returns the Phase-1 ``(volume, grid, options)`` entry the seed
    contributes, or ``None`` when the seed is stale or incompatible
    (wrong grid rank, infeasible grid, unknown option point, cost model
    rejection) — skipped, never fatal."""
    try:
        grid = tuple(int(g) for g in seed.grid)
        options = tuple((str(k), str(v)) for k, v in seed.options)
    except (AttributeError, TypeError, ValueError):
        return None
    if len(grid) != space.rank or grid not in grid_set:
        return None
    if options not in combos:
        return None
    try:
        volume = float(space.cost_model(n, dict(options)).cost(grid))
    except (ValueError, ArithmeticError):
        return None
    return (volume, grid, options)


def refit_candidate(space: SearchSpace, cand: Candidate,
                    procs: int) -> Candidate | None:
    """Re-instantiate a candidate from a *different* scale on the
    feasible grid of ``procs`` nearest in shape to its own (log-ratio
    distance per axis, ties lexicographic) — how the tuning service
    turns a cached winner from a nearby processor count into a
    ``warm_start`` seed. Distribution/order carry over when the rank
    matches; returns ``None`` when nothing feasible fits."""
    grids = space.grids(procs)
    if not grids:
        return None
    try:
        seed_grid = tuple(int(g) for g in cand.grid)
    except (TypeError, ValueError):
        return None
    if len(seed_grid) != space.rank or any(g < 1 for g in seed_grid):
        return None
    if seed_grid in grids:
        grid = seed_grid
    else:
        import math

        def dist(g: tuple[int, ...]) -> float:
            return sum((math.log(a) - math.log(b)) ** 2
                       for a, b in zip(g, seed_grid))

        grid = min(grids, key=lambda g: (dist(g), g))
    k = len(grid)
    d = tuple(cand.dist) if len(cand.dist) == k else (BLOCK_CYCLIC,) * k
    order = (tuple(cand.order) if sorted(cand.order) == list(range(k))
             else tuple(range(k)))
    return Candidate(grid=grid, dist=d, order=order, options=cand.options)


@dataclasses.dataclass
class PendingTune:
    """A tune split at the Phase-3 pricing boundary.

    ``prepare_tune`` runs Phases 1–2 (analytic scoring, beam pruning,
    warm-seed admission) and returns this handle; :meth:`jobs` is the
    Phase-3 expansion generator (consume exactly once — each yielded
    :class:`PriceJob` needs its ``placed_cost`` written, via
    ``price_jobs``/``stream_priced``); :meth:`finish` runs Phase 4 and
    builds the :class:`TuningReport`. ``tune_app`` composes the three
    inline; the tuning service (``repro.serving.mapsvc``) holds several
    PendingTunes open at once so their jobs price in shared
    cross-request ``price_stacks`` passes.
    """

    app: "object"
    space: SearchSpace
    n: int
    machine_shape: tuple[int, ...]
    scored: list
    shortlist: list
    pruned: int
    note: str
    leaderboard_n: int
    warm_seeds: int
    t0: float
    evaluated: list = dataclasses.field(default_factory=list)
    seen: dict = dataclasses.field(default_factory=dict)
    phase3_s: float = 0.0

    @property
    def prices_async(self) -> bool:
        """True when the cost model prices on the asynchronous-dispatch
        JAX engine — the case where streaming Phase 3 pays."""
        probe = self.space.cost_model(self.n, dict(self.shortlist[0][2]))
        return getattr(probe, "engine", None) == "batched-jax"

    def jobs(self):
        """Walk the shortlist, expand + dedupe variants, and yield one
        :class:`PriceJob` per beam entry whose placements a batch engine
        will price. Runs on the pipeline's producer thread (all mutation
        of ``seen``/``evaluated`` stays on this generator's thread; the
        consumer only writes ``placed_cost``). Models without a batch
        pricer fall back inline: the exact event engine prices here,
        volume models emit nothing and rank by locality alone."""
        space, n, machine_shape = self.space, self.n, self.machine_shape
        seen, evaluated, app = self.seen, self.evaluated, self.app
        for volume, grid, options in self.shortlist:
            survivors: list[tuple[ScoredCandidate, np.ndarray, bytes]] = []
            model = space.cost_model(n, dict(options))
            # A degraded machine (dead procs, non-uniform port contention)
            # breaks the per-level relabeling symmetry, so its dedup keys
            # and price-cache rows use the raw placement bytes instead of
            # the isomorphism-class representative.
            degraded = getattr(model, "degraded", None)
            for cand in space.variants(grid, options, machine_shape):
                program = build_program(machine_shape, cand,
                                        f"{app.name}_cand")
                assign = program.mapper.assignment_grid(cand.grid,
                                                        use_cache=False)
                # Dedupe same-(grid, options) variants whose placements
                # are isomorphic under per-level processor relabeling —
                # identical port loads, so identical volume, time and
                # locality; distinct option points stay on the
                # leaderboard even when their permutations coincide
                # (their volumes differ).
                if degraded is not None:
                    canon = np.asarray(assign, dtype=np.int64).tobytes()
                else:
                    canon = canonical_assignment(assign,
                                                 machine_shape).tobytes()
                key = (cand.grid, cand.options, canon)
                twin = seen.get(key)
                if twin is not None:  # isomorphic variant already seen
                    # Isomorphs tie on every ranking key, so keep the
                    # describe()-minimal one as the class representative
                    # — the winner the pre-dedup sort would have picked,
                    # independent of enumeration order.
                    if cand.describe() < twin.candidate.describe():
                        twin.candidate = cand
                    continue
                flat = assign.reshape(-1)
                bijective = flat.size == n and len(np.unique(flat)) == n
                node_grid = assign // machine_shape[1]
                entry = ScoredCandidate(
                    candidate=cand,
                    volume=volume,
                    evaluated=True,
                    bijective=bijective,
                    cross_node=cross_node_fraction(node_grid),
                    eval_path=program.mapper.last_eval_path,
                )
                seen[key] = entry
                evaluated.append(entry)
                if bijective:
                    survivors.append((entry, np.asarray(assign), canon))
            # Time-domain models price the surviving beam's ACTUAL
            # placements through the batch engine; volume models keep
            # ranking variants by locality alone.
            if not survivors:
                continue
            engine = getattr(model, "beam_pricer", lambda g: None)(grid)
            stack = np.stack([a for _, a, _ in survivors])
            entries = [e for e, _, _ in survivors]
            if engine is not None:
                cache = getattr(model, "cache", None)
                table = rows = None
                if cache is not None:
                    # Row digests reuse the dedup pass's canonical
                    # bytes — the cache key costs nothing extra here.
                    table = model.price_table_key(grid)
                    rows = [digest(c) for _, _, c in survivors]
                yield PriceJob(engine=engine, stack=stack, entries=entries,
                               table=table, rows=rows, cache=cache)
            elif hasattr(model, "price_assignments"):
                # Per-group fallback (e.g. the exact event engine).
                for entry, t in zip(entries,
                                    model.price_assignments(grid, stack)):
                    entry.placed_cost = float(t)

    def finish(self) -> TuningReport:
        """Phase 4: rank the evaluated variants, render the winner back
        to Mapple DSL source, verify the parse round-trip, score the
        untuned default and the legacy oracle, and assemble the report.
        Call only after every job from :meth:`jobs` has had its
        ``placed_cost`` written."""
        app, space = self.app, self.space
        n, machine_shape = self.n, self.machine_shape
        ranked = sorted(
            (s for s in self.evaluated if s.bijective),
            key=lambda s: (s.rank_cost, s.cross_node,
                           s.candidate.describe()),
        )
        if not ranked:
            raise ValueError(
                f"no bijective candidate survived for {app.name} at {n} procs"
            )
        best = ranked[0]

        best_program = build_program(machine_shape, best.candidate,
                                     f"{app.name}_tuned")
        directives = None
        if space.directives is not None:
            directives = space.directives(app.name, best.candidate.opts)
        source = render_source(app.name, best_program, directives)
        parsed = dsl.parse(
            source,
            machine_factory=lambda *a, **k: Machine(GPU, shape=machine_shape),
        )
        parsed_mapper = parsed.mappers[parsed.index_task_maps[app.name]]
        verified = bool(np.array_equal(
            parsed_mapper.assignment_grid(best.candidate.grid,
                                          use_cache=False),
            best_program.mapper.assignment_grid(best.candidate.grid),
        ))

        default_scored: ScoredCandidate | None = None
        default_cand = space.default_candidate(n)
        if default_cand is not None:
            model = space.cost_model(n, default_cand.opts)
            try:
                default_scored = ScoredCandidate(
                    candidate=default_cand,
                    volume=float(model.cost(default_cand.grid)),
                )
            except ValueError:
                default_scored = None

        oracle: tuple[float, float] | None = None
        if app.tuning is not None:
            try:
                oracle = tuple(app.tuning(n))  # type: ignore[assignment]
            except ValueError:
                oracle = None

        return TuningReport(
            app=app.name,
            procs=n,
            machine_shape=machine_shape,
            candidates_considered=len(self.scored),
            variants_evaluated=len(self.evaluated),
            pruned=self.pruned,
            best=best,
            best_program=best_program,
            best_source=source,
            best_ir=best_program.space.describe(),
            verified=verified,
            default=default_scored,
            oracle=oracle,
            leaderboard=ranked[:self.leaderboard_n],
            elapsed_s=time.perf_counter() - self.t0,
            phase3_s=self.phase3_s,
            note=self.note,
            warm_seeds=self.warm_seeds,
        )


def prepare_tune(app, procs: int | None = None, *, beam: int = DEFAULT_BEAM,
                 leaderboard: int = DEFAULT_LEADERBOARD,
                 warm_start: Iterable[Candidate] = (),
                 restrict: Iterable[Candidate] | None = None) -> PendingTune:
    """Phases 1–2 of :func:`tune_app`, returned as a :class:`PendingTune`.

    ``warm_start`` seeds (cached winners from a nearby scale, refit via
    :func:`refit_candidate`) join the beam *in addition to* the
    lowest-volume shortlist — a superset of the cold search space, so a
    warm search can never rank worse than the cold one, and when every
    seed is already shortlisted the report is bit-identical to cold
    (``warm_seeds == 0``). Stale or incompatible seeds are skipped.

    ``restrict`` turns Phase 1 into a *seeded* scan: only the given
    candidates' (grid, options) points (plus the space's default
    candidate as a safety net) are scored, instead of the full
    combos × grids enumeration. This is the fast path for failure
    remaps, where a known-good plan exists and scoring thousands of
    analytic points — each a device pricing for time-domain spaces —
    would dominate recovery latency. Falls back to the full enumeration
    when every restricted point is stale or infeasible.
    """
    space: SearchSpace | None = app.search_space
    if space is None:
        raise ValueError(f"application {app.name!r} declares no search space")
    t0 = time.perf_counter()
    n, note = _feasible_procs(space, app, procs)
    machine_shape = tuple(int(s) for s in app.machine_shape(n))

    # Phase 1: analytic scoring of every (grid, options) point — or, under
    # ``restrict``, of just the seeded points.
    grids = space.grids(n)
    scored: list[tuple[float, tuple[int, ...], tuple[tuple[str, str], ...]]] = []
    if restrict is not None:
        combo_set = set(space.option_combos())
        grid_set = set(grids)
        wanted = list(restrict)
        default_cand = space.default_candidate(n)
        if default_cand is not None:
            wanted.append(default_cand)
        seen_points: set[tuple] = set()
        for cand in wanted:
            entry = _admit_seed(space, cand, n, grid_set, combo_set)
            if entry is None or (entry[1], entry[2]) in seen_points:
                continue
            seen_points.add((entry[1], entry[2]))
            scored.append(entry)
        if scored:
            extra = (f"restricted search: {len(scored)} seeded point(s) "
                     f"scored in place of the full enumeration")
            note = f"{note}; {extra}" if note else extra
    if not scored:
        for options in space.option_combos():
            model = space.cost_model(n, dict(options))
            for grid in grids:
                try:
                    volume = float(model.cost(grid))
                except ValueError:
                    continue
                scored.append((volume, grid, options))
    if not scored:
        near = nearest_feasible_procs(space, n, max_delta=256)
        hint = f"; nearest feasible proc counts: {near}" if near else ""
        raise ValueError(
            f"no feasible candidate for {app.name} at {n} procs{hint}")
    scored.sort()

    # Phase 2: beam prune — a grid whose volume is dominated can never win,
    # since distribution/order variants only change locality, not volume.
    shortlist = list(scored[:max(beam, 1)])
    pruned = len(scored) - len(shortlist)

    # Warm-start admission: each seed that survives validation appends
    # its (grid, options) group to the shortlist unless Phase 2 kept it
    # already — strictly widening the beam, never replacing it.
    warm_admitted = 0
    seeds = list(warm_start)
    if seeds:
        combos = set(space.option_combos())
        grid_set = set(grids)
        have = {(g, o) for _, g, o in shortlist}
        for seed in seeds:
            entry = _admit_seed(space, seed, n, grid_set, combos)
            if entry is None or (entry[1], entry[2]) in have:
                continue
            have.add((entry[1], entry[2]))
            shortlist.append(entry)
            warm_admitted += 1
        if warm_admitted:
            extra = f"warm-start: {warm_admitted}/{len(seeds)} seeds joined the beam"
            note = f"{note}; {extra}" if note else extra

    return PendingTune(
        app=app,
        space=space,
        n=n,
        machine_shape=machine_shape,
        scored=scored,
        shortlist=shortlist,
        pruned=pruned,
        note=note,
        leaderboard_n=leaderboard,
        warm_seeds=warm_admitted,
        t0=t0,
    )


def tune_app(app, procs: int | None = None, *, beam: int = DEFAULT_BEAM,
             leaderboard: int = DEFAULT_LEADERBOARD,
             pipeline: bool | None = None,
             warm_start: Iterable[Candidate] = (),
             restrict: Iterable[Candidate] | None = None) -> TuningReport:
    """Search one application's mapper space; returns the full report.

    ``pipeline`` controls Phase 3's execution shape: ``True`` streams
    expansion and pricing through ``repro.search.pipeline`` (host
    expands group k+1 while the device prices group k), ``False`` keeps
    the strict barrier (expand everything, then one packed pricing
    sweep), ``None`` (default) picks the pipeline exactly when the cost
    model prices on the asynchronous-dispatch JAX engine — the host
    NumPy engine gains more from the barrier path's cross-group packing
    than from overlap. Both shapes produce bit-identical reports.

    ``warm_start`` seeds (e.g. cached winners from a nearby scale) widen
    the beam per :func:`prepare_tune` — results are never worse than the
    cold search, and bit-identical to it when no seed is novel.
    """
    pending = prepare_tune(app, procs, beam=beam, leaderboard=leaderboard,
                           warm_start=warm_start, restrict=restrict)

    # Phase 3: variant expansion + batch pricing — as a producer/consumer
    # pipeline (expansion of group k+1 overlaps device pricing of group
    # k) or as the legacy barrier, per ``pipeline``; identical numbers
    # either way.
    if pipeline is None:
        pipeline = pending.prices_async
    t3 = time.perf_counter()
    if pipeline:
        for job, times in stream_priced(pending.jobs()):
            for entry, t in zip(job.entries, times):
                entry.placed_cost = float(t)
    else:
        # All shortlisted grids x options in one candidates x phases
        # x ports pricing sweep, cache hits excluded up front.
        price_jobs(list(pending.jobs()))
    pending.phase3_s = time.perf_counter() - t3
    return pending.finish()


def tune_registry(applications: Iterable, procs: int | None = None,
                  *, beam: int = DEFAULT_BEAM,
                  pipeline: bool | None = None) -> list[TuningReport]:
    """Tune every application that declares a search space."""
    return [
        tune_app(app, procs, beam=beam, pipeline=pipeline)
        for app in applications
        if getattr(app, "search_space", None) is not None
    ]


def report_lines(report: TuningReport) -> list[str]:
    """Human-readable leaderboard + winner block for the --tune CLI."""
    lines = [
        f"[{report.app}] procs={report.procs} "
        f"machine={report.machine_shape[0]}x{report.machine_shape[1]} "
        f"candidates={report.candidates_considered} "
        f"evaluated={report.variants_evaluated} pruned={report.pruned} "
        f"({report.elapsed_s * 1e3:.1f} ms)"
        + (f"  {report.note}" if report.note else "")
    ]
    timed = any(s.placed_cost is not None for s in report.leaderboard)
    placed_hdr = f" {'placed_s':>10s}" if timed else ""
    lines.append(
        f"  {'candidate':32s} {'volume':>12s}{placed_hdr} "
        f"{'xnode':>6s} {'bij':>4s}"
    )
    for s in report.leaderboard:
        xnode = f"{s.cross_node:6.2f}" if s.cross_node is not None else "     -"
        placed = ""
        if timed:
            placed = (f" {s.placed_cost:10.3e}" if s.placed_cost is not None
                      else f" {'-':>10s}")
        lines.append(
            f"  {s.candidate.describe():32s} {s.volume:12.4g}{placed} {xnode} "
            f"{str(bool(s.bijective)):>4s}"
        )
    if report.default is not None:
        ratio = report.default.volume / max(report.best.volume, 1e-12)
        lines.append(
            f"  default {report.default.candidate.describe()} "
            f"volume={report.default.volume:.4g} "
            f"-> best ratio {ratio:.2f}x"
        )
    if report.oracle is not None:
        lines.append(
            f"  oracle (default, tuned)=({report.oracle[0]:.4g}, "
            f"{report.oracle[1]:.4g}) rediscovered={report.oracle_ok}"
        )
    lines.append(f"  best mapper IR: {report.best_ir}")
    lines.append(f"  dsl-verified: {report.verified}")
    lines.append("  best Mapple program:")
    lines.extend(f"    {ln}" for ln in report.best_source.rstrip().splitlines())
    return lines


__all__ = [
    "DEFAULT_BEAM",
    "PendingTune",
    "ScoredCandidate",
    "TuningReport",
    "cross_node_fraction",
    "feasible_procs",
    "nearest_feasible_procs",
    "prepare_tune",
    "refit_candidate",
    "report_lines",
    "tune_app",
    "tune_registry",
]
