"""Streaming Phase 3: overlap host candidate expansion with device pricing.

The tuner's Phase 3 has two halves with disjoint resources. Expanding a
beam entry into distribution x order variants — building the mapper IR
program, evaluating ``assignment_grid``, canonicalizing and deduping —
is host/NumPy work; pricing the surviving placements is (under the
``batched-jax`` engine) a compiled XLA program. Run as a barrier, each
half idles while the other works. This module runs them as a pipeline:

* a **producer thread** walks the expansion generator and feeds finished
  :class:`PriceJob` groups into a bounded queue (the bound is the
  backpressure: the producer can lead the consumer by at most
  ``queue_size`` groups, so peak memory stays flat no matter how fast
  expansion runs);
* the **consumer** (the caller's thread, via :func:`stream_priced`)
  pulls each group, resolves persistent price-cache hits, dispatches the
  misses with ``engine.step_times_async`` — JAX returns the instant the
  program is enqueued — and only blocks on a group's ``result()`` once
  ``in_flight`` newer groups are already queued behind it on the device
  (double buffering). Host expansion of group ``k+1`` therefore runs
  concurrently with device pricing of group ``k``.

The pipeline reorders *work*, never arithmetic: each group prices from
its own endpoint arrays into independent buckets, bit-identical to the
barrier path's packed sweep (``tests/test_pipeline.py`` holds the two
paths to ``==`` across the registry). Exceptions on either side cancel
the other and re-raise in the caller; closing the result generator
early unwinds the producer cleanly.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Iterable, Iterator, Sequence

import numpy as np

from repro.sim.batch import price_stacks
from repro.sim.price_cache import PriceCache

#: Producer lead bound (groups buffered between the threads).
DEFAULT_QUEUE_SIZE = 4

#: Dispatched-but-unmaterialized groups the consumer keeps on the device
#: before blocking on the oldest — 2 = classic double buffering.
DEFAULT_IN_FLIGHT = 2

_DONE = object()


@dataclasses.dataclass
class PriceJob:
    """One pricing group: a stack of bijective placements of one
    (grid, options) beam entry, plus per-row payloads and (optionally)
    the persistent-cache coordinates of every row.

    ``entries`` is opaque to the pipeline — the tuner passes its
    ``ScoredCandidate`` objects and writes ``placed_cost`` on yield.
    ``table``/``rows`` are the price-cache digests (table = everything
    but the placement, row = the canonical placement); ``None`` disables
    caching for the job.
    """

    engine: Any
    stack: np.ndarray
    entries: list
    table: bytes | None = None
    rows: Sequence[bytes] | None = None
    cache: PriceCache | None = None

    def split_cached(self) -> tuple[np.ndarray, list[int]]:
        """Look every row up in the persistent cache. Returns
        ``(times, miss_idx)``: ``times`` holds the hit values (misses
        NaN until priced), ``miss_idx`` the row indices that must price
        live. Without a cache every row is a miss."""
        times = np.full(len(self.entries), np.nan, dtype=np.float64)
        if self.cache is None or self.table is None or self.rows is None:
            return times, list(range(len(self.entries)))
        miss_idx = []
        for i, row in enumerate(self.rows):
            value = self.cache.get(self.table, row)
            if value is None:
                miss_idx.append(i)
            else:
                times[i] = value
        return times, miss_idx

    def store(self, miss_idx: Sequence[int], values: np.ndarray) -> None:
        """Persist freshly priced rows (one append per group)."""
        if self.cache is None or self.table is None or self.rows is None:
            return
        self.cache.put_many(
            self.table,
            [(self.rows[i], float(v)) for i, v in zip(miss_idx, values)],
        )


def _merge(job: PriceJob, times: np.ndarray, miss_idx: list[int],
           values: np.ndarray) -> np.ndarray:
    if miss_idx:
        times[np.asarray(miss_idx, dtype=np.intp)] = values
        job.store(miss_idx, values)
    return times


def price_job(job: PriceJob, *, fold: bool = True,
              incremental: bool = True) -> np.ndarray:
    """One group priced synchronously (cache consulted, misses priced,
    results persisted) — the building block the streaming consumer
    defers; also used directly by the tuner's barrier path for groups
    whose engine prices independently."""
    times, miss_idx = job.split_cached()
    if miss_idx:
        values = np.asarray(job.engine.step_times(
            job.stack[np.asarray(miss_idx, dtype=np.intp)],
            fold=fold, incremental=incremental))
    else:
        values = np.empty(0, dtype=np.float64)
    return _merge(job, times, miss_idx, values)


def price_jobs(jobs: Sequence[PriceJob], *, fold: bool = True,
               incremental: bool = True) -> list[np.ndarray]:
    """Price many groups in as few shared congestion passes as possible
    — the tuner's barrier Phase 3, and the tuning service's
    cross-request batching primitive (jobs from *different* requests
    pack into the same :func:`~repro.sim.batch.price_stacks` sweeps, so
    compatible queued requests share device passes).

    Persistent-cache hits are excluded up front and fresh prices are
    written back per group. Each job's ``entries`` get their
    ``placed_cost`` attribute written; the merged per-group times are
    also returned in job order.
    """
    if not jobs:
        return []
    splits = [job.split_cached() for job in jobs]
    priced = price_stacks(
        [(job.engine, job.stack[np.asarray(miss, dtype=np.intp)])
         for job, (_, miss) in zip(jobs, splits)],
        fold=fold, incremental=incremental,
    )
    out = []
    for job, (times, miss), values in zip(jobs, splits, priced):
        times = _merge(job, times, miss, np.asarray(values))
        for entry, t in zip(job.entries, times):
            entry.placed_cost = float(t)
        out.append(times)
    return out


def _produce(jobs: Iterable[PriceJob], out: "queue.Queue",
             stop: threading.Event) -> None:
    """Producer body: drain the expansion generator into the bounded
    queue, forwarding an exception (or exhaustion) as the final item.
    The timeout loop keeps the thread responsive to consumer-side
    cancellation even while the queue is full."""
    try:
        for job in jobs:
            while not stop.is_set():
                try:
                    out.put(job, timeout=0.05)
                    break
                except queue.Full:
                    continue
            if stop.is_set():
                return
        item: Any = _DONE
    except BaseException as exc:  # noqa: BLE001 - re-raised by consumer
        item = exc
    while not stop.is_set():
        try:
            out.put(item, timeout=0.05)
            return
        except queue.Full:
            continue


def stream_priced(jobs: Iterable[PriceJob], *,
                  queue_size: int = DEFAULT_QUEUE_SIZE,
                  in_flight: int = DEFAULT_IN_FLIGHT,
                  fold: bool = True, incremental: bool = True
                  ) -> Iterator[tuple[PriceJob, np.ndarray]]:
    """Yield ``(job, step_times)`` per group, producer/consumer style.

    ``jobs`` (typically the tuner's expansion generator) runs on a
    worker thread; this generator dispatches each arriving group
    asynchronously and yields groups in FIFO order, blocking on a
    group's device result only once ``in_flight`` newer dispatches are
    queued behind it. Values are identical to pricing each job with
    :func:`price_job` — only the waiting overlaps.
    """
    if queue_size < 1:
        raise ValueError(f"queue_size must be >= 1, got {queue_size}")
    if in_flight < 1:
        raise ValueError(f"in_flight must be >= 1, got {in_flight}")
    buf: "queue.Queue" = queue.Queue(maxsize=queue_size)
    stop = threading.Event()
    worker = threading.Thread(
        target=_produce, args=(jobs, buf, stop),
        name="tuner-phase3-producer", daemon=True,
    )
    worker.start()
    pending: list[tuple[PriceJob, Any, np.ndarray, list[int]]] = []

    def materialize(slot) -> tuple[PriceJob, np.ndarray]:
        job, handle, times, miss_idx = slot
        values = (np.asarray(handle.result()) if handle is not None
                  else np.empty(0, dtype=np.float64))
        return job, _merge(job, times, miss_idx, values)

    try:
        while True:
            item = buf.get()
            if item is _DONE:
                break
            if isinstance(item, BaseException):
                raise item
            job = item
            times, miss_idx = job.split_cached()
            handle = None
            if miss_idx:
                handle = job.engine.step_times_async(
                    job.stack[np.asarray(miss_idx, dtype=np.intp)],
                    fold=fold, incremental=incremental)
            pending.append((job, handle, times, miss_idx))
            if len(pending) > in_flight:
                yield materialize(pending.pop(0))
        for slot in pending:
            yield materialize(slot)
        pending = []
    finally:
        stop.set()
        worker.join(timeout=5.0)


__all__ = [
    "DEFAULT_IN_FLIGHT",
    "DEFAULT_QUEUE_SIZE",
    "PriceJob",
    "price_job",
    "price_jobs",
    "stream_priced",
]
