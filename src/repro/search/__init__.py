"""Mapper autotuner: cost-model-driven search over mapper IR programs.

``repro.search.space`` enumerates candidate mapper programs (grid
factorizations x distribution choices x transform orderings, as PR-2
mapping IR); ``repro.search.tuner`` scores them with the unified
:class:`~repro.core.commvolume.CostModel` objectives, prunes with a beam,
evaluates survivors through the vectorized ``assignment_grid`` batch
path, and reports the winning Mapple program. See docs/tuning.md.
"""
from repro.search.space import (
    BLOCK_CYCLIC,
    CYCLIC_BLOCK,
    Candidate,
    CandidateProgram,
    SearchSpace,
    build_program,
    node_split,
    render_source,
)
from repro.search.tuner import (
    ScoredCandidate,
    TuningReport,
    cross_node_fraction,
    report_lines,
    tune_app,
    tune_registry,
)
from repro.search.remap import (
    RemapResult,
    degraded_from_failures,
    remap_plan,
    submachine_options,
)

__all__ = [
    "BLOCK_CYCLIC",
    "CYCLIC_BLOCK",
    "Candidate",
    "CandidateProgram",
    "RemapResult",
    "SearchSpace",
    "ScoredCandidate",
    "TuningReport",
    "build_program",
    "degraded_from_failures",
    "remap_plan",
    "submachine_options",
    "cross_node_fraction",
    "node_split",
    "render_source",
    "report_lines",
    "tune_app",
    "tune_registry",
]
