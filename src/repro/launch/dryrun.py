import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the 16x16
single-pod mesh and the 2x16x16 multi-pod mesh must compile for every
assigned architecture and input shape, and the compiled artifacts yield
the memory/cost/collective numbers EXPERIMENTS.md reports.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json
"""
import argparse
import json
import time
import traceback
from pathlib import Path


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             mode: str | None = None, seq_shard: bool = True,
             verbose: bool = True, knobs=None) -> dict:
    import jax

    from repro.configs import get_config
    from repro.launch import hlo_cost, roofline
    from repro.launch import knobs as knobs_mod
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import runnable
    from repro.launch.steps import make_cell
    from repro.models.config import SHAPES

    if knobs is None:
        knobs = knobs_mod.Knobs()

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = runnable(cfg, shape)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "skipped" if not ok else "pending",
    }
    if not ok:
        record["reason"] = why
        if verbose:
            print(f"[skip] {arch} x {shape_name} x {mesh_name}: {why}")
        return record

    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        with mesh, knobs_mod.apply(knobs):
            cell = make_cell(arch, cfg, shape, mesh, mode=mode,
                             seq_shard=seq_shard)
            lowered = cell.lower()
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost_list = compiled.cost_analysis()
            xla_cost = cost_list if isinstance(cost_list, dict) else (
                cost_list[0] if cost_list else {}
            )
            hlo = compiled.as_text()
        # Loop-aware recount (XLA's cost_analysis counts while bodies once).
        costs = hlo_cost.analyze(hlo)
        cost = {"flops": costs.flops, "bytes accessed": costs.bytes}
        rt = roofline.terms(
            arch, shape, cfg, mesh_name, n_chips, cost, costs.collective_bytes
        )
        record.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            n_chips=n_chips,
            memory_analysis=_mem_dict(mem),
            flops=rt.hlo_flops,
            bytes_accessed=rt.hlo_bytes,
            collective_bytes=costs.collective_bytes,
            collectives={"bytes": costs.collective_by_kind},
            xla_cost_analysis={
                "flops": float(xla_cost.get("flops", 0.0)),
                "bytes accessed": float(xla_cost.get("bytes accessed", 0.0)),
            },
            roofline={
                "compute_s": rt.compute_s,
                "memory_s": rt.memory_s,
                "collective_s": rt.collective_s,
                "bottleneck": rt.bottleneck,
                "model_flops": rt.model_flops,
                "useful_flops_ratio": rt.flops_ratio,
            },
            sharding_mode=cell.plan.mode,
        )
        if verbose:
            print(f"[ok]   {arch} x {shape_name} x {mesh_name} "
                  f"({record['compile_s']}s, mode={cell.plan.mode})")
            print(f"       memory: {record['memory_analysis']}")
            print(f"       cost: flops={rt.hlo_flops:.3e} "
                  f"bytes={rt.hlo_bytes:.3e} "
                  f"coll={costs.collective_bytes / 2**20:.1f}MiB")
            print(f"       roofline: compute={rt.compute_s:.3e}s "
                  f"memory={rt.memory_s:.3e}s coll={rt.collective_s:.3e}s "
                  f"-> {rt.bottleneck}-bound, useful={rt.flops_ratio:.2f}")
    except Exception as e:  # noqa: BLE001 - report, continue the sweep
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[ERR]  {arch} x {shape_name} x {mesh_name}: {e}")
    return record


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(mem)[:500]
    return out


def main() -> None:
    from repro.configs import ARCH_IDS
    from repro.models.config import SHAPES

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (see repro.configs)")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all' (train_4k, prefill_32k, "
                         "decode_32k, long_500k)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--mode", default=None, choices=[None, "tp", "fsdp"],
                    help="override the sharding-policy mode")
    ap.add_argument("--no-seq-shard", action="store_true",
                    help="disable sequence-parallel residual sharding")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful baseline knobs (scan WKV, no "
                         "shard_map SP attention, no microbatching)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    from repro.launch.knobs import Knobs

    knobs = (
        Knobs(wkv_impl="scan", sp_attention=False, microbatch=1)
        if args.baseline else Knobs(wkv_impl="chunked")
    )

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    records = []
    t0 = time.time()
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                records.append(
                    run_cell(arch, shape, mesh_name, mode=args.mode,
                             seq_shard=not args.no_seq_shard, knobs=knobs)
                )
    ok = sum(r["status"] == "ok" for r in records)
    skip = sum(r["status"] == "skipped" for r in records)
    err = sum(r["status"] == "error" for r in records)
    print(f"\n=== dry-run: {ok} ok, {skip} skipped, {err} errors, "
          f"{time.time() - t0:.0f}s total ===")
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(records, indent=1))
        print(f"wrote {args.out}")
    if err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
