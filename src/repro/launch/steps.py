"""Step-function assembly for the dry-run and the real launchers.

For every (arch x shape) cell this produces:
  * the step callable (train_step / serve_step / prefill_step),
  * abstract arguments (ShapeDtypeStructs — nothing allocated),
  * in/out shardings pinned to the production mesh via the policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.launch import policy as policy_mod
from repro.launch import specs as specs_mod
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.registry import build
from repro.training import optimizer as opt_mod
from repro.training.loop import TrainState


@dataclasses.dataclass
class Cell:
    arch: str
    cfg: ModelConfig
    shape: ShapeConfig
    step_fn: Callable
    abstract_args: tuple
    in_shardings: Any
    out_shardings: Any
    plan: policy_mod.ShardingPlan

    def lower(self):
        # Donation (the paper's GarbageCollect directive translated):
        # train donates the whole state; decode donates the cache.
        donate = ()
        if self.shape.kind == "train":
            donate = (0,)
        elif self.shape.kind == "decode":
            donate = (1,)
        jitted = jax.jit(
            self.step_fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=donate,
        )
        return jitted.lower(*self.abstract_args)


def choose_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Smallest accumulation factor whose live activation estimate fits.

    Estimate per device: saved residuals (seq-sharded when SP is on) +
    the cross-entropy logits block (vocab-sharded).
    """
    import math

    from repro.launch.knobs import active

    if active().microbatch:
        return active().microbatch
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    model_size = mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") \
        else dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    b_dev = max(shape.global_batch // max(dp, 1), 1)
    sp = 16 if shape.seq_len % 16 == 0 else 1
    budget = 4.5e9
    for n in (1, 2, 4, 8, 16):
        if shape.global_batch % (dp * n):
            continue
        bd = b_dev / n
        resid = cfg.n_layers * bd * shape.seq_len * cfg.d_model * 2 / sp
        logits = bd * shape.seq_len * cfg.padded_vocab * 6 / max(model_size, 1)
        moe = 0.0
        if cfg.n_experts:
            # dispatch/recv/expert-act stashes per MoE layer (backward)
            n_moe = cfg.n_layers - cfg.first_dense_layers
            moe = 3.0 * n_moe * bd * shape.seq_len * cfg.topk \
                * cfg.d_model * 2 / max(model_size, 1)
        if resid + logits + moe < budget:
            return n
    return 16 if shape.global_batch % (dp * 16) == 0 else 1


def _abstract_state(model, opt_cfg) -> TrainState:
    params = model.abstract()
    zeros_like = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t
    )
    return TrainState(
        params=params,
        opt=opt_mod.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=zeros_like(params),
            nu=zeros_like(params),
        ),
        error=None,
    )


def make_cell(arch: str, cfg: ModelConfig, shape: ShapeConfig, mesh,
              *, mode: str | None = None, use_pallas: bool = False,
              seq_shard: bool = True) -> Cell:
    """Build the lowering cell for one (arch x shape) on ``mesh``."""
    import math

    from repro.models import sharding as act_sharding

    model = build(cfg)
    plan = policy_mod.make_plan(cfg, mesh, mode)
    act_sharding.set_sequence_sharding(
        "model" if (seq_shard and shape.kind in ("train", "prefill")
                    and shape.seq_len % 16 == 0) else None
    )
    # FSDP: pin the per-layer weight all-gather inside the scan body so
    # only one layer's gathered weights are live (see models/sharding.py).
    act_sharding.set_layer_barrier(plan.mode == "fsdp")
    # MoE dispatch groups = data shards (tokens-per-step permitting).
    dp_total = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp_total *= mesh.shape[ax]
    tokens_per_step = shape.global_batch * (
        1 if shape.is_decode else shape.seq_len
    )
    act_sharding.set_moe_groups(math.gcd(dp_total, tokens_per_step))

    if shape.kind == "train":
        opt_cfg = opt_mod.AdamWConfig(total_steps=10000)
        n_micro = choose_microbatches(cfg, shape, mesh)

        def train_step(state: TrainState, batch):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(
                    lambda p: model.loss(p, batch, use_pallas=use_pallas)
                )(state.params)
            else:
                # Gradient accumulation: scan over microbatches bounds the
                # live activation set to one microbatch's.
                micro = jax.tree.map(
                    lambda x: x.reshape(
                        (n_micro, x.shape[0] // n_micro) + x.shape[1:]
                    ),
                    batch,
                )
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), state.params
                )

                def micro_step(carry, mb):
                    acc_loss, acc_g = carry
                    loss, g = jax.value_and_grad(
                        lambda p: model.loss(p, mb, use_pallas=use_pallas)
                    )(state.params)
                    acc_g = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32) / n_micro,
                        acc_g, g,
                    )
                    return (acc_loss + loss / n_micro, acc_g), None

                (loss, grads), _ = jax.lax.scan(
                    micro_step, (0.0, zeros), micro
                )
            params, opt_state, metrics = opt_mod.update(
                opt_cfg, grads, state.opt, state.params
            )
            return TrainState(params, opt_state, None), {
                "loss": loss, **metrics,
            }

        state_abs = _abstract_state(model, opt_cfg)
        batch_abs = specs_mod.batch_specs(cfg, shape)
        p_sh = plan.params(model.schema)
        state_sh = TrainState(
            params=p_sh,
            opt=opt_mod.AdamWState(
                step=plan.replicated(),
                mu=plan.opt_moments(model.schema),
                nu=plan.opt_moments(model.schema),
            ),
            error=None,
        )
        batch_sh = plan.batch_like(batch_abs)
        metrics_sh = {
            "loss": plan.replicated(), "grad_norm": plan.replicated(),
            "lr": plan.replicated(),
        }
        return Cell(
            arch, cfg, shape, train_step, (state_abs, batch_abs),
            (state_sh, batch_sh), (state_sh, metrics_sh), plan,
        )

    if shape.kind == "prefill":

        def prefill_step(params, inputs):
            return model.last_logits(params, inputs, use_pallas=use_pallas)

        params_abs = model.abstract()
        in_abs = specs_mod.prefill_specs(cfg, shape)
        p_sh = plan.params(model.schema)
        in_sh = plan.batch_like(in_abs)
        out_sh = plan.replicated()
        return Cell(
            arch, cfg, shape, prefill_step,
            (params_abs, in_abs["inputs"]), (p_sh, in_sh["inputs"]), out_sh,
            plan,
        )

    # decode (decode_32k / long_500k)
    def serve_step(params, cache, pos, token):
        return model.decode_step(params, cache, pos, token)

    params_abs = model.abstract()
    d = specs_mod.decode_specs(cfg, shape)
    p_sh = plan.params(model.schema)
    cache_sh = plan.cache(d["cache"])
    tok_sh = plan.batch_like({"t": d["token"]})["t"]
    logits_sh = plan.batch_like({"l": jax.ShapeDtypeStruct((shape.global_batch,
                                                            1), jnp.float32)})["l"]
    return Cell(
        arch, cfg, shape, serve_step,
        (params_abs, d["cache"], d["pos"], d["token"]),
        (p_sh, cache_sh, plan.replicated(), tok_sh),
        (logits_sh, cache_sh),
        plan,
    )
