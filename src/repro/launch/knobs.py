"""Hillclimb knobs: named optimization levers the §Perf loop toggles.

Each knob is applied before a cell is lowered and reset after, so the
same process can A/B a lever:

  wkv_impl          — "scan" (baseline) | "chunked" (flash-linear-attention)
  moe_capacity      — MoE capacity factor (baseline 1.25)
  bf16_gather       — cast params to bf16 at layer entry so FSDP
                      all-gathers move half the bytes
  microbatch        — override gradient-accumulation factor (0 = policy)
  attn_chunks       — (q_chunk, kv_chunk) for the online-softmax attention
  sp_attention      — shard_map sequence-parallel attention (vs letting the
                      SPMD partitioner reshard the chunk loop)
"""
from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class Knobs:
    wkv_impl: str = "scan"
    moe_capacity: float = 1.25
    bf16_gather: bool = False
    microbatch: int = 0
    attn_chunks: tuple[int, int] = (1024, 1024)
    sp_attention: bool = True


_ACTIVE = Knobs()


def active() -> Knobs:
    return _ACTIVE


@contextlib.contextmanager
def apply(knobs: Knobs):
    """Install the knobs into the relevant modules for one lowering."""
    from repro.models import layers, moe, rwkv6

    global _ACTIVE
    saved = (
        rwkv6.WKV_IMPL, moe.CAPACITY_FACTOR, layers.Q_CHUNK, layers.KV_CHUNK,
        _ACTIVE,
    )
    try:
        rwkv6.set_wkv_impl(knobs.wkv_impl)
        moe.CAPACITY_FACTOR = knobs.moe_capacity
        layers.Q_CHUNK, layers.KV_CHUNK = knobs.attn_chunks
        _ACTIVE = knobs
        yield knobs
    finally:
        rwkv6.set_wkv_impl(saved[0])
        moe.CAPACITY_FACTOR = saved[1]
        layers.Q_CHUNK, layers.KV_CHUNK = saved[2], saved[3]
        _ACTIVE = saved[4]
