"""Abstract input specs per (arch x shape) cell — ShapeDtypeStruct only.

The dry-run lowers against these stand-ins; nothing is allocated. The same
pattern as shannon/kernels: weak-type-correct, shardable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ShapeConfig
from repro.models.registry import build


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.stub_frontend:
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    if cfg.num_codebooks > 1:
        labels = jax.ShapeDtypeStruct((B, S, cfg.num_codebooks), jnp.int32)
    else:
        labels = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return {"inputs": inputs, "labels": labels}


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """serve_step(params, cache, pos, token) stand-ins (minus params)."""
    model = build(cfg)
    B, S = shape.global_batch, shape.seq_len
    cache = model.cache_spec(B, S)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.stub_frontend:
        token = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
    else:
        token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return {"cache": cache, "pos": pos, "token": token}


def prefill_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.stub_frontend:
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    else:
        inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return {"inputs": inputs}


def runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is this (arch x shape) cell defined? (long_500k needs sub-quadratic.)"""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is full-attention (see DESIGN.md Arch-applicability)"
        )
    return True, ""
