"""Collective-traffic extraction from compiled (post-SPMD) HLO text.

``cost_analysis()`` has no collective-bytes entry, so we parse the
optimized HLO: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction contributes its *output* byte size (the
per-device wire volume of one firing, to first order — ring all-reduce moves
~2x its operand, all-gather's output is exactly the gathered bytes; the
roofline uses a consistent convention and reports the breakdown).

Instructions inside while-loop bodies execute `trip_count` times; the
parser tracks loop nesting via HLO computation call-sites when available
and otherwise reports the static count (noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE = re.compile(r"\b(" + "|".join(DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def shape_bytes(shape_text: str) -> int:
    """Total bytes of every typed shape appearing in ``shape_text``."""
    total = 0
    for dtype, dims in _SHAPE.findall(shape_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        rows = [
            f"  {k:20s} n={self.count_by_kind[k]:4d}  "
            f"{self.bytes_by_kind[k] / 2**20:10.2f} MiB"
            for k in sorted(self.bytes_by_kind)
        ]
        rows.append(f"  {'TOTAL':20s}       {self.total_bytes / 2**20:10.2f} MiB")
        return "\n".join(rows)


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum per-device collective output bytes over the module text.

    '-start' variants are counted; their paired '-done' is skipped so async
    collectives are not double counted.
    """
    bytes_by_kind: dict[str, int] = defaultdict(int)
    count_by_kind: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        out_shape, kind = m.group(1), m.group(2)
        b = shape_bytes(out_shape)
        if b == 0:
            continue
        bytes_by_kind[kind] += b
        count_by_kind[kind] += 1
    return CollectiveStats(dict(bytes_by_kind), dict(count_by_kind))


def dominant_ops(hlo_text: str, top: int = 8) -> list[tuple[str, int]]:
    """Largest single collective instructions (debugging the schedule)."""
    out = []
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _INSTR.match(line)
        if m:
            out.append((line.strip()[:140], shape_bytes(m.group(1))))
    out.sort(key=lambda t: -t[1])
    return out[:top]
