"""Serving launcher: prefill + batched decode with a KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
      --batch 4 --prompt-len 32 --gen 16

Reduced configs on CPU; same code path drives the full configs on a pod
(dryrun.py proves those compile).
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--scale", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import build

    cfg = get_config(args.arch)
    if args.scale == "reduced":
        cfg = cfg.reduced()
    model = build(cfg)
    key = jax.random.key(args.seed)
    params = model.init(key)
    B = args.batch
    max_len = args.prompt_len + args.gen

    if cfg.stub_frontend:
        prompt = 0.02 * jax.random.normal(
            key, (B, args.prompt_len, cfg.d_model), jnp.float32
        )
    else:
        prompt = jax.random.randint(
            key, (B, args.prompt_len), 0, cfg.vocab_size
        )

    # --- prefill: teacher-force the prompt through decode steps to build
    # the cache (single-token path keeps one code path for all families).
    decode = jax.jit(model.decode_step)
    cache = model.init_cache(B, max_len)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        tok = prompt[:, t:t + 1]
        logits, cache = decode(params, cache, jnp.int32(t), tok)
    prefill_s = time.time() - t0

    # --- batched greedy/temperature decode
    outs = []
    t0 = time.time()
    sample_key = jax.random.key(args.seed + 1)
    for t in range(args.prompt_len, max_len):
        flat = logits.reshape(B, -1)
        if args.temperature > 0:
            sample_key, sub = jax.random.split(sample_key)
            nxt = jax.random.categorical(sub, flat / args.temperature, axis=-1)
        else:
            nxt = jnp.argmax(flat, axis=-1)
        nxt = jnp.clip(nxt, 0, cfg.vocab_size - 1).astype(jnp.int32)
        outs.append(nxt)
        if cfg.stub_frontend:
            tok = 0.02 * jax.random.normal(
                jax.random.key(t), (B, 1, cfg.d_model), jnp.float32
            )
        else:
            tok = nxt[:, None]
        logits, cache = decode(params, cache, jnp.int32(t), tok)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0

    tokens = jnp.stack(outs, axis=1)
    print("generated token ids (first row):", tokens[0].tolist())
    print(json.dumps({
        "arch": args.arch,
        "prefill_s": round(prefill_s, 3),
        "decode_s": round(decode_s, 3),
        "decode_tok_per_s": round(B * args.gen / max(decode_s, 1e-9), 1),
    }))


if __name__ == "__main__":
    main()
