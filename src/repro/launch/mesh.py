"""Production meshes (a FUNCTION — importing this never touches devices).

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16).

The device ORDER inside the mesh is a Mapple decision: by default the
identity (block) order; ``mapper_permutation`` applies a Mapple mapper's
tile->device map (Sec. 5 translation) before reshaping, which is how the
hillclimb experiments reorder collectives without touching model code.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np


def make_production_mesh(*, multi_pod: bool = False, devices=None,
                         permutation: Sequence[int] | None = None):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    if devices is None:
        devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            "sets this automatically)"
        )
    devices = list(devices)[:n]
    if permutation is not None:
        devices = [devices[p] for p in permutation]
    dev_arr = np.asarray(devices, dtype=object).reshape(shape)
    return jax.sharding.Mesh(dev_arr, axes)


def mapper_permutation(mapper, grid_shape: Sequence[int]) -> np.ndarray:
    """Evaluate a Mapple mapper into a flat device permutation."""
    n = int(np.prod(tuple(grid_shape)))
    return mapper.tile_permutation(tuple(grid_shape), n)


def small_mesh(axis_names=("data", "model"), shape=None):
    """Mesh over whatever devices exist (tests / CPU examples)."""
    import jax

    devs = jax.devices()
    if shape is None:
        shape = (len(devs), 1)
    dev_arr = np.asarray(devs[: int(np.prod(shape))], dtype=object).reshape(shape)
    return jax.sharding.Mesh(dev_arr, axis_names)
