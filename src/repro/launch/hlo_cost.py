"""While-loop-aware HLO cost model (fixes XLA cost_analysis undercounting).

``compiled.cost_analysis()`` counts each while-loop body ONCE, but our
models scan over layers (and flash attention scans over KV chunks), so
flops / bytes / collective-bytes must be multiplied by loop trip counts.
This module parses the optimized HLO text into its computations, extracts
static trip counts from while conditions (the `constant(N)` in the
condition computation), and propagates costs through the call graph:

    cost(comp) = sum(instruction costs) + sum(child costs x multiplier)

  * flops: dot_general contributions (2 x out_elems x contraction), the
    MXU-relevant count (elementwise flops are bandwidth-bound and belong
    to the memory term);
  * bytes: operand + output bytes of every non-view instruction at fusion
    granularity (an HBM-traffic proxy consistent with XLA's convention);
  * collective bytes: output bytes of each collective firing.

Validated against analytic 6*N*D in tests/test_dryrun.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from repro.launch.hlo_analysis import DTYPE_BYTES, shape_bytes

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")
# NB: tuple types embed /*index=N*/ comments (hence `=` inside the type),
# so the type group must be permissive; opcodes are always `word(`.
_ASSIGN = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_SHAPE_TOKEN = re.compile(r"\b(" + "|".join(DTYPE_BYTES) + r")\[([0-9,]*)\]")
_CALLS = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_CONDITION = re.compile(r"condition=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_LHS_BDIMS = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
# Older XLA prints operand types inside call parens:
#   dot(f32[64,64]{1,0} %lhs, f32[64,64]{1,0} %rhs)
_DOT_CALL = re.compile(r"\bdot(?:-general)?\(([^)]*)\)")
_PCT_NAME = re.compile(r"%([\w.\-]+)")
_CONSTANT = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")

VIEW_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "reshape", "copy", "after-all", "partition-id",
    "replica-id", "iota", "broadcast",
}
COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "reduce-scatter-start",
}


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] = (
                self.collective_by_kind.get(k, 0.0) + v * mult
            )


def _first_shape_dims(text: str) -> list[int] | None:
    m = _SHAPE_TOKEN.search(text)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def _all_shapes_bytes(text: str) -> int:
    return shape_bytes(text)


@dataclasses.dataclass
class Computation:
    name: str
    lines: list


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_marker = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
                m = _COMP_HEADER.match(line.strip())
                name = None
                if m:
                    name = m.group(1)
                else:
                    head = line.strip().split()[0]
                    name = head.lstrip("%")
                    if name == "ENTRY":
                        name = line.strip().split()[1].lstrip("%")
                cur = Computation(name, [])
                if line.startswith("ENTRY"):
                    entry_marker = name
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
            else:
                cur.lines.append(line)
    if entry_marker:
        comps["__entry__"] = comps[entry_marker]
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest s32 constant in the condition computation (LT loops)."""
    best = 1
    for ln in cond.lines:
        m = _CONSTANT.search(ln)
        if m:
            best = max(best, int(m.group(1)))
    return best


def _dot_flops(line: str, symbols: dict[str, list[int]]) -> float:
    dims = _first_shape_dims(line)
    if dims is None:
        return 0.0
    out_elems = 1
    for d in dims:
        out_elems *= d
    contraction = 1
    call = _DOT_CALL.search(line)
    lhs_dims = None
    if call:
        operands = call.group(1)
        # Operand types, when printed, give the lhs shape directly; fall
        # back to the shape recorded at the lhs variable's definition.
        name_m = _PCT_NAME.search(operands)
        first_shape = _SHAPE_TOKEN.search(operands)
        if first_shape and (not name_m or first_shape.start() < name_m.start()):
            lhs_dims = [int(d) for d in first_shape.group(2).split(",") if d]
        elif name_m:
            lhs_dims = symbols.get(name_m.group(1))
    cd = _LHS_CDIMS.search(line)
    if lhs_dims is not None and cd is not None:
        for idx in cd.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contraction *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contraction


def analyze(hlo: str) -> Costs:
    comps = parse_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        return Costs()
    memo: dict[tuple[str, bool], Costs] = {}

    def comp_cost(name: str, stack: frozenset,
                  count_bytes: bool = True) -> Costs:
        """count_bytes=False inside fusion/apply computations: their
        internal ops live in registers/VMEM; HBM traffic is charged at the
        fusion call site."""
        key = (name, count_bytes)
        if key in memo:
            return memo[key]
        if name in stack or name not in comps:
            return Costs()
        comp = comps[name]
        symbols: dict[str, list[int]] = {}
        total = Costs()
        for line in comp.lines:
            m = _ASSIGN.match(line)
            if not m:
                continue
            var, out_type, op = m.group(1), m.group(2), m.group(3)
            dims = _first_shape_dims(out_type)
            if dims is not None:
                symbols[var] = dims
            if op in ("while",):
                body_m = _BODY.search(line)
                cond_m = _CONDITION.search(line)
                mult = 1
                if cond_m and cond_m.group(1) in comps:
                    mult = _trip_count(comps[cond_m.group(1)])
                if body_m:
                    total.add(comp_cost(body_m.group(1), stack | {name},
                                        count_bytes), mult)
                if cond_m:
                    total.add(comp_cost(cond_m.group(1), stack | {name},
                                        False), mult)
                continue
            if op in ("fusion", "call", "reduce", "map", "scatter", "sort",
                      "reduce-window", "select-and-scatter", "custom-call"):
                cm = _CALLS.search(line)
                if cm:
                    total.add(comp_cost(cm.group(1), stack | {name}, False),
                              1.0)
            if op in ("conditional",):
                for branch in re.findall(r"%([\w.\-]+)", line.split("(", 1)[1]):
                    if branch in comps:
                        total.add(comp_cost(branch, stack | {name}, False),
                                  1.0)
            base = op.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                if op.endswith("-done"):
                    continue
                b = _all_shapes_bytes(out_type)
                total.collective_bytes += b
                total.collective_by_kind[base] = (
                    total.collective_by_kind.get(base, 0.0) + b
                )
            if op in ("dot", "dot-general"):
                total.flops += _dot_flops(line, symbols)
            if op == "convolution":
                # flops ~ 2 * out_elems * (kernel elems per output); rare in
                # these models (hymba conv is expressed as shifts) — count
                # output elems x 2 as a floor.
                d = _first_shape_dims(out_type)
                if d:
                    n = 1
                    for x in d:
                        n *= x
                    total.flops += 2.0 * n
            if count_bytes and op not in VIEW_OPS and op != "while":
                # bytes: operands + outputs at fusion granularity
                total.bytes += _all_shapes_bytes(line)
        memo[key] = total
        return total

    # Entry name maps to the actual computation object; compute directly.
    entry_name = None
    for nm, c in comps.items():
        if c is entry and nm != "__entry__":
            entry_name = nm
            break
    return comp_cost(entry_name or "__entry__", frozenset())
