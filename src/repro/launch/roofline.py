"""Three-term roofline from a compiled dry-run artifact (per assignment):

    compute    = HLO_FLOPs   / peak_FLOP/s          (per chip)
    memory     = HLO_bytes   / HBM_bw               (per chip)
    collective = coll_bytes  / (links x link_bw)    (per chip)

cost_analysis() on the SPMD-partitioned module reports per-device flops and
bytes; collective bytes come from the HLO parse (hlo_analysis.py).
MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) checks how much of the
compiled compute is useful (remat / dispatch overhead shows up here).
"""
from __future__ import annotations

import dataclasses

from repro.core import machine as hw
from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    flops_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * n_chips)
    bottleneck: str
    n_chips: int

    def row(self) -> str:
        return (
            f"{self.arch:22s} {self.shape:12s} {self.mesh:7s} "
            f"{self.compute_s:10.3e} {self.memory_s:10.3e} "
            f"{self.collective_s:10.3e} {self.bottleneck:10s} "
            f"{self.flops_ratio:6.2f}"
        )


def active_params(cfg: ModelConfig) -> float:
    """Active (per-token) parameter count: dense params + top-k experts."""
    from repro.models.registry import build

    total = build(cfg).n_params
    if cfg.n_experts == 0:
        return float(total)
    d, f = cfg.d_model, cfg.moe_d_ff
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    routed_all = n_moe_layers * cfg.padded_experts * (3 * d * f)
    routed_active = n_moe_layers * cfg.topk * (3 * d * f)
    return float(total - routed_all + routed_active)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N_active*D for training; 2*N_active*D_tokens for inference."""
    n_active = active_params(cfg)
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def terms(
    arch: str,
    shape: ShapeConfig,
    cfg: ModelConfig,
    mesh_name: str,
    n_chips: int,
    cost: dict,
    collective_bytes: float,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / hw.PEAK_FLOPS_BF16
    memory_s = bytes_accessed / hw.HBM_BW
    # Innermost-level port bandwidth of the canonical pod (the per-chip
    # ICI aggregate) via the per-level MachineSpec tuple, so the roofline
    # and the simulator (repro.sim) share one fabric description.
    link_bw = hw.V5E_POD.link_bw(len(hw.V5E_POD.shape) - 1)
    collective_s = collective_bytes / link_bw
    mf = model_flops(cfg, shape)
    ratio = mf / max(flops * n_chips, 1.0)
    terms_map = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s,
    }
    bottleneck = max(terms_map, key=terms_map.get)  # type: ignore[arg-type]
    return RooflineTerms(
        arch=arch, shape=shape.name, mesh=mesh_name,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        hlo_flops=flops, hlo_bytes=bytes_accessed,
        collective_bytes=collective_bytes, model_flops=mf,
        flops_ratio=ratio, bottleneck=bottleneck, n_chips=n_chips,
    )


HEADER = (
    f"{'arch':22s} {'shape':12s} {'mesh':7s} "
    f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
    f"{'bound':10s} {'useful':>6s}"
)
