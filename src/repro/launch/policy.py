"""Sharding policy: map each arch's logical axes onto the production mesh.

The planner applies the Mapple decompose philosophy at the framework level
(DESIGN.md Sec. 4): given the fixed (data=16, model=16) pod mesh, choose
per-arch between

  * "tp"   — Megatron tensor parallelism on the model axis (requires the
             fused head / ffn / expert dims to divide 16); activations DP.
  * "fsdp" — ZeRO-3 parameter sharding on the model axis (any arch whose
             head counts do not divide 16: qwen2-7b 28H, smollm 9H,
             musicgen 24H, hymba 25H, rwkv6 40H); XLA all-gathers per layer.

plus the batch specification over ("pod", "data").
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeConfig
from repro.models.params import ShardingRules, opt_specs, param_specs

BATCH = ("pod", "data")
MODEL_AXIS_SIZE = 16


def choose_mode(cfg: ModelConfig) -> str:
    tp_ok = (
        cfg.n_heads % MODEL_AXIS_SIZE == 0
        and (cfg.n_experts == 0 or cfg.padded_experts % MODEL_AXIS_SIZE == 0)
        and (cfg.d_ff % MODEL_AXIS_SIZE == 0 or cfg.n_experts > 0)
    )
    return "tp" if tp_ok else "fsdp"


def make_rules(cfg: ModelConfig, mode: str | None = None) -> ShardingRules:
    return ShardingRules(
        mode=mode or choose_mode(cfg),
        model_axis="model",
        data_axis="data",
        model_size=MODEL_AXIS_SIZE,
    )


def _filter_spec(spec: P, mesh) -> P:
    """Drop axes not present in the mesh (single-pod vs multi-pod)."""
    names = set(mesh.axis_names)
    entries = []
    for e in spec:
        if e is None:
            entries.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a in names)
            entries.append(kept if kept else None)
        else:
            entries.append(e if e in names else None)
    return P(*entries)


def shard(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, _filter_spec(spec, mesh))


@dataclasses.dataclass
class ShardingPlan:
    mesh: Any
    rules: ShardingRules
    mode: str

    def params(self, schema) -> Any:
        specs = param_specs(schema, self.rules)
        return jax.tree.map(
            lambda s: shard(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P),
        )

    def opt_moments(self, schema) -> Any:
        """ZeRO-1 moment shardings (param specs + data axis)."""
        specs = opt_specs(schema, self.rules)
        return jax.tree.map(
            lambda s: shard(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P),
        )

    def replicated(self) -> NamedSharding:
        return shard(self.mesh, P())

    def batch_like(self, tree) -> Any:
        """Shard leading dim over (pod, data) when divisible."""

        def one(x):
            b = x.shape[0] if getattr(x, "ndim", 0) else 1
            total = 1
            for a in BATCH:
                if a in self.mesh.axis_names:
                    total *= self.mesh.shape[a]
            if b % max(total, 1) == 0 and x.ndim >= 1 and total > 1:
                return shard(self.mesh, P(BATCH))
            return self.replicated()

        return jax.tree.map(one, tree)

    def cache(self, cache_spec: dict) -> dict:
        """KV/state caches: batch dim over (pod, data) when divisible;
        the model axis takes the kv-head dim when it divides, else the
        cache SEQUENCE dim (sequence-parallel KV cache — the long-context
        serving layout; attention reductions cross shards via psum)."""

        def one(x):
            # layouts: (L, B, C, Kv, hd) | (L, B, C, r) | (L, B, H, N, N) |
            #          (L, B, W, di) | (L, B, di, n) | (L, B, D)
            entries: list[Any] = [None] * x.ndim
            total = 1
            for a in BATCH:
                if a in self.mesh.axis_names:
                    total *= self.mesh.shape[a]
            if x.ndim >= 2 and x.shape[1] % max(total, 1) == 0 and total > 1:
                entries[1] = BATCH
            if x.ndim >= 5 and x.shape[3] % MODEL_AXIS_SIZE == 0:
                entries[3] = "model"              # kv heads
            elif x.ndim >= 4 and x.shape[2] % MODEL_AXIS_SIZE == 0:
                entries[2] = "model"              # cache sequence dim
            return shard(self.mesh, P(*entries))

        return {k: one(v) for k, v in cache_spec.items()}


def make_plan(cfg: ModelConfig, mesh, mode: str | None = None) -> ShardingPlan:
    m = mode or choose_mode(cfg)
    return ShardingPlan(mesh=mesh, rules=make_rules(cfg, m), mode=m)
