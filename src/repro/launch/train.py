"""Training launcher: --arch <id> [--steps N] [--scale reduced|full].

On this CPU container it trains the REDUCED config end-to-end (the full
configs are exercised by dryrun.py); on a real pod the same driver runs the
full config over the production mesh with the same code path:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 16 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--scale", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--backpressure", type=int, default=2,
                    help="max in-flight steps (the Backpressure directive)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated failure at this step (demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.data import make_pipeline
    from repro.models import build
    from repro.runtime import FailureInjector, SimulatedFailure
    from repro.training import (
        AdamWConfig, TrainLoop, TrainState, init_state, make_train_step,
    )

    cfg = get_config(args.arch)
    if args.scale == "reduced":
        cfg = cfg.reduced()
    model = build(cfg)
    print(f"arch={args.arch} scale={args.scale} params={model.n_params:,}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps)
    pipe = make_pipeline(cfg, seq_len=args.seq, global_batch=args.batch,
                         seed=args.seed)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      compress_grads=args.compress_grads))

    mgr = None
    start = 0
    state = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3, async_save=True)
        if args.resume and mgr.latest_step() is not None:
            start, tree, extra = mgr.restore()
            state = TrainState.from_tree(tree)
            print(f"resumed from step {start}")
    if state is None:
        state = init_state(model, jax.random.key(args.seed), opt_cfg,
                           compress_grads=args.compress_grads)

    injector = (
        FailureInjector(fail_at_steps=(args.fail_at,), max_failures=1)
        if args.fail_at is not None else None
    )
    loop = TrainLoop(step_fn, pipe, backpressure=args.backpressure,
                     checkpoint_manager=mgr, save_every=args.save_every)
    t0 = time.time()
    if injector is None:
        state, hist = loop.run(state, start, args.steps)
    else:
        # Demonstrate checkpoint/restart under an injected failure.
        try:
            def guarded(step, st):
                injector.check(step)
                return step_fn(st, pipe.batch(step))

            guarded_loop = TrainLoop(guarded, pipe,
                                     backpressure=args.backpressure,
                                     checkpoint_manager=mgr,
                                     save_every=args.save_every)
            state, hist = guarded_loop.run(state, start, args.steps)
        except SimulatedFailure as e:
            print(f"!! {e}; restarting from latest checkpoint")
            assert mgr is not None, "--fail-at needs --ckpt-dir"
            mgr.wait()
            start, tree, _ = mgr.restore()
            state = TrainState.from_tree(tree)
            state, hist = loop.run(state, start, args.steps)
    dt = time.time() - t0
    if mgr is not None:
        mgr.wait()
    print(json.dumps({
        "first_loss": hist[0]["loss"], "last_loss": hist[-1]["loss"],
        "steps": len(hist), "wall_s": round(dt, 1),
        "steps_per_s": round(len(hist) / dt, 2),
    }))


if __name__ == "__main__":
    main()
