"""Deterministic synthetic data pipeline, host-sharded.

Produces reproducible token streams (a mixture of Zipfian unigrams and
repeated-ngram structure so losses actually decrease) keyed by
(seed, step, shard), so that:

  * restarts resume mid-epoch exactly (the cursor is the step counter
    persisted in checkpoints);
  * every data-parallel host generates only its shard (no global array on
    any single host) — the pattern a real corpus loader follows;
  * elastic rescales remap shards deterministically.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_alpha: float = 1.1
    ngram_period: int = 97


def _zipf_probs(vocab: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return (p / p.sum()).astype(np.float64)


@dataclasses.dataclass
class SyntheticTokens:
    cfg: DataConfig

    def __post_init__(self):
        self._probs = _zipf_probs(self.cfg.vocab_size, self.cfg.zipf_alpha)

    def batch_np(self, step: int, shard: int = 0, n_shards: int = 1
                 ) -> dict[str, np.ndarray]:
        """The shard's slice of the global batch for ``step``."""
        cfg = self.cfg
        if cfg.global_batch % n_shards:
            raise ValueError(
                f"global_batch {cfg.global_batch} not divisible by {n_shards}"
            )
        per = cfg.global_batch // n_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, shard])
        )
        toks = rng.choice(
            cfg.vocab_size, size=(per, cfg.seq_len + 1), p=self._probs
        ).astype(np.int32)
        # inject periodic structure: repeat a window to create learnable
        # bigram statistics
        period = cfg.ngram_period
        reps = cfg.seq_len // (2 * period)
        for r in range(reps):
            lo = 2 * r * period
            toks[:, lo + period: lo + 2 * period] = toks[:, lo: lo + period]
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}

    def batch(self, step: int, shard: int = 0, n_shards: int = 1):
        return {
            k: jnp.asarray(v) for k, v in
            self.batch_np(step, shard, n_shards).items()
        }


@dataclasses.dataclass
class SyntheticEmbeddings:
    """Stub modality frontend: precomputed frame/patch embeddings."""

    cfg: DataConfig
    d_model: int
    num_codebooks: int = 0

    def batch(self, step: int, shard: int = 0, n_shards: int = 1):
        cfg = self.cfg
        per = cfg.global_batch // n_shards
        key = jax.random.key(
            (cfg.seed * 1_000_003 + step * 613 + shard) % (2 ** 31)
        )
        k1, k2 = jax.random.split(key)
        emb = 0.02 * jax.random.normal(
            k1, (per, cfg.seq_len, self.d_model), jnp.float32
        )
        if self.num_codebooks > 1:
            labels = jax.random.randint(
                k2, (per, cfg.seq_len, self.num_codebooks), 0, cfg.vocab_size
            )
        else:
            labels = jax.random.randint(
                k2, (per, cfg.seq_len), 0, cfg.vocab_size
            )
        return {"inputs": emb, "labels": labels}


def make_pipeline(model_cfg, seq_len: int, global_batch: int, seed: int = 1234):
    dc = DataConfig(
        vocab_size=model_cfg.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=seed,
    )
    if model_cfg.stub_frontend:
        return SyntheticEmbeddings(dc, model_cfg.d_model,
                                   model_cfg.num_codebooks)
    return SyntheticTokens(dc)
