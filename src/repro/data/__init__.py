"""Data pipeline substrate."""
from repro.data.pipeline import DataConfig, SyntheticEmbeddings, SyntheticTokens, make_pipeline
