"""Persistent plan cache: tuned mapping plans stored once, served forever.

The tuning service's unit of work — "map this app on this machine at
this scale" — is a pure function of ``(app, scale, machine spec, pricing
value-tag, search knobs)``, so its winner is cacheable the same way PR
8's :class:`~repro.sim.price_cache.PriceCache` caches placement prices:
under a compact blake2b digest key, in an append-only file whose torn
tail drops cleanly.

Records are variable-length (a plan payload is a JSON document: winner
candidate, rendered Mapple source, IR, full leaderboard, provenance),
framed as::

    [16-byte key digest][u32 payload length][payload utf-8][crc32]

after an 8-byte ``RPLANS01`` magic, all in one file (``plans.log``)
under the cache root. The CRC covers key+payload, so a torn or
bit-flipped record is detected and the load stops there — the intact
prefix stays usable, the damaged tail re-tunes live, and the next write
rewrites the file whole from the intact records (self-healing, same
contract as the price cache). Duplicate keys are idempotent re-asserts.

Besides exact ``get(key)`` hits, the cache keeps a per-app index of
``(procs, key)`` pairs so :meth:`nearest` can surface the plans closest
in scale to a near-miss request — the seeds of the service's
warm-started beam search (``tune_app(warm_start=...)``).

A cache built with ``root=None`` is memory-only (a service without
``--cache-dir`` still dedupes within its own lifetime). Every live
instance is registered with :func:`repro.sim.collectives.register_cache`
so ``clear_caches()`` / ``cache_stats()`` cover plan caches alongside
schedule memos, JAX exports and price caches: clearing drops the
in-memory mirror (the disk store survives and reloads on next access —
that persistence is the point), stats aggregate hit/miss/write/dropped
counters.
"""
from __future__ import annotations

import json
import math
import struct
import threading
import weakref
import zlib
from pathlib import Path

from repro.sim.collectives import register_cache
from repro.sim.price_cache import DIGEST_BYTES, digest

_MAGIC = b"RPLANS01"
_HEAD = struct.Struct(f"<{DIGEST_BYTES}sI")     # key digest + payload length
_CRC = struct.Struct("<I")

_INSTANCES: "weakref.WeakSet[PlanCache]" = weakref.WeakSet()
_STAT_KEYS = ("hits", "misses", "writes", "dropped")


def plan_key(app: str, procs: int, spec_repr: str, value_tag: str,
             knobs: tuple = ()) -> bytes:
    """The canonical plan-cache key digest: application name, processor
    count, the machine spec's repr (the same spec digest the price cache
    tables use), the pricing engine's bit-stability tag, and whatever
    search knobs change the result (beam width, sim steps, ...)."""
    return digest(
        app.encode(),
        repr(int(procs)).encode(),
        spec_repr.encode(),
        value_tag.encode(),
        repr(tuple(knobs)).encode(),
    )


class PlanCache:
    """Append-only on-disk store of ``plan key -> payload dict``.

    Payloads must be JSON-serializable dicts; payloads carrying ``app``
    (str) and ``procs`` (int) fields additionally join the per-app
    nearest-scale index behind :meth:`nearest`.
    """

    def __init__(self, root: str | Path | None) -> None:
        self.root = Path(root) if root is not None else None
        if self.root is not None:
            self.root.mkdir(parents=True, exist_ok=True)
        self._plans: dict[bytes, dict] = {}
        self._by_app: dict[str, list[tuple[int, bytes]]] = {}
        self._loaded = self.root is None
        self._damaged = False
        self._lock = threading.Lock()
        self.stats_counters = {k: 0 for k in _STAT_KEYS}
        _INSTANCES.add(self)

    # ------------------------------------------------------------------ io
    @property
    def path(self) -> Path | None:
        return None if self.root is None else self.root / "plans.log"

    def _index(self, key: bytes, payload: dict) -> None:
        app, procs = payload.get("app"), payload.get("procs")
        if isinstance(app, str) and isinstance(procs, int):
            self._by_app.setdefault(app, []).append((procs, key))

    def _ensure_loaded(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        try:
            blob = self.path.read_bytes()
        except OSError:
            return
        if not blob.startswith(_MAGIC):
            # Foreign file or stale format: treated as empty, rewritten
            # whole on the next put.
            self.stats_counters["dropped"] += 1
            self._damaged = bool(blob)
            return
        off = len(_MAGIC)
        while off < len(blob):
            if off + _HEAD.size > len(blob):
                self.stats_counters["dropped"] += 1
                self._damaged = True
                return
            key, size = _HEAD.unpack_from(blob, off)
            end = off + _HEAD.size + size + _CRC.size
            if size > len(blob) or end > len(blob):
                self.stats_counters["dropped"] += 1
                self._damaged = True
                return
            raw = blob[off + _HEAD.size:off + _HEAD.size + size]
            (crc,) = _CRC.unpack_from(blob, off + _HEAD.size + size)
            if crc != zlib.crc32(key + raw):
                # Torn/corrupt record: keep the intact prefix, drop the
                # rest — those keys simply re-tune live.
                self.stats_counters["dropped"] += 1
                self._damaged = True
                return
            try:
                payload = json.loads(raw.decode("utf-8"))
            except (UnicodeDecodeError, json.JSONDecodeError):
                self.stats_counters["dropped"] += 1
                self._damaged = True
                return
            if key not in self._plans:
                self._plans[key] = payload
                self._index(key, payload)
            off = end

    @staticmethod
    def _record(key: bytes, payload: dict) -> bytes:
        raw = json.dumps(payload, sort_keys=True).encode("utf-8")
        return (_HEAD.pack(key, len(raw)) + raw
                + _CRC.pack(zlib.crc32(key + raw)))

    # -------------------------------------------------------------- access
    def get(self, key: bytes) -> dict | None:
        """The cached plan payload for one key digest, or None."""
        with self._lock:
            self._ensure_loaded()
            payload = self._plans.get(key)
            if payload is None:
                self.stats_counters["misses"] += 1
                return None
            self.stats_counters["hits"] += 1
            return dict(payload)

    def put(self, key: bytes, payload: dict) -> None:
        """Insert one plan and append it to disk (idempotent: an
        already-present key is a no-op — append-only files never restate
        a record)."""
        with self._lock:
            self._ensure_loaded()
            if key in self._plans:
                return
            payload = dict(payload)
            self._plans[key] = payload
            self._index(key, payload)
            self.stats_counters["writes"] += 1
            if self.path is None:
                return
            if self._damaged:
                # Appending past a tear would be unreadable (loads stop
                # at the damage), so rewrite the file whole from the
                # intact records — the write heals the store.
                blob = _MAGIC + b"".join(
                    self._record(k, p) for k, p in self._plans.items())
                self.path.write_bytes(blob)
                self._damaged = False
            else:
                header = b"" if self.path.exists() else _MAGIC
                with open(self.path, "ab") as fh:
                    fh.write(header + self._record(key, payload))

    def nearest(self, app: str, procs: int, *, count: int = 2,
                exclude: bytes | None = None) -> list[dict]:
        """The ``count`` cached plans for ``app`` nearest in scale to
        ``procs`` (log-ratio distance, ties to the smaller scale) —
        warm-start seed material for a near-miss request. ``exclude``
        drops one key (the requester's own, already known to miss)."""
        with self._lock:
            self._ensure_loaded()
            entries = self._by_app.get(app, ())
            ranked = sorted(
                (abs(math.log(max(p, 1) / max(procs, 1))), p, key)
                for p, key in entries
                if exclude is None or key != exclude
            )
            return [dict(self._plans[key]) for _, _, key in ranked[:count]]

    # ------------------------------------------------------------ lifecycle
    def clear(self) -> None:
        """Drop the in-memory mirror and zero counters; the disk store is
        untouched (the next access reloads it). A memory-only cache
        loses its plans — it has no disk to reload from."""
        with self._lock:
            self._plans.clear()
            self._by_app.clear()
            self._loaded = self.root is None
            self._damaged = False
            for k in self.stats_counters:
                self.stats_counters[k] = 0

    def stats(self) -> dict:
        with self._lock:
            return {**self.stats_counters, "plans": len(self._plans)}


def _caches_clear() -> None:
    for cache in list(_INSTANCES):
        cache.clear()


def _caches_stats() -> dict:
    out = {k: 0 for k in _STAT_KEYS}
    out["plans"] = 0
    for cache in list(_INSTANCES):
        for k, v in cache.stats().items():
            out[k] += v
    return out


register_cache("plan_cache", _caches_clear, _caches_stats)

__all__ = ["PlanCache", "plan_key"]
