"""Shared serving metrics: percentile math + the tuning service's stats.

:func:`percentile` is the one latency-quantile implementation both
serving stats surfaces use — :class:`~repro.serving.scheduler.ServeStats`
(the continuous-batching scheduler) and :class:`ServiceStats` (the
mapping-as-a-service tuning server, :mod:`repro.serving.mapsvc`). It is
the nearest-rank estimator: deterministic, exact at tiny sample counts
(0, 1 and 2 samples are unit-tested), and monotone in ``q``.

:class:`ServiceStats` aggregates one service instance's lifetime:
request/served/shed counts by outcome, plan-cache hit vs warm vs cold
search provenance, per-stage timings (admission wait, cache lookup,
search), and end-to-end latencies. ``summary()`` is the JSON metrics
surface (requests/sec, p50/p95/p99) the CLI and the load benchmark
emit.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Sequence


def percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of ``values`` (unsorted ok).

    ``q`` is in percent (0..100). Empty input returns 0.0; a single
    sample is every percentile of itself; with two samples the median
    is the lower one and p95/p99 the upper (rank ``ceil(q/100 * n)``,
    1-based, clamped into the sample).
    """
    if not 0 <= q <= 100:
        raise ValueError(f"q must be in [0, 100], got {q}")
    data = sorted(values)
    if not data:
        return 0.0
    rank = max(math.ceil(q / 100.0 * len(data)), 1)
    return data[min(rank, len(data)) - 1]


def latency_summary(latencies: Sequence[float],
                    prefix: str = "") -> dict[str, float]:
    """The standard p50/p95/p99 block, keys optionally prefixed."""
    return {
        f"{prefix}p50_s": percentile(latencies, 50),
        f"{prefix}p95_s": percentile(latencies, 95),
        f"{prefix}p99_s": percentile(latencies, 99),
    }


@dataclasses.dataclass
class ServiceStats:
    """Lifetime counters + timings of one :class:`MappingService`.

    Mutated only under the service's lock; ``summary()``/``to_json()``
    read a consistent snapshot the same way.
    """

    submitted: int = 0
    completed: int = 0                 # requests resolved with a plan
    #: Typed rejections by reason ("queue-full" | "deadline" |
    #: "timeout" | "error" | "closed").
    rejected: dict = dataclasses.field(default_factory=dict)
    #: Plan provenance of completed requests.
    cache_hits: int = 0                # exact plan-cache hits (no search)
    warm: int = 0                      # searched, seeded from a nearby plan
    cold: int = 0                      # searched from scratch
    #: Requests that rode another in-flight request's search (identical
    #: key coalesced inside one batch) — completed, but searched 0 times.
    coalesced: int = 0
    #: Searches actually executed (== distinct keys tuned).
    searches: int = 0
    #: Cross-request shared pricing passes (one per drained batch that
    #: had at least one search).
    shared_pricing_passes: int = 0
    #: Failure remaps served (priority RemapRequest resolutions).
    remaps: int = 0
    #: Worker-thread crashes survived: the batch being processed was
    #: requeued (once per ticket) instead of dropped.
    worker_crashes: int = 0
    latencies: list = dataclasses.field(default_factory=list)
    wait_s: list = dataclasses.field(default_factory=list)     # queue time
    cache_s: list = dataclasses.field(default_factory=list)    # lookup time
    search_s: list = dataclasses.field(default_factory=list)   # tune time
    first_submit_t: float | None = None
    last_resolve_t: float | None = None

    # ------------------------------------------------------------- updates
    def note_rejected(self, reason: str) -> None:
        self.rejected[reason] = self.rejected.get(reason, 0) + 1

    @property
    def shed(self) -> int:
        """Requests shed by admission control or deadlines (everything
        rejected for a non-error reason)."""
        return sum(n for reason, n in self.rejected.items()
                   if reason != "error")

    # ------------------------------------------------------------- surface
    def summary(self) -> dict:
        span = 0.0
        if self.first_submit_t is not None and self.last_resolve_t is not None:
            span = max(self.last_resolve_t - self.first_submit_t, 0.0)
        resolved = self.completed + sum(self.rejected.values())
        return {
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": dict(self.rejected),
            "shed": self.shed,
            "cache_hits": self.cache_hits,
            "warm": self.warm,
            "cold": self.cold,
            "coalesced": self.coalesced,
            "searches": self.searches,
            "shared_pricing_passes": self.shared_pricing_passes,
            "remaps": self.remaps,
            "worker_crashes": self.worker_crashes,
            "span_s": span,
            "requests_per_s": (resolved / span) if span > 0 else 0.0,
            "latency": latency_summary(self.latencies),
            "stages": {
                "wait": latency_summary(self.wait_s),
                "cache": latency_summary(self.cache_s),
                "search": latency_summary(self.search_s),
            },
        }

    def to_json(self, indent: int | None = 2) -> str:
        return json.dumps(self.summary(), indent=indent)


__all__ = ["ServiceStats", "latency_summary", "percentile"]
