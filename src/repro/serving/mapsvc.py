"""Mapping-as-a-service: a persistent, concurrent tuning server.

The batch CLI (``repro.apps.run --tune --time``) answers one mapping
question per process; this module keeps the tuner resident and answers a
*stream* of them. A :class:`MappingService` accepts
:class:`TuneRequest`\\ s ("map app X on machine M at scale N, priced on
engine E"), and resolves each to a :class:`MappingPlan` (winner IR +
rendered Mapple source + leaderboard + provenance) or a typed
:class:`Rejected`. Four mechanisms make the resident form pay:

* **Plan cache** (:mod:`repro.serving.plan_cache`): the winner of every
  search is stored under a digest of ``(app, procs, machine spec,
  value-tag, search knobs)``. An exact repeat resolves from the cache
  with *zero* recomputation — no Phase 1, no pricing — and the
  append-only file under ``cache_dir/plans`` makes hits survive
  restarts and cross processes.
* **Warm-started search**: a near-miss (same app, different scale) seeds
  the beam with cached winners re-instantiated on the new grid
  (:func:`~repro.search.tuner.refit_candidate`). Seeds *widen* the beam
  (superset of the cold shortlist), so a warm search is never worse
  than cold, and bit-identical to it when no seed is novel.
* **Admission + priority scheduling**: a bounded queue ordered by
  ``(priority, deadline)``; overload sheds with
  ``Rejected("queue-full")`` at submit, expired deadlines shed at
  dispatch, per-request timeouts report ``Rejected("timeout")``.
* **Cross-request batched pricing**: each drained batch coalesces
  identical keys to one search and prices *all* its searches' Phase-3
  candidate stacks in a single
  :func:`~repro.search.pipeline.price_jobs` call — jobs from different
  requests pack into shared ``BatchSimulator.price_stacks`` congestion
  passes.

``workers=0`` runs the service inline: callers submit, then
:meth:`MappingService.drain` processes the queue on the calling thread
(deterministic, the test/benchmark mode). ``workers>=1`` starts daemon
threads that drain continuously. Either way the tuner itself is
deterministic, so concurrent submission yields plans bit-identical to
serial runs.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import json
import threading
import time
from pathlib import Path
from typing import Sequence

from repro.search.space import Candidate
from repro.search.tuner import (
    DEFAULT_BEAM,
    DEFAULT_LEADERBOARD,
    prepare_tune,
    refit_candidate,
)
from repro.search.pipeline import price_jobs
from repro.search.remap import remap_plan
from repro.serving.plan_cache import PlanCache, plan_key
from repro.serving.stats import ServiceStats
from repro.sim.cost import (
    DEFAULT_ELEM_BYTES,
    DEFAULT_STEPS,
    spec_for,
    time_tuned_app,
)
from repro.sim.price_cache import PriceCache

#: Default admission-queue bound (submits past it shed immediately).
DEFAULT_QUEUE_LIMIT = 64
#: Default max requests drained (and cross-priced) per batch.
DEFAULT_COALESCE = 8


def value_tag(engine: str, dtype: str = "float64") -> str:
    """The pricing value family of an (engine, dtype) pair — mirrors
    ``SimulatedTimeCostModel.value_tag`` without building a model, so
    plan-cache keys are computable before any search machinery exists."""
    if engine == "batched-jax":
        return "jax-f32" if dtype == "float32" else "jax-f64"
    return "event-f64" if engine == "event" else "numpy-f64"


@dataclasses.dataclass(frozen=True)
class TuneRequest:
    """One mapping question.

    ``engine``/``dtype`` default to the service's; ``machine_shape``
    overrides the app registry's shape for ``procs``; ``priority`` sorts
    ascending (0 before 1); ``deadline_s`` (relative to submit) sheds
    the request if it has not *started* by then; ``timeout_s`` bounds
    end-to-end latency post-hoc (the plan is still cached)."""

    app: str
    procs: int | None = None
    machine_shape: tuple[int, ...] | None = None
    engine: str | None = None
    dtype: str | None = None
    priority: int = 0
    deadline_s: float | None = None
    timeout_s: float | None = None


@dataclasses.dataclass(frozen=True)
class RemapRequest:
    """A recovery question: processors failed under a running plan —
    re-place the work on the survivors, *now*.

    ``failures`` is anything
    :func:`~repro.search.remap.degraded_from_failures` accepts (a
    ``DegradedMachine``, ``NodeFailure``\\ s, node-death ``FaultEvent``\\ s,
    bare processor ids). The default ``priority=-1`` sorts remaps ahead
    of every routine tune in the admission heap — a cluster bleeding
    step time outranks speculative what-if tuning. ``mode`` picks the
    warm restricted search (default) or the full cold baseline."""

    app: str
    failures: object
    procs: int | None = None
    machine_shape: tuple[int, ...] | None = None
    engine: str | None = None
    dtype: str | None = None
    mode: str = "warm"
    priority: int = -1
    deadline_s: float | None = None
    timeout_s: float | None = None


@dataclasses.dataclass
class MappingPlan:
    """A resolved mapping: the tuner's winner plus service provenance.

    ``provenance`` is ``"cache"`` (exact plan-cache hit, zero search),
    ``"warm"`` (searched with cached seeds in the beam) or ``"cold"``
    (searched from scratch). ``payload()``/``from_payload()`` are the
    plan-cache serialization — JSON-stable, so cached plans round-trip
    across processes byte-for-byte."""

    app: str
    procs: int
    machine_shape: tuple[int, ...]
    value_tag: str
    candidate: dict                    # grid/dist/order/options of the winner
    placed_cost: float | None
    volume: float
    source: str                        # rendered Mapple DSL program
    ir: str                            # winner's mapper IR description
    verified: bool
    leaderboard: list                  # ScoredCandidate.row() dicts
    provenance: str = "cold"
    warm_seeds: int = 0
    elapsed_s: float = 0.0
    timings: dict = dataclasses.field(default_factory=dict)
    #: Recovery facts when this plan answered a :class:`RemapRequest`
    #: (``provenance == "remap"``): sub_shape, proc_map, the physical
    #: placement, and degraded/stale step times. ``None`` for routine
    #: tunes; never part of the cached payload (a remap answers one
    #: concrete failure, not the app x procs question the cache keys).
    remap: dict | None = None

    def payload(self) -> dict:
        """The JSON-serializable plan-cache record (provenance and
        timings are per-request facts, not part of the plan)."""
        return {
            "app": self.app,
            "procs": int(self.procs),
            "machine_shape": list(self.machine_shape),
            "value_tag": self.value_tag,
            "candidate": dict(self.candidate),
            "placed_cost": self.placed_cost,
            "volume": self.volume,
            "source": self.source,
            "ir": self.ir,
            "verified": self.verified,
            "leaderboard": [dict(r) for r in self.leaderboard],
        }

    @classmethod
    def from_payload(cls, payload: dict, *, provenance: str,
                     elapsed_s: float = 0.0,
                     timings: dict | None = None) -> "MappingPlan":
        return cls(
            app=payload["app"],
            procs=int(payload["procs"]),
            machine_shape=tuple(int(s) for s in payload["machine_shape"]),
            value_tag=payload["value_tag"],
            candidate=dict(payload["candidate"]),
            placed_cost=payload.get("placed_cost"),
            volume=float(payload["volume"]),
            source=payload["source"],
            ir=payload["ir"],
            verified=bool(payload["verified"]),
            leaderboard=[dict(r) for r in payload.get("leaderboard", [])],
            provenance=provenance,
            warm_seeds=0,
            elapsed_s=elapsed_s,
            timings=dict(timings or {}),
        )

    def summary(self) -> dict:
        out = self.payload()
        out.update(provenance=self.provenance, warm_seeds=self.warm_seeds,
                   elapsed_s=self.elapsed_s, timings=dict(self.timings))
        if self.remap is not None:
            out["remap"] = dict(self.remap)
        return out


@dataclasses.dataclass(frozen=True)
class Rejected:
    """A typed non-answer. ``reason`` is one of ``"queue-full"``,
    ``"deadline"``, ``"timeout"``, ``"error"``, ``"closed"``."""

    reason: str
    detail: str = ""
    app: str = ""


class Ticket:
    """The caller's handle on one submitted request."""

    def __init__(self, request: "TuneRequest | RemapRequest",
                 submit_t: float) -> None:
        self.request = request
        self.submit_t = submit_t
        self._event = threading.Event()
        self._result: "MappingPlan | Rejected | None" = None
        self._requeued = False         # one free retry after a worker crash

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> "MappingPlan | Rejected":
        """Block until resolved; raises ``TimeoutError`` if ``timeout``
        elapses first (the request itself keeps running)."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request for {self.request.app!r} still pending")
        assert self._result is not None
        return self._result


def _candidate_from(payload: dict) -> Candidate | None:
    """Rebuild a Candidate from a plan payload's ``candidate`` dict;
    ``None`` on malformed/stale payloads (skipped, never fatal)."""
    try:
        return Candidate(
            grid=tuple(int(g) for g in payload["grid"]),
            dist=tuple(str(d) for d in payload["dist"]),
            order=tuple(int(o) for o in payload["order"]),
            options=tuple((str(k), str(v)) for k, v in payload["options"]),
        )
    except (KeyError, TypeError, ValueError):
        return None


def plan_from_report(report, *, value_tag_: str, provenance: str,
                     timings: dict | None = None) -> MappingPlan:
    """Fold a :class:`~repro.search.tuner.TuningReport` into the service's
    plan shape (also used by the batch CLI's ``--warm-start-from``)."""
    best = report.best.candidate
    return MappingPlan(
        app=report.app,
        procs=report.procs,
        machine_shape=tuple(report.machine_shape),
        value_tag=value_tag_,
        candidate={
            "grid": list(best.grid),
            "dist": list(best.dist),
            "order": list(best.order),
            "options": [[k, v] for k, v in best.options],
        },
        placed_cost=report.best.placed_cost,
        volume=report.best.volume,
        source=report.best_source,
        ir=report.best_ir,
        verified=report.verified,
        leaderboard=[s.row() for s in report.leaderboard],
        provenance=provenance,
        warm_seeds=report.warm_seeds,
        elapsed_s=report.elapsed_s,
        timings=dict(timings or {}),
    )


def plan_key_for(tuned_app, procs: int | None = None, *, engine: str,
                 dtype: str = "float64", beam: int = DEFAULT_BEAM,
                 steps: int = DEFAULT_STEPS,
                 elem_bytes: int = DEFAULT_ELEM_BYTES
                 ) -> tuple[int, bytes, str]:
    """Resolve one (app, procs) question to its plan-cache coordinates:
    ``(resolved procs, key digest, value tag)``. The procs fallback
    matches the tuner's, so the key always names the scale the report
    will actually carry. Shared by the service and the batch CLI's
    ``--warm-start-from`` — one on-disk format."""
    space = tuned_app.search_space
    n = tuned_app.procs(procs)
    if space is not None and not space.grids(n):
        n = tuned_app.default_procs   # same fallback the tuner applies
    shape = tuple(int(s) for s in tuned_app.machine_shape(n))
    tag = value_tag(engine, dtype)
    key = plan_key(tuned_app.name, n, repr(spec_for(shape)), tag,
                   knobs=(beam, steps, elem_bytes))
    return n, key, tag


def warm_seeds_for(plans: PlanCache, app_name: str, procs: int, space, *,
                   exclude: bytes | None = None,
                   count: int = 2) -> list[Candidate]:
    """Cached winners for ``app_name`` nearest in scale to ``procs``,
    refit onto the live space's feasible grids — ``tune_app``'s
    ``warm_start`` argument, straight from a plan cache. Malformed or
    incompatible payloads are skipped."""
    seeds = []
    for payload in plans.nearest(app_name, procs, count=count,
                                 exclude=exclude):
        cand = _candidate_from(payload.get("candidate", {}))
        if cand is None:
            continue
        refit = refit_candidate(space, cand, procs)
        if refit is not None:
            seeds.append(refit)
    return seeds


class MappingService:
    """The resident tuning server. See the module docstring for the
    architecture; every public method is thread-safe."""

    def __init__(self, cache_dir: str | Path | None = None, *,
                 engine: str = "batched", dtype: str = "float64",
                 beam: int = DEFAULT_BEAM,
                 leaderboard: int = DEFAULT_LEADERBOARD,
                 steps: int = DEFAULT_STEPS,
                 elem_bytes: int = DEFAULT_ELEM_BYTES,
                 workers: int = 1,
                 queue_limit: int = DEFAULT_QUEUE_LIMIT,
                 coalesce: int = DEFAULT_COALESCE,
                 warm_start: bool = True,
                 store: bool = True) -> None:
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        if coalesce < 1:
            raise ValueError(f"coalesce must be >= 1, got {coalesce}")
        root = Path(cache_dir) if cache_dir is not None else None
        self.engine = engine
        self.dtype = dtype
        self.beam = beam
        self.leaderboard = leaderboard
        self.steps = steps
        self.elem_bytes = elem_bytes
        self.queue_limit = queue_limit
        self.coalesce = coalesce
        self.warm_start = warm_start
        self.store = store
        self.plans = PlanCache(None if root is None else root / "plans")
        self.prices = (PriceCache(root / "prices")
                       if root is not None else None)
        self.stats = ServiceStats()
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._heap: list = []          # (priority, deadline, seq, ticket)
        self._seq = itertools.count()
        self._closed = False
        self._workers = [
            threading.Thread(target=self._worker_loop,
                             name=f"mapsvc-worker-{i}", daemon=True)
            for i in range(max(workers, 0))
        ]
        for t in self._workers:
            t.start()

    # ------------------------------------------------------------- frontend
    def submit(self, request: "TuneRequest | RemapRequest") -> Ticket:
        """Enqueue one request. Always returns a ticket; admission
        control resolves it immediately with ``Rejected("queue-full")``
        or ``Rejected("closed")`` when the service cannot take it."""
        now = time.perf_counter()
        ticket = Ticket(request, now)
        with self._work:
            self.stats.submitted += 1
            if self.stats.first_submit_t is None:
                self.stats.first_submit_t = now
            if self._closed:
                self._resolve_locked(
                    ticket, Rejected("closed", "service closed", request.app))
            elif len(self._heap) >= self.queue_limit:
                self._resolve_locked(
                    ticket,
                    Rejected("queue-full",
                             f"admission queue at limit {self.queue_limit}",
                             request.app))
            else:
                deadline = (now + request.deadline_s
                            if request.deadline_s is not None
                            else float("inf"))
                heapq.heappush(
                    self._heap,
                    (request.priority, deadline, next(self._seq), ticket))
                self._work.notify()
        return ticket

    def map(self, request: TuneRequest,
            timeout: float | None = None) -> "MappingPlan | Rejected":
        """Submit-and-wait convenience. With ``workers=0`` the caller's
        thread drains the queue itself."""
        ticket = self.submit(request)
        if not self._workers:
            self.drain()
        return ticket.result(timeout)

    def drain(self) -> int:
        """Process the queue on the calling thread until empty; returns
        requests resolved. The ``workers=0`` mode — deterministic batch
        boundaries for tests and benchmarks."""
        resolved = 0
        while True:
            batch = self._take_batch(block=False)
            if not batch:
                return resolved
            resolved += len(batch)
            self._process_guarded(batch)

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Stop accepting, let workers finish the queue, join them. The
        remaining queue is drained inline when there are no workers."""
        with self._work:
            if self._closed:
                return
            self._closed = True
            self._work.notify_all()
        for t in self._workers:
            t.join()
        if not self._workers:
            self.drain()

    def __enter__(self) -> "MappingService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ scheduling
    def _resolve_locked(self, ticket: Ticket,
                        result: "MappingPlan | Rejected") -> None:
        now = time.perf_counter()
        self.stats.last_resolve_t = now
        if isinstance(result, Rejected):
            self.stats.note_rejected(result.reason)
        else:
            self.stats.completed += 1
            self.stats.latencies.append(now - ticket.submit_t)
        ticket._result = result
        ticket._event.set()

    def _resolve(self, ticket: Ticket,
                 result: "MappingPlan | Rejected") -> None:
        with self._lock:
            self._resolve_locked(ticket, result)

    def _take_batch(self, block: bool) -> list[Ticket]:
        """Pop up to ``coalesce`` requests in (priority, deadline, FIFO)
        order, shedding any whose deadline already passed. Blocks for
        work when ``block`` (worker mode) unless closing."""
        with self._work:
            while True:
                now = time.perf_counter()
                batch: list[Ticket] = []
                while self._heap and len(batch) < self.coalesce:
                    _, deadline, _, ticket = heapq.heappop(self._heap)
                    if now > deadline:
                        self._resolve_locked(
                            ticket,
                            Rejected("deadline",
                                     "deadline expired before dispatch",
                                     ticket.request.app))
                        continue
                    batch.append(ticket)
                if batch or not block:
                    return batch
                if self._closed:
                    return []
                self._work.wait(timeout=0.1)

    def _worker_loop(self) -> None:
        while True:
            batch = self._take_batch(block=True)
            if not batch:
                return                  # closed and queue empty
            self._process_guarded(batch)

    def _process_guarded(self, batch: list[Ticket]) -> None:
        """Run one batch, surviving a crash of the processing code
        itself (``_process`` catches per-request errors into typed
        ``Rejected``\\ s; this guard catches everything it could not —
        the "worker thread dies" case). Each still-unresolved ticket is
        requeued exactly once; a second crash resolves it with
        ``Rejected("error")`` so callers never hang on a dropped
        request."""
        try:
            self._process(batch)
        except BaseException as exc:  # noqa: BLE001 - survive the worker
            with self._work:
                self.stats.worker_crashes += 1
                for ticket in batch:
                    if ticket.done:
                        continue
                    if ticket._requeued:
                        self._resolve_locked(ticket, Rejected(
                            "error",
                            f"worker crashed twice on this request: {exc}",
                            ticket.request.app))
                        continue
                    ticket._requeued = True
                    deadline = (ticket.submit_t + ticket.request.deadline_s
                                if ticket.request.deadline_s is not None
                                else float("inf"))
                    heapq.heappush(
                        self._heap,
                        (ticket.request.priority, deadline,
                         next(self._seq), ticket))
                self._work.notify_all()

    # ------------------------------------------------------------- resolve
    def _request_key(self, request: TuneRequest):
        """Canonicalize one request: the tuned app object, resolved
        procs, machine shape, value tag and the plan-cache digest."""
        from repro import apps

        engine = request.engine or self.engine
        dtype = request.dtype or self.dtype
        app = apps.get(request.app)
        if request.machine_shape is not None:
            shape_over = tuple(int(s) for s in request.machine_shape)
            app = dataclasses.replace(
                app, machine_shape=lambda p, s=shape_over: s)
        tuned = time_tuned_app(app, steps=self.steps,
                               elem_bytes=self.elem_bytes, engine=engine,
                               dtype=dtype, cache=self.prices)
        n, key, tag = plan_key_for(tuned, request.procs, engine=engine,
                                   dtype=dtype, beam=self.beam,
                                   steps=self.steps,
                                   elem_bytes=self.elem_bytes)
        shape = tuple(int(s) for s in tuned.machine_shape(n))
        return tuned, n, shape, tag, key

    def _seeds(self, app_name: str, procs: int, space,
               exclude: bytes) -> list[Candidate]:
        if not self.warm_start:
            return []
        return warm_seeds_for(self.plans, app_name, procs, space,
                              exclude=exclude)

    def _remap(self, ticket: Ticket) -> None:
        """Serve one :class:`RemapRequest`: look up the stale winner and
        nearby cached plans as seeds, run the (restricted, warm)
        :func:`~repro.search.remap.remap_plan` search, and resolve the
        ticket with a ``provenance="remap"`` plan carrying the physical
        placement and recovery audit numbers. Remap plans are never
        stored — they answer one concrete failure, not the cache's
        (app, procs) question."""
        req = ticket.request
        t_start = time.perf_counter()
        try:
            from repro import apps

            engine = req.engine or self.engine
            dtype = req.dtype or self.dtype
            app = apps.get(req.app)
            if req.machine_shape is not None:
                shape_over = tuple(int(s) for s in req.machine_shape)
                app = dataclasses.replace(
                    app, machine_shape=lambda p, s=shape_over: s)
            tuned = time_tuned_app(app, steps=self.steps,
                                   elem_bytes=self.elem_bytes, engine=engine,
                                   dtype=dtype, cache=self.prices)
            n0, key, tag = plan_key_for(tuned, req.procs, engine=engine,
                                        dtype=dtype, beam=self.beam,
                                        steps=self.steps,
                                        elem_bytes=self.elem_bytes)
            stale_payload = self.plans.get(key)
            stale = (_candidate_from(stale_payload.get("candidate", {}))
                     if stale_payload is not None else None)
            seeds: list[Candidate] = []
            if self.warm_start:
                for payload in self.plans.nearest(app.name, n0, count=2,
                                                  exclude=key):
                    cand = _candidate_from(payload.get("candidate", {}))
                    if cand is not None:
                        seeds.append(cand)
            result = remap_plan(
                app, stale, req.failures, seeds=seeds, mode=req.mode,
                engine=engine, dtype=dtype, cache=self.prices,
                beam=self.beam, leaderboard=self.leaderboard,
                steps=self.steps, elem_bytes=self.elem_bytes,
                procs=req.procs)
        except Exception as exc:  # noqa: BLE001 - typed rejection
            self._resolve(ticket, Rejected("error", str(exc), req.app))
            return
        search_s = time.perf_counter() - t_start
        summary = result.summary()
        plan = dataclasses.replace(
            plan_from_report(result.report, value_tag_=value_tag(engine,
                                                                 dtype),
                             provenance="remap",
                             timings={"search_s": search_s}),
            remap={k: summary[k] for k in (
                "mode", "n_alive", "sub_shape", "proc_map", "placement",
                "degraded_step_s", "stale_step_s")})
        with self._lock:
            self.stats.remaps += 1
            self.stats.searches += 1
            self.stats.search_s.append(search_s)
            if result.report.warm_seeds:
                self.stats.warm += 1
            else:
                self.stats.cold += 1
        elapsed = time.perf_counter() - ticket.submit_t
        if req.timeout_s is not None and elapsed > req.timeout_s:
            self._resolve(ticket, Rejected(
                "timeout",
                f"resolved in {elapsed:.3f}s > budget {req.timeout_s}s",
                req.app))
            return
        self._resolve(ticket, dataclasses.replace(plan, elapsed_s=elapsed))

    def _process(self, batch: list[Ticket]) -> None:
        """Resolve one drained batch: remaps first (they outrank and
        never coalesce — each answers a distinct failure), then exact
        cache hits answer immediately; the rest coalesce by key, search
        Phases 1–2 each, then price *every* search's Phase-3 jobs in
        one shared ``price_jobs`` sweep before finishing Phase 4 per
        key."""
        groups: dict[bytes, list] = {}   # key -> [tuned, n, tag, tickets]
        for ticket in batch:
            if isinstance(ticket.request, RemapRequest):
                self._remap(ticket)
                continue
            req = ticket.request
            t_cache = time.perf_counter()
            try:
                tuned, n, _shape, tag, key = self._request_key(req)
                payload = self.plans.get(key)
            except Exception as exc:  # noqa: BLE001 - typed rejection
                self._resolve(ticket, Rejected("error", str(exc), req.app))
                continue
            now = time.perf_counter()
            with self._lock:
                self.stats.wait_s.append(t_cache - ticket.submit_t)
                self.stats.cache_s.append(now - t_cache)
            if payload is not None:
                with self._lock:
                    self.stats.cache_hits += 1
                self._resolve(ticket, MappingPlan.from_payload(
                    payload, provenance="cache",
                    elapsed_s=now - ticket.submit_t,
                    timings={"cache_s": now - t_cache}))
                continue
            group = groups.setdefault(key, [tuned, n, tag, []])
            group[3].append(ticket)

        if not groups:
            return

        # Phases 1-2 per unique key; Phase 3 jobs pooled across keys.
        pendings: dict[bytes, tuple] = {}
        all_jobs, job_spans = [], []
        for key, (tuned, n, tag, tickets) in groups.items():
            t_search = time.perf_counter()
            try:
                seeds = self._seeds(tuned.name, n, tuned.search_space, key)
                pending = prepare_tune(tuned, n, beam=self.beam,
                                       leaderboard=self.leaderboard,
                                       warm_start=seeds)
                jobs = list(pending.jobs())
            except Exception as exc:  # noqa: BLE001 - typed rejection
                for ticket in tickets:
                    self._resolve(ticket, Rejected("error", str(exc),
                                                   ticket.request.app))
                continue
            start = len(all_jobs)
            all_jobs.extend(jobs)
            job_spans.append((key, t_search, start, len(all_jobs)))
            pendings[key] = (pending, tuned, n, tag, tickets)

        if not pendings:
            return
        t3 = time.perf_counter()
        try:
            price_jobs(all_jobs)      # ONE sweep across every request
        except Exception as exc:  # noqa: BLE001 - typed rejection
            for pending, _, _, _, tickets in pendings.values():
                for ticket in tickets:
                    self._resolve(ticket, Rejected("error", str(exc),
                                                   ticket.request.app))
            return
        with self._lock:
            if all_jobs:
                self.stats.shared_pricing_passes += 1

        for key, t_search, _, _ in job_spans:
            pending, tuned, n, tag, tickets = pendings[key]
            pending.phase3_s = time.perf_counter() - t3
            try:
                report = pending.finish()
            except Exception as exc:  # noqa: BLE001 - typed rejection
                for ticket in tickets:
                    self._resolve(ticket, Rejected("error", str(exc),
                                                   ticket.request.app))
                continue
            search_s = time.perf_counter() - t_search
            provenance = "warm" if report.warm_seeds else "cold"
            plan = plan_from_report(report, value_tag_=tag,
                                    provenance=provenance,
                                    timings={"search_s": search_s})
            if self.store:
                self.plans.put(key, plan.payload())
            with self._lock:
                self.stats.searches += 1
                self.stats.search_s.append(search_s)
                self.stats.coalesced += max(len(tickets) - 1, 0)
                if report.warm_seeds:
                    self.stats.warm += len(tickets)
                else:
                    self.stats.cold += len(tickets)
            for ticket in tickets:
                now = time.perf_counter()
                elapsed = now - ticket.submit_t
                timeout_s = ticket.request.timeout_s
                if timeout_s is not None and elapsed > timeout_s:
                    # The plan is cached above regardless — the *next*
                    # ask answers instantly even though this one missed
                    # its budget.
                    self._resolve(ticket, Rejected(
                        "timeout",
                        f"resolved in {elapsed:.3f}s > budget {timeout_s}s",
                        ticket.request.app))
                    continue
                self._resolve(ticket, dataclasses.replace(
                    plan, elapsed_s=elapsed,
                    timings={**plan.timings, "wait_s": t3 - ticket.submit_t}))


def load_trace(path: str | Path) -> list[TuneRequest]:
    """Parse a JSONL request trace (one ``TuneRequest`` field dict per
    line; blank lines and ``#`` comments skipped)."""
    out = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        raw = json.loads(line)
        if "machine_shape" in raw and raw["machine_shape"] is not None:
            raw["machine_shape"] = tuple(int(s) for s in raw["machine_shape"])
        out.append(TuneRequest(**raw))
    return out


def replay(service: MappingService, requests: Sequence[TuneRequest],
           *, timeout: float | None = None
           ) -> list["MappingPlan | Rejected"]:
    """Submit a whole trace, drain (when the service has no workers) and
    collect results in submission order."""
    tickets = [service.submit(r) for r in requests]
    if not service._workers:
        service.drain()
    return [t.result(timeout) for t in tickets]


__all__ = [
    "DEFAULT_COALESCE",
    "DEFAULT_QUEUE_LIMIT",
    "MappingPlan",
    "MappingService",
    "Rejected",
    "RemapRequest",
    "Ticket",
    "TuneRequest",
    "load_trace",
    "plan_from_report",
    "plan_key_for",
    "replay",
    "value_tag",
    "warm_seeds_for",
]
