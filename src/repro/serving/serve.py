"""CLI front-end of the tuning service: ``python -m repro.serving.serve``.

Replays a request trace (``--trace FILE`` in JSONL, or a seeded
``--demo`` trace skewed toward repeats) through a resident
:class:`~repro.serving.mapsvc.MappingService` and prints one line per
resolved request plus the :class:`~repro.serving.stats.ServiceStats`
JSON metrics surface. Flags mirror the batch CLI
(``repro.apps.run``): ``--cache-dir`` persists both the plan cache and
the placement price cache, ``--backend``/``--dtype`` pick the pricing
engine.

Trace format (one JSON object per line; ``#`` comments and blanks ok)::

    {"app": "cannon"}
    {"app": "stencil", "procs": 16, "priority": 1}
    {"app": "cannon", "procs": 64, "deadline_s": 5.0, "timeout_s": 30.0}

Fields are :class:`~repro.serving.mapsvc.TuneRequest` arguments
verbatim. The process exits 1 only when a request failed with an
``"error"`` rejection — sheds (queue-full/deadline/timeout) are normal
operation under load and reported, not fatal.
"""
from __future__ import annotations

import argparse
import json
import random
import sys

from repro.serving.mapsvc import (
    DEFAULT_COALESCE,
    DEFAULT_QUEUE_LIMIT,
    MappingService,
    Rejected,
    TuneRequest,
    load_trace,
    replay,
)
from repro.search.tuner import DEFAULT_BEAM

_ENGINES = {"numpy": "batched", "jax": "batched-jax", "event": "event"}


def demo_trace(n: int, seed: int = 0) -> list[TuneRequest]:
    """A synthetic service workload: mixed apps and scales, skewed
    toward repeats (~70% of requests re-ask an earlier question — the
    regime a plan cache exists for)."""
    from repro import apps

    pool = [
        TuneRequest(app.name, procs)
        for app in apps.iter_apps()
        if app.search_space is not None
        for procs in (None, app.default_procs * 4)
    ]
    rng = random.Random(seed)
    out: list[TuneRequest] = []
    for _ in range(n):
        if out and rng.random() < 0.7:
            out.append(rng.choice(out))        # repeat an earlier question
        else:
            out.append(rng.choice(pool))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.serve",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--trace", metavar="FILE",
                     help="JSONL request trace to replay")
    src.add_argument("--demo", type=int, metavar="N",
                     help="generate a seeded N-request demo trace instead")
    ap.add_argument("--seed", type=int, default=0,
                    help="--demo trace seed (default 0)")
    ap.add_argument("--cache-dir", default=None, metavar="DIR",
                    help="persist plan + price caches under DIR "
                         "(plans in DIR/plans, prices in DIR/prices)")
    ap.add_argument("--backend", choices=tuple(_ENGINES), default="numpy",
                    help="pricing engine (default numpy)")
    ap.add_argument("--dtype", choices=("float64", "float32"),
                    default="float64", help="jax engine precision")
    ap.add_argument("--workers", type=int, default=1,
                    help="worker threads; 0 drains on the main thread "
                         "(default 1)")
    ap.add_argument("--queue-limit", type=int, default=DEFAULT_QUEUE_LIMIT,
                    help=f"admission bound (default {DEFAULT_QUEUE_LIMIT})")
    ap.add_argument("--coalesce", type=int, default=DEFAULT_COALESCE,
                    help="max requests batched per drain "
                         f"(default {DEFAULT_COALESCE})")
    ap.add_argument("--beam", type=int, default=DEFAULT_BEAM,
                    help=f"tuner beam width (default {DEFAULT_BEAM})")
    ap.add_argument("--no-warm-start", dest="warm_start",
                    action="store_false",
                    help="disable warm-seeding from nearby cached plans")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="also write the ServiceStats summary to PATH")
    ap.add_argument("--json", action="store_true",
                    help="emit one JSON object per request instead of text")
    args = ap.parse_args(argv)

    requests = (load_trace(args.trace) if args.trace
                else demo_trace(args.demo, args.seed))
    errors = 0
    with MappingService(args.cache_dir, engine=_ENGINES[args.backend],
                        dtype=args.dtype, beam=args.beam,
                        workers=args.workers, queue_limit=args.queue_limit,
                        coalesce=args.coalesce,
                        warm_start=args.warm_start) as svc:
        results = replay(svc, requests)
        for req, res in zip(requests, results):
            if isinstance(res, Rejected):
                errors += res.reason == "error"
                if args.json:
                    print(json.dumps({"app": req.app, "rejected": res.reason,
                                      "detail": res.detail}))
                else:
                    print(f"[{req.app}] REJECTED ({res.reason}) {res.detail}")
            elif args.json:
                print(json.dumps(res.summary()))
            else:
                cand = res.candidate
                desc = ("x".join(str(g) for g in cand["grid"])
                        + " " + "/".join(cand["dist"]))
                cost = ("" if res.placed_cost is None
                        else f" placed={res.placed_cost:.3e}s")
                print(f"[{res.app}] procs={res.procs} {res.provenance:>5s} "
                      f"{desc}{cost} ({res.elapsed_s * 1e3:.1f} ms)")
        summary = svc.stats.summary()
    print(json.dumps(summary, indent=2))
    if args.stats_json:
        with open(args.stats_json, "w") as fh:
            json.dump(summary, fh, indent=2)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
