"""Serving layer: continuous batching scheduler."""
from repro.serving.scheduler import ContinuousBatcher, Request, ServeStats
