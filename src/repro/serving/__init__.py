"""Serving layer: continuous batching scheduler + the tuning service.

Two servers live here. :class:`ContinuousBatcher` is the inference-side
slot scheduler (decode lockstep over a fixed cache pool);
:class:`MappingService` is mapping-as-a-service — a persistent,
concurrent tuning server with a cross-process plan cache
(:class:`PlanCache`), warm-started beam search, priority/deadline
admission and cross-request batched pricing (``python -m
repro.serving.serve`` is its CLI). Both report latencies through the
shared :func:`percentile` math in :mod:`repro.serving.stats`.
"""
from repro.serving.mapsvc import (
    MappingPlan,
    MappingService,
    Rejected,
    RemapRequest,
    Ticket,
    TuneRequest,
)
from repro.serving.plan_cache import PlanCache, plan_key
from repro.serving.scheduler import ContinuousBatcher, Request, ServeStats
from repro.serving.stats import ServiceStats, latency_summary, percentile

__all__ = [
    "ContinuousBatcher",
    "MappingPlan",
    "MappingService",
    "PlanCache",
    "Rejected",
    "RemapRequest",
    "Request",
    "ServeStats",
    "ServiceStats",
    "Ticket",
    "TuneRequest",
    "latency_summary",
    "percentile",
    "plan_key",
]
