"""Continuous-batching serving scheduler (vLLM-style slot management).

A fixed pool of B cache slots; requests join as slots free up, decode runs
in lockstep over the whole pool every step, finished sequences release
their slot immediately (no tail-of-batch stragglers). The cache slot is
reset implicitly: a new request writes from position 0, and the
position-validity mask in decode attention ignores stale entries.

This is the production pattern the decode_32k dry-run shape sizes: batch
128 slots x 32k cache on a pod.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.stats import percentile


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    # runtime state
    generated: list = dataclasses.field(default_factory=list)
    pos: int = 0                       # next position to feed
    slot: int = -1
    done: bool = False
    enqueue_t: float = 0.0
    finish_t: float = 0.0


@dataclasses.dataclass
class ServeStats:
    completed: int = 0
    steps: int = 0
    tokens_out: int = 0
    latencies: list = dataclasses.field(default_factory=list)

    def summary(self) -> dict:
        # Quantiles via the shared nearest-rank helper (repro.serving
        # .stats.percentile) — exact at tiny N, no off-by-one indexing.
        return {
            "completed": self.completed,
            "steps": self.steps,
            "tokens_out": self.tokens_out,
            "p50_latency_s": percentile(self.latencies, 50),
            "p95_latency_s": percentile(self.latencies, 95),
            "p99_latency_s": percentile(self.latencies, 99),
        }


class ContinuousBatcher:
    """Slot-based continuous batching around a model's decode_step.

    The model's decode_step signature is (params, cache, pos, tokens) with
    a SHARED scalar position; per-slot positions require per-slot masking,
    so the batcher tracks per-slot positions host-side and feeds the
    maximum (cache slots write at their own per-slot index via the token's
    implicit position — for the CPU-scale demo we keep a per-slot cache
    column and step slots in lockstep, padding finished/empty slots).
    """

    def __init__(self, model, params, n_slots: int, max_len: int,
                 eos_token: int | None = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos = eos_token
        self.queue: collections.deque[Request] = collections.deque()
        self.active: dict[int, Request] = {}       # slot -> request
        self.free_slots = list(range(n_slots))
        # one independent cache per slot (batch=1) so positions are per-slot
        self.caches = [model.init_cache(1, max_len) for _ in range(n_slots)]
        self._decode = jax.jit(model.decode_step)
        self.stats = ServeStats()

    # ------------------------------------------------------------- frontend
    def submit(self, req: Request) -> None:
        req.enqueue_t = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self.free_slots:
            slot = self.free_slots.pop()
            req = self.queue.popleft()
            req.slot = slot
            self.caches[slot] = jax.tree.map(
                jnp.zeros_like, self.caches[slot]
            )
            self.active[slot] = req

    # ----------------------------------------------------------------- step
    def step(self) -> None:
        """One scheduler tick: admit, advance every active slot one token."""
        self._admit()
        if not self.active:
            return
        for slot, req in list(self.active.items()):
            if req.pos < len(req.prompt):
                tok = int(req.prompt[req.pos])          # prefill (1 tok/step)
            else:
                tok = req.generated[-1] if req.generated else 0
            logits, self.caches[slot] = self._decode(
                self.params, self.caches[slot], jnp.int32(req.pos),
                jnp.asarray([[tok]], jnp.int32),
            )
            req.pos += 1
            if req.pos >= len(req.prompt):              # decoding phase
                nxt = int(jnp.argmax(logits.reshape(-1)))
                nxt = min(nxt, self.model.cfg.vocab_size - 1)
                req.generated.append(nxt)
                self.stats.tokens_out += 1
                hit_eos = self.eos is not None and nxt == self.eos
                if (len(req.generated) >= req.max_new_tokens or hit_eos
                        or req.pos >= self.max_len - 1):
                    req.done = True
                    req.finish_t = time.perf_counter()
                    self.stats.completed += 1
                    self.stats.latencies.append(req.finish_t - req.enqueue_t)
                    del self.active[slot]
                    self.free_slots.append(slot)
        self.stats.steps += 1

    def run_until_drained(self, max_steps: int = 100_000) -> ServeStats:
        while (self.queue or self.active) and self.stats.steps < max_steps:
            self.step()
        return self.stats
