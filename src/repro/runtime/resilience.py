"""Fault tolerance + straggler mitigation + elastic rescale (simulated).

At 1000+ nodes the mean time between failures is hours, so the framework
treats failure as the steady state:

  * :class:`FailureInjector` — deterministic simulated faults for tests
    (the CPU container has no real nodes to kill);
  * :class:`Supervisor` — the restart policy: catch step failure, restore
    the latest checkpoint, rebuild the step function, continue;
  * :class:`StragglerMonitor` — per-step timing watermarks; flags replicas
    whose EMA exceeds a p95-based threshold and emits a mitigation plan
    (bounded async dispatch already softens transient stragglers — the
    paper's Backpressure directive, repurposed);
  * :func:`elastic_plan` — given the surviving chip count, re-run the
    Mapple decompose planner and emit the (mesh, resharding) plan; combined
    with the mesh-agnostic checkpoints this is restore-with-new-plan.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable

import numpy as np


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raises SimulatedFailure at the scheduled steps (deterministic)."""

    fail_at_steps: tuple[int, ...] = ()
    max_failures: int = 1_000_000
    fired: int = 0

    def check(self, step: int) -> None:
        if self.fired < self.max_failures and step in self.fail_at_steps:
            self.fired += 1
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class Supervisor:
    """Restart-from-checkpoint policy around a step function."""

    checkpoint_manager: Any
    max_restarts: int = 3
    restarts: int = 0

    def run(self, *, state, start_step: int, n_steps: int,
            step_fn: Callable[[int, Any], Any],
            save_every: int, extra: dict | None = None,
            injector: FailureInjector | None = None):
        """Drives the loop; on failure restores the latest checkpoint and
        resumes. Returns (final_state, history)."""
        history: list[dict] = []
        step = start_step
        while step < n_steps:
            try:
                if injector is not None:
                    injector.check(step)
                state, metrics = step_fn(step, state)
                history.append({"step": step, **metrics})
                step += 1
                if step % save_every == 0:
                    self.checkpoint_manager.save(
                        step, state, {"cursor": step, **(extra or {})}
                    )
            except SimulatedFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                restored = self.checkpoint_manager.latest_step()
                if restored is None:
                    # No checkpoint yet: restart from the initial state.
                    step = start_step
                    history.append({"step": step, "event": f"restart:{e}"})
                    continue
                step, state, _ = self.checkpoint_manager.restore(restored)
                history.append({"step": step, "event": f"restored:{e}"})
        return state, history


@dataclasses.dataclass
class StragglerMonitor:
    """EMA per-replica step times; flags p95 outliers."""

    n_replicas: int
    ema_alpha: float = 0.2
    threshold: float = 1.5          # x median EMA

    def __post_init__(self):
        self.ema = np.zeros(self.n_replicas)
        self.count = 0

    def observe(self, step_times: np.ndarray) -> dict:
        """step_times: per-replica seconds for the last step."""
        if self.count == 0:
            self.ema = step_times.astype(np.float64)
        else:
            self.ema = (
                self.ema_alpha * step_times + (1 - self.ema_alpha) * self.ema
            )
        self.count += 1
        med = float(np.median(self.ema))
        flags = np.where(self.ema > self.threshold * max(med, 1e-9))[0]
        plan = None
        if len(flags):
            plan = {
                "action": "rebalance",
                "slow_replicas": flags.tolist(),
                # bounded async dispatch absorbs transient skew; persistent
                # skew triggers shard reassignment at the next checkpoint.
                "reassign_at_step": self.count + 10,
            }
        return {
            "median_ema": med,
            "max_over_median": float(self.ema.max() / max(med, 1e-9)),
            "stragglers": flags.tolist(),
            "plan": plan,
        }


def elastic_plan(n_chips_surviving: int, workload) -> dict:
    """Re-plan parallelism for the surviving chip count (Mapple decompose).

    workload: repro.core.autosharder.LMWorkload. Returns the new MeshPlan +
    the resharding recipe (restore checkpoint under the new shardings).
    """
    from repro.core.autosharder import plan_mesh

    # Degrade to the largest power-of-two no bigger than the survivor count
    # (torus wiring constraint on real pods).
    usable = 2 ** int(math.floor(math.log2(max(n_chips_surviving, 1))))
    plan = plan_mesh(usable, workload)
    return {
        "usable_chips": usable,
        "mesh": {"data": plan.dp, "model": plan.tp},
        "ep": plan.ep,
        "resharding": "restore latest checkpoint with new param shardings",
        "step_comm_bytes": plan.step_comm_bytes,
    }
