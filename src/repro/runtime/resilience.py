"""Fault tolerance + straggler mitigation + elastic rescale (simulated).

At 1000+ nodes the mean time between failures is hours, so the framework
treats failure as the steady state:

  * :class:`FailureInjector` — deterministic simulated faults for tests
    (the CPU container has no real nodes to kill);
  * :class:`Supervisor` — the restart policy: catch step failure, restore
    the latest checkpoint, rebuild the step function, continue;
  * :class:`StragglerMonitor` — per-step timing watermarks; flags replicas
    whose EMA exceeds a p95-based threshold and emits a mitigation plan
    (bounded async dispatch already softens transient stragglers — the
    paper's Backpressure directive, repurposed);
  * :func:`elastic_plan` — given the surviving chip count, re-run the
    Mapple decompose planner and emit the (mesh, resharding) plan; combined
    with the mesh-agnostic checkpoints this is restore-with-new-plan.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raises SimulatedFailure at the scheduled steps (deterministic).

    Each scheduled step fires **at most once**: after a restore rewinds
    the loop past an already-fired step, re-executing it must not
    re-raise — a real node dies once, and the re-fire would burn one
    restart per replay until ``max_restarts`` was exhausted."""

    fail_at_steps: tuple[int, ...] = ()
    max_failures: int = 1_000_000
    fired: int = 0
    fired_steps: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if (self.fired < self.max_failures and step in self.fail_at_steps
                and step not in self.fired_steps):
            self.fired_steps.add(step)
            self.fired += 1
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class Supervisor:
    """Restart-from-checkpoint policy around a step function."""

    checkpoint_manager: Any
    max_restarts: int = 3
    restarts: int = 0

    def run(self, *, state, start_step: int, n_steps: int,
            step_fn: Callable[[int, Any], Any],
            save_every: int, extra: dict | None = None,
            injector: FailureInjector | None = None,
            remap_fn: Callable[[Exception], Any] | None = None):
        """Drives the loop; on failure restores the latest checkpoint and
        resumes. Returns (final_state, history).

        ``remap_fn`` makes the restart *fault-aware*: called with the
        failure before each restore, it may return a remap plan (e.g.
        :func:`elastic_plan`'s output, or a
        :class:`~repro.serving.mapsvc.RemapRequest` resolution). A dict
        plan whose ``"step_fn"`` entry is callable swaps the step
        function — restore-with-new-placement — and the plan (minus the
        callable) is recorded in the history as a ``remapped`` event.
        Returning ``None`` keeps the old plan (plain restart)."""
        history: list[dict] = []
        step = start_step
        while step < n_steps:
            try:
                if injector is not None:
                    injector.check(step)
                state, metrics = step_fn(step, state)
                history.append({"step": step, **metrics})
                step += 1
                if step % save_every == 0:
                    self.checkpoint_manager.save(
                        step, state, {"cursor": step, **(extra or {})}
                    )
            except SimulatedFailure as e:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                if remap_fn is not None:
                    plan = remap_fn(e)
                    if plan is not None:
                        recorded = plan
                        if isinstance(plan, dict):
                            new_fn = plan.get("step_fn")
                            if callable(new_fn):
                                step_fn = new_fn
                            recorded = {k: v for k, v in plan.items()
                                        if k != "step_fn"}
                        history.append({"step": step, "event": "remapped",
                                        "plan": recorded})
                restored = self.checkpoint_manager.latest_step()
                if restored is None:
                    # No checkpoint yet: restart from the initial state.
                    step = start_step
                    history.append({"step": step, "event": f"restart:{e}"})
                    continue
                step, state, _ = self.checkpoint_manager.restore(restored)
                history.append({"step": step, "event": f"restored:{e}"})
        return state, history


@dataclasses.dataclass
class StragglerMonitor:
    """EMA per-replica step times; flags p95 outliers."""

    n_replicas: int
    ema_alpha: float = 0.2
    threshold: float = 1.5          # x median EMA

    def __post_init__(self):
        self.ema = np.zeros(self.n_replicas)
        self.count = 0

    def observe(self, step_times: np.ndarray) -> dict:
        """step_times: per-replica seconds for the last step."""
        if self.count == 0:
            self.ema = step_times.astype(np.float64)
        else:
            self.ema = (
                self.ema_alpha * step_times + (1 - self.ema_alpha) * self.ema
            )
        self.count += 1
        med = float(np.median(self.ema))
        flags = np.where(self.ema > self.threshold * max(med, 1e-9))[0]
        plan = None
        if len(flags):
            plan = {
                "action": "rebalance",
                "slow_replicas": flags.tolist(),
                # bounded async dispatch absorbs transient skew; persistent
                # skew triggers shard reassignment at the next checkpoint.
                "reassign_at_step": self.count + 10,
            }
        return {
            "median_ema": med,
            "max_over_median": float(self.ema.max() / max(med, 1e-9)),
            "stragglers": flags.tolist(),
            "plan": plan,
        }


def elastic_plan(n_chips_surviving: int, workload, *,
                 max_tp: int = 64) -> dict:
    """Re-plan parallelism for the surviving chip count (Mapple decompose).

    workload: repro.core.autosharder.LMWorkload. Returns the new MeshPlan +
    the resharding recipe (restore checkpoint under the new shardings).

    The usable chip count routes through the tuner's feasibility
    machinery: the mesh planner's divisibility constraints become a
    search space (:func:`~repro.core.autosharder.mesh_search_space`) and
    the plan keeps every survivor the space can host — 12 of 16 chips
    stay 12 when ``dp=12`` divides the batch, instead of collapsing to
    the power-of-two 8. When the survivor count itself is infeasible,
    :func:`~repro.search.tuner.nearest_feasible_procs` lands on the
    nearest feasible count that does not exceed the survivors.
    """
    from repro.core.autosharder import mesh_search_space, plan_mesh
    from repro.search.tuner import feasible_procs, nearest_feasible_procs

    space = mesh_search_space(workload, max_tp=max_tp)
    n = max(int(n_chips_surviving), 1)
    if feasible_procs(space, n):
        usable = n
    else:
        near = nearest_feasible_procs(space, n, count=8,
                                      max_delta=max(n - 1, 1))
        usable = next((m for m in near if m <= n), None)
        if usable is None:     # every near-feasible count needs more chips
            usable = next(
                (m for m in range(n - 1, 0, -1) if feasible_procs(space, m)),
                None)
        if usable is None:
            raise ValueError(
                f"no feasible chip count <= {n} for this workload"
            )
    plan = plan_mesh(usable, workload, max_tp=max_tp)
    return {
        "usable_chips": usable,
        "idle_chips": n - usable,
        "mesh": {"data": plan.dp, "model": plan.tp},
        "ep": plan.ep,
        "resharding": "restore latest checkpoint with new param shardings",
        "step_comm_bytes": plan.step_comm_bytes,
    }
