"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantized gradients before the DP all-reduce: 4x (fp32) / 2x
(bf16) wire-volume reduction on the dominant collective, with an error-
feedback accumulator so the quantization bias does not accumulate across
steps (Seide et al. 2014; Karimireddy et al. 2019 style).

In the pjit step the compress/decompress pair wraps the gradient tree; XLA
all-reduces the int8 payload. The error buffer is part of the train state
(sharded like the grads).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x: jax.Array) -> tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Block-wise symmetric int8 quantization. Returns (q, scales)."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_tree(grads: Any, error: Any) -> tuple[Any, Any]:
    """Quantize (grads + error); new error = input - dequantized.

    Returns (compressed_grads_as_float, new_error). The compressed values
    are exactly representable in int8 blocks — the all-reduce moves 1/4 of
    the bytes when the runtime transports the (q, scale) pair; here we model
    the numerics (what lands in the optimizer) and let the collective-bytes
    analysis account for the wire format.
    """

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize(x)
        deq = dequantize(q, s, g.shape, jnp.float32)
        return deq, x - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return comp, new_err


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
