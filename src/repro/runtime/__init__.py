"""Runtime resilience: failures, stragglers, elastic, compression."""
from repro.runtime.resilience import (
    FailureInjector, SimulatedFailure, StragglerMonitor, Supervisor, elastic_plan,
)
from repro.runtime import compression
