"""Test-support utilities (hypothesis fallback for hermetic environments)."""
