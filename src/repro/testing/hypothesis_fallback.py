"""Minimal, deterministic stand-in for ``hypothesis`` when it is absent.

The test suite property-tests the processor-space algebra and the
communication-volume models with ``hypothesis``. That dependency is declared
in ``pyproject.toml`` and installed in CI, but hermetic environments (the
container this repo is developed in) cannot pip-install. This module
implements exactly the strategy surface the tests use — ``integers``,
``sampled_from``, ``lists`` (+ ``.map``), ``data`` — and a ``@given`` that
replays a fixed number of deterministically seeded examples.

It is NOT a shrinking property-testing engine: failures report the drawn
values but are not minimized. ``tests/conftest.py`` installs it into
``sys.modules`` only when the real ``hypothesis`` is missing, so CI always
runs the real engine.
"""
from __future__ import annotations

import inspect
import random
import sys
import types
import zlib
from typing import Any, Callable, Sequence

_DEFAULT_MAX_EXAMPLES = 100
_ATTR = "_mapple_max_examples"


class SearchStrategy:
    """A strategy is just a draw function over a seeded ``random.Random``."""

    def __init__(self, draw: Callable[[random.Random], Any]) -> None:
        self._draw = draw

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(rng: random.Random) -> Any:
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too restrictive")

        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    pool = list(elements)
    return SearchStrategy(lambda rng: pool[rng.randrange(len(pool))])


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng: random.Random) -> list:
        n = rng.randint(min_size, max_size)
        return [elements._draw(rng) for _ in range(n)]

    return SearchStrategy(draw)


class DataObject:
    """Interactive draws inside a test body (``st.data()``)."""

    def __init__(self, rng: random.Random) -> None:
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label: str | None = None) -> Any:
        return strategy._draw(self._rng)


def data() -> SearchStrategy:
    return SearchStrategy(lambda rng: DataObject(rng))


def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline: Any = None, **_: Any) -> Callable:
    def deco(fn: Callable) -> Callable:
        setattr(fn, _ATTR, max_examples)
        return fn

    return deco


def given(**strategies: SearchStrategy) -> Callable:
    def deco(fn: Callable) -> Callable:
        def wrapper(*args: Any, **kwargs: Any) -> None:
            n = getattr(wrapper, _ATTR, None) or getattr(
                fn, _ATTR, _DEFAULT_MAX_EXAMPLES
            )
            base = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            for i in range(n):
                rng = random.Random((base << 20) | i)
                drawn = {k: s._draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:  # annotate with the failing example
                    shown = {
                        k: v for k, v in drawn.items()
                        if not isinstance(v, DataObject)
                    }
                    raise AssertionError(
                        f"falsifying example (#{i}): {shown!r}"
                    ) from e

        # Copy identity but NOT __wrapped__ (pytest would then introspect
        # the original signature and treat drawn arguments as fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


def install() -> None:
    """Register this module as ``hypothesis`` in ``sys.modules``."""
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "lists", "data"):
        setattr(st, name, globals()[name])
    st.SearchStrategy = SearchStrategy
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    mod.__mapple_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
