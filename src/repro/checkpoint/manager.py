"""Checkpointing: step-atomic, mesh-agnostic, async-capable, hash-verified.

Layout:  <dir>/step_<N>/
            manifest.json        (step, flat keys, shapes, dtypes, sha256s,
                                  data cursor, config fingerprint)
            arrays.npz           (flat key -> ndarray, saved unsharded)
         <dir>/LATEST            (atomic pointer file)

Mesh-agnostic restore: arrays are saved as logical (unsharded) values and
re-placed under whatever shardings the *new* mesh prescribes — this is what
makes elastic rescale (repro/runtime/elastic.py) a restore-with-new-plan
rather than a bespoke migration.

Async mode ships the host copy off-thread so the train loop only blocks on
device->host transfer, not on disk I/O (checkpoint/restart requirement for
long runs).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


SEP = "/"


def _flatten(tree, prefix=()) -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, prefix + (str(k),)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, prefix + (str(i),)))
    else:
        out[SEP.join(prefix)] = tree
    return out


def _unflatten(flat: dict[str, Any]) -> dict:
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_save: bool = False

    def __post_init__(self):
        Path(self.directory).mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: dict, extra: dict | None = None) -> str:
        """state: pytree of jax/np arrays. Returns the checkpoint path."""
        flat = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {})
            )
            self._thread.start()
            return str(Path(self.directory) / f"step_{step}")
        return self._write(step, host, extra or {})

    def _write(self, step: int, host: dict[str, np.ndarray], extra: dict) -> str:
        final = Path(self.directory) / f"step_{step}"
        tmp = Path(
            tempfile.mkdtemp(prefix=f".step_{step}_", dir=self.directory)
        )
        manifest = {
            "step": step,
            "extra": extra,
            "arrays": {
                k: {
                    "shape": list(v.shape),
                    "dtype": str(v.dtype),
                    "sha256": _sha(v),
                }
                for k, v in host.items()
            },
        }
        np.savez(tmp / "arrays.npz", **host)
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic publish
        latest = Path(self.directory) / "LATEST"
        tmp_latest = latest.with_suffix(".tmp")
        tmp_latest.write_text(str(step))
        os.replace(tmp_latest, latest)
        self._gc()
        return str(final)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(Path(self.directory) / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in Path(self.directory).glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_", 1)[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        latest = Path(self.directory) / "LATEST"
        if latest.exists():
            s = int(latest.read_text().strip())
            if (Path(self.directory) / f"step_{s}" / "manifest.json").exists():
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, shardings=None,
                verify: bool = True) -> tuple[int, dict, dict]:
        """Returns (step, state, extra). ``shardings``: optional pytree of
        NamedSharding to place restored arrays onto a (possibly different)
        mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = Path(self.directory) / f"step_{step}"
        manifest = json.loads((path / "manifest.json").read_text())
        with np.load(path / "arrays.npz") as z:
            host = {k: z[k] for k in z.files}
        if verify:
            for k, meta in manifest["arrays"].items():
                if _sha(host[k]) != meta["sha256"]:
                    raise IOError(f"checkpoint corruption in {k} at step {step}")
        flat_shardings = _flatten(shardings) if shardings is not None else {}
        placed = {}
        for k, v in host.items():
            s = flat_shardings.get(k)
            placed[k] = jax.device_put(v, s) if s is not None else v
        return step, _unflatten(placed), manifest["extra"]
