"""Checkpoint substrate."""
from repro.checkpoint.manager import CheckpointManager
