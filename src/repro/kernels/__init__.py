"""Pallas TPU kernels for the compute hot spots.

<name>.py   -- pl.pallas_call + explicit BlockSpec VMEM tiling
ops.py      -- jit'd public wrappers (interpret mode on CPU)
ref.py      -- pure-jnp oracles (the allclose targets)
"""
from repro.kernels import ops, ref  # noqa: F401
