"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def flash_attention(q, k, v, *, scale=None, window: int = 0,
                    causal: bool = True):
    """q/k/v: (BH, S, d) — naive softmax attention."""
    BH, S, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) * scale
    pos = jnp.arange(S)
    ok = jnp.ones((S, S), bool)
    if causal:
        ok = ok & (pos[:, None] >= pos[None, :])
    if window > 0:
        ok = ok & (pos[:, None] - pos[None, :] < window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def stencil(field: jax.Array) -> jax.Array:
    """One 5-point Jacobi sweep, edge-replicate boundaries."""
    fp = jnp.pad(field, 1, mode="edge")
    return (0.2 * (
        fp[1:-1, 1:-1] + fp[:-2, 1:-1] + fp[2:, 1:-1]
        + fp[1:-1, :-2] + fp[1:-1, 2:]
    )).astype(field.dtype)


def wkv6(r, k, v, w, u):
    """Sequential-scan WKV6. r/k/v/w: (BH,T,N); u: (BH,N)."""
    BH, T, N = r.shape

    def one(rb, kb, vb, wb, ub):
        def step(s, inp):
            r_t, k_t, v_t, w_t = inp
            kv = k_t[:, None] * v_t[None, :]
            y = ((s + ub[:, None] * kv) * r_t[:, None]).sum(axis=0)
            s = w_t[:, None] * s + kv
            return s, y

        s0 = jnp.zeros((N, N), jnp.float32)
        s, ys = jax.lax.scan(step, s0, (rb, kb, vb, wb))
        return ys, s

    y, s = jax.vmap(one)(r, k, v, w, u)
    return y.astype(r.dtype), s


def segment_rowmax(vals: jax.Array, seg: int = 1) -> jax.Array:
    """Per-row max of contiguous length-``seg`` segment sums (vals >= 0)."""
    rows, cols = vals.shape
    return vals.reshape(rows, cols // seg, seg).sum(axis=2).max(axis=1)


def mamba_scan(xs, dt, Bs, Cs, A):
    """Sequential selective scan. xs/dt: (B,T,di); Bs/Cs: (B,T,n); A: (di,n)."""
    B, T, di = xs.shape
    n = A.shape[1]

    def one(x_b, dt_b, B_b, C_b):
        def step(h, inp):
            x_t, dt_t, B_t, C_t = inp
            dA = jnp.exp(dt_t[:, None] * A)
            h = dA * h + (dt_t * x_t)[:, None] * B_t[None, :]
            y = (h * C_t[None, :]).sum(axis=1)
            return h, y

        h0 = jnp.zeros((di, n), jnp.float32)
        h, ys = jax.lax.scan(step, h0, (x_b, dt_b, B_b, C_b))
        return ys, h

    y, s = jax.vmap(one)(xs, dt, Bs, Cs)
    return y.astype(xs.dtype), s
