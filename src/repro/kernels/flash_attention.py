"""Flash attention (causal, optional sliding window) — Pallas TPU kernel.

Adaptation note (DESIGN.md): the CUDA flash algorithm tiles over SM shared
memory with warp-level softmax reductions; the TPU version tiles over VMEM
with the grid's sequential minor axis playing the role of the KV loop, fp32
running max / denominator held in VMEM scratch across grid steps, and the
MXU consuming (bq, d) x (d, bk) tiles. GQA is handled by folding the group
into the query-head grid axis so the same KV tile serves all group members.

Layout: q (BH, S, d), k/v (BKV, S, d) with BH = B*H, BKV = B*Kv.
Grid: (BH, S/bq, S/bk) — kv axis innermost (sequential accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, d_ref, *,
                  scale: float, window: int, n_k: int, bq: int, bk: int,
                  causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    run = True
    if causal:
        # Skip fully-masked blocks (the whole block above the diagonal).
        run = (ki * bk) <= (qi * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                     # (bq, d)
        k = k_ref[0]                                     # (bk, d)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                        # (bq, bk)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok = ok & (q_pos >= k_pos)
        if window > 0:
            ok = ok & (q_pos - k_pos < window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        d_ref[...] = d_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        denom = jnp.maximum(d_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,           # (BH, S, d)
    k: jax.Array,           # (BH, S, d)  (pre-expanded GQA)
    v: jax.Array,
    *,
    scale: float | None = None,
    window: int = 0,
    causal: bool = True,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    BH, S, d = q.shape
    scale = scale if scale is not None else d ** -0.5
    bq, bk = min(bq, S), min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    grid = (BH, S // bq, S // bk)
    kern = functools.partial(
        _flash_kernel, scale=scale, window=window, n_k=grid[2],
        bq=bq, bk=bk, causal=causal,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
