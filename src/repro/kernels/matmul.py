"""Blocked MXU matmul kernel (local compute of the distributed algorithms).

Tiling: grid (M/bm, N/bn, K/bk); A and B tiles stream through VMEM, the
output tile lives in VMEM across the K loop (the grid's fastest axis) and
accumulates in fp32. Block sizes default to 128/256/512-aligned shapes so
the MXU (128x128 systolic array) runs full tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with fp32 accumulation. Shapes must tile evenly."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
