"""2D 5-point stencil — Pallas TPU kernel (paper app 8's hot loop).

Halo handling without overlapping blocks: the same input array is passed
three times with row-block index maps (i-1, i, i+1) clamped at the grid
edges; the kernel assembles the 1-deep row halo in VMEM from the
neighbouring blocks' edge rows and edge-replicates columns in-register.
Grid is 1D over row tiles; full rows live in VMEM (row-major friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BM = 128


def _stencil_kernel(prev_ref, cur_ref, next_ref, o_ref, *, n_i: int, bm: int):
    i = pl.program_id(0)
    cur = cur_ref[...]                                 # (bm, N)
    # Row halos from neighbouring blocks (edge-replicated at boundaries).
    top = jnp.where(i == 0, cur[0:1], prev_ref[bm - 1:bm])
    bot = jnp.where(i == n_i - 1, cur[bm - 1:bm], next_ref[0:1])
    f = jnp.concatenate([top, cur, bot], axis=0)       # (bm+2, N)
    # Column halos by edge replication (in-register shift).
    left = jnp.concatenate([f[:, 0:1], f[:, :-1]], axis=1)
    right = jnp.concatenate([f[:, 1:], f[:, -1:]], axis=1)
    out = 0.2 * (f + left + right
                 + jnp.concatenate([f[0:1], f[:-1]], axis=0)
                 + jnp.concatenate([f[1:], f[-1:]], axis=0))
    o_ref[...] = out[1:-1, :].astype(o_ref.dtype)


def stencil_pallas(field: jax.Array, *, bm: int = DEFAULT_BM,
                   interpret: bool = False) -> jax.Array:
    """One Jacobi sweep of the 5-point stencil with edge-replicate BCs."""
    M, N = field.shape
    bm = min(bm, M)
    assert M % bm == 0, (M, bm)
    n_i = M // bm
    kern = functools.partial(_stencil_kernel, n_i=n_i, bm=bm)

    def clamp(idx):
        return jnp.clip(idx, 0, n_i - 1)

    return pl.pallas_call(
        kern,
        grid=(n_i,),
        in_specs=[
            pl.BlockSpec((bm, N), lambda i: (clamp(i - 1), 0)),
            pl.BlockSpec((bm, N), lambda i: (i, 0)),
            pl.BlockSpec((bm, N), lambda i: (clamp(i + 1), 0)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), field.dtype),
        interpret=interpret,
    )(field, field, field)
