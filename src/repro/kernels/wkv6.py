"""WKV6 recurrence (RWKV-6 "Finch") — Pallas TPU kernel.

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

Adaptation note (DESIGN.md): the reference CUDA kernel assigns one thread
per channel with shared-memory staging. On TPU the natural decomposition is
one grid step per (batch*head, time-chunk): the (N, N) state matrix lives
in VMEM scratch and persists across the sequential time-chunk axis; inside
a chunk a fori_loop applies the rank-1 updates with VPU outer products.
Time stays sequential (the recurrence is inherently so); parallelism comes
from the (batch*head) grid axis — on real TPUs, from Megacore + multiple
chips via shard_map over heads.

Layout: r/k/v/w (BH, T, N) fp32; u (BH, N); outputs y (BH, T, N) and the
final state (BH, N, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 128


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, sout_ref, s_ref,
                 *, bt: int, n_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0]                                        # (N,)

    def step(t, _):
        r_t = r_ref[0, t]                               # (N,)
        k_t = k_ref[0, t]
        v_t = v_ref[0, t]
        w_t = w_ref[0, t]
        kv = k_t[:, None] * v_t[None, :]                # (N, N) rank-1
        s = s_ref[...]
        y = ((s + u[:, None] * kv) * r_t[:, None]).sum(axis=0)
        y_ref[0, t] = y.astype(y_ref.dtype)
        s_ref[...] = w_t[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, bt, step, 0)

    @pl.when(ti == n_t - 1)
    def _flush():
        sout_ref[0] = s_ref[...].astype(sout_ref.dtype)


def wkv6_pallas(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    *, bt: int = DEFAULT_BT, interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (BH,T,N), final_state (BH,N,N)). Zero initial state."""
    BH, T, N = r.shape
    bt = min(bt, T)
    assert T % bt == 0, (T, bt)
    grid = (BH, T // bt)
    kern = functools.partial(_wkv6_kernel, bt=bt, n_t=grid[1])
    y, s_out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, N), lambda b, t: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, N), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, N, N), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, N), r.dtype),
            jax.ShapeDtypeStruct((BH, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_out
