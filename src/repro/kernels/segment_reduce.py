"""Blocked segment-reduce kernel for congestion tables.

The simulator's JAX pricing backend (``repro.sim.jax_backend``) reduces
dense per-candidate congestion tables ``vals[row, col]`` — one row per
(candidate, slab) pair, one column per processor — in two shapes:

  * ``seg == 1``: per-row **max** (the stride-1 level, where every port
    carries at most one message per slab and direction);
  * ``seg == level stride``: per-row max of contiguous **segment sums**
    (the outer levels, where the ``seg`` processors of one subtree share
    the subtree's port and their byte loads add before the max).

Both are one kernel: ``out[r] = max_j sum_{i<seg} vals[r, j*seg + i]``.

Tiling: grid (rows/br, cols/bc) with the column axis fastest; each block
reduces its (br, bc) tile to per-row partial maxima accumulated in VMEM
across the column sweep (``bc`` is always a multiple of ``seg``, so no
segment straddles a block boundary). Values are assumed non-negative
(they are message counts and byte loads): the wrapper zero-pads ragged
shapes, and a zero pad segment is exactly an idle port.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BR = 8
DEFAULT_BC = 512


def _segment_rowmax_kernel(v_ref, o_ref, acc_ref, *, seg: int, n_c: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    blk = v_ref[...]
    br, bc = blk.shape
    part = blk.reshape(br, bc // seg, seg).sum(axis=2).max(axis=1)
    acc_ref[...] = jnp.maximum(acc_ref[...], part)

    @pl.when(j == n_c - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def segment_rowmax_pallas(
    vals: jax.Array,
    seg: int = 1,
    *,
    br: int = DEFAULT_BR,
    bc: int = DEFAULT_BC,
    interpret: bool = False,
) -> jax.Array:
    """``max_j sum_{i<seg} vals[r, j*seg + i]`` per row, for ``vals >= 0``.

    Ragged shapes are zero-padded up to the block tiling (a zero pad
    segment behaves as an idle port under the non-negative contract).
    """
    rows, cols = vals.shape
    seg = int(seg)
    assert seg >= 1 and cols % seg == 0, (vals.shape, seg)
    bc = seg * max(1, min(bc, cols) // seg)
    br = min(br, rows)
    pad_r = -rows % br
    pad_c = -cols % bc
    if pad_r or pad_c:
        vals = jnp.pad(vals, ((0, pad_r), (0, pad_c)))
    grid = (vals.shape[0] // br, vals.shape[1] // bc)
    out = pl.pallas_call(
        functools.partial(_segment_rowmax_kernel, seg=seg, n_c=grid[1]),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((vals.shape[0],), vals.dtype),
        scratch_shapes=[pltpu.VMEM((br,), vals.dtype)],
        interpret=interpret,
    )(vals)
    return out[:rows]
