"""Jit'd public wrappers around the Pallas kernels.

On CPU backends (this container) the kernels execute in interpret mode —
the kernel body runs in Python for correctness validation; on TPU they
lower to Mosaic. Model code calls these through ``use_pallas=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa_mod
from repro.kernels import mamba_scan as ms_mod
from repro.kernels import matmul as mm_mod
from repro.kernels import segment_reduce as sr_mod
from repro.kernels import stencil as st_mod
from repro.kernels import wkv6 as wkv_mod


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm: int = mm_mod.DEFAULT_BM, bn: int = mm_mod.DEFAULT_BN,
           bk: int = mm_mod.DEFAULT_BK):
    return mm_mod.matmul_pallas(a, b, bm=bm, bn=bn, bk=bk,
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("window", "scale", "causal"))
def flash_attention(q, k, v, *, window: int = 0, scale=None,
                    causal: bool = True):
    """Model-layout wrapper: q (B,S,H,hd), k/v (B,S,Kv,hd) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    if Kv != H:
        k = jnp.repeat(k, H // Kv, axis=2)
        v = jnp.repeat(v, H // Kv, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    out = fa_mod.flash_attention_pallas(
        qf, kf, vf, window=window, scale=scale, causal=causal,
        interpret=_interpret(),
    )
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("bm",))
def stencil_step(field, bm: int = st_mod.DEFAULT_BM):
    return st_mod.stencil_pallas(field, bm=bm, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("seg", "br", "bc"))
def segment_rowmax(vals, seg: int = 1, br: int = sr_mod.DEFAULT_BR,
                   bc: int = sr_mod.DEFAULT_BC):
    """Per-row max of length-``seg`` segment sums (congestion reduce)."""
    return sr_mod.segment_rowmax_pallas(vals, seg, br=br, bc=bc,
                                        interpret=_interpret())


@jax.jit
def wkv6(r, k, v, w, u, state=None):
    """Model-layout wrapper: r/k/v/w (B,S,H,N), u (H,N), state (B,H,N,N).

    Contract: the fused kernel assumes a ZERO initial state (the training
    path always starts from zeros). The decode path (non-zero state, single
    step) uses the scan reference in repro.models.rwkv6 instead.
    """
    B, S, H, N = r.shape
    to_flat = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    uf = jnp.broadcast_to(u[None], (B, H, N)).reshape(B * H, N)
    y, s = wkv_mod.wkv6_pallas(
        to_flat(r), to_flat(k), to_flat(v), to_flat(w), uf,
        interpret=_interpret(),
    )
    y = y.reshape(B, H, S, N).transpose(0, 2, 1, 3)
    return y, s.reshape(B, H, N, N)


@jax.jit
def mamba_scan(xs, dt, Bs, Cs, A):
    """Selective scan (zero initial state); see kernels/mamba_scan.py."""
    return ms_mod.mamba_scan_pallas(xs, dt, Bs, Cs, A,
                                    interpret=_interpret())
