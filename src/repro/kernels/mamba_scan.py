"""Mamba-1 selective-scan — Pallas TPU kernel (hymba's SSM hot spot).

    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t        (per (di, n))
    y_t = sum_n h_t[:, n] * C_t[n]  + D * x_t (residual added by caller)

Adaptation note (DESIGN.md): the CUDA kernel parallelizes channels over
threads with state in registers; on TPU the state (di, n) lives in VMEM
scratch persisting across the sequential time-chunk grid axis, and the
(B)-batch axis provides the parallel grid dimension. Unlike WKV6 the decay
is per-(channel, state) so no chunk-matmul collapse exists (Mamba-2/SSD
restricts decay to per-head scalars to enable it) — the win over the jnp
scan is state residency in VMEM, not parallelization over time.

Layout: xs/dt (B, T, di); Bs/Cs (B, T, n); A (di, n).
Outputs: y (B, T, di), final state (B, di, n).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 64


def _mamba_kernel(xs_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, sout_ref,
                  s_ref, *, bt: int, n_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    A = a_ref[...]                                      # (di, n)

    def step(t, _):
        x_t = xs_ref[0, t]                              # (di,)
        dt_t = dt_ref[0, t]                             # (di,)
        B_t = b_ref[0, t]                               # (n,)
        C_t = c_ref[0, t]                               # (n,)
        dA = jnp.exp(dt_t[:, None] * A)                 # (di, n)
        dBx = (dt_t * x_t)[:, None] * B_t[None, :]      # (di, n)
        h = dA * s_ref[...] + dBx
        s_ref[...] = h
        y_ref[0, t] = (h * C_t[None, :]).sum(axis=1).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bt, step, 0)

    @pl.when(ti == n_t - 1)
    def _flush():
        sout_ref[0] = s_ref[...].astype(sout_ref.dtype)


def mamba_scan_pallas(
    xs: jax.Array, dt: jax.Array, Bs: jax.Array, Cs: jax.Array, A: jax.Array,
    *, bt: int = DEFAULT_BT, interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,T,di), final_state (B,di,n)). Zero initial state."""
    B, T, di = xs.shape
    n = A.shape[1]
    bt = min(bt, T)
    assert T % bt == 0, (T, bt)
    grid = (B, T // bt)
    kern = functools.partial(_mamba_kernel, bt=bt, n_t=grid[1])
    y, s_out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, di), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, di), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, n), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, bt, n), lambda b, t: (b, t, 0)),
            pl.BlockSpec((di, n), lambda b, t: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, di), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, di, n), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, di), xs.dtype),
            jax.ShapeDtypeStruct((B, di, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((di, n), jnp.float32)],
        interpret=interpret,
    )(xs, dt, Bs, Cs, A)
    return y, s_out
