"""Tests for the mapping IR: recorded op programs + vectorized evaluation.

Covers the batched-evaluation contract of docs/mapping_ir.md: scalar
``to_root`` and batched ``to_root_batch`` agree over random op chains, the
vectorized ``assignment_grid`` is bit-identical to the per-point
interpreter for every mapper in the library and the app registry, and
data-dependent bodies fall back automatically.
"""
import itertools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import apps
from repro.core import GPU, Machine
from repro.core.mapper import (
    Mapper,
    block_mapper,
    cyclic_mapper,
    linearize_cyclic_mapper,
)
from repro.core.pspace import Decompose, Merge, ProcSpace, Split, Swap
from repro.core.translate import declared_operands, owned_tiles, to_spmd
from repro.core.tuples import Tup
from repro.core import dsl


def all_indices(shape):
    return itertools.product(*(range(s) for s in shape))


# ------------------------------------------------------------- IR recording
def test_ops_are_recorded():
    m = Machine(GPU, shape=(8, 4))
    m2 = m.merge(0, 1).split(0, 4).swap(0, 1)
    assert m2.ops == (Merge(0, 1, 8), Split(0, 4), Swap(0, 1))
    assert m.ops == ()      # primitives never mutate the parent space


def test_decompose_records_single_op():
    m = Machine(GPU, shape=(16, 4))
    md = m.decompose_with(0, (4, 2, 2))
    assert md.ops == (Decompose(0, (4, 2, 2)),)
    assert md.shape == (4, 2, 2, 4)


def test_describe_round_trips_through_ir():
    m = Machine(GPU, shape=(12, 7))
    chain = m.split(0, 3).merge(1, 2).swap(0, 1).slice(0, 1, 4)
    assert chain.describe() == (
        "root(12, 7).split(0, 3).merge(1, 2).swap(0, 1).slice(0, 1, 4)"
    )
    rebuilt = ProcSpace.from_ir(chain.to_ir())
    assert rebuilt.shape == chain.shape
    for idx in all_indices(chain.shape):
        assert rebuilt.to_root(idx) == chain.to_root(idx)


def test_from_ir_rejects_unknown_op():
    with pytest.raises(ValueError):
        ProcSpace.from_ir({"root_shape": [4], "ops": [["frobnicate", 0]]})


# ------------------------------------------------- scalar/batch equivalence
def _random_chain(m, data):
    space = m
    n_ops = data.draw(st.integers(0, 5))
    for _ in range(n_ops):
        op = data.draw(st.sampled_from(
            ["split", "merge", "swap", "slice", "decompose"]))
        nd = space.ndim
        if op == "split":
            i = data.draw(st.integers(0, nd - 1))
            divs = [d for d in range(1, space.shape[i] + 1)
                    if space.shape[i] % d == 0]
            space = space.split(i, data.draw(st.sampled_from(divs)))
        elif op == "merge" and nd >= 2:
            p = data.draw(st.integers(0, nd - 2))
            q = data.draw(st.integers(p + 1, nd - 1))
            space = space.merge(p, q)
        elif op == "swap" and nd >= 2:
            p = data.draw(st.integers(0, nd - 1))
            q = data.draw(st.integers(0, nd - 1))
            if p != q:
                space = space.swap(p, q)
        elif op == "slice":
            i = data.draw(st.integers(0, nd - 1))
            low = data.draw(st.integers(0, space.shape[i] - 1))
            high = data.draw(st.integers(low + 1, space.shape[i]))
            space = space.slice(i, low, high)
        elif op == "decompose":
            i = data.draw(st.integers(0, nd - 1))
            space = space.decompose(i, (4, 4))
    return space


shapes = st.lists(st.integers(1, 6), min_size=1, max_size=4).map(tuple)


@settings(max_examples=100, deadline=None)
@given(shape=shapes, data=st.data())
def test_to_root_batch_equals_scalar_over_random_chains(shape, data):
    """The batched-evaluation contract: pure NumPy op replay == per-point."""
    space = _random_chain(Machine(GPU, shape=shape), data)
    points = list(all_indices(space.shape))
    batch = np.asarray(points, dtype=np.int64).reshape(len(points), space.ndim)
    roots = space.to_root_batch(batch)
    for pt, root in zip(points, roots):
        assert tuple(int(r) for r in root) == space.to_root(pt)


def test_to_root_batch_validates():
    m = Machine(GPU, shape=(2, 4))
    with pytest.raises(IndexError):
        m.to_root_batch(np.array([[0, 0, 0]]))          # wrong rank
    with pytest.raises(IndexError):
        m.to_root_batch(np.array([[0, 4]]))             # out of bounds


# ------------------------------------------------------------- batched Tup
def test_tup_batched_arithmetic_matches_scalar():
    ispace = (6, 4)
    batched = Tup.grid(ispace)
    assert batched.is_batched and batched.batch_size == 24
    expr = batched * (2, 2) / ispace % (3, 3)
    for b, pt in enumerate(all_indices(ispace)):
        scalar = Tup(pt) * (2, 2) / ispace % (3, 3)
        assert tuple(int(v[b]) for v in expr) == tuple(scalar)
    lin = batched.linearize(ispace)
    assert [int(x) for x in lin] == list(range(24))


def test_scalar_tup_unchanged():
    a = Tup((7, 9))
    assert not a.is_batched and a.batch_size is None
    assert tuple(a / (2, 3)) == (3, 3)
    assert hash(a) == hash(Tup((7, 9)))


# --------------------------------------------------- vectorized grid + cache
def test_vectorized_grid_bit_identical_for_library_mappers():
    m = Machine(GPU, shape=(2, 4))
    for mk in (block_mapper, cyclic_mapper, linearize_cyclic_mapper):
        mapper = mk(m)
        batched = mapper.assignment_grid((8, 8), use_cache=False)
        # the vectorized path must actually run, not silently fall back
        assert mapper.last_eval_path == "vectorized", mk.__name__
        np.testing.assert_array_equal(
            batched,
            mapper.assignment_grid((8, 8), vectorized=False, use_cache=False),
        )
        assert mapper.last_eval_path == "per-point"


@pytest.mark.parametrize("app", list(apps.iter_apps()),
                         ids=[a.name for a in apps.iter_apps()])
def test_registry_apps_bit_identical_scalar_vs_batched(app):
    """Acceptance: every app's device permutation identical on both paths."""
    n = app.default_procs
    grid = app.tile_grid(n)
    mapper = app.mapper(n)
    batched = mapper.assignment_grid(grid, use_cache=False)
    assert mapper.last_eval_path == "vectorized", app.name
    scalar = mapper.assignment_grid(grid, vectorized=False, use_cache=False)
    np.testing.assert_array_equal(batched, scalar)


def test_data_dependent_body_falls_back_to_per_point():
    """A body branching on ipoint cannot broadcast; fallback must kick in."""
    m = Machine(GPU, shape=(4, 1))

    def fn(ipoint, ispace):
        if ipoint[0] >= 2:              # truth value of an array -> fallback
            return m[(3, 0)]
        return m[(ipoint[0], 0)]

    mapper = Mapper("data_dep", fn)
    grid = mapper.assignment_grid((4,))
    assert grid.tolist() == [0, 1, 3, 3]
    assert mapper.last_eval_path == "per-point"


def test_constant_body_broadcasts():
    m = Machine(GPU, shape=(2, 2))
    mapper = Mapper("const", lambda ipoint, ispace: m[(1, 1)])
    assert mapper.assignment_grid((3, 3)).tolist() == [[3] * 3] * 3


def test_grid_cache_shared_across_analyses():
    m = Machine(GPU, shape=(2, 2))
    calls = []
    inner = block_mapper(m).fn

    def counting_fn(ipoint, ispace):
        calls.append(1)
        return inner(ipoint, ispace)

    mapper = Mapper("counted", counting_fn)
    assert mapper.is_bijective_on((2, 2), 4)
    n_after_first = len(calls)
    perm = mapper.tile_permutation((2, 2), 4)       # must reuse the cache
    grid = mapper.assignment_grid((2, 2))
    assert len(calls) == n_after_first
    assert sorted(perm) == [0, 1, 2, 3]
    assert grid.flags.writeable is False


def test_per_point_path_never_served_from_cache():
    """vectorized=False must recompute, even when a vectorized result for
    the same ispace is already cached — otherwise scalar-vs-batch
    equivalence checks would compare the cached grid with itself."""
    mapper = block_mapper(Machine(GPU, shape=(2, 2)))
    cached = mapper.assignment_grid((4, 4))         # populates the cache
    assert mapper.last_eval_path == "vectorized"
    scalar = mapper.assignment_grid((4, 4), vectorized=False)
    assert scalar is not cached
    assert mapper.last_eval_path == "per-point"
    np.testing.assert_array_equal(scalar, cached)
    # and the per-point result must not have poisoned the cache
    assert mapper.assignment_grid((4, 4)) is cached


# ------------------------------------------------- linearize_cyclic ranks
def test_linearize_cyclic_rank2():
    m = Machine(GPU, shape=(2, 2))
    mapper = linearize_cyclic_mapper(m)
    # column-major linearization: (i0, i1) -> i0 + 4*i1 over a (4, 3) grid
    for i0, i1 in all_indices((4, 3)):
        lin = i0 + 4 * i1
        assert mapper((i0, i1), (4, 3)).flat == (lin % 2) * 2 + (lin // 2) % 2


def test_linearize_cyclic_rank3():
    m = Machine(GPU, shape=(2, 4))
    mapper = linearize_cyclic_mapper(m)
    for pt in all_indices((2, 3, 2)):
        lin = pt[0] + 2 * pt[1] + 6 * pt[2]
        expect = m[(lin % 2, (lin // 2) % 4)].flat
        assert mapper(pt, (2, 3, 2)).flat == expect
    assert mapper.is_bijective_on((2, 2, 2), 8)


def test_linearize_cyclic_rank_mismatch_rejected():
    mapper = linearize_cyclic_mapper(Machine(GPU, shape=(2, 2)))
    with pytest.raises(ValueError):
        mapper((0, 0, 0), (4, 4))       # point rank 3, space rank 2


# ----------------------------------------------------- translate integration
CANNON_LIKE = """\
m = Machine(GPU)
m1 = m.merge(0, 1)

def mymap(Tuple ipoint, Tuple ispace):
    idx = ipoint * m1.size / ispace
    return m1[*idx]

IndexTaskMap mytask mymap
Region mytask arg0 GPU FBMEM
Layout mytask arg1 GPU C_order
GarbageCollect mytask acc
Region mytask out0 GPU FBMEM
"""


def test_to_spmd_derives_operand_names_from_directives():
    prog = dsl.parse(
        CANNON_LIKE,
        machine_factory=lambda *a, **k: Machine(GPU, shape=(2, 2)),
    )
    assert declared_operands(prog, "mytask") == ("acc", "arg0", "arg1", "out0")
    plan = to_spmd(prog, "mytask", (4,), ("x",), devices=[])
    assert set(plan.in_specs) == {"acc", "arg0", "arg1"}
    assert set(plan.out_specs) == {"out0"}
    assert "root(2, 2).merge(0, 1)" in plan.meta["mapper_ir"]


def test_output_operand_convention_is_exact_match():
    """Only `out`/`out<digits>` are outputs; an input named `output_mask`
    must stay an input (not be silently dropped from in_specs)."""
    from repro.core.translate import is_output_operand

    assert is_output_operand("out") and is_output_operand("out3")
    assert not is_output_operand("output_mask")
    assert not is_output_operand("outer")
    prog = dsl.parse(
        "m = Machine(GPU)\n"
        "m1 = m.merge(0, 1)\n"
        "def mymap(Tuple ipoint, Tuple ispace):\n"
        "    idx = ipoint * m1.size / ispace\n"
        "    return m1[*idx]\n"
        "IndexTaskMap mytask mymap\n"
        "Region mytask output_mask GPU FBMEM\n",
        machine_factory=lambda *a, **k: Machine(GPU, shape=(2, 2)),
    )
    plan = to_spmd(prog, "mytask", (4,), ("x",), devices=[])
    assert set(plan.in_specs) == {"output_mask"}
    assert set(plan.out_specs) == {"out"}


def test_to_spmd_falls_back_without_directives():
    prog = dsl.parse(
        "m = Machine(GPU)\n"
        "m1 = m.merge(0, 1)\n"
        "def mymap(Tuple ipoint, Tuple ispace):\n"
        "    idx = ipoint * m1.size / ispace\n"
        "    return m1[*idx]\n"
        "IndexTaskMap mytask mymap\n",
        machine_factory=lambda *a, **k: Machine(GPU, shape=(2, 2)),
    )
    plan = to_spmd(prog, "mytask", (4,), ("x",), devices=[])
    assert set(plan.in_specs) == {"arg0", "arg1"}
    assert set(plan.out_specs) == {"out"}


def test_owned_tiles_vectorized_grouping():
    m = Machine(GPU, shape=(2, 2))
    mapper = cyclic_mapper(m)
    owned = owned_tiles(mapper, (4, 4), 4)
    assert sorted(owned) == [0, 1, 2, 3]
    assert all(len(v) == 4 for v in owned.values())
    # row-major order within a device's tile list is preserved
    assert owned[0] == [(0, 0), (0, 2), (2, 0), (2, 2)]
    flat = {pt for pts in owned.values() for pt in pts}
    assert len(flat) == 16
