"""Multi-device correctness tests (subprocess: 8 fake CPU devices).

The main pytest process must keep a single device (the dry-run owns the
512-device configuration), so every multi-device check runs in a child
process with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = str(REPO / "src")


def run_snippet(code: str, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


MATMUL_SNIPPET = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Machine, GPU
from repro.core.commvolume import MatmulProblem
from repro.matmul import cannon, summa, pumma, johnson, solomonik, cosma
from repro.matmul.common import make_inputs

a, b = make_inputs(16, 24, 32, seed=1)
ref = np.asarray(a) @ np.asarray(b)
m4 = Machine(GPU, shape=(2, 2))
devs4 = jax.devices()[:4]

for mod, grid in [
    (cannon, cannon.grid_for(m4, devs4)),
    (summa, summa.grid_for(m4, devs4)),
    (pumma, pumma.grid_for(m4, devs4)),
    (johnson, johnson.grid_for(Machine(GPU, shape=(8, 1)))),
    (solomonik, solomonik.grid_for(Machine(GPU, shape=(2, 4)), c=2)),
    (cosma, cosma.grid_for(Machine(GPU, shape=(8, 1)), MatmulProblem(16, 32, 24))),
]:
    out = mod.matmul(a, b, grid)
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-4, (mod.__name__, err)
    print(mod.__name__, "OK", err)
"""


SCIENCE_SNIPPET = r"""
import jax, jax.numpy as jnp
from repro.core import Machine, GPU
from repro.science import stencil2d, circuit, pennant

cfg = stencil2d.StencilConfig(nx=32, ny=48, steps=3)
g = stencil2d.grid_for(Machine(GPU, shape=(2, 4)), cfg)
f0 = jax.random.normal(jax.random.key(0), (32, 48), jnp.float32)
assert float(jnp.abs(stencil2d.run(f0, g, cfg) - stencil2d.reference(f0, cfg)).max()) < 1e-5
print("stencil OK")

ccfg = circuit.CircuitConfig(pieces=8, steps=3)
st = circuit.generate(ccfg, seed=2)
cg = circuit.grid_for(Machine(GPU, shape=(2, 4)), ccfg)
assert float(jnp.abs(circuit.run(st, cg, ccfg) - circuit.reference(st, ccfg)).max()) < 1e-5
print("circuit OK")

pcfg = pennant.PennantConfig(nzx=32, nzy=32, steps=3)
ps = pennant.init_state(pcfg)
pg = pennant.grid_for(Machine(GPU, shape=(2, 4)), pcfg)
for o, r in zip(pennant.run(ps, pg, pcfg), pennant.reference(ps, pcfg)):
    assert float(jnp.abs(o - r).max()) < 1e-5
print("pennant OK")
"""


MAPPER_MESH_SNIPPET = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import Machine, GPU, block_mapper, cyclic_mapper
from repro.core.translate import mesh_from_mapper

m = Machine(GPU, shape=(2, 4))
# Block mapper -> identity permutation.
mesh_b = mesh_from_mapper(block_mapper(m), (2, 4), ("x", "y"))
ids_b = np.array([[d.id for d in row] for row in mesh_b.devices])
assert (ids_b == np.arange(8).reshape(2, 4)).all(), ids_b

# Cyclic mapper over a merged 1D space -> strided permutation.
m1 = m.merge(0, 1)
cy = cyclic_mapper(m1, "cyclic1d")
mesh_c = mesh_from_mapper(cy, (8,), ("x",))
ids_c = np.array([d.id for d in mesh_c.devices])
# cyclic: tile t -> proc t % 8 == identity on an 8-grid; use a 2D cyclic.
mesh2 = mesh_from_mapper(cyclic_mapper(m), (2, 4), ("x", "y"))
print("mapper-mesh OK", ids_c.tolist())

# Sharded array placement follows the permuted mesh.
x = jnp.arange(16.0).reshape(2, 8)
s = NamedSharding(mesh_b, P("x", "y"))
xs = jax.device_put(x, s)
assert xs.sharding.is_equivalent_to(s, 2)
print("placement OK")
"""


HEURISTIC_GAP_SNIPPET = r"""
# Fig. 13: the runtime-heuristic mapper must produce a DIFFERENT device
# order than the algorithm-specified mapper (that is the whole point), and
# both must still compute a correct product.
import numpy as np, jax, jax.numpy as jnp
from repro.core import Machine, GPU
from repro.matmul import cannon, runtime_heuristic_mapper
from repro.matmul.common import build_grid, make_inputs

m = Machine(GPU, shape=(2, 2))
a, b = make_inputs(16, 16, 16, seed=3)
ref = np.asarray(a) @ np.asarray(b)

g_spec = cannon.grid_for(m, jax.devices()[:4])
g_heur = build_grid(runtime_heuristic_mapper(m), (2, 2), ("x", "y"),
                    jax.devices()[:4])
perm_spec = [d.id for d in g_spec.mesh.devices.flat]
perm_heur = [d.id for d in g_heur.mesh.devices.flat]
for g in (g_spec, g_heur):
    out = cannon.matmul(a, b, g)
    assert float(jnp.abs(out - ref).max()) < 1e-4
print("spec:", perm_spec, "heur:", perm_heur)
"""


@pytest.mark.slow
def test_matmul_algorithms_multidevice():
    out = run_snippet(MATMUL_SNIPPET)
    assert out.count("OK") == 6


@pytest.mark.slow
def test_science_apps_multidevice():
    out = run_snippet(SCIENCE_SNIPPET)
    assert out.count("OK") == 3


@pytest.mark.slow
def test_mapper_to_mesh_translation():
    out = run_snippet(MAPPER_MESH_SNIPPET)
    assert "placement OK" in out


@pytest.mark.slow
def test_heuristic_vs_spec_mapper_both_correct():
    out = run_snippet(HEURISTIC_GAP_SNIPPET)
    assert "spec:" in out


MOE_EP_SNIPPET = r"""
# shard_map expert-parallel MoE must match the dense pjit path when no
# tokens are dropped (capacity semantics differ only under drops).
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.models import moe as moe_mod
from repro.models import sharding as shd
from repro.configs import get_config
from repro.models import build

moe_mod.CAPACITY_FACTOR = 16.0    # no drops in either path
cfg = get_config("qwen2-moe-a2.7b").reduced()
model = build(cfg)
params = model.init(jax.random.key(0))
layer0 = jax.tree.map(lambda p: p[0], params["moe_layers"])["moe"]
x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)

ref, aux_ref = moe_mod._moe_dense(layer0, x, cfg)

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
shd.set_sequence_sharding("model")
with mesh:
    out, aux = jax.jit(lambda p, x: moe_mod.moe_apply(p, x, cfg))(layer0, x)
shd.set_sequence_sharding(None)
err = float(jnp.abs(out - ref).max())
print("ep-vs-dense err:", err, "aux:", float(aux), float(aux_ref))
assert err < 1e-4, err
assert abs(float(aux) - float(aux_ref)) < 1e-4
print("moe EP OK")
"""


@pytest.mark.slow
def test_moe_shard_map_matches_dense():
    out = run_snippet(MOE_EP_SNIPPET)
    assert "moe EP OK" in out
