"""End-to-end behaviour tests for the whole system.

DSL text -> mapper -> mesh translation -> distributed compute -> training
with checkpoint/restart — the full path a user takes.
"""
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = Path(__file__).resolve().parent.parent


def test_dsl_to_assignment_end_to_end():
    """A textual Mapple program drives an actual device assignment."""
    from repro.core import dsl

    prog = dsl.parse("""
m = Machine(GPU, shape=(2, 2))

def block2d(Tuple ipoint, Tuple ispace):
    idx = ipoint * m.size / ispace
    return m[*idx]

IndexTaskMap stencil block2d
Region stencil arg0 GPU FBMEM
Backpressure stencil 2
""")
    mapper = prog.mappers["block2d"]
    grid = mapper.assignment_grid((4, 4))
    # quadrant block assignment over 4 processors
    assert grid[0, 0] == grid[1, 1]
    assert len(np.unique(grid)) == 4
    assert mapper.is_bijective_on((2, 2), 4)
    assert prog.backpressure["stencil"] == 2


def test_paper_figures_numerics():
    """The numbers the paper derives must fall out of the implementation."""
    from repro.core import (
        greedy_factorization, halo_surface_volume, optimal_factorization,
    )
    from repro.core.decompose import count_factorizations

    # Fig. 8: 96 vs 84 boundary elements.
    assert 2 * halo_surface_volume((12, 18), greedy_factorization(6, 2)) == 96
    assert 2 * halo_surface_volume(
        (12, 18), optimal_factorization(6, (12, 18))
    ) == 84
    # Sec. 4.3: d=16, k=3 -> 15 factorizations; d=48 -> 45.
    assert count_factorizations(16, 3) == 15
    assert count_factorizations(48, 3) == 45
    # Sec. 4.3 closing example: d=72 over (8, 9) -> perfectly balanced.
    assert optimal_factorization(72, (8, 9)) == (8, 9)


def test_train_checkpoint_restart_cycle():
    """Supervisor restores from checkpoint after an injected failure and
    training completes with decreasing loss."""
    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config
    from repro.data import make_pipeline
    from repro.models import build
    from repro.runtime import FailureInjector, Supervisor
    from repro.training import (
        AdamWConfig, TrainState, init_state, make_train_step,
    )

    cfg = get_config("smollm-135m").reduced()
    model = build(cfg)
    opt_cfg = AdamWConfig(lr=2e-3, warmup_steps=3, total_steps=40)
    pipe = make_pipeline(cfg, seq_len=32, global_batch=8)
    jitted = jax.jit(make_train_step(model, opt_cfg))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)

        def step_fn(step, tree):
            st, metrics = jitted(TrainState.from_tree(tree), pipe.batch(step))
            return st.as_tree(), {k: float(v) for k, v in metrics.items()}

        state = init_state(model, jax.random.key(0), opt_cfg)
        sup = Supervisor(mgr)
        final, hist = sup.run(
            state=state.as_tree(), start_step=0, n_steps=20,
            step_fn=step_fn, save_every=5,
            injector=FailureInjector(fail_at_steps=(12,), max_failures=1),
        )
        losses = [h["loss"] for h in hist if "loss" in h]
        assert any("restored" in str(h.get("event", "")) for h in hist)
        assert losses[-1] < losses[0]


def test_autosharder_respects_constraints():
    from repro.core.autosharder import LMWorkload, plan_mesh

    wl = LMWorkload(global_batch=256, seq_len=4096, d_model=3584,
                    n_layers=28, n_heads=28, n_kv_heads=4, param_count=7.6e9)
    plan = plan_mesh(256, wl)
    assert plan.dp * plan.tp == 256
    assert 256 % plan.dp == 0
    # 28 heads: tp must divide 28 (or be 1)
    assert plan.tp == 1 or 28 % plan.tp == 0


@pytest.mark.slow
def test_dryrun_single_cell_compiles():
    """One full dry-run cell in a subprocess (512 fake devices)."""
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "smollm-135m", "--shape", "train_4k", "--mesh", "single"],
        capture_output=True, text=True, timeout=420, env=env, cwd=str(REPO),
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "1 ok, 0 skipped, 0 errors" in proc.stdout


def test_elastic_restore_under_new_sharding():
    """Checkpoint written once restores under different shardings
    (mesh-agnostic restore — the elastic-rescale mechanism)."""
    from repro.checkpoint import CheckpointManager

    tree = {"w": jnp.arange(32.0).reshape(8, 4)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, tree)
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
        )
        sh = {"w": jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, None))}
        step, restored, _ = mgr.restore(shardings=sh)
        assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
