"""Tests for the persistent pricing cache (repro.sim.price_cache)."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro import apps
from repro.sim.collectives import cache_stats, clear_caches
from repro.sim.cost import time_tuned_app
from repro.sim.price_cache import _REC, _MAGIC, PriceCache, digest
from repro.search.tuner import tune_app

REPO = Path(__file__).resolve().parent.parent


def _table_file(cache: PriceCache) -> Path:
    files = sorted(cache.root.glob("*.price"))
    assert files, "no table file written"
    return files[0]


# ------------------------------------------------------------------- basics
def test_round_trip_and_idempotent_put(tmp_path):
    cache = PriceCache(tmp_path)
    t, r = digest(b"table"), digest(b"row")
    assert cache.get(t, r) is None
    cache.put(t, r, 3.5)
    cache.put(t, r, 3.5)            # duplicate: no second record
    assert cache.get(t, r) == 3.5
    assert cache.stats()["writes"] == 1
    size = _table_file(cache).stat().st_size
    assert size == len(_MAGIC) + _REC.size


def test_distinct_digests_for_framing():
    assert digest(b"ab", b"c") != digest(b"a", b"bc")
    assert digest(b"x") != digest(b"x", b"")


def test_fresh_process_round_trip(tmp_path):
    """A value written by one process is served to another — the
    cross-run promise the warm-re-tune speedup rests on."""
    snippet = f"""
import sys; sys.path.insert(0, {str(REPO / "src")!r})
from repro.sim.price_cache import PriceCache, digest
c = PriceCache({str(tmp_path)!r})
c.put(digest(b"t"), digest(b"r"), 1.75)
"""
    subprocess.run([sys.executable, "-c", snippet], check=True)
    cache = PriceCache(tmp_path)
    assert cache.get(digest(b"t"), digest(b"r")) == 1.75


# -------------------------------------------------------------- resilience
def test_corrupt_record_drops_tail_keeps_prefix(tmp_path):
    cache = PriceCache(tmp_path)
    t = digest(b"table")
    rows = [digest(bytes([i])) for i in range(3)]
    cache.put_many(t, [(r, float(i)) for i, r in enumerate(rows)])
    path = _table_file(cache)
    blob = bytearray(path.read_bytes())
    # Flip one byte inside the SECOND record's value field.
    off = len(_MAGIC) + _REC.size + 20
    blob[off] ^= 0xFF
    path.write_bytes(bytes(blob))
    fresh = PriceCache(tmp_path)
    assert fresh.get(t, rows[0]) == 0.0          # intact prefix survives
    assert fresh.get(t, rows[1]) is None         # corrupted -> miss
    assert fresh.get(t, rows[2]) is None         # past the tear -> miss
    assert fresh.stats()["dropped"] == 1
    # The miss re-prices live and re-persists.
    fresh.put(t, rows[1], 1.0)
    assert PriceCache(tmp_path).get(t, rows[1]) == 1.0


def test_stale_magic_treated_as_empty(tmp_path):
    cache = PriceCache(tmp_path)
    t, r = digest(b"t"), digest(b"r")
    cache.put(t, r, 2.0)
    path = _table_file(cache)
    path.write_bytes(b"RPRICE00" + path.read_bytes()[len(_MAGIC):])
    fresh = PriceCache(tmp_path)
    assert fresh.get(t, r) is None
    assert fresh.stats()["dropped"] == 1


def test_truncated_trailing_record_dropped(tmp_path):
    cache = PriceCache(tmp_path)
    t, r = digest(b"t"), digest(b"r")
    cache.put(t, r, 2.0)
    path = _table_file(cache)
    path.write_bytes(path.read_bytes()[:-5])     # tear mid-record
    fresh = PriceCache(tmp_path)
    assert fresh.get(t, r) is None
    assert fresh.stats()["dropped"] == 1


# ---------------------------------------------------- collectives registry
def test_clear_caches_drops_memory_not_disk(tmp_path):
    cache = PriceCache(tmp_path)
    t, r = digest(b"t"), digest(b"r")
    cache.put(t, r, 4.0)
    clear_caches()
    assert cache.stats()["tables"] == 0          # in-memory mirror gone
    assert cache.get(t, r) == 4.0                # disk reload serves it
    stats = cache_stats()["price_cache"]
    assert stats["hits"] >= 1


# ------------------------------------------------------------- tuner level
@pytest.mark.parametrize("engine", ["batched", "batched-jax"])
def test_warm_tune_hits_cache_and_reproduces_report(tmp_path, engine):
    if engine == "batched-jax":
        pytest.importorskip("jax")
    app = apps.get("cannon")
    timed_cold = time_tuned_app(app, engine=engine,
                                cache=PriceCache(tmp_path))
    cold = tune_app(timed_cold)
    warm_cache = PriceCache(tmp_path)            # fresh instance = new run
    timed_warm = time_tuned_app(app, engine=engine, cache=warm_cache)
    warm = tune_app(timed_warm)
    assert warm_cache.stats()["hits"] > 0
    assert warm_cache.stats()["writes"] == 0     # everything was cached
    assert warm.best.candidate.describe() == cold.best.candidate.describe()
    assert [s.placed_cost for s in warm.leaderboard] \
        == [s.placed_cost for s in cold.leaderboard]


def test_value_tags_isolate_engines(tmp_path):
    """NumPy and JAX prices agree only to tolerance, so each engine
    family owns its own tables — a warm NumPy cache must not feed a JAX
    tune."""
    pytest.importorskip("jax")
    app = apps.get("summa")
    cache = PriceCache(tmp_path)
    tune_app(time_tuned_app(app, engine="batched", cache=cache))
    before = cache.stats()["writes"]
    assert before > 0
    tune_app(time_tuned_app(app, engine="batched-jax", cache=cache))
    assert cache.stats()["writes"] > before      # jax re-priced its own


def test_cost_model_cost_short_circuits(tmp_path):
    """Phase 1's default-placement score caches too (the warm-re-tune
    speedup needs Phase 1 to skip schedule builds as well)."""
    app = apps.get("summa")
    n = app.default_procs
    cache = PriceCache(tmp_path)
    space = time_tuned_app(app, cache=cache).search_space
    model = space.cost_model(n, {})
    grid = app.tile_grid(n)
    first = model.cost(grid)
    hits0 = cache.stats()["hits"]
    assert model.cost(grid) == first
    assert cache.stats()["hits"] == hits0 + 1
    assert np.isfinite(first)
