"""Tests for the decompose solver (paper Sec. 4) — optimality, baselines."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.decompose import (
    count_factorizations,
    enumerate_factorizations,
    greedy_factorization,
    greedy_workload_factorization,
    halo_objective,
    optimal_factorization,
    prime_factorization,
    transpose_objective,
)
from repro.core.commvolume import (
    aniso_halo_volume,
    halo_surface_volume,
    transpose_volume,
)


def test_prime_factorization():
    assert prime_factorization(1) == []
    assert prime_factorization(2) == [2]
    assert prime_factorization(48) == [2, 2, 2, 2, 3]
    assert prime_factorization(97) == [97]


def test_enumeration_complete_and_counts():
    # Sec 4.3: d=16, k=3 -> C(6,2)=15 factorizations.
    facts = list(enumerate_factorizations(16, 3))
    assert len(facts) == 15 == count_factorizations(16, 3)
    assert all(math.prod(f) == 16 for f in facts)
    assert len(set(facts)) == len(facts)
    # d = 48 = 2^4 * 3: C(6,2) * C(3,2) = 15 * 3 = 45.
    assert count_factorizations(48, 3) == 45
    assert len(list(enumerate_factorizations(48, 3))) == 45


@settings(max_examples=100, deadline=None)
@given(d=st.integers(1, 512), k=st.integers(1, 4))
def test_count_matches_enumeration_property(d, k):
    """Closed form prod_j C(a_j + k - 1, k - 1) == the enumerator's output,
    with no duplicates and every tuple multiplying back to d."""
    facts = list(enumerate_factorizations(d, k))
    assert count_factorizations(d, k) == len(facts)
    assert len(set(facts)) == len(facts)
    assert all(math.prod(f) == d for f in facts)


def test_paper_sec41_example():
    """6 procs, iteration (12,18): optimal grid (2,3), greedy picks (3,2)."""
    assert optimal_factorization(6, (12, 18)) == (2, 3)
    assert greedy_factorization(6, 2) == (3, 2)
    # Volumes from Fig. 8: 96 vs 84 boundary elements.
    assert 2 * halo_surface_volume((12, 18), (3, 2)) == pytest.approx(96)
    assert 2 * halo_surface_volume((18, 12), (3, 2)) == pytest.approx(84)
    assert 2 * halo_surface_volume((12, 18), (2, 3)) == pytest.approx(84)


def test_paper_sec43_greedy_strawman():
    """d=72, l=(8,9): greedy workload balancing is suboptimal; search exact."""
    opt = optimal_factorization(72, (8, 9))
    assert opt == (8, 9)  # workload (1, 1)
    greedy = greedy_workload_factorization(72, (8, 9))
    obj = halo_objective((8, 9))
    assert obj(greedy) >= obj(opt)


def test_decompose_3d_fig9():
    """Fig. 9: 16 procs over (4,8,4) -> workload (2,2,2) i.e. grid (2,4,2)."""
    assert optimal_factorization(16, (4, 8, 4)) == (2, 4, 2)


def test_anisotropic_objective():
    """Sec 7.2.1: heavy halo in dim 0 pushes cuts to dim 1."""
    iso = optimal_factorization(16, (64, 64))
    assert iso == (4, 4)
    aniso = optimal_factorization(16, (64, 64), halo=(16.0, 1.0))
    # Cutting along dim 0 is 16x more expensive -> fewer cuts across dim 0.
    assert aniso[0] < aniso[1]
    v_iso = aniso_halo_volume((64, 64), iso, (16.0, 1.0))
    v_opt = aniso_halo_volume((64, 64), aniso, (16.0, 1.0))
    assert v_opt <= v_iso


def test_transpose_objective():
    obj = transpose_objective((256, 256), transpose_dims=(0,))
    f = optimal_factorization(64, (256, 256), objective=obj)
    # All-to-all along dim 0 penalizes splitting dim 0.
    assert f[0] <= f[1]
    assert transpose_volume((256, 256), (1, 64), (0,)) == 0.0


@settings(max_examples=80, deadline=None)
@given(
    d=st.integers(1, 96),
    lengths=st.lists(st.integers(1, 64), min_size=1, max_size=3).map(tuple),
)
def test_optimal_beats_every_factorization(d, lengths):
    """The optimality claim of Sec 4.3: enumerator <= every candidate."""
    k = len(lengths)
    obj = halo_objective(lengths)
    best = optimal_factorization(d, lengths)
    assert math.prod(best) == d
    for cand in enumerate_factorizations(d, k):
        assert obj(best) <= obj(cand) + 1e-12


@settings(max_examples=60, deadline=None)
@given(
    d=st.integers(1, 64),
    k=st.integers(1, 4),
)
def test_greedy_is_valid_factorization(d, k):
    f = greedy_factorization(d, k)
    assert math.prod(f) == d
    assert list(f) == sorted(f, reverse=True)


@settings(max_examples=40, deadline=None)
@given(
    d=st.integers(2, 64),
    lengths=st.lists(st.integers(2, 64), min_size=2, max_size=3).map(tuple),
)
def test_optimal_never_worse_than_greedy(d, lengths):
    """The paper's headline: decompose >= Algorithm 1, always."""
    k = len(lengths)
    obj = halo_objective(lengths)
    opt = optimal_factorization(d, lengths)
    gre = greedy_factorization(d, k)
    assert obj(opt) <= obj(gre) + 1e-12


def test_surface_volume_matches_aniso_form():
    """2S (Sec 4.2) and the directional form agree up to boundary terms."""
    lengths, factors = (24, 36), (4, 6)
    s = halo_surface_volume(lengths, factors)
    # interior cuts: (d0-1) planes of size l1 + (d1-1) planes of size l0
    expected = (factors[0] - 1) * lengths[1] + (factors[1] - 1) * lengths[0]
    assert s == pytest.approx(expected)
