"""Tests for the decompose solver (paper Sec. 4) — optimality, baselines."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.decompose import (
    cached_optimal,
    count_factorizations,
    enumerate_factorizations,
    greedy_factorization,
    greedy_workload_factorization,
    halo_objective,
    optimal_factorization,
    prime_factorization,
    transpose_objective,
)
from repro.core.commvolume import (
    aniso_halo_volume,
    halo_surface_volume,
    transpose_volume,
)


def test_prime_factorization():
    assert prime_factorization(1) == []
    assert prime_factorization(2) == [2]
    assert prime_factorization(48) == [2, 2, 2, 2, 3]
    assert prime_factorization(97) == [97]


def test_enumeration_complete_and_counts():
    # Sec 4.3: d=16, k=3 -> C(6,2)=15 factorizations.
    facts = list(enumerate_factorizations(16, 3))
    assert len(facts) == 15 == count_factorizations(16, 3)
    assert all(math.prod(f) == 16 for f in facts)
    assert len(set(facts)) == len(facts)
    # d = 48 = 2^4 * 3: C(6,2) * C(3,2) = 15 * 3 = 45.
    assert count_factorizations(48, 3) == 45
    assert len(list(enumerate_factorizations(48, 3))) == 45


@settings(max_examples=100, deadline=None)
@given(d=st.integers(1, 512), k=st.integers(1, 4))
def test_count_matches_enumeration_property(d, k):
    """Closed form prod_j C(a_j + k - 1, k - 1) == the enumerator's output,
    with no duplicates and every tuple multiplying back to d."""
    facts = list(enumerate_factorizations(d, k))
    assert count_factorizations(d, k) == len(facts)
    assert len(set(facts)) == len(facts)
    assert all(math.prod(f) == d for f in facts)


def test_paper_sec41_example():
    """6 procs, iteration (12,18): optimal grid (2,3), greedy picks (3,2)."""
    assert optimal_factorization(6, (12, 18)) == (2, 3)
    assert greedy_factorization(6, 2) == (3, 2)
    # Volumes from Fig. 8: 96 vs 84 boundary elements.
    assert 2 * halo_surface_volume((12, 18), (3, 2)) == pytest.approx(96)
    assert 2 * halo_surface_volume((18, 12), (3, 2)) == pytest.approx(84)
    assert 2 * halo_surface_volume((12, 18), (2, 3)) == pytest.approx(84)


def test_paper_sec43_greedy_strawman():
    """d=72, l=(8,9): greedy workload balancing is suboptimal; search exact."""
    opt = optimal_factorization(72, (8, 9))
    assert opt == (8, 9)  # workload (1, 1)
    greedy = greedy_workload_factorization(72, (8, 9))
    obj = halo_objective((8, 9))
    assert obj(greedy) >= obj(opt)


def test_decompose_3d_fig9():
    """Fig. 9: 16 procs over (4,8,4) -> workload (2,2,2) i.e. grid (2,4,2)."""
    assert optimal_factorization(16, (4, 8, 4)) == (2, 4, 2)


def test_anisotropic_objective():
    """Sec 7.2.1: heavy halo in dim 0 pushes cuts to dim 1."""
    iso = optimal_factorization(16, (64, 64))
    assert iso == (4, 4)
    aniso = optimal_factorization(16, (64, 64), halo=(16.0, 1.0))
    # Cutting along dim 0 is 16x more expensive -> fewer cuts across dim 0.
    assert aniso[0] < aniso[1]
    v_iso = aniso_halo_volume((64, 64), iso, (16.0, 1.0))
    v_opt = aniso_halo_volume((64, 64), aniso, (16.0, 1.0))
    assert v_opt <= v_iso


def test_transpose_objective():
    obj = transpose_objective((256, 256), transpose_dims=(0,))
    f = optimal_factorization(64, (256, 256), objective=obj)
    # All-to-all along dim 0 penalizes splitting dim 0.
    assert f[0] <= f[1]
    assert transpose_volume((256, 256), (1, 64), (0,)) == 0.0


@settings(max_examples=80, deadline=None)
@given(
    d=st.integers(1, 96),
    lengths=st.lists(st.integers(1, 64), min_size=1, max_size=3).map(tuple),
)
def test_optimal_beats_every_factorization(d, lengths):
    """The optimality claim of Sec 4.3: enumerator <= every candidate."""
    k = len(lengths)
    obj = halo_objective(lengths)
    best = optimal_factorization(d, lengths)
    assert math.prod(best) == d
    for cand in enumerate_factorizations(d, k):
        assert obj(best) <= obj(cand) + 1e-12


@settings(max_examples=60, deadline=None)
@given(
    d=st.integers(1, 64),
    k=st.integers(1, 4),
)
def test_greedy_is_valid_factorization(d, k):
    f = greedy_factorization(d, k)
    assert math.prod(f) == d
    assert list(f) == sorted(f, reverse=True)


@settings(max_examples=40, deadline=None)
@given(
    d=st.integers(2, 64),
    lengths=st.lists(st.integers(2, 64), min_size=2, max_size=3).map(tuple),
)
def test_optimal_never_worse_than_greedy(d, lengths):
    """The paper's headline: decompose >= Algorithm 1, always."""
    k = len(lengths)
    obj = halo_objective(lengths)
    opt = optimal_factorization(d, lengths)
    gre = greedy_factorization(d, k)
    assert obj(opt) <= obj(gre) + 1e-12


def test_surface_volume_matches_aniso_form():
    """2S (Sec 4.2) and the directional form agree up to boundary terms."""
    lengths, factors = (24, 36), (4, 6)
    s = halo_surface_volume(lengths, factors)
    # interior cuts: (d0-1) planes of size l1 + (d1-1) planes of size l0
    expected = (factors[0] - 1) * lengths[1] + (factors[1] - 1) * lengths[0]
    assert s == pytest.approx(expected)


# ------------------------------------------- objective / volume agreement
@settings(max_examples=60, deadline=None)
@given(
    d=st.integers(2, 96),
    lengths=st.lists(st.integers(2, 64), min_size=2, max_size=3).map(tuple),
    halo=st.lists(st.sampled_from([1.0, 2.0, 5.0]), min_size=3,
                  max_size=3).map(tuple),
    tdim=st.integers(0, 2),
)
def test_transpose_objective_argmin_matches_exact_volumes(d, lengths, halo,
                                                          tdim):
    """The argmin of transpose_objective over the enumerator must coincide
    with the argmin of the exact aniso_halo_volume + transpose_volume sum
    (Sec. 7.2: the objective IS those volumes, not a proxy)."""
    k = len(lengths)
    h = halo[:k]
    tdims = (tdim % k,)
    obj = transpose_objective(lengths, tdims, halo=h)

    def exact(f):
        return aniso_halo_volume(lengths, f, h) + transpose_volume(
            lengths, f, tdims
        )

    cands = list(enumerate_factorizations(d, k))
    by_obj = min(cands, key=lambda f: (obj(f), f))
    by_exact = min(cands, key=lambda f: (exact(f), f))
    # Tie-robust argmin agreement: each metric's winner must achieve the
    # other's minimum (winners may differ only between exactly-tied grids).
    assert exact(by_obj) == pytest.approx(exact(by_exact), rel=1e-12)
    assert obj(by_exact) == pytest.approx(obj(by_obj), rel=1e-12)
    assert obj(by_obj) == pytest.approx(exact(by_obj), rel=1e-9)


@settings(max_examples=60, deadline=None)
@given(
    d=st.sampled_from([2, 4, 8, 16, 32, 64]),
    lengths=st.lists(st.sampled_from([64, 128, 256, 512]), min_size=2,
                     max_size=3).map(tuple),
)
def test_halo_objective_ranking_matches_exact_surface(d, lengths):
    """On divisible candidates the scale-free halo objective must rank
    factorizations exactly as the exact interior-surface volume does
    (they differ by a constant: sum_m prod_{n != m} l_n)."""
    k = len(lengths)
    divisible = [
        f for f in enumerate_factorizations(d, k)
        if all(length % fm == 0 for length, fm in zip(lengths, f))
    ]
    assert divisible  # powers of two over power-of-two extents
    obj = halo_objective(lengths)
    by_obj = sorted(divisible, key=lambda f: (obj(f), f))
    by_exact = sorted(divisible, key=lambda f: (halo_surface_volume(lengths, f), f))
    assert by_obj == by_exact


# ----------------------------------------------------- require_divisible
def test_require_divisible_picks_divisible_optimum():
    """d=8 over (4,6): unconstrained optimum (2,4) does not divide the
    extents; the integrality-constrained solver returns (4,2)."""
    assert optimal_factorization(8, (4, 6)) == (2, 4)
    assert optimal_factorization(8, (4, 6), require_divisible=True) == (4, 2)


def test_cached_optimal_threads_require_divisible():
    assert cached_optimal(8, (4, 6)) == (2, 4)
    assert cached_optimal(8, (4, 6), require_divisible=True) == (4, 2)
    # Falls back to the unconstrained optimum when nothing divides.
    assert cached_optimal(8, (5, 7), require_divisible=True) == \
        cached_optimal(8, (5, 7))
    # Memoization: same call returns the identical tuple object.
    a = cached_optimal(64, (1024, 8192), require_divisible=True)
    b = cached_optimal(64, (1024, 8192), require_divisible=True)
    assert a is b
