"""The JAX pricing backend vs the NumPy batch engine.

The contract under test: ``JaxBatchSimulator`` returns the NumPy
engine's numbers — to float64 round-off in its default dtype, on every
formulation (dense gather, segment scatter, Pallas reduce), for any
placement (bijective or not), regardless of the NumPy side's folding /
incremental flags — while pricing whole stacks as compiled programs.
"""
import numpy as np
import pytest

from repro import apps
from repro.sim import jax_backend as jb
from repro.sim.batch import price_stacks
from repro.sim.cost import SimulatedTimeCostModel, time_search_space

pytestmark = pytest.mark.skipif(not jb.have_jax(),
                                reason="jax unavailable")

# float64 (the default) reproduces the NumPy engine to round-off; the
# registry parity gate in benchmarks/sim_eval.py runs at 1e-6 relative.
F64_RTOL = 1e-12
# float32 accumulates port loads in single precision: fine for search
# ranking, NOT for the parity gate (use float64 there) — see
# docs/simulator.md "Backends".
F32_RTOL = 5e-4


def _model(app_name: str, opts: dict | None = None):
    app = apps.get(app_name)
    sp = time_search_space(app)
    combo = dict(next(iter(app.search_space.option_combos())))
    n = app.default_procs
    model = sp.cost_model(n, opts if opts is not None else combo)
    grid = next(g for g in app.search_space.grids(n))
    return model, grid, n


def _stack(model, grid, n, n_rand: int = 4, seed: int = 0):
    rng = np.random.default_rng(seed)
    rows = [model._default_assignment(grid).reshape(-1)]
    rows += [rng.permutation(n) for _ in range(n_rand)]
    return np.stack(rows)


def _rel(got, ref):
    return float((np.abs(got - ref)
                  / np.maximum(np.abs(ref), 1e-300)).max())


@pytest.mark.parametrize("app_name", ["summa", "stencil", "circuit",
                                      "solomonik"])
def test_f64_parity_vs_numpy_fold_on_and_off(app_name):
    model, grid, n = _model(app_name)
    eng = model.batch(grid)
    stack = _stack(model, grid, n)
    got = jb.to_jax(eng).step_times(stack)
    for fold in (True, False):
        ref = eng.step_times(stack, fold=fold, incremental=fold)
        assert _rel(got, ref) <= F64_RTOL


@pytest.mark.parametrize("app_name", ["summa", "stencil"])
def test_scatter_mode_parity(app_name, monkeypatch):
    """With the dense ceiling forced to zero every schedule takes the
    general segment-scatter formulation — same numbers."""
    monkeypatch.setattr(jb, "_DENSE_CELLS_MAX", 0)
    model, grid, n = _model(app_name)
    eng = model.batch(grid)
    stack = _stack(model, grid, n)
    jeng = jb.to_jax(eng)
    # The export is memoized on the (shared, memoized) schedule object —
    # drop any dense export a previous pricing left there.
    getattr(jeng.schedule, "_jax_exports", {}).clear()
    got = jeng.step_times(stack)
    exp = jb._export_for(jeng.schedule, jeng.topology)
    assert exp.mode == "scatter"
    assert _rel(got, eng.step_times(stack)) <= F64_RTOL
    getattr(jeng.schedule, "_jax_exports", {}).clear()


def test_pallas_reduce_parity():
    model, grid, n = _model("summa")
    eng = model.batch(grid)
    stack = _stack(model, grid, n)
    ref = eng.step_times(stack)
    got = jb.to_jax(eng, use_pallas=True).step_times(stack)
    assert _rel(got, ref) <= F64_RTOL


def test_f32_is_looser_than_f64():
    """The dtype boundary: float32 drifts past float64 round-off (single
    -precision port-load accumulation) but stays inside the documented
    search-ranking tolerance. Anything needing the 1e-6 parity gate must
    run float64."""
    model, grid, n = _model("summa")
    eng = model.batch(grid)
    stack = _stack(model, grid, n)
    ref = eng.step_times(stack)
    rel32 = _rel(jb.to_jax(eng, dtype="float32").step_times(stack), ref)
    rel64 = _rel(jb.to_jax(eng).step_times(stack), ref)
    assert rel64 <= F64_RTOL
    assert rel32 <= F32_RTOL
    assert rel32 > rel64          # f32 really is the lossy tier


def test_non_bijective_rows_fall_back_to_scatter():
    """Dense mode needs invertible rows; a stack with repeated target
    processors must still price exactly (via the scatter formulation)."""
    model, grid, n = _model("stencil")
    eng = model.batch(grid)
    bad = np.tile(np.arange(n) // 2 * 2, (3, 1))
    ref = eng.step_times(bad)
    got = jb.to_jax(eng).step_times(bad)
    assert _rel(got, ref) <= F64_RTOL


def test_fold_flags_are_moot():
    model, grid, n = _model("summa")
    jeng = jb.to_jax(model.batch(grid))
    stack = _stack(model, grid, n)
    a = jeng.step_times(stack)
    b = jeng.step_times(stack, fold=False, incremental=False)
    np.testing.assert_array_equal(a, b)


def test_chunked_pricing_matches_single_call(monkeypatch):
    """Shrinking the device budget forces multiple padded chunks; the
    result must be bit-identical to the one-chunk pricing."""
    model, grid, n = _model("summa")
    eng = model.batch(grid)
    stack = _stack(model, grid, n, n_rand=6)
    whole = jb.to_jax(eng).step_times(stack)
    monkeypatch.setattr(jb, "_MAX_DEVICE_ELEMS", 1)
    jeng = jb.to_jax(eng)
    jb._export_for(jeng.schedule, jeng.topology)._fns.clear()
    chunked = jeng.step_times(stack)
    np.testing.assert_array_equal(whole, chunked)


def test_price_stacks_routes_jax_engines():
    """Mixed numpy/jax stacks through one price_stacks call: the jax
    engine prices independently, the numpy engine joins the shared pass,
    and both return the same seconds."""
    model, grid, n = _model("stencil")
    eng = model.batch(grid)
    jeng = jb.to_jax(eng)
    stack = _stack(model, grid, n)
    out_np, out_jax = price_stacks([(eng, stack), (jeng, stack)])
    assert _rel(out_jax, out_np) <= F64_RTOL


def test_cost_model_engine_batched_jax():
    model, grid, n = _model("summa")
    jmodel = SimulatedTimeCostModel(
        pattern=model.pattern, spec=model.spec,
        step_flops=model.step_flops, base=model.base,
        engine="batched-jax",
    )
    assert isinstance(jmodel.beam_pricer(grid), jb.JaxBatchSimulator)
    assert abs(jmodel.cost(grid) - model.cost(grid)) \
        <= F64_RTOL * abs(model.cost(grid))
    got = jmodel.price_assignments(grid, _stack(model, grid, n))
    ref = model.price_assignments(grid, _stack(model, grid, n))
    assert _rel(got, ref) <= F64_RTOL


def test_cost_model_rejects_unknown_engine():
    model, grid, n = _model("summa")
    with pytest.raises(ValueError, match="engine"):
        SimulatedTimeCostModel(
            pattern=model.pattern, spec=model.spec,
            step_flops=model.step_flops, engine="batched-tpu",
        )


def test_invalid_dtype_rejected():
    model, grid, n = _model("summa")
    with pytest.raises(ValueError, match="dtype"):
        jb.to_jax(model.batch(grid), dtype="float16")


def test_tuner_picks_same_winner_on_jax_engine():
    """End to end: the autotuner searching on the jax engine lands on
    the same winning candidate as on the numpy engine."""
    from repro.search.tuner import tune_app
    from repro.sim.cost import time_tuned_app

    app = apps.get("summa")
    rep_np = tune_app(time_tuned_app(app), None)
    rep_jax = tune_app(time_tuned_app(app, engine="batched-jax"), None)
    assert (rep_jax.best.candidate.describe()
            == rep_np.best.candidate.describe())
    assert rep_jax.best.placed_cost == pytest.approx(
        rep_np.best.placed_cost, rel=1e-9)


def test_cli_backend_flag():
    from repro.apps.run import main

    assert main(["--app", "summa", "--tune", "--time",
                 "--backend", "jax"]) == 0
    with pytest.raises(SystemExit):
        main(["--app", "summa", "--tune", "--backend", "jax"])


def test_export_cached_on_schedule():
    model, grid, n = _model("stencil")
    jeng = jb.to_jax(model.batch(grid))
    jeng.step_times(_stack(model, grid, n, n_rand=1))
    e1 = jb._export_for(jeng.schedule, jeng.topology)
    e2 = jb._export_for(jeng.schedule, jeng.topology)
    assert e1 is e2
    assert e1._fns              # compiled callables retained
