"""Tests for the streaming Phase-3 pipeline (repro.search.pipeline).

The pipeline's contract is "reorders work, never arithmetic": every
number a pipelined tune produces must equal the barrier path's — bit for
bit in f64, and bit for bit between the async and synchronous entry
points of the same engine in f32 (the f32-vs-f64 drift belongs to the
engine, not the pipeline). Plus the mechanics: the bounded queue must
actually bound the producer's lead, producer exceptions must surface in
the consumer, and early consumer exit must unwind the producer thread.
"""
import threading
import time

import numpy as np
import pytest

from repro import apps
from repro.sim.batch import batch_simulator, price_stacks
from repro.sim.cost import time_tuned_app
from repro.sim.jax_backend import have_jax, to_jax
from repro.search.pipeline import PriceJob, price_job, stream_priced
from repro.search.tuner import tune_app

TIMED_APPS = [a for a in apps.iter_apps()
              if a.search_space is not None
              and getattr(a, "collective", None) is not None]
APP_IDS = [a.name for a in TIMED_APPS]

pytestmark = pytest.mark.skipif(not have_jax(), reason="jax not installed")


def _leaderboard_key(report):
    return [(s.candidate.describe(), s.volume, s.placed_cost,
             s.cross_node, s.bijective) for s in report.leaderboard]


# ------------------------------------------------------------- bit identity
@pytest.mark.parametrize("app", TIMED_APPS, ids=APP_IDS)
def test_pipeline_matches_barrier_across_registry_jax(app):
    """Pipelined and barrier Phase 3 rank identically on the JAX engine:
    same winner, same leaderboard, placed seconds equal to the last
    bit (f64)."""
    timed = time_tuned_app(app, engine="batched-jax")
    streamed = tune_app(timed, pipeline=True)
    barrier = tune_app(timed, pipeline=False)
    assert streamed.best.candidate.describe() \
        == barrier.best.candidate.describe()
    assert _leaderboard_key(streamed) == _leaderboard_key(barrier)


@pytest.mark.parametrize("app", TIMED_APPS[:3], ids=APP_IDS[:3])
def test_pipeline_matches_barrier_numpy_engine(app):
    """The host NumPy engine streams too (eager handles): identical
    reports either way."""
    timed = time_tuned_app(app, engine="batched")
    streamed = tune_app(timed, pipeline=True)
    barrier = tune_app(timed, pipeline=False)
    assert _leaderboard_key(streamed) == _leaderboard_key(barrier)


def _stack_jobs(engine, rng, n_groups=4, rows=6):
    nt = int(np.prod(engine.schedule.grid))
    return [
        PriceJob(engine=engine,
                 stack=np.stack([rng.permutation(nt)
                                 for _ in range(rows)]),
                 entries=list(range(rows)))
        for _ in range(n_groups)
    ]


@pytest.mark.parametrize("fold", [True, False], ids=["fold", "nofold"])
@pytest.mark.parametrize("dtype", ["float64", "float32"])
def test_stream_priced_bitwise_equals_sync(fold, dtype):
    """stream_priced == price_job == step_times for random placements,
    with folding on and off and in both precisions — the async path must
    run the same programs, so equality is exact, not approximate."""
    app = apps.get("summa")
    n = app.default_procs
    eng = to_jax(batch_simulator(
        app.collective, _spec(app, n), app.tile_grid(n),
        step_flops=float(app.step_flops(n))), dtype=dtype)
    rng = np.random.default_rng(7)
    jobs = _stack_jobs(eng, rng)
    streamed = {id(j): t for j, t in
                stream_priced(iter(jobs), fold=fold, incremental=fold)}
    for job in jobs:
        sync = price_job(job, fold=fold, incremental=fold)
        direct = np.asarray(job.engine.step_times(job.stack, fold=fold,
                                                  incremental=fold))
        assert np.array_equal(streamed[id(job)], sync)
        assert np.array_equal(sync, direct)


def test_stream_priced_matches_price_stacks_numpy():
    """The NumPy engine's streamed groups equal the packed-sweep values
    bit for bit (independent buckets: packing never changed the
    arithmetic)."""
    app = apps.get("summa")
    n = app.default_procs
    eng = batch_simulator(app.collective, _spec(app, n), app.tile_grid(n),
                          step_flops=float(app.step_flops(n)))
    rng = np.random.default_rng(11)
    jobs = _stack_jobs(eng, rng)
    packed = price_stacks([(j.engine, j.stack) for j in jobs])
    streamed = {id(j): t for j, t in stream_priced(iter(jobs))}
    for job, expect in zip(jobs, packed):
        assert np.array_equal(streamed[id(job)], expect)


def _spec(app, n):
    from repro.sim.cost import spec_for

    return spec_for(tuple(int(s) for s in app.machine_shape(n)))


# --------------------------------------------------------------- mechanics
def test_bounded_queue_limits_producer_lead():
    """The producer blocks once queue_size groups wait unconsumed: its
    lead over the consumer stays <= queue_size + in_flight + 1 (one
    group in its hands, in_flight dispatched, queue_size buffered)."""
    app = apps.get("summa")
    n = app.default_procs
    eng = batch_simulator(app.collective, _spec(app, n), app.tile_grid(n),
                          step_flops=float(app.step_flops(n)))
    rng = np.random.default_rng(3)
    produced = []
    consumed = []
    max_lead = []
    queue_size, in_flight = 2, 1

    def jobs():
        for job in _stack_jobs(eng, rng, n_groups=12, rows=2):
            produced.append(1)
            yield job

    for _job, _t in stream_priced(jobs(), queue_size=queue_size,
                                  in_flight=in_flight):
        time.sleep(0.02)          # slow consumer: let the producer run
        consumed.append(1)
        max_lead.append(len(produced) - len(consumed))
    assert len(consumed) == 12
    assert max(max_lead) <= queue_size + in_flight + 1


def test_producer_exception_propagates():
    app = apps.get("summa")
    n = app.default_procs
    eng = batch_simulator(app.collective, _spec(app, n), app.tile_grid(n),
                          step_flops=float(app.step_flops(n)))
    rng = np.random.default_rng(5)

    def jobs():
        yield _stack_jobs(eng, rng, n_groups=1)[0]
        raise RuntimeError("expansion exploded")

    results = []
    with pytest.raises(RuntimeError, match="expansion exploded"):
        for job, t in stream_priced(jobs()):
            results.append(t)
    # The group produced before the failure still priced.
    assert len(results) <= 1


def test_early_consumer_exit_unwinds_producer():
    """Closing the result generator mid-stream must stop the producer
    thread (no daemon thread left spinning on a full queue)."""
    app = apps.get("summa")
    n = app.default_procs
    eng = batch_simulator(app.collective, _spec(app, n), app.tile_grid(n),
                          step_flops=float(app.step_flops(n)))
    rng = np.random.default_rng(9)
    before = threading.active_count()
    gen = stream_priced(iter(_stack_jobs(eng, rng, n_groups=8)),
                        queue_size=1, in_flight=1)
    next(gen)
    gen.close()
    deadline = time.time() + 5.0
    while threading.active_count() > before and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() <= before


def test_invalid_bounds_rejected():
    with pytest.raises(ValueError):
        list(stream_priced(iter([]), queue_size=0))
    with pytest.raises(ValueError):
        list(stream_priced(iter([]), in_flight=0))
