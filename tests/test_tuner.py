"""Tests for the mapper autotuner (repro.search) and its search spaces."""
import math

import numpy as np
import pytest

from repro import apps
from repro.core import dsl
from repro.core.decompose import enumerate_factorizations
from repro.core.machine import GPU, Machine
from repro.search.space import (
    BLOCK_CYCLIC,
    CYCLIC_BLOCK,
    Candidate,
    build_program,
    node_split,
    render_source,
)
from repro.search.tuner import (
    cross_node_fraction,
    tune_app,
    tune_registry,
)

ALL_APPS = list(apps.iter_apps())
APP_IDS = [a.name for a in ALL_APPS]


# ----------------------------------------------------------- candidate space
def test_all_nine_apps_declare_search_spaces():
    assert all(a.search_space is not None for a in ALL_APPS)


@pytest.mark.parametrize("app", ALL_APPS, ids=APP_IDS)
def test_search_grids_are_valid_factorizations(app):
    space = app.search_space
    grids = space.grids(64)
    assert grids
    for g in grids:
        assert len(g) == space.rank
        assert math.prod(g) == 64


def test_node_split_divides_the_grid():
    nf = node_split((16, 4), (8, 8))
    assert nf is not None and math.prod(nf) == 16
    assert all(g % f == 0 for g, f in zip((8, 8), nf))
    assert node_split((8, 1), (2, 4)) is None       # flat machine
    assert node_split((1, 8), (2, 4)) is None


@pytest.mark.parametrize("dist", [
    (BLOCK_CYCLIC, BLOCK_CYCLIC),
    (BLOCK_CYCLIC, CYCLIC_BLOCK),
    (CYCLIC_BLOCK, BLOCK_CYCLIC),
    (CYCLIC_BLOCK, CYCLIC_BLOCK),
])
@pytest.mark.parametrize("order", [(0, 1), (1, 0)])
def test_candidate_programs_are_bijective(dist, order):
    """Every distribution x order variant is a bijection onto the machine."""
    cand = Candidate(grid=(4, 16), dist=dist, order=order)
    prog = build_program((16, 4), cand, "t")
    grid = prog.mapper.assignment_grid((4, 16), use_cache=False)
    assert prog.mapper.last_eval_path == "vectorized"
    assert sorted(grid.reshape(-1)) == list(range(64))


def test_candidate_ir_records_decompose_and_swap():
    cand = Candidate(grid=(4, 16), dist=(BLOCK_CYCLIC,) * 2, order=(1, 0))
    prog = build_program((16, 4), cand, "t")
    ir = prog.space.describe()
    assert "decompose" in ir and "swap" in ir
    # Order variants change the permutation, not the volume.
    base = build_program(
        (16, 4),
        Candidate(grid=(4, 16), dist=(BLOCK_CYCLIC,) * 2, order=(0, 1)),
        "t",
    )
    a = prog.mapper.assignment_grid((4, 16), use_cache=False)
    b = base.mapper.assignment_grid((4, 16), use_cache=False)
    assert not np.array_equal(a, b)
    assert sorted(a.reshape(-1)) == sorted(b.reshape(-1))


def test_rendered_source_matches_ir_program():
    """The Mapple DSL rendering of a candidate reproduces its permutation."""
    for cand in (
        Candidate(grid=(4, 16), dist=(BLOCK_CYCLIC, CYCLIC_BLOCK),
                  order=(1, 0)),
        Candidate(grid=(2, 32), dist=(BLOCK_CYCLIC, BLOCK_CYCLIC),
                  order=(0, 1)),
    ):
        prog = build_program((16, 4), cand, "t")
        src = render_source("t", prog)
        parsed = dsl.parse(
            src, machine_factory=lambda *a, **k: Machine(GPU, shape=(16, 4))
        )
        mapper = parsed.mappers[parsed.index_task_maps["t"]]
        np.testing.assert_array_equal(
            mapper.assignment_grid(cand.grid, use_cache=False),
            prog.mapper.assignment_grid(cand.grid, use_cache=False),
        )


def test_block_cyclic_beats_cyclic_block_on_node_locality():
    """The Fig. 12 hierarchy (block over nodes) keeps neighbours on-node."""
    bc = build_program(
        (16, 4), Candidate((8, 8), (BLOCK_CYCLIC,) * 2, (0, 1)), "t"
    )
    cb = build_program(
        (16, 4), Candidate((8, 8), (CYCLIC_BLOCK,) * 2, (0, 1)), "t"
    )
    gpus = 4
    f_bc = cross_node_fraction(
        bc.mapper.assignment_grid((8, 8), use_cache=False) // gpus)
    f_cb = cross_node_fraction(
        cb.mapper.assignment_grid((8, 8), use_cache=False) // gpus)
    assert f_bc < f_cb


# ------------------------------------------------------------------- tuning
@pytest.mark.parametrize("app", ALL_APPS, ids=APP_IDS)
def test_tuner_rediscovers_the_hand_tuned_oracle(app):
    """The regression oracle: search must reproduce the default volume
    exactly and achieve volume <= the hand-tuned value, at paper scale
    and at 64 processors."""
    for procs in (None, 64):
        rep = tune_app(app, procs)
        assert rep.best.bijective
        assert rep.best.eval_path == "vectorized"
        assert rep.verified, rep.best_source
        assert rep.oracle is not None
        assert rep.oracle_ok, (
            f"{app.name}@{rep.procs}: best {rep.best.volume} vs "
            f"oracle {rep.oracle}"
        )


def test_tuner_beats_or_matches_every_candidate_grid():
    """Beam pruning cannot lose the optimum: the winner's volume equals the
    exhaustive minimum over all valid grids."""
    app = apps.get("stencil")
    space = app.search_space
    model = space.cost_model(64, {})
    exhaustive = min(model.cost(g) for g in space.grids(64))
    rep = tune_app(app, 64)
    assert rep.best.volume == exhaustive


def test_tuner_prefers_low_cross_node_variants():
    """Among equal-volume variants the winner minimizes cross-node hops."""
    rep = tune_app(apps.get("cannon"), 64)
    equal_volume = [
        s for s in rep.leaderboard if s.volume == rep.best.volume
    ]
    assert len(equal_volume) > 1      # dist variants really were searched
    assert rep.best.cross_node == min(s.cross_node for s in equal_volume)


def test_tuner_circuit_finds_zcmem_placement():
    rep = tune_app(apps.get("circuit"), 8)
    assert rep.best.candidate.opts["arg1"] == "ZCMEM"
    assert "ZCMEM" in rep.best_source
    assert rep.best.volume == pytest.approx(0.75 * apps.get("circuit").comm_volume(8))


def test_tuner_falls_back_on_infeasible_procs():
    rep = tune_app(apps.get("cannon"), 6)     # no square grid of 6
    assert rep.procs == apps.get("cannon").default_procs
    assert rep.note


def test_tune_registry_covers_all_apps():
    reports = tune_registry(apps.iter_apps(), 64)
    assert {r.app for r in reports} == set(apps.names())
    assert all(r.oracle_ok for r in reports)


def test_searched_volume_never_above_registry_defaults():
    """Search is a strict improvement path: for every app the tuned volume
    is <= the app's own default-mapper volume model at 64 procs."""
    for app in ALL_APPS:
        rep = tune_app(app, 64)
        if rep.default is not None:
            assert rep.best.volume <= rep.default.volume * (1 + 1e-9)


def test_enumerator_backs_the_grid_axis():
    """The grid axis is the Sec. 4.3 enumerator, validity-filtered."""
    space = apps.get("johnson").search_space
    assert set(space.grids(64)) == set(enumerate_factorizations(64, 3))
    cannon_space = apps.get("cannon").search_space
    assert cannon_space.grids(64) == [(8, 8)]


# ---------------------------------------------------------------- warm start
def test_warm_start_with_known_winner_is_bit_identical():
    """Seeding every registry app's search with its own cold winner must
    change nothing: the seed is already shortlisted, so the superset
    beam degenerates to the cold beam (warm_seeds == 0, leaderboards
    bit-equal)."""
    for app in ALL_APPS:
        cold = tune_app(app, 64)
        warm = tune_app(app, 64, warm_start=[cold.best.candidate])
        assert warm.warm_seeds == 0, app.name
        assert warm.best.candidate == cold.best.candidate, app.name
        assert ([ (s.candidate, s.volume, s.placed_cost)
                  for s in warm.leaderboard ]
                == [ (s.candidate, s.volume, s.placed_cost)
                     for s in cold.leaderboard ]), app.name


def test_warm_start_never_worse_than_cold():
    """Seeds strictly widen the beam, so the warm best can never rank
    below the cold best — across the registry, with cross-scale seeds
    refit from the paper-scale winner."""
    from repro.search.tuner import refit_candidate

    for app in ALL_APPS:
        cold_small = tune_app(app)
        procs = cold_small.procs * 4
        if not app.search_space.grids(procs):
            continue
        cold = tune_app(app, procs)
        seed = refit_candidate(app.search_space, cold_small.best.candidate,
                               procs)
        warm = tune_app(app, procs, warm_start=[seed] if seed else [])
        assert warm.best.rank_cost <= cold.best.rank_cost, app.name


def test_warm_start_stale_seed_skipped_not_fatal():
    """Wrong-rank grids, infeasible grids, unknown options and malformed
    seeds are all skipped; the report equals the cold one."""
    app = apps.get("cannon")
    cold = tune_app(app, 64)
    stale = [
        Candidate(grid=(4, 4, 4), dist=("bc",) * 3, order=(0, 1, 2)),
        Candidate(grid=(3, 5), dist=("bc", "bc"), order=(0, 1)),
        Candidate(grid=(8, 8), dist=("bc", "bc"), order=(0, 1),
                  options=(("nosuch", "opt"),)),
        object(),                       # not even a Candidate
    ]
    warm = tune_app(app, 64, warm_start=stale)
    assert warm.warm_seeds == 0
    assert warm.best.candidate == cold.best.candidate
    assert warm.variants_evaluated == cold.variants_evaluated


def test_warm_start_novel_seed_joins_the_beam():
    """A valid seed outside the beam shortlist widens the search and is
    counted (and noted) in the report."""
    app = apps.get("johnson")
    space = app.search_space
    cold = tune_app(app, 64, beam=1)
    shortlisted = {cold.best.candidate.grid}
    novel_grid = next(g for g in space.grids(64) if g not in shortlisted)
    seed = Candidate(grid=novel_grid, dist=("bc",) * 3, order=(0, 1, 2))
    warm = tune_app(app, 64, beam=1, warm_start=[seed])
    assert warm.warm_seeds == 1
    assert "warm-start" in warm.note
    assert warm.variants_evaluated > cold.variants_evaluated
    assert warm.best.rank_cost <= cold.best.rank_cost


def test_refit_candidate_carries_and_repairs():
    from repro.search.tuner import refit_candidate

    space = apps.get("cannon").search_space
    # Exact-feasible grid carries over untouched.
    c = Candidate(grid=(8, 8), dist=("cb", "bc"), order=(1, 0))
    r = refit_candidate(space, c, 64)
    assert r == c
    # Different scale: nearest feasible grid, dist/order preserved.
    r2 = refit_candidate(space, c, 16)
    assert r2.grid == (4, 4) and r2.dist == ("cb", "bc")
    assert r2.order == (1, 0)
    # Infeasible target scale (no square grid of 6) -> None.
    assert refit_candidate(space, c, 6) is None
    # Wrong-rank seed -> None.
    bad = Candidate(grid=(2, 2, 2), dist=("bc",) * 3, order=(0, 1, 2))
    assert refit_candidate(space, bad, 64) is None
