"""Scale-path properties: symmetry folding, incremental re-pricing, and
the schedule-size guard.

The batched engine's folded/incremental fast paths must be *bit-equal*
to dense pricing — they skip work only when the skipped slab's port
loads are provably identical floats, so any divergence at all is a bug.
These tests drive the equality across random machine shapes, the full
registry, adversarial (symmetry-free) placements where folding must
fall back, and single-op placement edits where incremental reuse must
fire.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro import apps
from repro.core.machine import MachineSpec
from repro.search.space import build_program
from repro.search.tuner import feasible_procs, nearest_feasible_procs
from repro.sim.batch import (
    FOLD_STATS,
    batch_simulator,
    fold_stats_reset,
)
from repro.sim.collectives import (
    CollectivePattern,
    packed_schedule,
    schedule_transfer_bound,
)
from repro.sim.cost import (
    MAX_SCHEDULE_TRANSFERS,
    spec_for,
    time_search_space,
)

MATMUL = {"m": 4096, "n": 4096, "k": 4096}


def _spec(shape):
    names = tuple(f"l{i}" for i in range(len(shape)))
    return MachineSpec(shape=tuple(shape), level_names=names)


def _sim(pattern, spec, grid):
    return batch_simulator(pattern, spec, grid, step_flops=1e9)


def _dense(sim, stack):
    return sim.step_times(stack, fold=False, incremental=False)


# ------------------------------------------------------------- fold parity
@pytest.mark.parametrize("shape,grid", [
    ((4, 4), (4, 4)),
    ((2, 8), (4, 4)),
    ((16,), (4, 4)),
    ((2, 2, 4), (4, 4)),
    ((3, 2, 5), (5, 6)),
    ((2, 32), (8, 8)),
])
def test_folded_pricing_bit_equal_across_machine_shapes(shape, grid):
    """Folded == dense, bit for bit, whatever the machine hierarchy —
    on the symmetric default placement (folds fire) and on random
    permutations (folds fall back per candidate)."""
    spec = _spec(shape)
    pattern = CollectivePattern("panel_broadcast", MATMUL)
    sim = _sim(pattern, spec, grid)
    rng = np.random.default_rng(int(np.prod(shape)))
    n = spec.nprocs
    rows = [np.arange(n, dtype=np.int64)]
    rows += [rng.permutation(n) for _ in range(3)]
    stack = np.stack(rows)
    assert np.array_equal(sim.step_times(stack), _dense(sim, stack))


def test_folding_fires_on_symmetric_placement():
    spec = _spec((2, 32))
    sim = _sim(CollectivePattern("panel_broadcast", MATMUL), spec, (8, 8))
    fold_stats_reset()
    a = np.arange(64, dtype=np.int64)[None, :]
    dense = _dense(sim, a)
    assert FOLD_STATS["pairs_folded"] == 0     # dense path never folds
    fold_stats_reset()
    assert np.array_equal(sim.step_times(a), dense)
    assert FOLD_STATS["pairs_folded"] > 0
    assert FOLD_STATS["pairs_priced"] < dense.size * sim.schedule.n_unique


def test_adversarial_placements_fall_back_and_stay_exact():
    """A placement with no translation symmetry must be priced densely
    (the fallback counter proves the fold was attempted and refused),
    and the result must still equal dense pricing bit for bit."""
    spec = _spec((2, 32))
    sim = _sim(CollectivePattern("panel_broadcast", MATMUL), spec, (8, 8))
    rng = np.random.default_rng(7)
    stack = np.stack([rng.permutation(64) for _ in range(4)])
    fold_stats_reset()
    folded = sim.step_times(stack)
    assert FOLD_STATS["fold_fallbacks"] > 0
    assert np.array_equal(folded, _dense(sim, stack))


def test_non_bijective_placement_falls_back_and_stays_exact():
    spec = _spec((2, 32))
    sim = _sim(CollectivePattern("panel_broadcast", MATMUL), spec, (8, 8))
    a = np.arange(64, dtype=np.int64)
    a[1] = a[0]                                # collision: not a permutation
    fold_stats_reset()
    folded = sim.step_times(a[None, :])
    assert FOLD_STATS["fold_fallbacks"] > 0
    assert np.array_equal(folded, _dense(sim, a[None, :]))


def test_folded_pricing_bit_equal_for_every_registry_app():
    """Default placement + every bijective tuner variant of every
    registry app: folded/incremental == dense bit for bit."""
    procs = 256
    for app in apps.iter_apps():
        n = procs if app.search_space.grids(procs) else app.default_procs
        shape = tuple(int(s) for s in app.machine_shape(n))
        sp = time_search_space(app)
        for opts in app.search_space.option_combos():
            model = sp.cost_model(n, dict(opts))
            for grid in app.search_space.grids(n)[:4]:
                try:
                    model._validate(grid)
                except ValueError:
                    continue
                cands = [model._default_assignment(grid)]
                for c in app.search_space.variants(grid, tuple(opts), shape):
                    prog = build_program(shape, c, "scale_test")
                    a = prog.mapper.assignment_grid(c.grid, use_cache=False)
                    if len(np.unique(a.reshape(-1))) == n:
                        cands.append(np.asarray(a))
                stack = np.stack(cands)
                sim = model.batch(grid)
                assert np.array_equal(sim.step_times(stack),
                                      _dense(sim, stack)), \
                    f"{app.name} {grid} {opts}"
            break  # one option combo per app keeps the sweep fast


# ------------------------------------------------------- incremental reuse
def test_incremental_reuse_bit_equal_over_one_op_edits():
    """Rows that differ from the base placement by one local edit only
    re-price the slabs the edit touches; results must equal pricing
    every row in isolation."""
    spec = _spec((8, 8))
    sim = _sim(CollectivePattern("panel_broadcast", MATMUL), spec, (8, 8))
    rng = np.random.default_rng(11)
    base = np.arange(64, dtype=np.int64)
    rows = [base]
    for _ in range(5):
        edit = base.copy()
        i, j = rng.choice(64, size=2, replace=False)
        edit[i], edit[j] = edit[j], edit[i]    # one-op move: swap two tiles
        rows.append(edit)
    stack = np.stack(rows)
    fold_stats_reset()
    got = sim.step_times(stack)
    assert FOLD_STATS["pairs_reused"] > 0
    want = np.concatenate([sim.step_times(r[None, :]) for r in stack])
    assert np.array_equal(got, want)


def test_incremental_identical_rows_reuse_everything():
    spec = _spec((8, 8))
    sim = _sim(CollectivePattern("panel_broadcast", MATMUL), spec, (8, 8))
    stack = np.tile(np.arange(64, dtype=np.int64), (3, 1))
    fold_stats_reset()
    got = sim.step_times(stack)
    assert FOLD_STATS["pairs_reused"] > 0
    assert got[0] == got[1] == got[2]
    assert np.array_equal(got, _dense(sim, stack))


# ------------------------------------------------------ schedule size guard
def test_transfer_bound_dominates_built_schedules():
    """The O(1) bound must never under-count the schedule it guards."""
    for app in apps.iter_apps():
        n = 64 if app.search_space.grids(64) else app.default_procs
        for grid in app.search_space.grids(n)[:6]:
            bound = schedule_transfer_bound(app.collective, grid)
            built = packed_schedule(app.collective, grid)
            assert bound >= built.n_transfers, (app.name, grid)


def test_transfer_bound_unknown_kind_raises():
    with pytest.raises(ValueError, match="transfer bound"):
        schedule_transfer_bound(CollectivePattern("mystery", {}), (4, 4))


def test_cost_model_rejects_oversized_schedules():
    """A skewed panel grid at 16384 procs expands to ~2.7e8 transfers;
    the time model must refuse it as infeasible instead of building it."""
    app = next(a for a in apps.iter_apps() if a.name == "summa")
    model = time_search_space(app).cost_model(16384, {})
    bound = schedule_transfer_bound(app.collective, (1, 16384))
    assert bound > MAX_SCHEDULE_TRANSFERS
    with pytest.raises(ValueError, match="transfers"):
        model.cost((1, 16384))
    assert model.cost((128, 128)) > 0.0        # the square grid still prices


# --------------------------------------------------------- procs validation
def test_feasible_procs_helpers():
    app = next(a for a in apps.iter_apps() if a.name == "cannon")
    assert feasible_procs(app.search_space, 1024)
    assert not feasible_procs(app.search_space, 1000)
    near = nearest_feasible_procs(app.search_space, 1000)
    assert near and near[0] in (961, 1024)
    assert all(feasible_procs(app.search_space, m) for m in near)
