"""Fault-aware stack: DegradedMachine pricing, failure injection in the
event engine, and the warm remap path (search + service).

Acceptance contracts exercised here (mirrored by
``benchmarks/resilience_bench.py``):

  * a mask/contention-free ``DegradedMachine`` prices **bit-identically**
    to the healthy machine through all three engines (event, batched
    NumPy, batched JAX) — registry-wide;
  * with degradation applied, batched-vs-event agreement stays <= 1e-9;
  * remapped plans place **zero** work on masked processors.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro import apps
from repro.core.machine import DegradedMachine, MachineSpec
from repro.search.remap import (
    degraded_from_failures,
    price_on_degraded,
    remap_plan,
    submachine_options,
)
from repro.search.tuner import tune_app
from repro.sim.batch import batch_simulator
from repro.sim.collectives import build_phases
from repro.sim.cost import (
    SimulatedTimeCostModel,
    default_assignment,
    spec_for,
    time_tuned_app,
)
from repro.sim.engine import (
    FaultEvent,
    NodeFailure,
    simulate_steps,
    simulate_steps_with_faults,
)
from repro.sim.topology import Topology

SPEC24 = MachineSpec(shape=(2, 4), level_names=("node", "gpu"))


def _app_model(app, *, engine="batched", degraded=None, procs=None):
    n = procs or app.default_procs
    spec = spec_for(app.machine_shape(n))
    return SimulatedTimeCostModel(
        pattern=app.collective, spec=spec,
        step_flops=float(app.step_flops(n)),
        engine=engine, degraded=degraded,
    ), n, spec


def _default_grid(app, n):
    return app.search_space.default_grid(n) if app.search_space.default_grid \
        else app.search_space.grids(n)[0]


# ----------------------------------------------------------- DegradedMachine
def test_degraded_machine_validates():
    with pytest.raises(ValueError, match="out of range"):
        DegradedMachine(spec=SPEC24, dead_procs=(8,))
    with pytest.raises(ValueError, match="every processor"):
        DegradedMachine(spec=SPEC24, dead_procs=tuple(range(8)))
    with pytest.raises(ValueError, match="one tuple per level"):
        DegradedMachine(spec=SPEC24, contention=((1.0, 1.0),))
    with pytest.raises(ValueError, match="port factors"):
        DegradedMachine(spec=SPEC24, contention=((1.0,), (1.0,) * 8))
    with pytest.raises(ValueError, match=">= 1.0"):
        DegradedMachine(spec=SPEC24, contention=((0.5, 1.0), (1.0,) * 8))


def test_degraded_machine_queries_and_constructors():
    deg = DegradedMachine.fail_procs(SPEC24, [5, 1, 5])
    assert deg.dead_procs == (1, 5)            # sorted, deduped
    assert deg.n_alive == 6
    assert deg.alive_procs() == (0, 2, 3, 4, 6, 7)
    assert not deg.is_trivial

    node = DegradedMachine.fail_nodes(SPEC24, 0, [1])
    assert node.dead_procs == (4, 5, 6, 7)

    cont = DegradedMachine.contend(SPEC24, 0, {1: 2.0})
    assert cont.port_contention(0) == (1.0, 2.0)
    assert cont.port_contention(1) == (1.0,) * 8
    assert not cont.is_trivial

    assert DegradedMachine.healthy(SPEC24).is_trivial
    assert DegradedMachine.contend(SPEC24, 0, {}).is_trivial


def test_degraded_machine_merge_composes():
    a = DegradedMachine.fail_procs(SPEC24, [0])
    b = DegradedMachine.contend(SPEC24, 0, {1: 3.0})
    c = DegradedMachine.contend(SPEC24, 0, {1: 2.0})
    m = a.merged(b).merged(c)
    assert m.dead_procs == (0,)
    assert m.port_contention(0) == (1.0, 6.0)   # factors multiply
    other = MachineSpec(shape=(4, 2), level_names=("node", "gpu"))
    with pytest.raises(ValueError, match="different machines"):
        a.merged(DegradedMachine.healthy(other))


def test_trivial_view_normalizes_to_none():
    topo = Topology.from_spec(SPEC24,
                              degraded=DegradedMachine.healthy(SPEC24))
    assert topo.degraded is None
    model = SimulatedTimeCostModel(
        pattern=apps.get("stencil").collective, spec=SPEC24,
        step_flops=1e12, degraded=DegradedMachine.healthy(SPEC24))
    assert model.degraded is None
    healthy = SimulatedTimeCostModel(
        pattern=apps.get("stencil").collective, spec=SPEC24,
        step_flops=1e12)
    assert model.price_table_key((2, 4)) == healthy.price_table_key((2, 4))


# --------------------------------------------------------- pricing parity
def test_trivial_degraded_bit_identical_registry_all_engines():
    """Acceptance: a mask/contention-free DegradedMachine is bit-identical
    to the healthy path through event, batched NumPy and batched JAX —
    every registry app."""
    for app in apps.iter_apps():
        for engine in ("batched", "event", "batched-jax"):
            model, n, spec = _app_model(app, engine=engine)
            triv, _, _ = _app_model(
                app, engine=engine,
                degraded=DegradedMachine.healthy(spec))
            grid = _default_grid(app, n)
            assert triv.cost(grid) == model.cost(grid), (app.name, engine)


def test_contended_batched_matches_event_registry():
    """Acceptance: under port contention the analytic envelope still
    tracks the event queue to 1e-9 — every registry app."""
    for app in apps.iter_apps():
        model, n, spec = _app_model(app)
        deg = DegradedMachine.contend(spec, 0, {0: 2.5})
        deg = deg.merged(
            DegradedMachine.contend(spec, 1, {1: 1.5})
            if len(spec.shape) > 1 else DegradedMachine.healthy(spec))
        dm, _, _ = _app_model(app, degraded=deg)
        de, _, _ = _app_model(app, engine="event", degraded=deg)
        grid = _default_grid(app, n)
        assign = dm._default_assignment(grid)
        tb = dm.batch(grid).step_time(assign)
        te = de.simulate(grid, assign).per_step_time()
        assert tb == pytest.approx(te, abs=1e-9), app.name
        healthy, _, _ = _app_model(app)
        assert tb >= healthy.batch(grid).step_time(assign), app.name


def test_contended_jax_matches_numpy():
    app = apps.get("summa")
    _, n, spec = _app_model(app)
    deg = DegradedMachine.contend(spec, 0, {0: 2.5, 1: 1.7})
    dn, _, _ = _app_model(app, degraded=deg)
    dj, _, _ = _app_model(app, engine="batched-jax", degraded=deg)
    grid = _default_grid(app, n)
    assign = dn._default_assignment(grid)
    tn = dn.batch(grid).step_time(assign)
    tj = dj.batch(grid).step_time(assign)
    assert tj == pytest.approx(tn, rel=1e-9)


def test_dead_processors_are_unplaceable_all_engines():
    app = apps.get("stencil")
    _, n, spec = _app_model(app)
    deg = DegradedMachine.fail_procs(spec, [3])
    grid = _default_grid(app, n)
    assign = default_assignment(spec.shape, grid)   # touches proc 3
    for engine in ("batched", "batched-jax"):
        model, _, _ = _app_model(app, engine=engine, degraded=deg)
        with pytest.raises(ValueError, match="dead processor"):
            model.batch(grid).step_times(
                np.asarray(assign, dtype=np.int64).reshape(1, -1),
                fold=False)
    event, _, _ = _app_model(app, engine="event", degraded=deg)
    with pytest.raises(ValueError, match="dead processor"):
        event.simulate(grid, assign)


def test_fold_respects_contention_symmetry():
    """Folded pricing must refuse (and fall back) when a shift breaks the
    per-port contention pattern — folded == dense either way."""
    app = apps.get("summa")
    n = 16
    spec = spec_for(app.machine_shape(n))
    deg = DegradedMachine.contend(spec, 0, {0: 3.0})
    sim = batch_simulator(app.collective, spec, (4, 4),
                          step_flops=float(app.step_flops(n)),
                          degraded=deg)
    stack = np.stack([
        default_assignment(spec.shape, (4, 4)).reshape(-1),
        np.roll(default_assignment(spec.shape, (4, 4)).reshape(-1), 4),
    ])
    folded = sim.step_times(stack, fold=True)
    dense = sim.step_times(stack, fold=False)
    np.testing.assert_array_equal(folded, dense)


# ----------------------------------------------------------- fault injection
def test_fault_event_validates():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(t=0.0, kind="meteor")
    with pytest.raises(ValueError, match=">= 0"):
        FaultEvent(t=-1.0, kind="node-death", procs=(0,))
    with pytest.raises(ValueError, match="at least one processor"):
        FaultEvent(t=0.0, kind="node-death")
    with pytest.raises(ValueError, match="factor"):
        FaultEvent(t=0.0, kind="link-slowdown", factor=0.5)
    with pytest.raises(ValueError, match="duration"):
        FaultEvent(t=0.0, kind="link-slowdown", factor=2.0, duration=0.0)


def _stencil_setup():
    app = apps.get("stencil")
    n = app.default_procs
    spec = spec_for(app.machine_shape(n))
    grid = _default_grid(app, n)
    assign = default_assignment(spec.shape, grid)
    phases = build_phases(app.collective, grid, assign, elem_bytes=4)
    compute_s = float(app.step_flops(n)) / (n * spec.peak_flops)
    return spec, grid, assign, phases, compute_s


def test_no_faults_bit_identical_to_simulate_steps():
    spec, _, _, phases, compute_s = _stencil_setup()
    topo = Topology.from_spec(spec)
    base = simulate_steps(phases, topo, compute_s=compute_s, steps=3)
    run = simulate_steps_with_faults(phases, topo, compute_s=compute_s,
                                     steps=3)
    assert run.survived
    assert run.timeline == base
    assert run.per_step_time() == base.per_step_time()


def test_node_death_halts_with_typed_failure():
    spec, _, assign, phases, compute_s = _stencil_setup()
    topo = Topology.from_spec(spec)
    base = simulate_steps(phases, topo, compute_s=compute_s, steps=3)
    t_kill = base.makespan / 2
    run = simulate_steps_with_faults(
        phases, topo, compute_s=compute_s, steps=3,
        faults=[FaultEvent(t=t_kill, kind="node-death", procs=(2,))],
        placement=assign)
    assert not run.survived
    assert isinstance(run.failure, NodeFailure)
    assert run.failure.procs == (2,)
    assert run.failure.time == t_kill
    assert run.timeline.makespan == t_kill
    assert all(s.end <= t_kill for s in run.timeline.segments)
    with pytest.raises(ValueError, match="no step time"):
        run.per_step_time()


def test_node_death_outside_placement_is_survived():
    spec, _, _, phases, compute_s = _stencil_setup()
    topo = Topology.from_spec(spec)
    base = simulate_steps(phases, topo, compute_s=compute_s, steps=3)
    run = simulate_steps_with_faults(
        phases, topo, compute_s=compute_s, steps=3,
        faults=[FaultEvent(t=base.makespan / 2, kind="node-death",
                           procs=(2,))],
        placement=[p for p in range(spec.nprocs) if p != 2][:4])
    assert run.survived and run.timeline.makespan == base.makespan


def test_link_slowdown_window_reprices_dispatches():
    spec, _, _, phases, compute_s = _stencil_setup()
    topo = Topology.from_spec(spec)
    base = simulate_steps(phases, topo, compute_s=compute_s, steps=3)
    # Window covering the whole run: slower than healthy.
    slow = simulate_steps_with_faults(
        phases, topo, compute_s=compute_s, steps=3,
        faults=[FaultEvent(t=0.0, kind="link-slowdown", level=0,
                           factor=4.0, duration=base.makespan * 10)])
    assert slow.survived
    assert slow.timeline.makespan > base.makespan
    # Window entirely after the run: bit-identical to healthy.
    late = simulate_steps_with_faults(
        phases, topo, compute_s=compute_s, steps=3,
        faults=[FaultEvent(t=base.makespan * 10, kind="link-slowdown",
                           level=0, factor=4.0, duration=1.0)])
    assert late.timeline == base
    # Permanent window == statically contended machine's makespan.
    deg = DegradedMachine.contend(
        spec, 0, {p: 4.0 for p in range(spec.level_ports[0])})
    static = simulate_steps(
        phases, Topology.from_spec(spec, degraded=deg),
        compute_s=compute_s, steps=3)
    assert slow.timeline.makespan == pytest.approx(static.makespan,
                                                   rel=1e-12)


# ------------------------------------------------------------------- remap
def test_degraded_from_failures_folds_evidence():
    spec = SPEC24
    view = degraded_from_failures(spec, [
        NodeFailure(time=1.0, step=3, procs=(1,)),
        FaultEvent(t=0.5, kind="node-death", procs=(2,)),
        FaultEvent(t=0.1, kind="link-slowdown", factor=2.0),  # weather
        5,
        DegradedMachine.contend(spec, 0, {0: 2.0}),
    ])
    assert view.dead_procs == (1, 2, 5)
    assert view.port_contention(0) == (2.0, 1.0)
    ready = DegradedMachine.fail_procs(spec, [7])
    assert degraded_from_failures(spec, ready) is ready
    with pytest.raises(ValueError, match="different machine"):
        degraded_from_failures(
            spec, DegradedMachine.healthy(
                MachineSpec(shape=(4, 2), level_names=("node", "gpu"))))


def test_submachine_options_rank_and_avoid_dead():
    deg = DegradedMachine.fail_procs(SPEC24, [3])
    opts = list(submachine_options(deg))
    (shape0, pm0) = opts[0]
    # 7 survive but nodes are uneven (3+4): the best *regular* grid is
    # 2 nodes x 3 procs = 6.
    assert shape0 == (2, 3) and len(pm0) == 6
    for shape, pm in opts:
        a, g = shape
        assert len(pm) == a * g
        assert not set(pm) & set(deg.dead_procs)
        # node-major: logical node i' lives inside ONE physical node
        for i in range(a):
            nodes = {pm[i * g + k] // 4 for k in range(g)}
            assert len(nodes) == 1


def test_remap_places_zero_work_on_masked_procs_registry():
    """Acceptance: remapped plans never touch a dead processor — every
    registry app, one dead proc."""
    for app in apps.iter_apps():
        n = app.default_procs
        spec = spec_for(app.machine_shape(n))
        deg = DegradedMachine.fail_procs(spec, [n - 1])
        res = remap_plan(app, None, deg, mode="warm")
        placed = set(res.placement.reshape(-1).tolist())
        assert not placed & set(deg.dead_procs), app.name
        assert placed <= set(deg.alive_procs()), app.name
        assert np.isfinite(res.degraded_step_s), app.name
        assert res.procs == res.sub_shape[0] * res.sub_shape[1]


def test_remap_warm_start_never_worse_than_stale():
    """On a contention-only degradation (stale plan still placeable) the
    remap — seeded with the stale winner — must never price worse than
    keeping the stale placement."""
    for name in ("stencil", "summa"):
        app = apps.get(name)
        n = app.default_procs
        spec = spec_for(app.machine_shape(n))
        stale = tune_app(time_tuned_app(app), n)
        deg = DegradedMachine.contend(spec, 0, {0: 3.0})
        res = remap_plan(app, stale, deg, mode="warm")
        assert np.isfinite(res.stale_step_s)
        assert res.degraded_step_s <= res.stale_step_s * (1 + 1e-12), name
        # the seeded points replaced the full Phase-1 enumeration
        assert "restricted search" in res.report.note


def test_remap_stale_plan_on_dead_proc_prices_inf():
    app = apps.get("stencil")
    n = app.default_procs
    spec = spec_for(app.machine_shape(n))
    stale = tune_app(time_tuned_app(app), n)
    res = remap_plan(app, stale, DegradedMachine.fail_procs(spec, [0]))
    assert res.stale_step_s == float("inf")
    assert np.isfinite(res.degraded_step_s)


def test_remap_audit_price_matches_event_engine():
    """The batched audit pricing of the physically translated placement
    agrees with the exact event queue on the same degraded machine."""
    from repro.sim.cost import pattern_with_options

    app = apps.get("stencil")
    n = app.default_procs
    spec = spec_for(app.machine_shape(n))
    deg = DegradedMachine.fail_procs(spec, [0]).merged(
        DegradedMachine.contend(spec, 0, {1: 2.0}))
    res = remap_plan(app, None, deg)
    best = res.report.best.candidate
    pattern = pattern_with_options(app.collective, dict(best.options))
    grid = tuple(int(g) for g in best.grid)
    compute_s = float(app.step_flops(res.procs)) / (res.procs
                                                    * spec.peak_flops)
    phases = build_phases(pattern, grid, res.placement, elem_bytes=4)
    t_event = simulate_steps(
        phases, Topology.from_spec(spec, degraded=deg),
        compute_s=compute_s, steps=3).per_step_time()
    t_batched = price_on_degraded(app, deg, best, res.placement,
                                  procs=res.procs)
    assert t_batched == pytest.approx(t_event, abs=1e-9)


def test_remap_warm_vs_cold_same_submachine():
    app = apps.get("summa")
    n = app.default_procs
    spec = spec_for(app.machine_shape(n))
    stale = tune_app(time_tuned_app(app), n)
    deg = DegradedMachine.fail_procs(spec, [1])
    warm = remap_plan(app, stale, deg, mode="warm")
    cold = remap_plan(app, stale, deg, mode="cold")
    assert warm.sub_shape == cold.sub_shape
    assert warm.mode == "warm" and cold.mode == "cold"
    # cold runs the full enumeration: it can only match or beat warm
    assert cold.degraded_step_s <= warm.degraded_step_s * (1 + 1e-12)
    with pytest.raises(ValueError, match="mode"):
        remap_plan(app, stale, deg, mode="lukewarm")


def test_remap_refuses_when_nothing_survives_feasibly():
    import dataclasses

    app = apps.get("cannon")
    # A space that needs at least a 2x2 square grid: 3 survivors cannot
    # host it on any regular sub-machine.
    space = dataclasses.replace(
        app.search_space, grid_ok=lambda f: f[0] == f[1] >= 2)
    strict = dataclasses.replace(app, search_space=space)
    spec = spec_for(app.machine_shape(4))
    deg = DegradedMachine.fail_procs(spec, [0])        # 3 of 4 survive
    with pytest.raises(ValueError, match="sub-machine"):
        remap_plan(strict, None, deg, procs=4)
    bare = dataclasses.replace(app, search_space=None)
    with pytest.raises(ValueError, match="search space"):
        remap_plan(bare, None, deg)
