"""Discrete-event simulator tests: topology, schedules, engine, cost model.

Covers the headline property the subsystem exists for — two mappings with
IDENTICAL communication volume get DIFFERENT simulated times when one
keeps neighbours on a node and the other scatters them round-robin — plus
the flat-topology equivalence with ``machine.modeled_step_time``, the
Backpressure depth agreement across DSL -> plan -> training loop ->
engine, and the registry-wide oracle guarantees of the time-domain tuner.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro import apps
from repro.core import dsl, machine as hw
from repro.core.commvolume import HaloCostModel
from repro.core.machine import PAPER_CLUSTER, MachineSpec
from repro.core.translate import to_spmd
from repro.search.tuner import tune_app
from repro.sim.batch import (
    batch_simulator,
    canonical_assignment,
    price_stacks,
)
from repro.sim.collectives import (
    CollectivePattern,
    Phase,
    alltoall,
    build_phases,
    packed_schedule,
    ring_allgather,
    tree_broadcast,
    tree_reduce,
)
from repro.sim.cost import (
    SimulatedTimeCostModel,
    default_assignment,
    simulate_app,
    time_search_space,
    time_tuned_app,
)
from repro.sim.engine import Task, simulate_steps, simulate_tasks
from repro.sim.topology import Topology

STENCIL_LENGTHS = (1024, 8192)


# ------------------------------------------------------------- MachineSpec
def test_link_bw_per_level_tuple():
    spec = MachineSpec(shape=(2, 4), level_names=("node", "gpu"),
                       link_bws=(6e9, 2e11))
    assert spec.link_bw(0) == 6e9
    assert spec.link_bw(1) == 2e11
    with pytest.raises(ValueError):
        spec.link_bw(2)
    with pytest.raises(ValueError):
        spec.link_bw(-1)


def test_link_bw_default_derivation():
    spec = MachineSpec(shape=(2, 4), level_names=("node", "gpu"))
    assert spec.link_bw(0) == spec.dci_bw
    assert spec.link_bw(1) == spec.ici_bw * spec.ici_links
    flat = MachineSpec(shape=(8,), level_names=("chip",))
    assert flat.link_bw(0) == flat.ici_bw * flat.ici_links


def test_machinespec_validation():
    with pytest.raises(ValueError):
        MachineSpec(shape=(2, 4), level_names=("node",))
    with pytest.raises(ValueError):
        MachineSpec(shape=(2, 4), level_names=("node", "gpu"),
                    link_bws=(6e9,))
    with pytest.raises(ValueError):
        MachineSpec(shape=(2, 4), level_names=("node", "gpu"),
                    link_bws=(6e9, -1.0))


# ---------------------------------------------------------------- topology
def test_crossing_levels():
    topo = Topology.from_spec(PAPER_CLUSTER)           # (2 nodes, 4 gpus)
    src = np.array([0, 0, 0])
    dst = np.array([1, 4, 0])
    # 0->1 same node (level 1); 0->4 crosses nodes (level 0); 0->0 local.
    assert topo.crossing_levels(src, dst).tolist() == [1, 0, 2]


def test_phase_time_contention_scales_with_port_load():
    topo = Topology.from_spec(PAPER_CLUSTER)
    one = topo.phase_time(np.array([0]), np.array([4]), np.array([1e6]))
    # Four gpus of node 0 each send to node 1: same NIC, 4x the bytes.
    four = topo.phase_time(np.arange(4), np.arange(4, 8), np.full(4, 1e6))
    assert four > 3.5 * one
    # Intra-node transfers on distinct ports don't contend.
    intra = topo.phase_time(np.array([0, 2]), np.array([1, 3]),
                            np.full(2, 1e6))
    solo = topo.phase_time(np.array([0]), np.array([1]), np.array([1e6]))
    assert intra == pytest.approx(solo)


def test_local_transfers_are_free():
    topo = Topology.from_spec(PAPER_CLUSTER)
    assert topo.phase_time(np.array([3]), np.array([3]), np.array([1e9])) == 0.0


# -------------------------------------------------------------- collectives
def test_ring_allgather_volume():
    phases = ring_allgather([0, 1, 2, 3], 4096.0)
    assert len(phases) == 3                     # p-1 rounds
    assert sum(p.total_bytes for p in phases) == pytest.approx(
        3 * 4096.0)                             # (p-1)/p * total per member


def test_tree_broadcast_reaches_everyone():
    group = [5, 2, 7, 1, 6]
    phases = tree_broadcast(group, 10.0)
    have = {5}
    for ph in phases:
        for s, d in zip(ph.src, ph.dst):
            assert int(s) in have
            have.add(int(d))
    assert have == set(group)


def test_tree_reduce_mirrors_broadcast():
    group = [0, 1, 2, 3]
    b = tree_broadcast(group, 8.0)
    r = tree_reduce(group, 8.0)
    assert sum(p.total_bytes for p in b) == sum(p.total_bytes for p in r)
    assert r[-1].dst.tolist() == [0]            # last hop lands on the root


def test_alltoall_pairwise():
    (ph,) = alltoall([0, 1, 2], 7.0)
    assert len(ph.src) == 6                     # p*(p-1) directed pairs
    assert ph.total_bytes == pytest.approx(42.0)


def test_halo_phases_track_assignment():
    pattern = CollectivePattern("halo", {"lengths": (16, 16), "fields": 2})
    grid = (2, 2)
    assign = np.arange(4).reshape(grid)
    phases = build_phases(pattern, grid, assign, elem_bytes=4)
    # 2 axes x 2 directions; every tile sends one face per phase.
    assert len(phases) == 4
    face = 2 * (16 / 2) * 4
    assert all(p.total_bytes == pytest.approx(4 * face) for p in phases)


def test_build_phases_validates():
    pattern = CollectivePattern("halo", {"lengths": (16, 16)})
    with pytest.raises(ValueError):
        build_phases(pattern, (2, 2), np.arange(8).reshape(2, 4))
    with pytest.raises(ValueError):
        build_phases(CollectivePattern("nope"), (2,), np.arange(2))
    with pytest.raises(ValueError):   # systolic shift needs a square grid
        build_phases(CollectivePattern("shift", {"m": 8, "n": 8, "k": 8}),
                     (2, 4), np.arange(8).reshape(2, 4))


# ------------------------------------------------------------------- engine
def test_engine_respects_dependencies_and_resources():
    tasks = [
        Task(key="a", duration=2.0, resource="r1"),
        Task(key="b", duration=1.0, resource="r1"),
        Task(key="c", duration=1.0, resource="r2", deps=("a",)),
    ]
    tl = simulate_tasks(tasks)
    seg = {s.key: s for s in tl.segments}
    assert seg["a"].start == 0.0 and seg["a"].end == 2.0
    assert seg["b"].start == 2.0                # serial resource
    assert seg["c"].start == 2.0                # dependency on a
    assert tl.makespan == 3.0


def test_engine_rejects_cycles_and_unknown_deps():
    with pytest.raises(ValueError):
        simulate_tasks([Task(key="a", duration=1.0, resource="r",
                             deps=("missing",))])
    with pytest.raises(ValueError):
        simulate_tasks([
            Task(key="a", duration=1.0, resource="r", deps=("b",)),
            Task(key="b", duration=1.0, resource="r", deps=("a",)),
        ])


def _comm_bound_setup():
    spec = MachineSpec(shape=(4,), level_names=("chip",), link_bws=(1e9,))
    topo = Topology.from_spec(spec, alphas=(0.0,))
    procs = np.arange(4)
    ph = Phase("ring", procs, np.roll(procs, -1), np.full(4, 1e6))
    return topo, ph


def test_backpressure_bounds_in_flight_depth():
    topo, ph = _comm_bound_setup()
    for bp in (1, 2, 4):
        tl = simulate_steps([ph], topo, compute_s=1e-7, steps=10,
                            backpressure=bp)
        assert tl.max_in_flight == bp
    with pytest.raises(ValueError):
        simulate_steps([ph], topo, compute_s=1e-7, steps=2, backpressure=0)


def test_backpressure_overlap_shortens_makespan():
    spec = MachineSpec(shape=(4,), level_names=("chip",), link_bws=(1e9,))
    topo = Topology.from_spec(spec, alphas=(0.0,))
    procs = np.arange(4)
    ph = Phase("ring", procs, np.roll(procs, -1), np.full(4, 1e6))
    compute = 1e-3                     # comparable to the 1 ms comm phase
    serial = simulate_steps([ph], topo, compute_s=compute, steps=6,
                            backpressure=1)
    pipelined = simulate_steps([ph], topo, compute_s=compute, steps=6,
                               backpressure=3)
    assert pipelined.makespan < serial.makespan * 0.75


def test_flat_topology_matches_modeled_step_time():
    """machine.modeled_step_time IS the simulator's flat special case: a
    1-level machine with uniform neighbour traffic reproduces the
    max(compute, comm) envelope; the closed form adds only its 10%
    overlap tax."""
    n = 16
    spec = MachineSpec(shape=(n,), level_names=("chip",))
    topo = Topology.from_spec(spec, alphas=(0.0,))
    flops, elems = 1e12, 3e8
    procs = np.arange(n)
    ph = Phase("ring", procs, np.roll(procs, -1),
               np.full(n, elems * 4 / n))
    tl = simulate_steps([ph], topo, compute_s=flops / (n * spec.peak_flops),
                        steps=6, backpressure=2)
    sim = tl.per_step_time()
    compute = flops / (n * spec.peak_flops)
    comm = elems * 4 / (n * spec.link_bw(0))
    envelope = max(compute, comm)
    assert sim == pytest.approx(envelope, rel=1e-9)
    modeled = hw.modeled_step_time(flops, elems, n)
    assert envelope <= modeled <= envelope + 0.1 * min(compute, comm) + 1e-15
    # and the spec-routed form agrees with the default constants
    assert hw.modeled_step_time(flops, elems, n, spec=spec) == \
        pytest.approx(modeled)


# ------------------------------------------------- the headline acceptance
def _stencil_cost_model(assignment):
    return SimulatedTimeCostModel(
        pattern=CollectivePattern(
            "halo", {"lengths": STENCIL_LENGTHS, "fields": 1}),
        spec=PAPER_CLUSTER,
        step_flops=5.0 * STENCIL_LENGTHS[0] * STENCIL_LENGTHS[1],
        base=HaloCostModel(STENCIL_LENGTHS),
        assignment_fn=lambda grid: assignment,
    )


def test_simulator_separates_mappings_volume_ties():
    """On PAPER_CLUSTER (2 nodes x 4 GPUs) the simulator ranks a
    decomposed stencil mapping strictly faster than naive round-robin
    while the flat volume model ties them — the effect the subsystem
    exists to expose."""
    grid = (2, 4)
    decomposed = default_assignment(PAPER_CLUSTER.shape, grid)
    lin = np.arange(8).reshape(grid)
    round_robin = (lin % 2) * 4 + lin // 2      # neighbours alternate nodes
    assert not np.array_equal(decomposed, round_robin)
    model_dec = _stencil_cost_model(decomposed)
    model_rr = _stencil_cost_model(round_robin)
    # The flat objectives are placement-blind: the two candidates' volume
    # scores tie (cost is a function of the grid alone — the assignment
    # never enters), and so do their flat modeled step times.
    v_dec, v_rr = model_dec.base.cost(grid), model_rr.base.cost(grid)
    assert v_dec == v_rr
    flops = 5.0 * STENCIL_LENGTHS[0] * STENCIL_LENGTHS[1]
    assert hw.modeled_step_time(flops, v_dec, 8) == \
        hw.modeled_step_time(flops, v_rr, 8)
    # The simulator sees the placements.
    t_dec = model_dec.cost(grid)
    t_rr = model_rr.cost(grid)
    assert t_dec < t_rr                          # strictly faster
    assert t_rr / t_dec > 1.5                    # and by a fabric-sized margin


def test_simulated_cost_model_is_a_cost_model():
    model = _stencil_cost_model(default_assignment(PAPER_CLUSTER.shape, (2, 4)))
    assert callable(model)                       # CostModel protocol
    with pytest.raises(ValueError):              # wrong arity -> base rejects
        model.cost((2, 2, 2))
    with pytest.raises(ValueError):              # doesn't cover the machine
        model.cost((2, 2))


# ------------------------------------------------------ tuner integration
def test_time_tuner_plugs_in_unchanged_and_matches_oracles():
    """SimulatedTimeCostModel drops into tune_app via the CostModel
    protocol; at the paper's Table 2 cluster scale the time-optimal
    winner's volume matches the tuning oracle for EVERY registry app."""
    for app in apps.iter_apps():
        rep = tune_app(time_tuned_app(app))
        assert rep.verified, app.name
        vol_model = app.search_space.cost_model(
            rep.procs, rep.best.candidate.opts)
        winner_volume = vol_model.cost(rep.best.candidate.grid)
        o_def, o_tuned = app.tuning(rep.procs)
        assert winner_volume <= o_tuned * (1 + 1e-9), (
            f"{app.name}: time winner volume {winner_volume} regresses "
            f"tuned oracle {o_tuned}"
        )


def test_time_tuner_never_regresses_default_at_scale():
    for app in apps.iter_apps():
        rep = tune_app(time_tuned_app(app), 64)
        vol_model = app.search_space.cost_model(
            rep.procs, rep.best.candidate.opts)
        winner_volume = vol_model.cost(rep.best.candidate.grid)
        o_def, _ = app.tuning(rep.procs)
        assert winner_volume <= o_def * (1 + 1e-9), app.name


# ------------------------------------------------------------ simulate_app
def test_simulate_app_registry_smoke():
    for app in apps.iter_apps():
        rep = simulate_app(app)
        assert rep.step_time_s > 0
        assert rep.n_phases > 0
        assert rep.comm_s > 0
        assert 0.0 <= rep.inter_node_bytes_frac <= 1.0
        assert rep.max_in_flight <= rep.backpressure
        assert rep.timeline.steps == 3


def test_simulate_app_requires_collective():
    import dataclasses

    app = dataclasses.replace(apps.get("stencil"), collective=None)
    with pytest.raises(ValueError):
        simulate_app(app)


# --------------------------------------------- Backpressure end to end
BACKPRESSURE_SOURCE = """\
m = Machine(GPU)
m1 = m.merge(0, 1)

def bptask_map(Tuple ipoint, Tuple ispace):
    idx = ipoint * m1.size / ispace
    return m1[*idx]

IndexTaskMap bptask bptask_map
Region bptask arg0 GPU FBMEM
Backpressure bptask 3
"""


class _FakePipeline:
    def batch(self, step):
        return step


def test_backpressure_depth_agrees_end_to_end():
    """DSL parse -> translate plan -> training-loop in-flight bound ->
    simulator in-flight bound all agree on the same depth."""
    from repro.training import TrainLoop

    depth = 3
    program = dsl.parse(BACKPRESSURE_SOURCE)
    assert program.backpressure["bptask"] == depth

    plan = to_spmd(program, "bptask", (8,), ("x",), devices=[])
    assert plan.backpressure == depth

    # Training loop: max dispatched-but-not-retired steps == depth.
    dispatched = 0
    peak = {"v": 0}
    retired = []

    def step_fn(state, batch):
        nonlocal dispatched
        dispatched += 1
        return state, {"loss": 0.0}

    def on_step(s, m):
        retired.append(s)
        peak["v"] = max(peak["v"], dispatched - len(retired))

    loop = TrainLoop(step_fn=step_fn, pipeline=_FakePipeline(),
                     backpressure=plan.backpressure)
    loop.run(state=None, start_step=0, n_steps=12, log_every=0,
             on_step=on_step)
    assert peak["v"] == depth
    assert retired == list(range(12))

    # Simulator: a comm-bound step pipeline fills exactly `depth` steps.
    topo, ph = _comm_bound_setup()
    tl = simulate_steps([ph], topo, compute_s=1e-7, steps=12,
                        backpressure=plan.backpressure)
    assert tl.max_in_flight == depth


def test_simulate_app_honors_plan_backpressure():
    rep = simulate_app(apps.get("cannon"))      # Backpressure cannon 1
    assert rep.backpressure == 1
    assert rep.max_in_flight == 1
    rep2 = simulate_app(apps.get("summa"))      # Backpressure summa 2
    assert rep2.backpressure == 2


# ------------------------------------------------------- batched engine
def _both_engines(pattern, spec, grid, assign, *, step_flops=1e12,
                  backpressure=2, steps=3):
    """(batched step time, event step time) of one placement."""
    bs = batch_simulator(pattern, spec, grid, step_flops=step_flops,
                         backpressure=backpressure, steps=steps)
    topo = Topology.from_spec(spec)
    phases = build_phases(pattern, grid, assign)
    compute_s = step_flops / (spec.nprocs * spec.peak_flops)
    tl = simulate_steps(phases, topo, compute_s=compute_s, steps=steps,
                        backpressure=backpressure)
    return bs.step_time(np.asarray(assign)), tl.per_step_time()


HALO22 = CollectivePattern("halo", {"lengths": (64, 64)})


def test_stride_crossing_levels_match_coordinate_walk():
    for shape in [(2, 4), (8,), (1, 4), (4, 1), (2, 2, 2), (3, 2, 5)]:
        topo = Topology.from_spec(
            MachineSpec(shape=shape, level_names=tuple("l%d" % i
                                                       for i in range(len(shape)))))
        n = topo.nprocs
        src, dst = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
        got = topo.crossing_levels(src.reshape(-1), dst.reshape(-1))
        cs, cd = topo.coords(src.reshape(-1)), topo.coords(dst.reshape(-1))
        diff = cs != cd
        expect = np.where(diff.any(axis=-1), np.argmax(diff, axis=-1),
                          len(shape))
        np.testing.assert_array_equal(got, expect)


def test_bucket_times_matches_per_phase_pricing():
    """The bucketed pass (dense and sparse) reproduces phase_time exactly."""
    rng = np.random.default_rng(7)
    topo = Topology.from_spec(MachineSpec(shape=(4, 8),
                                          level_names=("node", "gpu")))
    phases = [
        Phase(f"p{i}", rng.integers(0, 32, 50), rng.integers(0, 32, 50),
              rng.uniform(1e3, 1e6, 50))
        for i in range(6)
    ]
    expect = np.array([topo.phase_time(p.src, p.dst, p.nbytes)
                       for p in phases])
    got = topo.phase_times(phases)
    np.testing.assert_array_equal(got, expect)
    # Force the sparse path by inflating the bucket count.
    src = np.concatenate([p.src for p in phases])
    dst = np.concatenate([p.dst for p in phases])
    w = np.concatenate([p.nbytes for p in phases])
    bucket = np.repeat(np.arange(6), [p.src.size for p in phases])
    import repro.sim.topology as topo_mod
    old = topo_mod._DENSE_PORT_CELLS
    topo_mod._DENSE_PORT_CELLS = 1
    try:
        sparse = topo.bucket_times(src, dst, w, bucket, 6)
    finally:
        topo_mod._DENSE_PORT_CELLS = old
    # The sparse sweep's pairwise reduceat sums may differ from the dense
    # bincount by rounding ulps — far inside the 1e-9 engine contract.
    np.testing.assert_allclose(sparse, expect, rtol=1e-12, atol=0)


def test_batch_engine_matches_event_engine_registry_paper_scale():
    """The acceptance contract: batched analytic envelope == event-queue
    per-step time to 1e-9 on the paper cluster for all nine apps."""
    for app in apps.iter_apps():
        sp = time_search_space(app)
        n = app.default_procs
        for opts in app.search_space.option_combos():
            model = sp.cost_model(n, dict(opts))
            for grid in app.search_space.grids(n):
                try:
                    model.base.cost(grid)
                except ValueError:
                    continue
                assign = model._default_assignment(grid)
                t_batch = model.batch(grid).step_time(assign)
                t_event = model.simulate(grid, assign).per_step_time()
                assert t_batch == pytest.approx(t_event, abs=1e-9), (
                    app.name, grid)


@pytest.mark.parametrize("shape,grid", [
    ((1, 4), (2, 2)),          # single-node machine
    ((4, 1), (2, 2)),          # one processor per node
    ((8,), (2, 4)),            # flat machine
    ((2, 4), (1, 8)),          # degenerate grid, unit leading axis
    ((2, 4), (8, 1)),          # degenerate grid, unit trailing axis
    ((1, 1), (1, 1)),          # single processor
])
def test_engines_agree_on_topology_edge_cases(shape, grid):
    spec = MachineSpec(shape=shape,
                       level_names=("node", "gpu")[: len(shape)])
    assign = default_assignment(shape, grid)
    for bp in (1, 2):
        tb, te = _both_engines(HALO22, spec, grid, assign, backpressure=bp)
        assert tb == pytest.approx(te, abs=1e-9)


def test_engines_agree_on_bandwidth_ties():
    """Equal per-level bandwidths (no fast intra-node fabric) price
    identically through both engines."""
    spec = MachineSpec(shape=(2, 4), level_names=("node", "gpu"),
                       link_bws=(5e9, 5e9))
    assign = default_assignment((2, 4), (2, 4))
    tb, te = _both_engines(HALO22, spec, (2, 4), assign)
    assert tb == pytest.approx(te, abs=1e-9)


def test_engines_agree_on_single_step_and_deep_backpressure():
    spec = PAPER_CLUSTER
    assign = default_assignment(spec.shape, (2, 4))
    for bp, steps in [(1, 1), (2, 1), (4, 3), (2, 3)]:
        tb, te = _both_engines(HALO22, spec, (2, 4), assign,
                               backpressure=bp, steps=steps)
        assert tb == pytest.approx(te, abs=1e-9)


def test_one_proc_groups_emit_no_phases():
    assert ring_allgather([5], 1e6) == []
    assert tree_broadcast([3], 1e6) == []
    assert alltoall([7], 1e6) == []
    spec = MachineSpec(shape=(1, 1), level_names=("node", "gpu"))
    bs = batch_simulator(HALO22, spec, (1, 1), step_flops=1e12)
    # No fabric at all: step time is the pure compute leg.
    assert bs.step_time(np.zeros((1, 1), np.int64)) == pytest.approx(
        1e12 / spec.peak_flops)


def test_packed_schedule_is_memoized_and_dedups_slabs():
    pattern = CollectivePattern("gather_scatter", {"nodes_per_piece": 4})
    a = packed_schedule(pattern, (8,))
    assert packed_schedule(pattern, (8,)) is a           # cache hit
    # Two rings of 7 identical rounds each, and reduce-scatter reuses the
    # all-gather wire schedule: 14 phases collapse to ONE unique slab.
    assert a.n_phases == 14
    assert a.n_unique == 1
    # An equal-content pattern (different object) hits the same entry.
    twin = CollectivePattern("gather_scatter", {"nodes_per_piece": 4})
    assert packed_schedule(twin, (8,)) is a


def test_pattern_params_may_hold_arrays_and_dicts():
    """Memoization keys must accept the unhashable param values the
    pre-cache code tolerated (ndarray lengths, nested dicts)."""
    pattern = CollectivePattern(
        "halo", {"lengths": np.array([64, 64]), "meta": {"note": "x"}})
    assign = default_assignment((2, 4), (2, 4))
    phases = build_phases(pattern, (2, 4), assign)
    ref = build_phases(HALO22, (2, 4), assign)
    assert [p.total_bytes for p in phases] == [p.total_bytes for p in ref]


def test_build_phases_memoized_by_assignment_digest():
    assign = default_assignment((2, 4), (2, 4))
    a = build_phases(HALO22, (2, 4), assign)
    b = build_phases(HALO22, (2, 4), assign.copy())      # equal content
    assert all(x.src is y.src for x, y in zip(a, b))     # shared slabs
    other = build_phases(HALO22, (2, 4), assign.T.reshape(2, 4))
    assert any(not np.array_equal(x.src, y.src) for x, y in zip(a, other))


def test_canonical_assignment_collapses_relabelings():
    assign = default_assignment((2, 4), (2, 4))
    canon = canonical_assignment(assign, (2, 4))
    # Swap the two nodes and permute gpus inside one node: same class.
    relabeled = (1 - assign // 4) * 4 + (assign % 4 + 1) % 4
    assert not np.array_equal(assign, relabeled)
    np.testing.assert_array_equal(
        canonical_assignment(relabeled, (2, 4)), canon)
    # And the batch engine prices the two placements identically.
    bs = batch_simulator(HALO22, PAPER_CLUSTER, (2, 4), step_flops=1e12)
    times = bs.step_times(np.stack([assign, relabeled]))
    assert times[0] == times[1]
    # A structurally different placement leaves the class.
    rr = (assign % 2) * 4 + assign // 2
    assert not np.array_equal(canonical_assignment(rr, (2, 4)), canon)


def test_price_stacks_matches_per_stack_pricing():
    spec = PAPER_CLUSTER
    halo3 = CollectivePattern("halo", {"lengths": (32, 96), "fields": 3})
    b1 = batch_simulator(HALO22, spec, (2, 4), step_flops=1e12)
    b2 = batch_simulator(halo3, spec, (4, 2), step_flops=1e12,
                         backpressure=1)
    s1 = np.stack([default_assignment(spec.shape, (2, 4)),
                   np.arange(8).reshape(2, 4)])
    s2 = np.stack([np.arange(8).reshape(4, 2)])
    grouped = price_stacks([(b1, s1), (b2, s2)])
    np.testing.assert_array_equal(grouped[0], b1.step_times(s1))
    np.testing.assert_array_equal(grouped[1], b2.step_times(s2))


def test_cost_model_engines_agree_and_validate():
    model_b = _stencil_cost_model(default_assignment(PAPER_CLUSTER.shape,
                                                     (2, 4)))
    model_e = dataclasses_replace_engine(model_b, "event")
    assert model_b.cost((2, 4)) == pytest.approx(model_e.cost((2, 4)),
                                                 abs=1e-9)
    with pytest.raises(ValueError):
        dataclasses_replace_engine(model_b, "warp")


def dataclasses_replace_engine(model, engine):
    import dataclasses

    return dataclasses.replace(model, engine=engine)


def test_tuner_dedups_isomorphic_placements():
    """Variants whose placements only relabel processors within machine
    levels are priced once; the winner is unaffected (identical costs)."""
    rep = tune_app(time_tuned_app(apps.get("cannon")), 64)
    assert rep.best.placed_cost is not None
    assert rep.best.placed_cost <= min(
        s.placed_cost for s in rep.leaderboard if s.placed_cost is not None
    )
    keys = {
        (s.candidate.grid, s.candidate.options) for s in rep.leaderboard
    }
    assert keys                                          # beam survived


# ----------------------------------------------------- default placement
def test_default_assignment_is_bijective_and_blocked():
    for machine, grid in [((2, 4), (2, 4)), ((16, 4), (8, 8)),
                          ((16, 4), (1, 64)), ((2, 4), (8,)),
                          ((1, 8), (2, 4))]:
        a = default_assignment(machine, grid)
        n = int(np.prod(grid))
        assert sorted(a.reshape(-1).tolist()) == list(range(n))


def test_local_axes_keep_collective_groups_on_node():
    # Solomonik (4, 4, 4) on a (16, 4) machine: the c axis (axis 2) must
    # stay intra-node so 2.5D replication rides the fast fabric.
    a = default_assignment((16, 4), (4, 4, 4), local_axes=(2,))
    nodes = a // 4
    assert (nodes == nodes[:, :, :1]).all()
