"""Discrete-event simulator tests: topology, schedules, engine, cost model.

Covers the headline property the subsystem exists for — two mappings with
IDENTICAL communication volume get DIFFERENT simulated times when one
keeps neighbours on a node and the other scatters them round-robin — plus
the flat-topology equivalence with ``machine.modeled_step_time``, the
Backpressure depth agreement across DSL -> plan -> training loop ->
engine, and the registry-wide oracle guarantees of the time-domain tuner.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro import apps
from repro.core import dsl, machine as hw
from repro.core.commvolume import HaloCostModel
from repro.core.machine import PAPER_CLUSTER, MachineSpec
from repro.core.translate import to_spmd
from repro.search.tuner import tune_app
from repro.sim.collectives import (
    CollectivePattern,
    Phase,
    alltoall,
    build_phases,
    ring_allgather,
    tree_broadcast,
    tree_reduce,
)
from repro.sim.cost import (
    SimulatedTimeCostModel,
    default_assignment,
    simulate_app,
    time_tuned_app,
)
from repro.sim.engine import Task, simulate_steps, simulate_tasks
from repro.sim.topology import Topology

STENCIL_LENGTHS = (1024, 8192)


# ------------------------------------------------------------- MachineSpec
def test_link_bw_per_level_tuple():
    spec = MachineSpec(shape=(2, 4), level_names=("node", "gpu"),
                       link_bws=(6e9, 2e11))
    assert spec.link_bw(0) == 6e9
    assert spec.link_bw(1) == 2e11
    with pytest.raises(ValueError):
        spec.link_bw(2)
    with pytest.raises(ValueError):
        spec.link_bw(-1)


def test_link_bw_default_derivation():
    spec = MachineSpec(shape=(2, 4), level_names=("node", "gpu"))
    assert spec.link_bw(0) == spec.dci_bw
    assert spec.link_bw(1) == spec.ici_bw * spec.ici_links
    flat = MachineSpec(shape=(8,), level_names=("chip",))
    assert flat.link_bw(0) == flat.ici_bw * flat.ici_links


def test_machinespec_validation():
    with pytest.raises(ValueError):
        MachineSpec(shape=(2, 4), level_names=("node",))
    with pytest.raises(ValueError):
        MachineSpec(shape=(2, 4), level_names=("node", "gpu"),
                    link_bws=(6e9,))
    with pytest.raises(ValueError):
        MachineSpec(shape=(2, 4), level_names=("node", "gpu"),
                    link_bws=(6e9, -1.0))


# ---------------------------------------------------------------- topology
def test_crossing_levels():
    topo = Topology.from_spec(PAPER_CLUSTER)           # (2 nodes, 4 gpus)
    src = np.array([0, 0, 0])
    dst = np.array([1, 4, 0])
    # 0->1 same node (level 1); 0->4 crosses nodes (level 0); 0->0 local.
    assert topo.crossing_levels(src, dst).tolist() == [1, 0, 2]


def test_phase_time_contention_scales_with_port_load():
    topo = Topology.from_spec(PAPER_CLUSTER)
    one = topo.phase_time(np.array([0]), np.array([4]), np.array([1e6]))
    # Four gpus of node 0 each send to node 1: same NIC, 4x the bytes.
    four = topo.phase_time(np.arange(4), np.arange(4, 8), np.full(4, 1e6))
    assert four > 3.5 * one
    # Intra-node transfers on distinct ports don't contend.
    intra = topo.phase_time(np.array([0, 2]), np.array([1, 3]),
                            np.full(2, 1e6))
    solo = topo.phase_time(np.array([0]), np.array([1]), np.array([1e6]))
    assert intra == pytest.approx(solo)


def test_local_transfers_are_free():
    topo = Topology.from_spec(PAPER_CLUSTER)
    assert topo.phase_time(np.array([3]), np.array([3]), np.array([1e9])) == 0.0


# -------------------------------------------------------------- collectives
def test_ring_allgather_volume():
    phases = ring_allgather([0, 1, 2, 3], 4096.0)
    assert len(phases) == 3                     # p-1 rounds
    assert sum(p.total_bytes for p in phases) == pytest.approx(
        3 * 4096.0)                             # (p-1)/p * total per member


def test_tree_broadcast_reaches_everyone():
    group = [5, 2, 7, 1, 6]
    phases = tree_broadcast(group, 10.0)
    have = {5}
    for ph in phases:
        for s, d in zip(ph.src, ph.dst):
            assert int(s) in have
            have.add(int(d))
    assert have == set(group)


def test_tree_reduce_mirrors_broadcast():
    group = [0, 1, 2, 3]
    b = tree_broadcast(group, 8.0)
    r = tree_reduce(group, 8.0)
    assert sum(p.total_bytes for p in b) == sum(p.total_bytes for p in r)
    assert r[-1].dst.tolist() == [0]            # last hop lands on the root


def test_alltoall_pairwise():
    (ph,) = alltoall([0, 1, 2], 7.0)
    assert len(ph.src) == 6                     # p*(p-1) directed pairs
    assert ph.total_bytes == pytest.approx(42.0)


def test_halo_phases_track_assignment():
    pattern = CollectivePattern("halo", {"lengths": (16, 16), "fields": 2})
    grid = (2, 2)
    assign = np.arange(4).reshape(grid)
    phases = build_phases(pattern, grid, assign, elem_bytes=4)
    # 2 axes x 2 directions; every tile sends one face per phase.
    assert len(phases) == 4
    face = 2 * (16 / 2) * 4
    assert all(p.total_bytes == pytest.approx(4 * face) for p in phases)


def test_build_phases_validates():
    pattern = CollectivePattern("halo", {"lengths": (16, 16)})
    with pytest.raises(ValueError):
        build_phases(pattern, (2, 2), np.arange(8).reshape(2, 4))
    with pytest.raises(ValueError):
        build_phases(CollectivePattern("nope"), (2,), np.arange(2))
    with pytest.raises(ValueError):   # systolic shift needs a square grid
        build_phases(CollectivePattern("shift", {"m": 8, "n": 8, "k": 8}),
                     (2, 4), np.arange(8).reshape(2, 4))


# ------------------------------------------------------------------- engine
def test_engine_respects_dependencies_and_resources():
    tasks = [
        Task(key="a", duration=2.0, resource="r1"),
        Task(key="b", duration=1.0, resource="r1"),
        Task(key="c", duration=1.0, resource="r2", deps=("a",)),
    ]
    tl = simulate_tasks(tasks)
    seg = {s.key: s for s in tl.segments}
    assert seg["a"].start == 0.0 and seg["a"].end == 2.0
    assert seg["b"].start == 2.0                # serial resource
    assert seg["c"].start == 2.0                # dependency on a
    assert tl.makespan == 3.0


def test_engine_rejects_cycles_and_unknown_deps():
    with pytest.raises(ValueError):
        simulate_tasks([Task(key="a", duration=1.0, resource="r",
                             deps=("missing",))])
    with pytest.raises(ValueError):
        simulate_tasks([
            Task(key="a", duration=1.0, resource="r", deps=("b",)),
            Task(key="b", duration=1.0, resource="r", deps=("a",)),
        ])


def _comm_bound_setup():
    spec = MachineSpec(shape=(4,), level_names=("chip",), link_bws=(1e9,))
    topo = Topology.from_spec(spec, alphas=(0.0,))
    procs = np.arange(4)
    ph = Phase("ring", procs, np.roll(procs, -1), np.full(4, 1e6))
    return topo, ph


def test_backpressure_bounds_in_flight_depth():
    topo, ph = _comm_bound_setup()
    for bp in (1, 2, 4):
        tl = simulate_steps([ph], topo, compute_s=1e-7, steps=10,
                            backpressure=bp)
        assert tl.max_in_flight == bp
    with pytest.raises(ValueError):
        simulate_steps([ph], topo, compute_s=1e-7, steps=2, backpressure=0)


def test_backpressure_overlap_shortens_makespan():
    spec = MachineSpec(shape=(4,), level_names=("chip",), link_bws=(1e9,))
    topo = Topology.from_spec(spec, alphas=(0.0,))
    procs = np.arange(4)
    ph = Phase("ring", procs, np.roll(procs, -1), np.full(4, 1e6))
    compute = 1e-3                     # comparable to the 1 ms comm phase
    serial = simulate_steps([ph], topo, compute_s=compute, steps=6,
                            backpressure=1)
    pipelined = simulate_steps([ph], topo, compute_s=compute, steps=6,
                               backpressure=3)
    assert pipelined.makespan < serial.makespan * 0.75


def test_flat_topology_matches_modeled_step_time():
    """machine.modeled_step_time IS the simulator's flat special case: a
    1-level machine with uniform neighbour traffic reproduces the
    max(compute, comm) envelope; the closed form adds only its 10%
    overlap tax."""
    n = 16
    spec = MachineSpec(shape=(n,), level_names=("chip",))
    topo = Topology.from_spec(spec, alphas=(0.0,))
    flops, elems = 1e12, 3e8
    procs = np.arange(n)
    ph = Phase("ring", procs, np.roll(procs, -1),
               np.full(n, elems * 4 / n))
    tl = simulate_steps([ph], topo, compute_s=flops / (n * spec.peak_flops),
                        steps=6, backpressure=2)
    sim = tl.per_step_time()
    compute = flops / (n * spec.peak_flops)
    comm = elems * 4 / (n * spec.link_bw(0))
    envelope = max(compute, comm)
    assert sim == pytest.approx(envelope, rel=1e-9)
    modeled = hw.modeled_step_time(flops, elems, n)
    assert envelope <= modeled <= envelope + 0.1 * min(compute, comm) + 1e-15
    # and the spec-routed form agrees with the default constants
    assert hw.modeled_step_time(flops, elems, n, spec=spec) == \
        pytest.approx(modeled)


# ------------------------------------------------- the headline acceptance
def _stencil_cost_model(assignment):
    return SimulatedTimeCostModel(
        pattern=CollectivePattern(
            "halo", {"lengths": STENCIL_LENGTHS, "fields": 1}),
        spec=PAPER_CLUSTER,
        step_flops=5.0 * STENCIL_LENGTHS[0] * STENCIL_LENGTHS[1],
        base=HaloCostModel(STENCIL_LENGTHS),
        assignment_fn=lambda grid: assignment,
    )


def test_simulator_separates_mappings_volume_ties():
    """On PAPER_CLUSTER (2 nodes x 4 GPUs) the simulator ranks a
    decomposed stencil mapping strictly faster than naive round-robin
    while the flat volume model ties them — the effect the subsystem
    exists to expose."""
    grid = (2, 4)
    decomposed = default_assignment(PAPER_CLUSTER.shape, grid)
    lin = np.arange(8).reshape(grid)
    round_robin = (lin % 2) * 4 + lin // 2      # neighbours alternate nodes
    assert not np.array_equal(decomposed, round_robin)
    model_dec = _stencil_cost_model(decomposed)
    model_rr = _stencil_cost_model(round_robin)
    # The flat objectives are placement-blind: the two candidates' volume
    # scores tie (cost is a function of the grid alone — the assignment
    # never enters), and so do their flat modeled step times.
    v_dec, v_rr = model_dec.base.cost(grid), model_rr.base.cost(grid)
    assert v_dec == v_rr
    flops = 5.0 * STENCIL_LENGTHS[0] * STENCIL_LENGTHS[1]
    assert hw.modeled_step_time(flops, v_dec, 8) == \
        hw.modeled_step_time(flops, v_rr, 8)
    # The simulator sees the placements.
    t_dec = model_dec.cost(grid)
    t_rr = model_rr.cost(grid)
    assert t_dec < t_rr                          # strictly faster
    assert t_rr / t_dec > 1.5                    # and by a fabric-sized margin


def test_simulated_cost_model_is_a_cost_model():
    model = _stencil_cost_model(default_assignment(PAPER_CLUSTER.shape, (2, 4)))
    assert callable(model)                       # CostModel protocol
    with pytest.raises(ValueError):              # wrong arity -> base rejects
        model.cost((2, 2, 2))
    with pytest.raises(ValueError):              # doesn't cover the machine
        model.cost((2, 2))


# ------------------------------------------------------ tuner integration
def test_time_tuner_plugs_in_unchanged_and_matches_oracles():
    """SimulatedTimeCostModel drops into tune_app via the CostModel
    protocol; at the paper's Table 2 cluster scale the time-optimal
    winner's volume matches the tuning oracle for EVERY registry app."""
    for app in apps.iter_apps():
        rep = tune_app(time_tuned_app(app))
        assert rep.verified, app.name
        vol_model = app.search_space.cost_model(
            rep.procs, rep.best.candidate.opts)
        winner_volume = vol_model.cost(rep.best.candidate.grid)
        o_def, o_tuned = app.tuning(rep.procs)
        assert winner_volume <= o_tuned * (1 + 1e-9), (
            f"{app.name}: time winner volume {winner_volume} regresses "
            f"tuned oracle {o_tuned}"
        )


def test_time_tuner_never_regresses_default_at_scale():
    for app in apps.iter_apps():
        rep = tune_app(time_tuned_app(app), 64)
        vol_model = app.search_space.cost_model(
            rep.procs, rep.best.candidate.opts)
        winner_volume = vol_model.cost(rep.best.candidate.grid)
        o_def, _ = app.tuning(rep.procs)
        assert winner_volume <= o_def * (1 + 1e-9), app.name


# ------------------------------------------------------------ simulate_app
def test_simulate_app_registry_smoke():
    for app in apps.iter_apps():
        rep = simulate_app(app)
        assert rep.step_time_s > 0
        assert rep.n_phases > 0
        assert rep.comm_s > 0
        assert 0.0 <= rep.inter_node_bytes_frac <= 1.0
        assert rep.max_in_flight <= rep.backpressure
        assert rep.timeline.steps == 3


def test_simulate_app_requires_collective():
    import dataclasses

    app = dataclasses.replace(apps.get("stencil"), collective=None)
    with pytest.raises(ValueError):
        simulate_app(app)


# --------------------------------------------- Backpressure end to end
BACKPRESSURE_SOURCE = """\
m = Machine(GPU)
m1 = m.merge(0, 1)

def bptask_map(Tuple ipoint, Tuple ispace):
    idx = ipoint * m1.size / ispace
    return m1[*idx]

IndexTaskMap bptask bptask_map
Region bptask arg0 GPU FBMEM
Backpressure bptask 3
"""


class _FakePipeline:
    def batch(self, step):
        return step


def test_backpressure_depth_agrees_end_to_end():
    """DSL parse -> translate plan -> training-loop in-flight bound ->
    simulator in-flight bound all agree on the same depth."""
    from repro.training import TrainLoop

    depth = 3
    program = dsl.parse(BACKPRESSURE_SOURCE)
    assert program.backpressure["bptask"] == depth

    plan = to_spmd(program, "bptask", (8,), ("x",), devices=[])
    assert plan.backpressure == depth

    # Training loop: max dispatched-but-not-retired steps == depth.
    dispatched = 0
    peak = {"v": 0}
    retired = []

    def step_fn(state, batch):
        nonlocal dispatched
        dispatched += 1
        return state, {"loss": 0.0}

    def on_step(s, m):
        retired.append(s)
        peak["v"] = max(peak["v"], dispatched - len(retired))

    loop = TrainLoop(step_fn=step_fn, pipeline=_FakePipeline(),
                     backpressure=plan.backpressure)
    loop.run(state=None, start_step=0, n_steps=12, log_every=0,
             on_step=on_step)
    assert peak["v"] == depth
    assert retired == list(range(12))

    # Simulator: a comm-bound step pipeline fills exactly `depth` steps.
    topo, ph = _comm_bound_setup()
    tl = simulate_steps([ph], topo, compute_s=1e-7, steps=12,
                        backpressure=plan.backpressure)
    assert tl.max_in_flight == depth


def test_simulate_app_honors_plan_backpressure():
    rep = simulate_app(apps.get("cannon"))      # Backpressure cannon 1
    assert rep.backpressure == 1
    assert rep.max_in_flight == 1
    rep2 = simulate_app(apps.get("summa"))      # Backpressure summa 2
    assert rep2.backpressure == 2


# ----------------------------------------------------- default placement
def test_default_assignment_is_bijective_and_blocked():
    for machine, grid in [((2, 4), (2, 4)), ((16, 4), (8, 8)),
                          ((16, 4), (1, 64)), ((2, 4), (8,)),
                          ((1, 8), (2, 4))]:
        a = default_assignment(machine, grid)
        n = int(np.prod(grid))
        assert sorted(a.reshape(-1).tolist()) == list(range(n))


def test_local_axes_keep_collective_groups_on_node():
    # Solomonik (4, 4, 4) on a (16, 4) machine: the c axis (axis 2) must
    # stay intra-node so 2.5D replication rides the fast fabric.
    a = default_assignment((16, 4), (4, 4, 4), local_axes=(2,))
    nodes = a // 4
    assert (nodes == nodes[:, :, :1]).all()
