"""Tests for the textual Mapple front-end and mapper library (Figs. 1, 7, 12)."""
import numpy as np
import pytest

from repro.core import (
    GPU,
    Machine,
    block_cyclic_mapper,
    block_mapper,
    cyclic_mapper,
    hierarchical_block_mapper,
    linear_cyclic_mapper,
)
from repro.core import dsl


FIG1A = """
m = Machine(GPU)

def block2d(Tuple point, Tuple space):
    idx = point * m.size / space
    return m[*idx]

IndexTaskMap loop0 block2d
Region task_init arg0 GPU FBMEM
Layout task_finish arg1 CPU C order
GarbageCollect systolic arg2
Backpressure systolic 1
"""


def test_fig1a_parses():
    prog = dsl.parse(FIG1A)
    assert set(prog.mappers) == {"block2d"}
    assert prog.index_task_maps == {"loop0": "block2d"}
    assert prog.regions[("task_init", "arg0")] == ("gpu", "device")
    assert prog.layouts[("task_finish", "arg1")].order == "C"
    assert ("systolic", "arg2") in prog.garbage_collect
    assert prog.backpressure["systolic"] == 1
    assert prog.loc() == 9  # the paper's LoC counting convention


def test_fig3_block2d_value():
    prog = dsl.parse(
        "m = Machine(GPU, shape=(2, 2))\n"
        "def block2D(Tuple ipoint, Tuple ispace):\n"
        "    idx = ipoint * m.size / ispace\n"
        "    return m[*idx]\n",
        machine_factory=lambda *a, **k: Machine(GPU, shape=(2, 2)),
    )
    # Fig. 3: point (2,3) in (6,6) -> node 0, GPU 1.
    p = prog.mappers["block2D"]((2, 3), (6, 6))
    assert p.coords == (0, 1)


def test_fig4_linear_cyclic():
    src = """
m = Machine(GPU, shape=(2, 2))
m1 = m.merge(0, 1)
def linearCyclic(Tuple ipoint, Tuple ispace):
    linearized = ipoint[0] * ispace[1] + ipoint[1]
    idx = linearized % m1.size[0]
    return m1[idx]
"""
    prog = dsl.parse(src)
    mp = prog.mappers["linearCyclic"]
    # 4x4 iteration space round-robins over 4 processors.
    flats = [mp((i, j), (4, 4)).flat for i in range(4) for j in range(4)]
    assert flats[:4] == [mp((0, j), (4, 4)).flat for j in range(4)]
    assert sorted(set(flats)) == [0, 1, 2, 3]


def test_ternary_desugar():
    src = """
m = Machine(GPU, shape=(4, 1))
def conditional(Tuple ipoint, Tuple ispace):
    grid_size = ispace[0] > ispace[2] ? ispace[0] : ispace[2]
    linearized = ipoint[0] + ipoint[1] * grid_size + ipoint[2] * grid_size * grid_size
    return m[linearized % m.size[0], 0]
"""
    prog = dsl.parse(src)
    p = prog.mappers["conditional"]((1, 0, 0), (4, 2, 2))
    assert p.coords == (1, 0)


def test_dsl_is_sandboxed():
    with pytest.raises((NameError, SyntaxError, ImportError, Exception)):
        prog = dsl.parse(
            "def evil(Tuple a, Tuple b):\n"
            "    return __import__('os').system('true')\n"
        )
        prog.mappers["evil"]((0,), (1,))


def test_unknown_directive_rejected():
    with pytest.raises(SyntaxError):
        dsl.parse("Frobnicate task arg\n")


def test_indextaskmap_requires_known_mapper():
    with pytest.raises(NameError):
        dsl.parse("IndexTaskMap loop0 nonexistent\n")


# --------------------------------------------------------- Fig. 7 distributions
def grid_of(mapper, ispace):
    return mapper.assignment_grid(ispace)


def test_fig7_block_variants():
    m = Machine(GPU, shape=(2, 2))
    g = grid_of(block_mapper(m), (4, 4))
    # block2D: quadrants.
    assert g[0, 0] == g[1, 1] and g[0, 0] != g[0, 2]
    m1 = m.merge(0, 1).split(0, 1)   # (1, 4) -> block1D_x slabs along y
    g1 = grid_of(block_mapper(m1, "block1D_x"), (4, 4))
    assert (g1[:, 0] == g1[:, 0][0]).all() is np.True_ or len(set(g1[:, 0])) == 1
    m2 = m.merge(0, 1).split(0, 4)   # (4, 1) -> block1D_y slabs along x
    g2 = grid_of(block_mapper(m2, "block1D_y"), (4, 4))
    assert len(set(g2[0, :])) == 1
    assert len(set(g2[:, 0])) == 4


def test_fig7_cyclic_variants():
    m = Machine(GPU, shape=(2, 2))
    g = grid_of(cyclic_mapper(m), (4, 4))
    assert g[0, 0] == g[2, 2] and g[0, 0] == g[0, 0]
    assert g[0, 0] != g[1, 1] or True
    # cyclic repeats with period (2, 2)
    assert (g[0:2, 0:2] == g[2:4, 2:4]).all()
    gbc = grid_of(block_cyclic_mapper(m), (8, 8))
    # block-cyclic: blocks of 2x2 cycle with period 4.
    assert (gbc[0:2, 0:2] == gbc[0, 0]).all()
    assert (gbc[0:4, 0:4] == gbc[4:8, 4:8]).all()


def test_linear_cyclic_mapper_subdiagonal():
    m = Machine(GPU, shape=(2, 2))
    lc = linear_cyclic_mapper(m)
    g = grid_of(lc, (4, 4))
    assert sorted(np.unique(g)) == [0, 1, 2, 3]


def test_hierarchical_block_mapper_bijective():
    """Fig. 12 mapper covers every processor exactly once per tile grid."""
    m = Machine(GPU, shape=(2, 4))
    hb = hierarchical_block_mapper(m, (4, 2))
    assert hb.is_bijective_on((4, 2), 8)
    hb3 = hierarchical_block_mapper(m, (2, 2, 2))
    assert hb3.is_bijective_on((2, 2, 2), 8)
