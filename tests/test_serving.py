"""Continuous-batching scheduler tests."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build
from repro.serving import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def served_model():
    import jax

    cfg = get_config("smollm-135m").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    return model, params, cfg


def test_continuous_batching_completes_all(served_model):
    model, params, cfg = served_model
    rng = np.random.default_rng(0)
    batcher = ContinuousBatcher(model, params, n_slots=3, max_len=64)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size,
                                           size=rng.integers(3, 9)),
                max_new_tokens=int(rng.integers(2, 6)))
        for i in range(8)
    ]
    for r in reqs:
        batcher.submit(r)
    stats = batcher.run_until_drained()
    assert stats.completed == 8
    assert all(r.done for r in reqs)
    assert all(1 <= len(r.generated) <= r.max_new_tokens for r in reqs)
    # slots were reused: more requests than slots
    assert stats.steps > 0
    s = stats.summary()
    assert s["p95_latency_s"] >= s["p50_latency_s"]


def test_slot_reuse_isolation(served_model):
    """A slot reused by a new request must not leak the old cache: the
    same prompt gives the same completion whether run first or after
    another request occupied the slot."""
    model, params, cfg = served_model
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=6)

    solo = ContinuousBatcher(model, params, n_slots=1, max_len=32)
    r1 = Request(uid=0, prompt=prompt, max_new_tokens=4)
    solo.submit(r1)
    solo.run_until_drained()

    shared = ContinuousBatcher(model, params, n_slots=1, max_len=32)
    filler = Request(uid=1, prompt=rng.integers(0, cfg.vocab_size, size=10),
                     max_new_tokens=4)
    r2 = Request(uid=2, prompt=prompt, max_new_tokens=4)
    shared.submit(filler)
    shared.submit(r2)
    shared.run_until_drained()

    assert r1.generated == r2.generated


# ----------------------------------------------------- shared percentile math
def test_percentile_empty_returns_zero():
    from repro.serving.stats import percentile

    assert percentile([], 50) == 0.0
    assert percentile([], 99) == 0.0


def test_percentile_single_sample_is_every_quantile():
    from repro.serving.stats import percentile

    for q in (0, 1, 50, 95, 99, 100):
        assert percentile([7.0], q) == 7.0


def test_percentile_two_samples():
    from repro.serving.stats import percentile

    data = [2.0, 1.0]                  # unsorted on purpose
    assert percentile(data, 50) == 1.0     # ceil(0.5*2)=1 -> lower sample
    assert percentile(data, 95) == 2.0     # ceil(0.95*2)=2 -> upper sample
    assert percentile(data, 99) == 2.0
    assert percentile(data, 0) == 1.0      # rank clamps to 1


def test_percentile_nearest_rank_no_off_by_one():
    from repro.serving.stats import percentile

    data = list(range(1, 101))             # 1..100
    assert percentile(data, 50) == 50
    assert percentile(data, 95) == 95      # NOT data[95] == 96
    assert percentile(data, 99) == 99
    assert percentile(data, 100) == 100


def test_percentile_rejects_out_of_range_q():
    import pytest as _pytest

    from repro.serving.stats import percentile

    with _pytest.raises(ValueError):
        percentile([1.0], 101)
    with _pytest.raises(ValueError):
        percentile([1.0], -1)


def test_serve_stats_summary_uses_shared_percentiles():
    from repro.serving.scheduler import ServeStats
    from repro.serving.stats import percentile

    stats = ServeStats(completed=3, steps=5, tokens_out=9,
                       latencies=[0.3, 0.1, 0.2])
    s = stats.summary()
    assert s["p50_latency_s"] == percentile(stats.latencies, 50) == 0.2
    assert s["p95_latency_s"] == percentile(stats.latencies, 95) == 0.3
    assert s["p99_latency_s"] == 0.3       # p99 present and correct
