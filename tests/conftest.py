"""Shared test configuration.

Prefers the real ``hypothesis`` engine (installed in CI via pyproject);
in hermetic environments without it, installs the deterministic fallback
from ``repro.testing.hypothesis_fallback`` so the property tests still run.
"""
import os
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro.testing import hypothesis_fallback

    hypothesis_fallback.install()

import pytest  # noqa: E402


@pytest.fixture
def clear_schedule_caches():
    """Cold schedule caches before and after the test — for tests that
    assert on cache counters or need cold-build paths (the collectives
    memos are module-level and otherwise leak across tests)."""
    from repro.sim.collectives import clear_caches

    clear_caches()
    yield
    clear_caches()
