"""Unit + property tests for the processor-space algebra (paper Fig. 6)."""
import itertools
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Machine, GPU
from repro.core.pspace import ProcSpace
from repro.core.tuples import Tup


def all_indices(shape):
    return itertools.product(*(range(s) for s in shape))


# ------------------------------------------------------------------- shapes
def test_split_shape():
    m = Machine(GPU, shape=(8, 4))
    assert m.split(0, 2).shape == (2, 4, 4)
    assert m.split(1, 4).shape == (8, 4, 1)


def test_split_invalid():
    m = Machine(GPU, shape=(8, 4))
    with pytest.raises(ValueError):
        m.split(0, 3)
    with pytest.raises(IndexError):
        m.split(2, 2)


def test_merge_shape():
    m = Machine(GPU, shape=(2, 3, 5))
    assert m.merge(0, 1).shape == (6, 5)
    assert m.merge(0, 2).shape == (10, 3)
    assert m.merge(1, 2).shape == (2, 15)


def test_swap_slice_shape():
    m = Machine(GPU, shape=(2, 3, 5))
    assert m.swap(0, 2).shape == (5, 3, 2)
    assert m.slice(2, 1, 4).shape == (2, 3, 3)


# ---------------------------------------------------------------- semantics
def test_split_semantics_paper():
    """m'[a_i, a_{i+1}] = m[a_i + a_{i+1} * d]."""
    m = Machine(GPU, shape=(6,))
    ms = m.split(0, 2)
    for a0 in range(2):
        for a1 in range(3):
            assert ms.to_root((a0, a1)) == (a0 + a1 * 2,)


def test_merge_semantics_paper():
    """m'[a_p] = m[a_p mod s_p, floor(a_p / s_p)]."""
    m = Machine(GPU, shape=(2, 3))
    mm = m.merge(0, 1)
    for a in range(6):
        assert mm.to_root((a,)) == (a % 2, a // 2)


def test_merge_nonadjacent():
    m = Machine(GPU, shape=(2, 5, 3))
    mm = m.merge(0, 2)  # fuse dims 0 and 2 -> (6, 5)
    assert mm.shape == (6, 5)
    seen = set()
    for idx in all_indices(mm.shape):
        root = mm.to_root(idx)
        assert root == (idx[0] % 2, idx[1], idx[0] // 2)
        seen.add(root)
    assert len(seen) == 30


def test_slice_semantics():
    m = Machine(GPU, shape=(8,))
    ms = m.slice(0, 2, 6)
    assert [ms.to_root((i,)) for i in range(4)] == [(2,), (3,), (4,), (5,)]


def test_paper_sec33_split_merge_identity():
    """Sec 3.3 worked example: split(0,d) then merge(0,1) is the identity."""
    m = Machine(GPU, shape=(12, 7))
    for d in (2, 3, 4, 6):
        m2 = m.split(0, d).merge(0, 1)
        assert m2.shape == m.shape
        for idx in all_indices(m.shape):
            assert m2.to_root(idx) == idx


def test_decompose_equals_split_sequence():
    """Sec 4.2: decompose(i, T) == the split sequence with optimal factors."""
    m = Machine(GPU, shape=(16, 4))
    md = m.decompose(0, (4, 8, 4))
    factors = md.shape[0:3]
    ms = m
    for n, f in enumerate(factors[:-1]):
        ms = ms.split(0 + n, f)
    assert ms.shape == md.shape
    for idx in all_indices(md.shape):
        assert md.to_root(idx) == ms.to_root(idx)


def test_indexing_modes():
    m = Machine(GPU, shape=(2, 4))
    p = m[(1, 2)]
    assert p.coords == (1, 2) and p.flat == 6
    assert m[1] == 4                      # int on nD -> extent
    assert tuple(m[:1]) == (2,)           # slice -> Tup of extents
    m1 = m.merge(0, 1)
    assert m1[5].coords == (5 % 2, 5 // 2)  # int on 1D -> processor via merge map


# ------------------------------------------------------------ property tests
shapes = st.lists(st.integers(1, 6), min_size=1, max_size=4).map(tuple)


@settings(max_examples=100, deadline=None)
@given(shape=shapes, data=st.data())
def test_every_transform_is_root_bijection(shape, data):
    """Any chain of primitives keeps the index map a bijection onto the root."""
    m = Machine(GPU, shape=shape)
    space = m
    n_ops = data.draw(st.integers(0, 4))
    for _ in range(n_ops):
        op = data.draw(st.sampled_from(["split", "merge", "swap"]))
        nd = space.ndim
        if op == "split":
            i = data.draw(st.integers(0, nd - 1))
            divs = [d for d in range(1, space.shape[i] + 1) if space.shape[i] % d == 0]
            d = data.draw(st.sampled_from(divs))
            space = space.split(i, d)
        elif op == "merge" and nd >= 2:
            p = data.draw(st.integers(0, nd - 2))
            q = data.draw(st.integers(p + 1, nd - 1))
            space = space.merge(p, q)
        elif op == "swap" and nd >= 2:
            p = data.draw(st.integers(0, nd - 1))
            q = data.draw(st.integers(0, nd - 1))
            space = space.swap(p, q)
    assert space.nprocs == m.nprocs
    roots = {space.to_root(idx) for idx in all_indices(space.shape)}
    assert len(roots) == m.nprocs


@settings(max_examples=60, deadline=None)
@given(
    s0=st.integers(1, 36),
    d=st.integers(1, 36),
)
def test_split_merge_inverse_property(s0, d):
    if s0 % d:
        return
    m = Machine(GPU, shape=(s0, 3))
    m2 = m.split(0, d).merge(0, 1)
    for idx in all_indices(m.shape):
        assert m2.to_root(idx) == idx


# ----------------------------------------------------------------- tuples
def test_tup_arithmetic():
    a = Tup((2, 3))
    assert tuple(a * (2, 2)) == (4, 6)
    assert tuple(a * 2) == (4, 6)
    assert tuple(Tup((7, 9)) / (2, 3)) == (3, 3)
    assert tuple(Tup((7, 9)) % (2, 4)) == (1, 1)
    assert Tup((1, 2)).linearize((4, 4)) == 6
    assert Tup((3, 4)).prod() == 12


def test_tup_rank_mismatch():
    with pytest.raises(ValueError):
        Tup((1, 2)) * (1, 2, 3)
