"""Communication-volume model tests (Sec. 4.2 / 7.2 + matmul costs)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.commvolume import (
    GatherScatterCostModel,
    HaloCostModel,
    LMCommModel,
    LMStepCostModel,
    MatmulCostModel,
    MatmulProblem,
    TransposeCostModel,
    aniso_halo_volume,
    cannon_volume,
    cosma_grid,
    halo_surface_volume,
    hyperrect_surface,
    johnson_volume,
    solomonik_volume,
    summa_volume,
    transpose_volume,
)


def test_hyperrect_surface_cube():
    # unit cube: SA = 6
    assert hyperrect_surface((1.0, 1.0, 1.0)) == pytest.approx(6.0)
    # 2x3x4 cuboid: 2*(6+8+12) = 52
    assert hyperrect_surface((2.0, 3.0, 4.0)) == pytest.approx(52.0)


def test_halo_surface_3d_fig9():
    """Fig. 9: (4,8,4) over (2,4,2): interior surface area."""
    s = halo_surface_volume((4, 8, 4), (2, 4, 2))
    # cuts: 1 yz-plane (8*4) + 3 xz-planes (4*4) + 1 xy-plane (4*8)
    assert s == pytest.approx(1 * 32 + 3 * 16 + 1 * 32)


@settings(max_examples=40, deadline=None)
@given(
    l0=st.integers(4, 64), l1=st.integers(4, 64),
    d0=st.sampled_from([1, 2, 4]), d1=st.sampled_from([1, 2, 4]),
)
def test_halo_surface_matches_cut_counting(l0, l1, d0, d1):
    if l0 % d0 or l1 % d1:
        return
    s = halo_surface_volume((l0, l1), (d0, d1))
    expected = (d0 - 1) * l1 + (d1 - 1) * l0
    assert s == pytest.approx(expected)


def test_aniso_reduces_to_directional_form():
    v = aniso_halo_volume((16, 32), (2, 4), (1.0, 1.0))
    assert v == pytest.approx(2 * 32 + 4 * 16)
    # heavier halo in dim 0 scales only that term
    v2 = aniso_halo_volume((16, 32), (2, 4), (3.0, 1.0))
    assert v2 == pytest.approx(3 * 2 * 32 + 4 * 16)


def test_transpose_volume_limits():
    assert transpose_volume((8, 8), (1, 4), (0,)) == 0.0     # no split: local
    v = transpose_volume((8, 8), (4, 1), (0,))
    assert v == pytest.approx((1 - 0.25) * 64)


def test_matmul_volume_scaling():
    p = MatmulProblem(4096, 4096, 4096)
    # doubling the grid dimension increases total shift volume
    assert cannon_volume(p, (8, 8)) > cannon_volume(p, (4, 4))
    assert summa_volume(p, (8, 8)) > 0
    # 3D beats 2D asymptotically (per-processor volume)
    v2d = cannon_volume(p, (8, 8)) / 64
    v3d = johnson_volume(p, (4, 4, 4)) / 64
    assert v3d < v2d
    # 2.5D with replication c>1 reduces shift volume vs c=1
    s1 = solomonik_volume(p, (8, 8, 1))
    s4 = solomonik_volume(p, (4, 4, 4))
    assert s4 < s1 * 2  # replication trades broadcast for fewer shifts


def test_cosma_grid_prefers_large_dims():
    p = MatmulProblem(16384, 128, 16384)
    g = cosma_grid(p, 64)
    assert math.prod(g) == 64
    # m and k are large; n tiny -> few cuts along n
    assert g[1] <= 2


def test_solomonik_rejects_non_square_grids():
    """(q1, q2, c) with q1 != q2 used to be silently collapsed onto q1."""
    p = MatmulProblem(4096, 4096, 4096)
    with pytest.raises(ValueError):
        solomonik_volume(p, (8, 4, 2))
    with pytest.raises(ValueError):
        solomonik_volume(p, (4, 4, 0))
    # Square grids unchanged.
    assert solomonik_volume(p, (4, 4, 4)) > 0


def test_cannon_rejects_non_square_grids():
    with pytest.raises(ValueError):
        cannon_volume(MatmulProblem(64, 64, 64), (4, 2))


# ------------------------------------------------------- CostModel protocol
def test_cost_models_wrap_the_closed_forms():
    p = MatmulProblem(4096, 4096, 4096)
    assert MatmulCostModel(p, "cannon").cost((8, 8)) == cannon_volume(p, (8, 8))
    assert MatmulCostModel(p, "summa")((4, 16)) == summa_volume(p, (4, 16))
    assert MatmulCostModel(p, "cosma").cost((4, 4, 4)) == \
        johnson_volume(p, (4, 4, 4))
    halo = HaloCostModel((1024, 8192), fields=3)
    assert halo.cost((2, 32)) == 3 * halo_surface_volume((1024, 8192), (2, 32))
    aniso = HaloCostModel((64, 64), halo=(2.0, 1.0))
    assert aniso.cost((4, 4)) == aniso_halo_volume((64, 64), (4, 4), (2.0, 1.0))
    t = TransposeCostModel((256, 256), (0,))
    assert t.cost((4, 16)) == pytest.approx(
        aniso_halo_volume((256, 256), (4, 16), (1.0, 1.0))
        + transpose_volume((256, 256), (4, 16), (0,))
    )
    gs = GatherScatterCostModel(64, discount=0.75)
    assert gs.cost((8,)) == 0.75 * (2.0 * 7 * 64 * 8)
    lm = LMCommModel(param_bytes=4e9, act_bytes_per_layer=1e8, n_layers=32)
    cm = LMStepCostModel(lm)
    assert cm.cost((8, 4)) == lm.step_volume(8, 4)
    assert cm.cost((8, 4, 2)) == lm.step_volume(8, 4, 2)


def test_cost_models_raise_on_invalid_candidates():
    p = MatmulProblem(64, 64, 64)
    with pytest.raises(ValueError):
        MatmulCostModel(p, "cannon").cost((4, 2))        # non-square
    with pytest.raises(ValueError):
        MatmulCostModel(p, "solomonik").cost((8, 4, 2))  # non-square
    with pytest.raises(ValueError):
        MatmulCostModel(p, "summa").cost((2, 2, 2))      # wrong arity
    with pytest.raises(ValueError):
        MatmulCostModel(p, "nope")
    with pytest.raises(ValueError):
        HaloCostModel((64, 64)).cost((2, 2, 2))
    with pytest.raises(ValueError):
        LMStepCostModel(LMCommModel(1e9, 1e8, 2)).cost((2, 2, 2, 2))


def test_cost_model_is_a_decompose_objective():
    """The same CostModel object drops into the Sec. 4.3 solver."""
    from repro.core.decompose import optimal_factorization

    model = HaloCostModel((1024, 8192))
    best = optimal_factorization(64, (1024, 8192), objective=model)
    assert model(best) <= model((8, 8))
    assert best in {(2, 32), (4, 16)}  # the exact-volume tie at 64 procs


def test_lm_comm_model_monotonicity():
    m = LMCommModel(param_bytes=4e9, act_bytes_per_layer=1e8, n_layers=32)
    # pure DP all-reduce grows with dp then saturates at 2x params
    assert m.step_volume(2, 1) < m.step_volume(16, 1) < 2 * 4e9
    # TP adds per-layer activation traffic
    assert m.step_volume(16, 1) < m.step_volume(16, 16) + 1
    moe = LMCommModel(param_bytes=4e9, act_bytes_per_layer=1e8, n_layers=32,
                      moe_tokens_bytes=1e9, n_moe_layers=24)
    assert moe.step_volume(4, 4, ep=4) > moe.step_volume(4, 4, ep=1)
