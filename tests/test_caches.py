"""Schedule-cache lifecycle and fold-stats scoping.

Covers the public cache API of ``repro.sim.collectives`` (``clear_caches``
/ ``cache_stats`` / FIFO eviction churn with bit-identical rebuilds) and
the per-run ``fold_stats()`` scopes of ``repro.sim.batch`` (nested and
concurrent runs must not corrupt each other's counters).
"""
import threading

import numpy as np
import pytest

from repro.sim import batch, collectives
from repro.sim.collectives import (
    CollectivePattern,
    build_phases,
    cache_stats,
    clear_caches,
    packed_schedule,
    schedule_cache_clear,
)


def _ring(p: int) -> CollectivePattern:
    """A 1-D halo exchange (the ring-neighbor schedule) sized to ``p``."""
    return CollectivePattern("halo", {"lengths": (16 * p,)})


def _snapshot(packed):
    return {f: np.array(getattr(packed, f))
            for f in ("phase_map", "starts", "phase_id", "src", "dst",
                      "nbytes", "fold_rep", "fold_shift")}


# ------------------------------------------------------------- cache stats
def test_cache_stats_counts_hits_and_misses(clear_schedule_caches):
    s = cache_stats()
    assert s["packed_hits"] == s["packed_misses"] == 0
    packed_schedule(_ring(8), (8,))
    packed_schedule(_ring(8), (8,))
    s = cache_stats()
    assert s["packed_misses"] == 1
    assert s["packed_hits"] == 1
    assert s["packed_size"] == 1


def test_clear_caches_empties_and_zeroes(clear_schedule_caches):
    packed_schedule(_ring(8), (8,))
    build_phases(_ring(8), (8,), np.arange(8))
    assert cache_stats()["packed_size"] == 1
    clear_caches()
    s = cache_stats()
    assert s["packed_size"] == s["phases_size"] == 0
    assert s["packed_hits"] == s["packed_misses"] == 0
    assert s["phases_hits"] == s["phases_misses"] == 0


def test_schedule_cache_clear_is_alias(clear_schedule_caches):
    packed_schedule(_ring(8), (8,))
    schedule_cache_clear()
    assert cache_stats()["packed_size"] == 0


# --------------------------------------------------------- eviction churn
def test_packed_cache_eviction_rebuilds_bit_identical(
        clear_schedule_caches, monkeypatch):
    """Overflowing the FIFO evicts the oldest entries (counted), and a
    rebuilt schedule is bit-identical to the evicted one."""
    monkeypatch.setattr(collectives, "_PACKED_CACHE_MAX", 2)
    first = packed_schedule(_ring(4), (4,))
    want = _snapshot(first)
    for p in (8, 16):           # churn the 2-entry cache past (4,)
        packed_schedule(_ring(p), (p,))
    s = cache_stats()
    assert s["packed_evictions"] >= 1
    assert s["packed_size"] <= 2
    rebuilt = packed_schedule(_ring(4), (4,))
    assert rebuilt is not first
    got = _snapshot(rebuilt)
    for f, arr in want.items():
        np.testing.assert_array_equal(arr, got[f], err_msg=f)


def test_phases_cache_eviction_rebuilds_bit_identical(
        clear_schedule_caches, monkeypatch):
    monkeypatch.setattr(collectives, "_PHASES_CACHE_MAX", 2)
    rng = np.random.default_rng(0)
    assigns = [rng.permutation(8) for _ in range(3)]
    want = [(ph.src.copy(), ph.dst.copy(), ph.nbytes.copy())
            for ph in build_phases(_ring(8), (8,), assigns[0])]
    for a in assigns[1:]:       # churn past the first assignment's entry
        build_phases(_ring(8), (8,), a)
    assert cache_stats()["phases_evictions"] >= 1
    got = build_phases(_ring(8), (8,), assigns[0])
    assert len(got) == len(want)
    for ph, (src, dst, nbytes) in zip(got, want):
        np.testing.assert_array_equal(ph.src, src)
        np.testing.assert_array_equal(ph.dst, dst)
        np.testing.assert_array_equal(ph.nbytes, nbytes)


def test_eviction_keeps_newest_entries(clear_schedule_caches, monkeypatch):
    monkeypatch.setattr(collectives, "_PACKED_CACHE_MAX", 2)
    for p in (4, 8, 16):
        packed_schedule(_ring(p), (p,))
    before = cache_stats()
    packed_schedule(_ring(16), (16,))      # newest: must still be cached
    after = cache_stats()
    assert after["packed_hits"] == before["packed_hits"] + 1


# -------------------------------------------------------------- fold stats
def _price_something():
    """One real fold-counted pricing pass (translation-symmetric stack)."""
    from repro.sim.batch import batch_simulator
    from repro.sim.cost import spec_for

    eng = batch_simulator(_ring(16), spec_for((4, 4)), (16,),
                          step_flops=1e9)
    eng.step_times(np.stack([np.arange(16), np.roll(np.arange(16), 1)]))


def test_fold_stats_scope_counts_one_run():
    with batch.fold_stats() as fs:
        _price_something()
    assert fs["pairs_priced"] > 0
    with batch.fold_stats() as fs2:
        pass
    assert fs2["pairs_priced"] == 0        # fresh scope, no leakage


def test_fold_stats_nested_scopes_both_count():
    with batch.fold_stats() as outer:
        _price_something()
        inner_before = outer["pairs_priced"]
        with batch.fold_stats() as inner:
            _price_something()
        assert inner["pairs_priced"] > 0
        assert outer["pairs_priced"] == inner_before + inner["pairs_priced"]


def test_fold_stats_global_totals_still_accumulate():
    batch.fold_stats_reset()
    with batch.fold_stats():
        _price_something()
    assert batch.FOLD_STATS["pairs_priced"] > 0
    snap = batch.fold_stats_snapshot()
    assert snap == batch.FOLD_STATS and snap is not batch.FOLD_STATS


def test_fold_stats_threads_are_isolated():
    """A scope opened on one thread never sees another thread's counts
    (the regression the bare module global allowed)."""
    results = {}

    def worker(name):
        with batch.fold_stats() as fs:
            _price_something()
            results[name] = dict(fs)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    with batch.fold_stats() as main_scope:
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    # Worker scopes each saw exactly their own run...
    assert results[0]["pairs_priced"] == results[1]["pairs_priced"] > 0
    # ...and the main thread's scope saw none of them.
    assert main_scope["pairs_priced"] == 0


def test_fold_stats_keys_stable():
    assert set(batch.fold_stats_snapshot()) == set(batch.FOLD_STAT_KEYS)
    with batch.fold_stats() as fs:
        assert set(fs) == set(batch.FOLD_STAT_KEYS)


def test_legacy_reset_zeroes_globals():
    _price_something()
    batch.fold_stats_reset()
    assert all(v == 0 for v in batch.FOLD_STATS.values())


def test_fold_stats_scope_closes_on_exception():
    with pytest.raises(RuntimeError):
        with batch.fold_stats():
            raise RuntimeError("boom")
    with batch.fold_stats() as fs:     # stack must be clean again
        pass
    assert fs["pairs_priced"] == 0
