"""Pipeline-parallel (pod axis) correctness: pipelined == sequential."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

SNIPPET = r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.training.pipeline import (
    bubble_fraction, pipelined_apply, split_stages,
)

# toy residual-MLP layers: params (L, D, D)
L, D, M, Bm = 8, 16, 4, 2
key = jax.random.key(0)
W = 0.3 * jax.random.normal(key, (L, D, D), jnp.float32)

def layer_fn(w, x):
    return x + jnp.tanh(x @ w)

x = jax.random.normal(jax.random.key(1), (M, Bm, D), jnp.float32)

# sequential reference
def seq_apply(W, x_all):
    def body(h, w):
        return layer_fn(w, h), None
    out, _ = jax.lax.scan(body, x_all.reshape(M * Bm, D), W)
    return out.reshape(M, Bm, D)

ref = seq_apply(W, x)

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
stages = split_stages({"w": W}, 2)
apply = pipelined_apply(lambda p, h: layer_fn(p["w"], h), mesh,
                        n_microbatches=M)
out = jax.jit(lambda s, x: apply(s, x))(stages, x)
err = float(jnp.abs(out - ref).max())
print("pipeline fwd err:", err)
assert err < 1e-5

# grad through the pipeline matches sequential grad
def loss_pipe(stages, x):
    return jnp.sum(apply(stages, x) ** 2)

def loss_seq(W, x):
    return jnp.sum(seq_apply(W, x) ** 2)

g_pipe = jax.grad(lambda W_: loss_pipe(split_stages({"w": W_}, 2), x))(W)
g_seq = jax.grad(lambda W_: loss_seq(W_, x))(W)
gerr = float(jnp.abs(g_pipe - g_seq).max())
print("pipeline grad err:", gerr)
assert gerr < 1e-4

print("bubble:", bubble_fraction(2, M))
print("pipeline OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", SNIPPET], capture_output=True, text=True,
        timeout=420, env=env,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "pipeline OK" in proc.stdout
