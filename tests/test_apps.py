"""Tests for the unified nine-app registry and its end-to-end pipeline."""
import importlib.util
import math

import numpy as np
import pytest

from repro import apps
from repro.apps.definitions import (
    CIRCUIT_NODES_PER_PIECE,
    MATMUL_PROBLEM,
    PENNANT_FIELDS,
    PENNANT_ZONES,
    STENCIL_LENGTHS,
)
from repro.core.commvolume import (
    cannon_volume,
    halo_surface_volume,
    johnson_volume,
)
from repro.core.decompose import optimal_factorization

ALL_APPS = list(apps.iter_apps())
APP_IDS = [a.name for a in ALL_APPS]


def test_all_nine_paper_apps_registered():
    assert set(apps.names()) == {
        "cannon", "summa", "pumma", "johnson", "solomonik", "cosma",
        "circuit", "stencil", "pennant",
    }
    assert len(list(apps.iter_apps(kind=apps.MATMUL))) == 6
    assert len(list(apps.iter_apps(kind=apps.SCIENCE))) == 3


def test_registry_lookup_errors():
    with pytest.raises(KeyError):
        apps.get("nonexistent")
    with pytest.raises(ValueError):
        apps.register(apps.get("cannon"))  # duplicate name


@pytest.mark.parametrize("app", ALL_APPS, ids=APP_IDS)
def test_mapple_program_parses(app):
    prog = app.program()
    assert app.name in prog.index_task_maps
    mapper_name = prog.index_task_maps[app.name]
    assert mapper_name in prog.mappers
    assert prog.loc() > 0


@pytest.mark.parametrize("app", ALL_APPS, ids=APP_IDS)
def test_mapper_is_bijective_on_tile_grid(app):
    n = app.default_procs
    grid = app.tile_grid(n)
    assert math.prod(grid) == n
    assert app.mapper(n).is_bijective_on(grid, n)


@pytest.mark.parametrize("app", ALL_APPS, ids=APP_IDS)
def test_translate_produces_valid_permutation(app):
    plan = app.spmd_plan()
    n = plan.meta["nprocs"]
    perm = plan.meta["device_permutation"]
    assert sorted(perm) == list(range(n))
    assert plan.meta["task"] == app.name
    assert plan.axis_names == app.axis_names
    assert plan.backpressure >= 1


@pytest.mark.parametrize("app", ALL_APPS, ids=APP_IDS)
def test_mapple_matches_lowlevel_fixture(app):
    """The DSL program and the raw-JAX baseline express the same mapping."""
    spec = importlib.util.spec_from_file_location(
        f"{app.name}_raw_fixture", app.lowlevel_path()
    )
    raw = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(raw)
    assert raw.MACHINE_SHAPE == app.machine_shape(app.default_procs)
    raw_grid = raw.assignment_grid(raw.GRID_SHAPE, raw.MACHINE_SHAPE)
    dsl_grid = app.mapper().assignment_grid(raw.GRID_SHAPE)
    np.testing.assert_array_equal(raw_grid, dsl_grid)


@pytest.mark.parametrize("app", ALL_APPS, ids=APP_IDS)
def test_loc_reduction_over_lowlevel(app):
    """Table 1's direction: the DSL program is several times smaller."""
    assert app.lowlevel_loc() / app.mapple_loc() > 2.0


def test_comm_volume_closed_forms():
    """Registry volumes equal independently computed closed forms."""
    # Cannon on (2, 2): q*q*(q-1)*(tile_a+tile_b).
    p = MATMUL_PROBLEM
    assert apps.get("cannon").comm_volume(4) == pytest.approx(
        cannon_volume(p, (2, 2))
    )
    assert apps.get("johnson").comm_volume(8) == pytest.approx(
        johnson_volume(p, (2, 2, 2))
    )
    # Stencil: Sec. 4.2 interior-surface volume at the decompose grid.
    g = optimal_factorization(8, STENCIL_LENGTHS)
    assert apps.get("stencil").comm_volume(8) == pytest.approx(
        halo_surface_volume(STENCIL_LENGTHS, g)
    )
    # cut counting for a (1, 8) slab grid: 7 interior cuts of l0 elements
    assert halo_surface_volume(STENCIL_LENGTHS, (1, 8)) == pytest.approx(
        7 * STENCIL_LENGTHS[0]
    )
    # Pennant: 3 exchanged fields scale the halo volume.
    gp = optimal_factorization(8, PENNANT_ZONES)
    assert apps.get("pennant").comm_volume(8) == pytest.approx(
        PENNANT_FIELDS * halo_surface_volume(PENNANT_ZONES, gp)
    )
    # Circuit: all_gather + psum_scatter ring volume, 2*(p-1)*n elements.
    assert apps.get("circuit").comm_volume(8) == pytest.approx(
        2 * 7 * 8 * CIRCUIT_NODES_PER_PIECE
    )


def test_tuning_never_worse_than_default():
    for app in ALL_APPS:
        v_def, v_tuned = app.tuning(app.default_procs)
        assert v_tuned <= v_def * (1 + 1e-9), app.name


def test_invalid_proc_counts_rejected():
    with pytest.raises(ValueError):
        apps.get("cannon").tile_grid(6)       # not square
    with pytest.raises(ValueError):
        apps.get("johnson").tile_grid(16)     # not cubic


def test_scaling_to_larger_machines():
    """Every app that accepts 64 processors stays bijective there."""
    for app in ALL_APPS:
        plan = app.spmd_plan(64)
        perm = plan.meta["device_permutation"]
        assert sorted(perm) == list(range(64)), app.name


def test_directives_reach_the_plan():
    plan = apps.get("circuit").spmd_plan()
    assert plan.memory_kinds["arg1"] == "pinned_host"   # Region ... ZCMEM
    cannon = apps.get("cannon").spmd_plan()
    assert cannon.donate == ("arg2",)                   # GarbageCollect
    assert cannon.backpressure == 1                     # Backpressure


def test_run_cli_all_analysis():
    """`python -m repro.apps.run --all` end to end (analysis path)."""
    from repro.apps import run as apprun

    assert apprun.main(["--all"]) == 0
    assert apprun.main(["--app", "summa", "--procs", "64"]) == 0


@pytest.mark.slow
def test_run_cli_execute_subprocess():
    """Full numeric validation of all nine apps on fake devices."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.apps.run", "--all", "--execute"],
        capture_output=True, text=True, timeout=600, env=env, cwd=str(repo),
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert proc.stdout.count("True") >= 9
