"""Runtime resilience edge cases: FailureInjector fire-once semantics,
Supervisor restart policy corners, StragglerMonitor degenerate inputs,
and fault-aware restore through ``remap_fn``."""
from __future__ import annotations

import numpy as np
import pytest

from repro.runtime import (
    FailureInjector,
    SimulatedFailure,
    StragglerMonitor,
    Supervisor,
)


class FakeCheckpoints:
    """Dict-backed stand-in for CheckpointManager (state is any object)."""

    def __init__(self):
        self.saved: dict[int, object] = {}

    def save(self, step, state, extra=None):
        self.saved[step] = state

    def latest_step(self):
        return max(self.saved) if self.saved else None

    def restore(self, step):
        return step, self.saved[step], {}


def counting_step(log):
    def step_fn(step, state):
        log.append(step)
        return state + 1, {"loss": float(state)}
    return step_fn


# ------------------------------------------------------------------ injector
def test_injector_fires_each_step_at_most_once():
    inj = FailureInjector(fail_at_steps=(3, 5))
    with pytest.raises(SimulatedFailure):
        inj.check(3)
    inj.check(3)                       # replayed after restore: no re-raise
    with pytest.raises(SimulatedFailure):
        inj.check(5)
    inj.check(5)
    assert inj.fired == 2


def test_injector_max_failures_caps_distinct_steps():
    inj = FailureInjector(fail_at_steps=(1, 2, 3), max_failures=2)
    for step in (1, 2):
        with pytest.raises(SimulatedFailure):
            inj.check(step)
    inj.check(3)                       # budget spent
    assert inj.fired == 2


def test_restart_from_no_checkpoint_does_not_loop():
    """The satellite regression: a failure before the first checkpoint
    restarts from the initial state, replays the failing step, and must
    NOT re-fire — one restart, then clean completion."""
    mgr = FakeCheckpoints()
    sup = Supervisor(mgr, max_restarts=3)
    log = []
    state, history = sup.run(
        state=0, start_step=0, n_steps=6, step_fn=counting_step(log),
        save_every=100,                # never checkpoints
        injector=FailureInjector(fail_at_steps=(2,)),
    )
    assert sup.restarts == 1
    events = [h for h in history if "event" in h]
    assert len(events) == 1 and events[0]["event"].startswith("restart")
    # steps 0..5 all completed; 0 and 1 replayed once after the restart
    assert log == [0, 1, 0, 1, 2, 3, 4, 5]


def test_supervisor_exceeding_max_restarts_reraises():
    mgr = FakeCheckpoints()
    sup = Supervisor(mgr, max_restarts=2)
    log = []
    with pytest.raises(SimulatedFailure):
        sup.run(
            state=0, start_step=0, n_steps=8, step_fn=counting_step(log),
            save_every=1,
            injector=FailureInjector(fail_at_steps=(1, 2, 3)),
        )
    assert sup.restarts == 3           # third failure exceeded the budget


def test_supervisor_restores_latest_checkpoint():
    mgr = FakeCheckpoints()
    sup = Supervisor(mgr, max_restarts=3)
    log = []
    state, history = sup.run(
        state=0, start_step=0, n_steps=10, step_fn=counting_step(log),
        save_every=4,
        injector=FailureInjector(fail_at_steps=(6,)),
    )
    assert state == 10
    restored = [h for h in history if "event" in h]
    assert len(restored) == 1 and restored[0]["event"].startswith("restored")
    assert restored[0]["step"] == 4    # rewound to the step-4 checkpoint


def test_supervisor_remap_fn_swaps_step_function():
    """Fault-aware restore: remap_fn's plan replaces the step function and
    is recorded in the history (minus the callable)."""
    mgr = FakeCheckpoints()
    sup = Supervisor(mgr, max_restarts=3)
    before, after = [], []

    def remap_fn(exc):
        assert isinstance(exc, SimulatedFailure)
        return {"step_fn": counting_step(after), "mesh": {"data": 6},
                "usable_chips": 6}

    state, history = sup.run(
        state=0, start_step=0, n_steps=6, step_fn=counting_step(before),
        save_every=2,
        injector=FailureInjector(fail_at_steps=(3,)),
        remap_fn=remap_fn,
    )
    assert state == 6
    remaps = [h for h in history if h.get("event") == "remapped"]
    assert len(remaps) == 1
    assert remaps[0]["plan"] == {"mesh": {"data": 6}, "usable_chips": 6}
    assert "step_fn" not in remaps[0]["plan"]
    assert before == [0, 1, 2] and after == [2, 3, 4, 5]


def test_supervisor_remap_fn_none_keeps_plan():
    mgr = FakeCheckpoints()
    sup = Supervisor(mgr, max_restarts=3)
    log = []
    state, history = sup.run(
        state=0, start_step=0, n_steps=4, step_fn=counting_step(log),
        save_every=2,
        injector=FailureInjector(fail_at_steps=(2,)),
        remap_fn=lambda exc: None,
    )
    assert state == 4
    assert not [h for h in history if h.get("event") == "remapped"]


# ----------------------------------------------------------------- straggler
def test_straggler_monitor_single_replica_emits_no_plan():
    mon = StragglerMonitor(n_replicas=1)
    for _ in range(20):
        report = mon.observe(np.array([1.0]))
    assert report["stragglers"] == []
    assert report["plan"] is None
    assert report["max_over_median"] == pytest.approx(1.0)


def test_straggler_monitor_all_equal_emits_no_plan():
    mon = StragglerMonitor(n_replicas=8)
    for _ in range(20):
        report = mon.observe(np.full(8, 2.5))
    assert report["stragglers"] == []
    assert report["plan"] is None


def test_straggler_monitor_zero_times_no_div_by_zero():
    mon = StragglerMonitor(n_replicas=4)
    report = mon.observe(np.zeros(4))
    assert report["plan"] is None
    assert np.isfinite(report["max_over_median"])
