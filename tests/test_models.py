"""Per-architecture smoke tests (reduced configs) + layer-level invariants.

Every assigned arch instantiates a reduced same-family config and runs one
forward/train step on CPU, asserting output shapes and no NaNs. Full
configs are only exercised via the dry-run (ShapeDtypeStruct).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config
from repro.models import build
from repro.models import layers
from repro.models.config import SHAPES


def make_batch(r, key, B=2, S=16):
    if r.stub_frontend:
        inputs = jax.random.normal(key, (B, S, r.d_model), jnp.float32)
    else:
        inputs = jax.random.randint(key, (B, S), 0, r.vocab_size)
    if r.num_codebooks > 1:
        labels = jax.random.randint(key, (B, S, r.num_codebooks), 0, r.vocab_size)
    else:
        labels = jax.random.randint(key, (B, S), 0, r.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = get_config(arch)
    r = cfg.reduced()
    model = build(r)
    key = jax.random.key(0)
    params = model.init(key)
    batch = make_batch(r, key)

    logits, _ = model.logits(params, batch["inputs"])
    B, S = 2, 16
    if r.num_codebooks > 1:
        assert logits.shape == (B, S, r.num_codebooks, r.padded_vocab)
    else:
        assert logits.shape == (B, S, r.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one train step: loss + grads finite
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2)
            for g in jax.tree_util.tree_leaves(grads))
    )
    assert jnp.isfinite(gnorm) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_step(arch):
    cfg = get_config(arch)
    r = cfg.reduced()
    model = build(r)
    key = jax.random.key(1)
    params = model.init(key)
    B, C = 2, 32
    cache = model.init_cache(B, C)
    if r.stub_frontend:
        tok = jax.random.normal(key, (B, 1, r.d_model), jnp.float32)
    else:
        tok = jax.random.randint(key, (B, 1), 0, r.vocab_size)
    logits, cache = model.decode_step(params, cache, jnp.int32(0), tok)
    logits, cache = model.decode_step(params, cache, jnp.int32(1), tok)
    assert not bool(jnp.isnan(logits).any())
    # cache shapes preserved
    for k, v in model.cache_spec(B, C).items():
        assert cache[k].shape == v.shape, k


@pytest.mark.parametrize("arch", ["qwen2-7b", "rwkv6-3b", "hymba-1.5b",
                                  "deepseek-v2-lite-16b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the full forward logits."""
    cfg = get_config(arch)
    r = dataclasses.replace(cfg.reduced(), dtype="float32")
    model = build(r)
    key = jax.random.key(2)
    params = model.init(key)
    B, S = 1, 8
    toks = jax.random.randint(key, (B, S), 0, r.vocab_size)
    full_logits, _ = model.logits(params, toks, remat=False)

    cache = model.init_cache(B, max(S, r.sliding_window or S))
    outs = []
    for t in range(S):
        logits, cache = model.decode_step(
            params, cache, jnp.int32(t), toks[:, t:t + 1]
        )
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-3, atol=2e-3
    )


def test_chunked_attention_matches_naive():
    key = jax.random.key(0)
    B, S, H, Kv, hd = 2, 64, 4, 2, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp.float32)
    k = jax.random.normal(kk, (B, S, Kv, hd), jnp.float32)
    v = jax.random.normal(kv, (B, S, Kv, hd), jnp.float32)
    for window in (0, 16):
        ref = layers.naive_attention(q, k, v, window=window)
        out = layers.chunked_attention(q, k, v, window=window,
                                       q_chunk=16, kv_chunk=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([32, 64]),
    h=st.sampled_from([2, 4]),
    window=st.sampled_from([0, 8, 32]),
)
def test_chunked_attention_property(s, h, window):
    key = jax.random.key(s * 31 + h)
    q = jax.random.normal(key, (1, s, h, 8), jnp.float32)
    k = jax.random.normal(key, (1, s, h, 8), jnp.float32)
    v = jax.random.normal(key, (1, s, h, 8), jnp.float32)
    ref = layers.naive_attention(q, k, v, window=window)
    out = layers.chunked_attention(q, k, v, window=window,
                                   q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_swa_ring_buffer_decode_matches_full_cache():
    """Ring-buffer SWA cache must agree with a full cache + window mask."""
    cfg = get_config("h2o-danube-1.8b")
    r = dataclasses.replace(cfg.reduced(), dtype="float32", sliding_window=8)
    model = build(r)
    params = model.init(jax.random.key(0))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, r.vocab_size)
    full_logits, _ = model.logits(params, toks, remat=False)
    cache = model.init_cache(B, 10_000)   # capped at window=8
    assert cache["k"].shape[2] == 8
    for t in range(S):
        logits, cache = model.decode_step(
            params, cache, jnp.int32(t), toks[:, t:t + 1]
        )
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=2e-3, atol=2e-3,
    )


def test_param_counts_match_published():
    """Full-config schema param counts vs published model sizes."""
    expected = {
        "h2o-danube-1.8b": 1.8e9,
        "granite-3-2b": 2.5e9,
        "qwen2-7b": 7.6e9,
        "smollm-135m": 1.35e8,
        "deepseek-v2-lite-16b": 15.7e9,
        "rwkv6-3b": 3.1e9,
        "pixtral-12b": 11.6e9,     # text backbone of the 12B (vision stubbed)
    }
    for arch, target in expected.items():
        n = build(get_config(arch)).n_params
        assert abs(n - target) / target < 0.12, (arch, n, target)


def test_moe_routing_mass_conservation():
    """Every surviving token's gates sum to ~1; dropped tokens pass through
    residual only (output magnitude bounded)."""
    from repro.models import moe as moe_mod

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    layer0 = jax.tree.map(lambda p: p[0], params["moe_layers"])
    out, aux = moe_mod.moe_apply(layer0["moe"], x, cfg)
    assert out.shape == x.shape
    assert jnp.isfinite(aux)
    assert not bool(jnp.isnan(out).any())


def test_shapes_table():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288


def test_hymba_pallas_mamba_path_matches_scan():
    """use_pallas routes the mamba side through the VMEM kernel."""
    cfg = get_config("hymba-1.5b").reduced()
    model = build(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab_size)
    l1, _ = model.logits(params, toks, remat=False)
    l2, _ = model.logits(params, toks, use_pallas=True, remat=False)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(l1),
                               rtol=1e-4, atol=1e-4)
