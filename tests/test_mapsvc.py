"""Tests for the tuning service (repro.serving.mapsvc + plan_cache)."""
import json
import subprocess
import sys
import threading
import zlib
from pathlib import Path

import pytest

from repro.serving.mapsvc import (
    MappingPlan,
    MappingService,
    Rejected,
    TuneRequest,
    load_trace,
    plan_key_for,
    replay,
    value_tag,
)
from repro.serving.plan_cache import _CRC, _HEAD, _MAGIC, PlanCache, plan_key
from repro.sim.collectives import cache_stats, clear_caches

REPO = Path(__file__).resolve().parent.parent


def _essence(res):
    """Provenance/timing-independent plan content for identity checks."""
    assert isinstance(res, MappingPlan), res
    return (res.app, res.procs, json.dumps(res.candidate, sort_keys=True),
            res.placed_cost, res.source,
            json.dumps(res.leaderboard, sort_keys=True))


# --------------------------------------------------------------- plan cache
def test_plan_cache_round_trip_and_idempotent_put(tmp_path):
    cache = PlanCache(tmp_path)
    key = plan_key("cannon", 4, "spec", "numpy-f64", (6, 3, 4))
    assert cache.get(key) is None
    payload = {"app": "cannon", "procs": 4, "candidate": {"grid": [2, 2]}}
    cache.put(key, payload)
    cache.put(key, payload)           # duplicate: no second record
    assert cache.get(key) == payload
    assert cache.stats() == {"hits": 1, "misses": 1, "writes": 1,
                             "dropped": 0, "plans": 1}


def test_plan_cache_memory_only_without_root():
    cache = PlanCache(None)
    key = plan_key("a", 1, "s", "numpy-f64")
    cache.put(key, {"x": 1})
    assert cache.get(key) == {"x": 1}
    assert cache.path is None
    cache.clear()
    assert cache.get(key) is None     # nothing on disk to reload


def test_plan_cache_nearest_ranks_by_log_scale(tmp_path):
    cache = PlanCache(tmp_path)
    for procs in (4, 16, 64, 1024):
        cache.put(plan_key("app", procs, "s", "t"),
                  {"app": "app", "procs": procs})
    near = cache.nearest("app", 20, count=2)
    assert [p["procs"] for p in near] == [16, 64]
    excl = cache.nearest("app", 16, count=1,
                         exclude=plan_key("app", 16, "s", "t"))
    assert excl[0]["procs"] in (4, 64)


def test_plan_cache_corrupt_tail_drops_cleanly(tmp_path):
    cache = PlanCache(tmp_path)
    keys = [plan_key("app", p, "s", "t") for p in (2, 4, 8)]
    for k, p in zip(keys, (2, 4, 8)):
        cache.put(k, {"app": "app", "procs": p})
    path = cache.path
    blob = bytearray(path.read_bytes())
    blob[-2] ^= 0xFF                  # flip a CRC byte of the last record
    path.write_bytes(bytes(blob))

    fresh = PlanCache(tmp_path)
    assert fresh.get(keys[0]) is not None
    assert fresh.get(keys[1]) is not None
    assert fresh.get(keys[2]) is None            # torn tail dropped
    assert fresh.stats()["dropped"] == 1

    # The next write heals the file whole: all intact records survive.
    fresh.put(keys[2], {"app": "app", "procs": 8})
    healed = PlanCache(tmp_path)
    assert all(healed.get(k) is not None for k in keys)
    assert healed.stats()["dropped"] == 0


def test_plan_cache_truncated_record_drops(tmp_path):
    cache = PlanCache(tmp_path)
    key = plan_key("app", 2, "s", "t")
    cache.put(key, {"app": "app", "procs": 2})
    path = cache.path
    path.write_bytes(path.read_bytes()[:-3])     # torn mid-CRC
    fresh = PlanCache(tmp_path)
    assert fresh.get(key) is None
    assert fresh.stats()["dropped"] == 1


def test_plan_cache_foreign_file_treated_as_empty(tmp_path):
    root = tmp_path / "plans"
    root.mkdir()
    (root / "plans.log").write_bytes(b"not a plan store")
    cache = PlanCache(root)
    key = plan_key("app", 2, "s", "t")
    assert cache.get(key) is None
    cache.put(key, {"app": "app", "procs": 2})   # rewrites the file whole
    assert PlanCache(root).get(key) is not None


def test_plan_cache_record_framing_crc_covers_key_and_payload(tmp_path):
    cache = PlanCache(tmp_path)
    key = plan_key("app", 2, "s", "t")
    cache.put(key, {"z": 1})
    blob = cache.path.read_bytes()
    assert blob.startswith(_MAGIC)
    k, size = _HEAD.unpack_from(blob, len(_MAGIC))
    raw = blob[len(_MAGIC) + _HEAD.size:len(_MAGIC) + _HEAD.size + size]
    (crc,) = _CRC.unpack_from(blob, len(_MAGIC) + _HEAD.size + size)
    assert k == key and json.loads(raw) == {"z": 1}
    assert crc == zlib.crc32(key + raw)


def test_plan_cache_registered_with_collectives(tmp_path):
    cache = PlanCache(tmp_path)
    cache.put(plan_key("a", 1, "s", "t"), {"app": "a", "procs": 1})
    assert cache_stats()["plan_cache"]["plans"] >= 1
    clear_caches()
    assert cache.stats()["plans"] == 0
    # Disk store survives the clear and reloads on next access.
    assert cache.get(plan_key("a", 1, "s", "t")) is not None


# ------------------------------------------------------------ service basics
def test_exact_repeat_hits_plan_cache(tmp_path):
    with MappingService(tmp_path, workers=0) as svc:
        first = svc.map(TuneRequest("cannon"))
        second = svc.map(TuneRequest("cannon"))
    assert first.provenance == "cold"
    assert second.provenance == "cache"
    assert _essence(first) == _essence(second)
    assert svc.stats.cache_hits == 1 and svc.stats.searches == 1


def test_plan_survives_to_second_service_instance(tmp_path):
    with MappingService(tmp_path, workers=0) as svc:
        cold = svc.map(TuneRequest("stencil"))
    clear_caches()
    with MappingService(tmp_path, workers=0) as svc2:
        warm = svc2.map(TuneRequest("stencil"))
    assert warm.provenance == "cache"
    assert svc2.stats.searches == 0
    assert _essence(cold) == _essence(warm)


def test_second_process_gets_plan_cache_hits(tmp_path):
    snippet = f"""
import sys; sys.path.insert(0, {str(REPO / "src")!r})
from repro.serving.mapsvc import MappingService, TuneRequest
with MappingService({str(tmp_path)!r}, workers=0) as svc:
    plan = svc.map(TuneRequest("cannon", procs=16))
    print(plan.provenance)
"""
    out = subprocess.run([sys.executable, "-c", snippet], check=True,
                         capture_output=True, text=True)
    assert out.stdout.strip() == "cold"
    with MappingService(tmp_path, workers=0) as svc:
        plan = svc.map(TuneRequest("cannon", procs=16))
    assert plan.provenance == "cache"


def test_plan_payload_round_trips(tmp_path):
    with MappingService(tmp_path, workers=0) as svc:
        plan = svc.map(TuneRequest("summa"))
    back = MappingPlan.from_payload(plan.payload(), provenance="cache")
    assert _essence(back) == _essence(plan)
    assert back.verified and back.value_tag == "numpy-f64"


def test_coalescing_identical_requests_search_once(tmp_path):
    svc = MappingService(tmp_path, workers=0, coalesce=8)
    tickets = [svc.submit(TuneRequest("cannon")) for _ in range(4)]
    svc.drain()
    results = [t.result(5.0) for t in tickets]
    assert all(isinstance(r, MappingPlan) for r in results)
    assert svc.stats.searches == 1
    assert svc.stats.coalesced == 3
    assert len({_essence(r) for r in results}) == 1
    svc.close()


def test_batch_prices_across_requests_in_one_pass(tmp_path):
    svc = MappingService(tmp_path, workers=0, coalesce=8)
    for name, procs in (("cannon", None), ("stencil", None), ("summa", 16)):
        svc.submit(TuneRequest(name, procs))
    svc.drain()
    # Three distinct searches, one shared cross-request pricing sweep.
    assert svc.stats.searches == 3
    assert svc.stats.shared_pricing_passes == 1
    svc.close()


# ------------------------------------------------------- concurrency == serial
def test_concurrent_submitters_match_serial_plans(tmp_path):
    trace = [TuneRequest(a, p) for a, p in
             (("cannon", None), ("stencil", None), ("cannon", 16),
              ("summa", None), ("cannon", None), ("stencil", 16))]
    with MappingService(tmp_path / "serial", workers=0,
                        warm_start=False) as svc:
        serial = [svc.map(r) for r in trace]

    clear_caches()
    with MappingService(tmp_path / "conc", workers=3,
                        warm_start=False) as svc:
        tickets = [None] * len(trace)

        def submit(i):
            tickets[i] = svc.submit(trace[i])

        threads = [threading.Thread(target=submit, args=(i,))
                   for i in range(len(trace))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        concurrent = [t.result(30.0) for t in tickets]

    assert [_essence(r) for r in serial] == [_essence(r) for r in concurrent]


# --------------------------------------------------------------- rejections
def test_queue_full_returns_typed_rejection(tmp_path):
    svc = MappingService(tmp_path, workers=0, queue_limit=2)
    t1 = svc.submit(TuneRequest("cannon"))
    t2 = svc.submit(TuneRequest("stencil"))
    t3 = svc.submit(TuneRequest("summa"))
    assert t3.done
    shed = t3.result()
    assert isinstance(shed, Rejected) and shed.reason == "queue-full"
    svc.drain()
    assert isinstance(t1.result(), MappingPlan)
    assert isinstance(t2.result(), MappingPlan)
    assert svc.stats.rejected == {"queue-full": 1}
    assert svc.stats.shed == 1
    svc.close()


def test_expired_deadline_sheds_at_dispatch(tmp_path):
    svc = MappingService(tmp_path, workers=0)
    ticket = svc.submit(TuneRequest("cannon", deadline_s=-1.0))
    svc.drain()
    res = ticket.result()
    assert isinstance(res, Rejected) and res.reason == "deadline"
    assert svc.stats.searches == 0
    svc.close()


def test_timeout_budget_rejects_but_still_caches(tmp_path):
    svc = MappingService(tmp_path, workers=0)
    res = svc.map(TuneRequest("cannon", timeout_s=0.0))
    assert isinstance(res, Rejected) and res.reason == "timeout"
    # The plan was cached regardless: the repeat answers from cache.
    repeat = svc.map(TuneRequest("cannon"))
    assert isinstance(repeat, MappingPlan)
    assert repeat.provenance == "cache"
    svc.close()


def test_unknown_app_returns_error_rejection(tmp_path):
    svc = MappingService(tmp_path, workers=0)
    res = svc.map(TuneRequest("nosuchapp"))
    assert isinstance(res, Rejected) and res.reason == "error"
    assert "nosuchapp" in res.detail
    svc.close()


def test_submit_after_close_rejects_closed(tmp_path):
    svc = MappingService(tmp_path, workers=0)
    svc.close()
    res = svc.submit(TuneRequest("cannon")).result()
    assert isinstance(res, Rejected) and res.reason == "closed"


def test_priority_orders_dispatch(tmp_path):
    svc = MappingService(tmp_path, workers=0, coalesce=1)
    low = svc.submit(TuneRequest("cannon", priority=5))
    high = svc.submit(TuneRequest("stencil", priority=0))
    svc.drain()
    # coalesce=1 -> one batch each; the high-priority request resolved
    # first even though it was submitted second.
    assert high.result().elapsed_s < low.result().elapsed_s or (
        svc.stats.completed == 2)
    assert isinstance(high.result(), MappingPlan)
    svc.close()


# ------------------------------------------------------------------- stats
def test_service_stats_summary_shape(tmp_path):
    with MappingService(tmp_path, workers=0) as svc:
        svc.map(TuneRequest("cannon"))
        svc.map(TuneRequest("cannon"))
        svc.submit(TuneRequest("cannon", deadline_s=-1.0))
        svc.drain()
        s = svc.stats.summary()
    assert s["submitted"] == 3
    assert s["completed"] == 2
    assert s["cache_hits"] == 1 and s["cold"] == 1
    assert s["rejected"] == {"deadline": 1} and s["shed"] == 1
    assert s["requests_per_s"] > 0
    for block in (s["latency"], s["stages"]["wait"], s["stages"]["cache"],
                  s["stages"]["search"]):
        assert set(block) == {"p50_s", "p95_s", "p99_s"}
    json.dumps(s)                       # the surface must be JSON-clean


def test_warm_provenance_and_never_worse(tmp_path):
    """A near-miss scale seeded from the cache must never rank worse
    than the cold search at that scale."""
    with MappingService(tmp_path, workers=0) as svc:
        svc.map(TuneRequest("pennant"))
        seeded = svc.map(TuneRequest("pennant", procs=64))
    clear_caches()
    with MappingService(tmp_path / "coldroot", workers=0,
                        warm_start=False) as svc2:
        cold = svc2.map(TuneRequest("pennant", procs=64))
    assert isinstance(seeded, MappingPlan) and isinstance(cold, MappingPlan)
    assert seeded.placed_cost <= cold.placed_cost
    if seeded.warm_seeds:
        assert seeded.provenance == "warm"


# --------------------------------------------------------------------- misc
def test_value_tag_matches_cost_model():
    from repro.sim.cost import SimulatedTimeCostModel, spec_for
    from repro.sim.collectives import CollectivePattern

    pattern = CollectivePattern(kind="shift")
    for engine, dtype in (("batched", "float64"), ("batched-jax", "float64"),
                          ("batched-jax", "float32"), ("event", "float64")):
        model = SimulatedTimeCostModel(
            pattern=pattern, spec=spec_for((2, 2)), step_flops=1.0,
            engine=engine, dtype=dtype)
        assert value_tag(engine, dtype) == model.value_tag


def test_plan_key_for_matches_report_procs():
    from repro import apps
    from repro.sim.cost import time_tuned_app

    tuned = time_tuned_app(apps.get("cannon"))
    n, key, tag = plan_key_for(tuned, None, engine="batched")
    assert n == tuned.default_procs
    assert tag == "numpy-f64"
    n2, key2, _ = plan_key_for(tuned, 16, engine="batched")
    assert n2 == 16 and key2 != key


def test_load_trace_parses_jsonl(tmp_path):
    path = tmp_path / "trace.jsonl"
    path.write_text(
        "# comment\n"
        '{"app": "cannon"}\n'
        "\n"
        '{"app": "stencil", "procs": 16, "priority": 1,'
        ' "machine_shape": [4, 4]}\n'
    )
    reqs = load_trace(path)
    assert [r.app for r in reqs] == ["cannon", "stencil"]
    assert reqs[1].procs == 16 and reqs[1].machine_shape == (4, 4)


def test_replay_resolves_in_submission_order(tmp_path):
    trace = [TuneRequest("cannon"), TuneRequest("cannon"),
             TuneRequest("badname")]
    with MappingService(tmp_path, workers=0) as svc:
        results = replay(svc, trace)
    assert isinstance(results[0], MappingPlan)
    # The identical repeat either coalesced into the same batch's search
    # ("cold", zero extra searches) or hit the plan cache.
    assert isinstance(results[1], MappingPlan)
    assert _essence(results[0]) == _essence(results[1])
    assert svc.stats.searches == 1
    assert isinstance(results[2], Rejected)


def test_serve_cli_demo_smoke(tmp_path, capsys):
    from repro.serving.serve import main

    rc = main(["--demo", "4", "--cache-dir", str(tmp_path),
               "--workers", "0", "--json"])
    out = capsys.readouterr().out
    assert rc == 0
    assert '"submitted": 4' in out


# ------------------------------------------------------------------- remap
def test_remap_request_resolves_with_recovery_facts(tmp_path):
    from repro.serving.mapsvc import RemapRequest

    with MappingService(tmp_path, workers=0) as svc:
        svc.map(TuneRequest("stencil", procs=8))     # cache the healthy plan
        res = svc.map(RemapRequest(app="stencil", failures=[3], procs=8))
        assert isinstance(res, MappingPlan)
        assert res.provenance == "remap"
        facts = res.remap
        assert facts is not None
        assert 3 not in facts["proc_map"]
        placed = {p for row in facts["placement"] for p in
                  (row if isinstance(row, list) else [row])}
        assert 3 not in placed
        # stale plan touched the dead proc -> impossible; remap is finite
        assert facts["stale_step_s"] == float("inf")
        assert facts["degraded_step_s"] < float("inf")
        assert svc.stats.remaps == 1
        assert json.dumps(res.summary())             # serializable surface


def test_remap_outranks_queued_tunes(tmp_path):
    from repro.serving.mapsvc import RemapRequest

    svc = MappingService(tmp_path, workers=0, coalesce=1)
    tune = svc.submit(TuneRequest("cannon", priority=0))
    remap = svc.submit(RemapRequest(app="stencil", failures=[0], procs=8))
    svc.drain()
    # default remap priority -1 dispatches before the priority-0 tune
    assert isinstance(remap.result(), MappingPlan)
    assert remap.result().elapsed_s <= tune.result().elapsed_s or (
        svc.stats.completed == 2)
    svc.close()


def test_remap_bad_failures_returns_typed_error(tmp_path):
    from repro.serving.mapsvc import RemapRequest

    with MappingService(tmp_path, workers=0) as svc:
        res = svc.map(RemapRequest(app="stencil", failures=list(range(8)),
                                   procs=8))
    assert isinstance(res, Rejected) and res.reason == "error"


# ------------------------------------------------------------ worker crash
def test_worker_crash_requeues_batch_once(tmp_path, monkeypatch):
    svc = MappingService(tmp_path, workers=0)
    real_process = svc._process
    crashes = {"n": 0}

    def crashing(batch):
        if crashes["n"] == 0:
            crashes["n"] += 1
            raise RuntimeError("worker died")
        real_process(batch)

    monkeypatch.setattr(svc, "_process", crashing)
    ticket = svc.submit(TuneRequest("cannon"))
    svc.drain()
    res = ticket.result()
    assert isinstance(res, MappingPlan)              # requeued, then served
    assert svc.stats.worker_crashes == 1
    assert svc.stats.summary()["worker_crashes"] == 1
    svc.close()


def test_worker_crash_twice_rejects_instead_of_hanging(tmp_path, monkeypatch):
    svc = MappingService(tmp_path, workers=0)
    monkeypatch.setattr(
        svc, "_process",
        lambda batch: (_ for _ in ()).throw(RuntimeError("dead again")))
    ticket = svc.submit(TuneRequest("cannon"))
    svc.drain()
    res = ticket.result()
    assert isinstance(res, Rejected) and res.reason == "error"
    assert "twice" in res.detail
    assert svc.stats.worker_crashes == 2
    svc.close()


def test_worker_thread_crash_requeues_with_live_workers(tmp_path):
    """End to end through real worker threads: the first batch attempt
    dies inside the worker, the ticket is requeued and still resolves."""
    svc = MappingService(tmp_path, workers=2)
    real_process = svc._process
    lock = threading.Lock()
    crashed = {"done": False}

    def crash_once(batch):
        with lock:
            first = not crashed["done"]
            crashed["done"] = True
        if first:
            raise RuntimeError("simulated worker death")
        real_process(batch)

    svc._process = crash_once
    ticket = svc.submit(TuneRequest("stencil"))
    res = ticket.result(timeout=60.0)
    assert isinstance(res, MappingPlan)
    assert svc.stats.worker_crashes == 1
    svc.close()
